"""int8 serving datapath: fp32-fused vs int8-per-layer vs int8-fused.

The paper's FPGA engine (§V, §VI-C) runs the whole MLP with 8-bit
inter-layer activations and never spills them off-chip.  PR 1 fused the
fp32 path; this benchmark tracks the int8 analogue for each paper stack and
batch in {1, 16, 64, 256}:

* ``fp32_fused_ms``  — the fp32 ``mode="fused"`` plan: the PR-1 megakernel.
* ``int8_layer_ms``  — the int8 ``mode="per_layer"`` plan: L launches,
  every quantized activation round-trips HBM.
* ``int8_fused_ms``  — the int8 ``mode="fused"`` plan: one launch, the
  int8 re-quantization happens in VMEM between resident layers.

All paths flow through ``serving.ExecutionPlan`` (mode, blocks and the
one-time int8 calibration resolved at plan build) and run the actual
Pallas kernel bodies (interpret mode off-TPU).  A bit-exactness gate (int8
fused == int8 per-layer, the §VI-C contract) guards every row.

Extends the repo-root ``BENCH_fused_serving.json`` (written by
bench_fused_serving) with an ``int8_rows`` section so the cross-PR perf
trajectory covers both datapaths; also writes
results/bench/int8_fused.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fused_serving import (BATCHES, _rand_pack,
                                            merge_root_json)
from benchmarks.common import save
from repro import serving
from repro.configs.paper_mlps import MLP_GSC, MLP_HR


def _best_of(fn, repeats: int) -> float:
    jax.block_until_ready(fn())               # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return min(times)


def run(fast: bool = False):
    repeats = 5 if fast else 15
    rows = []
    for cfg in (MLP_GSC, MLP_HR):
        pack = _rand_pack(cfg)
        calib_x = jnp.asarray(np.random.default_rng(0).normal(
            size=(64, cfg.d_in)), jnp.float32)
        calib = serving.calibrate_act_scales(pack, calib_x)
        plan_f32 = serving.build_plan(pack, mode="fused")
        plan_i8f = serving.build_plan(pack, mode="fused", act_dtype="int8",
                                      calib=calib)
        plan_i8l = serving.build_plan(pack, mode="per_layer",
                                      act_dtype="int8", calib=calib)
        for batch in BATCHES:
            rng = np.random.default_rng(batch)
            x = jnp.asarray(rng.normal(size=(batch, cfg.d_in)), jnp.float32)

            y_fused = plan_i8f.run(x)
            y_layer = plan_i8l.run(x)
            # §VI-C contract: the fused int8 datapath reproduces the
            # per-layer chain exactly (shared scale-folding arithmetic).
            # Bitwise holds when the per-layer kernel accumulates K in one
            # block — always true in interpret/CPU mode; a TPU block_k
            # split of a wide layer can move a sum by one ulp and flip a
            # quantization boundary, so there the gate is relative.
            bit_exact = bool(np.array_equal(np.asarray(y_fused),
                                            np.asarray(y_layer)))
            if jax.default_backend() == "tpu":
                np.testing.assert_allclose(y_fused, y_layer,
                                           rtol=1e-3, atol=1e-3)
            else:
                assert bit_exact, (cfg.name, batch)

            t_f32 = _best_of(lambda: plan_f32.run(x), repeats)
            t_i8l = _best_of(lambda: plan_i8l.run(x), repeats)
            t_i8f = _best_of(lambda: plan_i8f.run(x), repeats)
            row = {"model": cfg.name, "batch": batch,
                   # the kernel schedule the int8 fused plan's bucket
                   # actually bound for this batch (ws|batch_tiled|db|stream)
                   "schedule": plan_i8f.schedule_for(batch),
                   "fp32_fused_ms": t_f32 * 1e3,
                   "int8_layer_ms": t_i8l * 1e3,
                   "int8_fused_ms": t_i8f * 1e3,
                   "int8_fused_speedup_vs_layer": t_i8l / max(t_i8f, 1e-12),
                   "bit_exact_vs_per_layer": bit_exact}
            rows.append(row)
            print(f"{cfg.name:12s} b={batch:<4d} fp32-fused "
                  f"{row['fp32_fused_ms']:8.2f} ms  int8-layer "
                  f"{row['int8_layer_ms']:8.2f} ms  int8-fused "
                  f"{row['int8_fused_ms']:8.2f} ms [{row['schedule']}]  "
                  f"({row['int8_fused_speedup_vs_layer']:.2f}x vs layer)",
                  flush=True)

    from benchmarks.common import topology
    for r in rows:
        r.update(topology())     # guard only compares matching topology
    summary = {
        "backend": jax.default_backend(),
        "batches": list(BATCHES),
        "int8_rows": rows,
        "int8_fused_not_slower_at_16plus": all(
            r["int8_fused_speedup_vs_layer"] >= 0.95
            for r in rows if r["batch"] >= 16),
    }
    save("int8_fused", summary)
    # merge into the repo-root perf-trajectory file alongside the fp32 rows
    merge_root_json(summary)
    return summary


if __name__ == "__main__":
    run()
