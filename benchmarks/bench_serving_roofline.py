"""Tables VI–VIII analogue: per-arch serving efficiency from the dry-run.

The paper compares FPGA/ASIC accelerators on throughput, power and area.
Those metrics have no TPU meaning; the comparable system-level question is
'what does one serving step cost on the production mesh, and what bound is
it at'.  This bench reads results/dryrun/*.json (decode cells) and reports
per arch: roofline-bound step time, tokens/s/chip, the dominant term, and
the 4-bit-weights memory saving realised in the compiled artifact.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save
from repro.configs import get_config
from repro.launch import roofline
from repro.launch.specs import SHAPES


def run(dryrun_dir: str = "results/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              "*decode_32k_pod16x16.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "OK":
            continue
        cfg = get_config(rec["arch"])
        t = roofline.roofline_terms(rec, cfg)
        b = SHAPES["decode_32k"]["batch"]
        tokens_per_s = b / t["bound_s"] if t["bound_s"] else float("inf")
        rows.append({
            "arch": rec["arch"],
            "bound_s_per_step": t["bound_s"],
            "dominant": t["dominant"],
            "tokens_per_s_per_chip": tokens_per_s / rec["n_devices"],
            "tokens_per_s_pod": tokens_per_s,
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
        })
        print(f"{rec['arch']:20s} {t['dominant']:10s} "
              f"{t['bound_s']*1e3:8.2f} ms/step "
              f"{tokens_per_s:10.0f} tok/s/pod", flush=True)
    if rows:
        save("serving_roofline", rows)
    else:
        print("no decode dry-run records found; run the dry-run first")
    return rows


if __name__ == "__main__":
    run()
