"""Ragged-arrival serving: micro-batched engine vs naive per-request.

The runtime above the kernel decides realized efficiency: FantastIC4's
execution units only hit their §V throughput when every launch carries a
full row tile, but real traffic arrives one request at a time.  This
benchmark replays Poisson request traces (seeded, deterministic) through
two frontends over the *same* ``serving.ExecutionPlan``:

* **naive**   — one launch per request (``max_bucket=1``): what a serving
  loop without a batching layer does.
* **engine**  — the ``serving.MicroBatcher``: requests coalesce into
  power-of-two row buckets (continuous batching under backlog, immediate
  dispatch when idle), padded rows sliced back out per request.

Arrival timestamps are virtual; every launch runs for real on device, and
the virtual clock advances by a pre-calibrated per-bucket service-time
table (warm best-of-3) so the A/B comparison is deterministic rather than
host-noise roulette.  Offered load sweeps λ·t₁ ∈ {0.3, 1, 3, 10} (t₁ = the
calibrated single-request latency), covering idle-engine dispatch through
deep backlog; request sizes are ragged (1–8 rows, about 70% single-row).

Extends the repo-root ``BENCH_fused_serving.json`` with a
``serving_engine_rows`` section (plus ``engine_not_slower_everywhere``);
also writes results/bench/serving_engine.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fused_serving import _rand_pack, merge_root_json
from benchmarks.common import save
from repro import serving
from repro.configs.paper_mlps import MLP_GSC, MLP_HR

LOADS = (0.3, 1.0, 3.0, 10.0)           # offered load: lambda * t_single
MAX_DELAY_S = 2e-3


def _requests(cfg, n, seed):
    """Ragged request sizes: mostly single rows, some small batches."""
    rng = np.random.default_rng(seed)
    sizes = rng.choice([1, 1, 1, 1, 1, 1, 1, 2, 4, 8], size=n)
    return [jnp.asarray(rng.normal(size=(int(s), cfg.d_in)), jnp.float32)
            for s in sizes]


def _service_table(plan, repeats: int = 3) -> dict:
    """Warm per-bucket service times (best-of-N): the deterministic
    virtual-clock costs for both frontends."""
    table = {}
    for b in plan.bucket_sizes:
        x = jnp.zeros((b, plan.d_in), jnp.float32)
        fn = plan.entry(b)
        jax.block_until_ready(fn(x))          # compile + warm
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            times.append(time.perf_counter() - t0)
        table[b] = min(times)
    return table


def run(fast: bool = False):
    # both stacks in BOTH modes: merge_root_json replaces the whole
    # serving_engine_rows section, so a --fast refresh that dropped a
    # stack would trip the CI row-loss guard after any full run.
    n_req = 48 if fast else 192
    rows = []
    for cfg in (MLP_GSC, MLP_HR):
        pack = _rand_pack(cfg)
        plan = serving.build_plan(pack, mode="fused")
        # same "print what actually executed" rule as launch/serve.py:
        # the per-bucket schedule table of the resolved plan, BEFORE the
        # replay is timed — the service-time table below is per bucket,
        # so each number is only meaningful against its schedule.
        desc = plan.describe()
        bucket_schedules = {str(b): s for b, s in
                            desc["bucket_schedules"].items()}
        print(f"{cfg.name}: bucket -> schedule " + ", ".join(
            f"{b}:{desc['bucket_schedules'][b]}"
            f"[bm={desc['bucket_block_m'][b]},{desc['bucket_sources'][b]}]"
            for b in desc["bucket_sizes"])
            + f"; ws crossover {desc['ws_crossover_rows']} rows "
              f"(prior {desc['ws_prior_rows']} "
              f"[{desc['ws_prior_source']}])", flush=True)
        for note in desc["notes"]:
            print(f"{cfg.name}: note: {note}", flush=True)
        table = _service_table(plan, repeats=3 if fast else 5)
        t1 = table[1]
        xs = _requests(cfg, n_req, seed=7)
        total_rows = sum(int(x.shape[0]) for x in xs)

        for load in LOADS:
            lam = load / max(t1, 1e-9)        # requests per second
            rng = np.random.default_rng(int(load * 100) + 11)
            arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n_req))

            naive = serving.replay(plan, xs, arrivals,
                                   max_delay=MAX_DELAY_S, max_bucket=1,
                                   service_times=table)
            engine = serving.replay(plan, xs, arrivals,
                                    max_delay=MAX_DELAY_S,
                                    service_times=table)
            # padding parity on the replayed traffic itself: coalesced
            # results must match the per-request run row for row.
            for a, b in zip(naive["results"], engine["results"]):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, rtol=1e-5)
            row = {
                "model": cfg.name, "load": load,
                "arrival_rps": lam, "requests": n_req,
                "rows_total": total_rows,
                "bucket_schedules": bucket_schedules,
                "naive_throughput_rps": naive["throughput_rps"],
                "engine_throughput_rps": engine["throughput_rps"],
                "throughput_gain": engine["throughput_rps"]
                / max(naive["throughput_rps"], 1e-12),
                "naive_latency_p95_ms": naive["latency_p95_ms"],
                "engine_latency_p95_ms": engine["latency_p95_ms"],
                "engine_flushes": engine["stats"]["flushes"],
                "engine_bucket_hist": {str(k): v for k, v in
                                       engine["stats"]["bucket_hist"].items()},
                "engine_padded_rows": engine["stats"]["padded_rows"],
            }
            rows.append(row)
            print(f"{cfg.name:12s} load={load:<5.1f} "
                  f"naive {row['naive_throughput_rps']:8.1f} req/s "
                  f"engine {row['engine_throughput_rps']:8.1f} req/s "
                  f"({row['throughput_gain']:.2f}x)  p95 "
                  f"{row['naive_latency_p95_ms']:7.2f} -> "
                  f"{row['engine_latency_p95_ms']:7.2f} ms", flush=True)

    from benchmarks.common import topology
    for r in rows:
        r.update(topology())     # guard only compares matching topology
    summary = {
        "backend": jax.default_backend(),
        "loads": list(LOADS),
        "serving_engine_rows": rows,
        "engine_not_slower_everywhere": all(
            r["throughput_gain"] >= 1.0 - 1e-9 for r in rows),
    }
    save("serving_engine", summary)
    merge_root_json(summary)
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(ap.parse_args().fast)
