"""Trace-driven SLO benchmark: bursty + diurnal load through tiered models.

The robustness layer's live exercise: both paper stacks (GSC, HR) are
registered TWICE each — once on the latency tier, once on the
throughput tier — behind one ``ServingFrontend`` with bounded queues and
admission control on.  Two seeded arrival traces drive them on the real
clock:

* **bursty** — ON/OFF: bursts arrive at ~10x the sustainable row rate,
  separated by near-idle gaps.  This is the overload acceptance case:
  the bounded queue must stay flat (max queued rows observed is
  recorded), overflow must be a typed prompt rejection, and the latency
  tier's p99 must hold within its deadline because the admission
  controller sheds what the cost model proves unservable.
* **diurnal** — a sinusoidal rate swinging 0.2x..1.8x around the mean:
  the shaped-load case where shedding should be rare and goodput high.

Tier budgets are scaled from the *measured* top-bucket service time
(``tier.scaled``), so the SLOs mean the same thing on an interpret-mode
host and on hardware.  A second leg replays the bursty trace with a
``FaultInjector`` at a 10% transient launch-failure rate: the retry rung
of the degradation ladder must keep goodput (completed-within-SLO
fraction of offered) close to the fault-free run.

Extends the repo-root ``BENCH_fused_serving.json`` with
``slo_trace_rows`` keyed (trace, tier) — per-tier p50/p95/p99 latency,
``within_slo_frac``, ``shed_rate``, ``goodput_fault`` — guarded by
``scripts/check_bench_rows.py`` (row loss + additive-rate regression);
also writes results/bench/slo_traces.json.
"""
from __future__ import annotations

import time

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.bench_fused_serving import _rand_pack, merge_root_json
from benchmarks.bench_serving_engine import _requests
from benchmarks.common import save
from repro import serving
from repro.configs.paper_mlps import MLP_GSC, MLP_HR
from repro.runtime.fault import FaultInjector

CLOCK = time.monotonic
MAX_BUCKET = 16          # serving cap: keeps interpret-host runs bounded
# queue depth in tiles per tier: a latency-tier request that waits a
# full queue behind it is already lost, so its queue is shallow and
# overflow is shed promptly; the throughput tier buffers deep.
QUEUE_TILES = {"latency": 1, "throughput": 4}
TIER_NAMES = ("latency", "throughput")


def _svc_table(plan, repeats: int = 2) -> dict:
    """Warm per-bucket service times up to MAX_BUCKET only (the full
    bucket ladder is _service_table's job in bench_serving_engine)."""
    table = {}
    for b in plan.bucket_sizes:
        if b > MAX_BUCKET:
            break
        x = jnp.zeros((b, plan.d_in), jnp.float32)
        fn = plan.entry(b)
        jax.block_until_ready(fn(x))          # compile + warm
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            times.append(time.perf_counter() - t0)
        table[b] = min(times)
    return table


def _scaled_tiers(svc_top: float) -> dict:
    """Tier budgets in units of the measured top-bucket service time, so
    'latency tier' promises the same multiple of a launch everywhere.
    The 4x factor covers dispatch/coalesce overhead the bare kernel
    timing misses; floors at the stock (wall-clock-second) tiers on
    fast hosts."""
    unit = max(1.0, 4.0 * svc_top / 1e-3)
    return {name: serving.TIERS[name].scaled(unit) for name in TIER_NAMES}


def _bursty_arrivals(n: int, base_rate: float, seed: int) -> np.ndarray:
    """ON/OFF: bursts of ~8 requests at 10x base, gaps at 0.1x base."""
    rng = np.random.default_rng(seed)
    gaps, on = [], True
    for i in range(n):
        if i % 16 == 0 and i:
            on = not on
        rate = base_rate * (10.0 if on else 0.1)
        gaps.append(rng.exponential(1.0 / rate))
    return np.cumsum(gaps)


def _diurnal_arrivals(n: int, base_rate: float, seed: int) -> np.ndarray:
    """Sinusoidal rate 0.2x..1.8x around base over ~2 periods."""
    rng = np.random.default_rng(seed)
    period = n / (2.0 * base_rate)            # ~2 cycles over the trace
    t, out = 0.0, []
    for _ in range(n):
        rate = base_rate * (1.0 + 0.8 * np.sin(2 * np.pi * t / period))
        t += rng.exponential(1.0 / max(rate, 1e-9 * base_rate))
        out.append(t)
    return np.asarray(out)


TRACES = {"bursty": _bursty_arrivals, "diurnal": _diurnal_arrivals}


def _drive(frontend, trace, deadlines) -> dict:
    """Submit the merged (arrival, model, x) trace in wall time; collect
    per-model completions/rejections against intended arrival instants
    and the high-water mark of every model's queue."""
    t0 = CLOCK()
    futs = []
    batchers = {mid: frontend.registry.batcher(mid)
                for mid in {m for _, m, _ in trace}}
    max_queued = {mid: 0 for mid in batchers}
    for a, mid, x in trace:
        wait = t0 + a - CLOCK()
        if wait > 0:
            time.sleep(wait)
        futs.append((mid, a, frontend.submit(mid, x)))
        for m, b in batchers.items():
            max_queued[m] = max(max_queued[m], b.pending_rows)
    lat, shed = {}, {}
    for mid, a, f in futs:
        try:
            s = f.result(timeout=300.0)
            lat.setdefault(mid, []).append(s.finish - t0 - a)
        except serving.Rejected:
            shed[mid] = shed.get(mid, 0) + 1
    out = {}
    for mid in {m for _, m, _ in trace}:
        ls = np.asarray(lat.get(mid, [0.0]))
        n_ok = len(lat.get(mid, []))
        n_shed = shed.get(mid, 0)
        dl = deadlines[mid]
        out[mid] = {
            "offered": n_ok + n_shed,
            "completed": n_ok,
            "shed": n_shed,
            "latency_p50_ms": float(np.percentile(ls, 50) * 1e3),
            "latency_p95_ms": float(np.percentile(ls, 95) * 1e3),
            "latency_p99_ms": float(np.percentile(ls, 99) * 1e3),
            "within_slo": int(np.sum(ls <= dl)) if n_ok else 0,
            "max_queued_rows_seen": max_queued[mid],
        }
    return out


def _frontend(plans, svc, tiers, *, fault_rate: float = 0.0,
              seed: int = 0):
    fe = serving.ServingFrontend()
    for name, plan in plans.items():
        target = plan
        if fault_rate > 0:
            target = FaultInjector(plan, rate=fault_rate, seed=seed)
        for tname in TIER_NAMES:
            fe.register(f"{name}:{tname}", target, tier=tiers[tname],
                        max_bucket=MAX_BUCKET,
                        max_queued_rows=QUEUE_TILES[tname] * MAX_BUCKET,
                        service_times=svc[name])
    return fe


def run(fast: bool = False):
    n_req = 24 if fast else 72               # per registered model
    configs = (MLP_GSC, MLP_HR)
    plans, svc = {}, {}
    for cfg in configs:
        plan = serving.build_plan(_rand_pack(cfg), mode="fused")
        plans[cfg.name] = plan
        svc[cfg.name] = _svc_table(plan, repeats=2 if fast else 3)
    svc_top = max(t[max(t)] for t in svc.values())
    tiers = _scaled_tiers(svc_top)
    print("tiers (scaled): " + ", ".join(
        f"{t.name}: delay={t.max_delay * 1e3:.2f}ms "
        f"deadline={t.deadline * 1e3:.1f}ms" for t in tiers.values()),
        flush=True)
    deadlines = {f"{name}:{tname}": tiers[tname].deadline
                 for name in plans for tname in TIER_NAMES}
    # sustainable row rate for the shared stream: one top-bucket launch
    # per svc_top, split across the four registered models.
    base_rate = MAX_BUCKET / svc_top / (2 * len(configs))

    rows = []
    for trace_name, gen in TRACES.items():
        merged = []
        for i, name in enumerate(plans):
            xs = _requests([c for c in configs if c.name == name][0],
                           n_req, seed=23 + i)
            for j, tname in enumerate(TIER_NAMES):
                arr = gen(n_req, base_rate, seed=7 * i + j)
                merged += [(float(a), f"{name}:{tname}", x)
                           for a, x in zip(arr, xs)]
        merged.sort(key=lambda t: t[0])

        legs = {}
        for leg, rate in (("clean", 0.0), ("fault", 0.10)):
            fe = _frontend(plans, svc, tiers, fault_rate=rate, seed=11)
            with fe:
                legs[leg] = _drive(fe, merged, deadlines)
            if leg == "fault":
                stats = fe.stats
        for tname in TIER_NAMES:
            mids = [f"{n}:{tname}" for n in plans]

            def agg(leg, key, mids=mids):
                return sum(legs[leg][m][key] for m in mids)

            offered = agg("clean", "offered")
            row = {
                "trace": trace_name,
                "tier": tname,
                "models": list(plans),
                "tier_deadline_ms": tiers[tname].deadline * 1e3,
                "offered": offered,
                "completed": agg("clean", "completed"),
                "shed": agg("clean", "shed"),
                "shed_rate": agg("clean", "shed") / max(offered, 1),
                "latency_p50_ms": max(legs["clean"][m]["latency_p50_ms"]
                                      for m in mids),
                "latency_p95_ms": max(legs["clean"][m]["latency_p95_ms"]
                                      for m in mids),
                "latency_p99_ms": max(legs["clean"][m]["latency_p99_ms"]
                                      for m in mids),
                "within_slo_frac":
                    agg("clean", "within_slo") / max(offered, 1),
                "max_queued_rows_seen":
                    max(legs["clean"][m]["max_queued_rows_seen"]
                        for m in mids),
                "queue_bound_rows": QUEUE_TILES[tname] * MAX_BUCKET,
                "goodput_fault":
                    agg("fault", "within_slo")
                    / max(agg("fault", "offered"), 1),
                "fault_retries": stats["retries"],
                "fault_fallbacks": stats["fallbacks"],
            }
            rows.append(row)
            print(f"{trace_name:8s} {tname:10s} "
                  f"p99={row['latency_p99_ms']:8.2f}ms "
                  f"slo={row['within_slo_frac']:.2f} "
                  f"shed={row['shed_rate']:.2f} "
                  f"goodput_fault={row['goodput_fault']:.2f} "
                  f"maxq={row['max_queued_rows_seen']}"
                  f"/{row['queue_bound_rows']}", flush=True)

    from benchmarks.common import topology
    for r in rows:
        r.update(topology())     # guard only compares matching topology
    bounded = all(r["max_queued_rows_seen"] <= r["queue_bound_rows"]
                  for r in rows)
    summary = {
        "bench": "slo_traces",
        "backend": jax.default_backend(),
        "tiers": {t.name: {"max_delay_s": t.max_delay,
                           "deadline_s": t.deadline,
                           "weight_s": t.weight}
                  for t in tiers.values()},
        "queue_always_bounded": bounded,
        "rows": rows,
    }
    save("slo_traces", summary)
    merge_root_json({"slo_trace_rows": rows,
                     "slo_queue_always_bounded": bounded})
    assert bounded, "queued rows exceeded max_queued_rows"
    return summary


if __name__ == "__main__":
    import sys
    run(fast="--fast" in sys.argv)
