"""Fig. 9 analogue: accuracy vs sparsity Pareto fronts — EC4T (ours, 16
centroids) vs the EC2T ternary baseline, λ swept, same model/task/steps."""
from __future__ import annotations

from benchmarks.common import save, train_mlp
from benchmarks.ec2t_baseline import train_mlp_ec2t
from repro.configs.paper_mlps import MLP_HR

LAMBDAS = (0.0, 0.05, 0.2, 0.5, 1.0)


def run(steps: int = 200):
    rows = []
    for lam in LAMBDAS:
        _, _, _, m4 = train_mlp(MLP_HR, lam=lam, steps=steps)
        m2 = train_mlp_ec2t(MLP_HR, lam=lam, steps=steps)
        rows.append({"lam": lam,
                     "ec4t_acc": m4["acc"], "ec4t_sparsity": m4["sparsity"],
                     "ec2t_acc": m2["acc"], "ec2t_sparsity": m2["sparsity"]})
        print(f"λ={lam:<5} EC4T acc={m4['acc']:.3f}@{m4['sparsity']:.2f}sp | "
              f"EC2T acc={m2['acc']:.3f}@{m2['sparsity']:.2f}sp", flush=True)
    save("fig9_pareto", rows)
    return rows


if __name__ == "__main__":
    run()
