"""Table II analogue: accuracy + compression ratios of EC4T-trained MLPs.

Per model × λ operating point: accuracy, model size, CR with the *hybrid*
per-layer format selection (the paper's contribution 4), CR with CSR-only
(the EIE/Eyeriss baseline the paper compares to) and the trivial dense-4bit
CR — reproducing the 'hybrid beats single-format' Table II claim.
"""
from __future__ import annotations

import numpy as np

from repro.configs.paper_mlps import MLPS
from repro.core import ecl, formats
from benchmarks.common import save, train_mlp


def run(steps: int = 250):
    rows = []
    for name, cfg in MLPS.items():
        for lam in (0.05, 0.4):
            params, qs, bn, metrics = train_mlp(cfg, lam=lam, steps=steps)
            hybrid_bits = csr_bits = dense4_bits = fp32_bits = ext_bits = 0
            for layer, lq in zip(params["layers"], qs["layers"]):
                node = layer["kernel"]
                codes = np.asarray(ecl.assign(
                    node["w"], node["omega"], lq["kernel"]["probs"], lam))
                nnz = int(np.count_nonzero(codes))
                fp32_bits += codes.size * 32
                dense4_bits += formats.analytic_size_bits(
                    codes.shape, nnz, "dense4")
                csr_bits += formats.analytic_size_bits(codes.shape, nnz, "csr")
                paper_best = min(
                    formats.analytic_size_bits(codes.shape, nnz, f)
                    for f in formats.FORMATS)
                hybrid_bits += paper_best
                # beyond-paper: entropy-coded (canonical huffman) option
                ext_bits += min(paper_best,
                                formats.analytic_size_bits_huffman(codes))
            rows.append({
                "model": name, "lam": lam, **metrics,
                "size_mb_fp32": fp32_bits / 8 / 1e6,
                "CR_hybrid": fp32_bits / hybrid_bits,
                "CR_csr_only": fp32_bits / csr_bits,
                "CR_dense4": fp32_bits / dense4_bits,
                "CR_hybrid_plus_huffman": fp32_bits / ext_bits,
                "hybrid_vs_csr": csr_bits / hybrid_bits,
                "hybrid_vs_dense4": dense4_bits / hybrid_bits,
            })
            print(f"{name:15s} λ={lam:<5} acc={metrics['acc']:.3f} "
                  f"sparse={metrics['sparsity']:.2f} "
                  f"CR={rows[-1]['CR_hybrid']:.1f} "
                  f"(csr-only {rows[-1]['CR_csr_only']:.1f}, "
                  f"dense4 {rows[-1]['CR_dense4']:.1f}, "
                  f"+huffman {rows[-1]['CR_hybrid_plus_huffman']:.1f})",
                  flush=True)
    save("table2_compression", rows)
    return rows


if __name__ == "__main__":
    run()
