"""Multi-model async serving: one frontend, several packs, real clock.

Serving several compact MLPs from one device is the deployment shape
FantastIC4 targets — the §V execution units only hit 2.45 TOPS if
*something* always has a full row tile to launch, and with multiple
models sharing the engine the idle gaps of one stream are another's
batches.  This benchmark is the live counterpart of
``bench_serving_engine`` (which replays on a virtual clock): here the
``serving.ServingFrontend`` dispatch thread runs on the **real** clock —
arrivals are honored by sleeping, requests land from ``submit()``,
deadlines expire in wall time — so what is measured is the runnable
server, scheduling overhead included.

For each offered load the same seeded ragged Poisson traces (1–8 rows,
~70% single-row; per-model rate ``load / (n_models · t₁ᵐᵃˣ)`` with t₁
the calibrated single-request latency) are served two ways:

* **frontend** — every model's trace through ONE ``ServingFrontend``
  (shared dispatch thread + execution stream, deadline-FIFO across
  models with the full-tile fast path).  Aggregate throughput counts all
  models' requests over the frontend makespan; latency is reported per
  model (p95 against the *intended* arrival time, so scheduling delay
  counts).
* **naive** — each model's trace alone, one blocking launch per request
  as it arrives: the best single-pack no-batching baseline.  The bar the
  aggregate has to clear: ``aggregate_gain =
  aggregate_throughput / best(naive throughput)`` ≥ 1 at every load —
  below 1 the shared frontend would be worse than dedicating the device
  to its fastest single model.

Extends the repo-root ``BENCH_fused_serving.json`` with
``multi_model_rows`` (plus ``aggregate_not_slower_everywhere``), guarded
by ``scripts/check_bench_rows.py`` (row loss by load, per-model schedule
labels, ``aggregate_gain`` regression); also writes
results/bench/multi_model.json.
"""
from __future__ import annotations

import time

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.bench_fused_serving import _rand_pack, merge_root_json
from benchmarks.bench_serving_engine import _requests, _service_table
from benchmarks.common import save
from repro import serving
from repro.configs.paper_mlps import MLP_GSC, MLP_HR

LOADS = (0.6, 2.0, 8.0)              # combined offered load: sum(λ·t₁)
MAX_DELAY_S = 2e-3
CLOCK = time.monotonic


def _naive_real(plan, xs, arrivals) -> dict:
    """One blocking launch per request, arrivals honored in wall time —
    the single-pack no-batching baseline, measured on the same clock."""
    t0 = CLOCK()
    lats = []
    t = 0.0
    for x, a in zip(xs, arrivals):
        wait = t0 + a - CLOCK()
        if wait > 0:
            time.sleep(wait)
        jax.block_until_ready(plan.run(x))
        t = CLOCK() - t0
        lats.append(t - a)
    makespan = max(t, float(arrivals[-1]))
    lats = np.asarray(lats)
    return {"throughput_rps": len(xs) / max(makespan, 1e-12),
            "latency_p95_ms": float(np.percentile(lats, 95) * 1e3),
            "latency_mean_ms": float(lats.mean() * 1e3)}


def _frontend_real(frontend, trace) -> dict:
    """Submit the merged (arrival, model, x) trace in wall time; collect
    per-model latencies against the intended arrival instants."""
    t0 = CLOCK()
    futs = []
    for a, mid, x in trace:
        wait = t0 + a - CLOCK()
        if wait > 0:
            time.sleep(wait)
        futs.append((mid, a, frontend.submit(mid, x)))
    served = [(mid, a, f.result(timeout=120.0)) for mid, a, f in futs]
    makespan = max(max(s.finish - t0 for _, _, s in served),
                   float(trace[-1][0]))
    lat_by_model = {}
    for mid, a, s in served:
        lat_by_model.setdefault(mid, []).append(s.finish - t0 - a)
    return {
        "throughput_rps": len(served) / max(makespan, 1e-12),
        "makespan_s": makespan,
        "per_model": {
            mid: {"throughput_rps": len(ls) / max(makespan, 1e-12),
                  "latency_p95_ms": float(np.percentile(ls, 95) * 1e3),
                  "latency_mean_ms": float(np.mean(ls) * 1e3)}
            for mid, ls in lat_by_model.items()},
    }


def run(fast: bool = False):
    n_req = 32 if fast else 96
    configs = (MLP_GSC, MLP_HR)
    plans, schedules, tables = {}, {}, {}
    for cfg in configs:
        plan = serving.build_plan(_rand_pack(cfg), mode="fused")
        desc = plan.describe()
        print(f"{cfg.name}: bucket -> schedule " + ", ".join(
            f"{b}:{desc['bucket_schedules'][b]}"
            for b in desc["bucket_sizes"]), flush=True)
        plans[cfg.name] = plan
        schedules[cfg.name] = {str(b): s for b, s in
                               desc["bucket_schedules"].items()}
        tables[cfg.name] = _service_table(plan, repeats=2 if fast else 3)
    t1 = max(t[1] for t in tables.values())

    # per-model traces: same ragged mix as bench_serving_engine, same
    # arrival rate for every model (the slower pack's t1 sets the scale)
    # so the single-pack baselines see the same trace they'd see alone.
    rows = []
    for load in LOADS:
        lam = load / (len(configs) * max(t1, 1e-9))
        traces = {}
        for i, cfg in enumerate(configs):
            rng = np.random.default_rng(int(load * 100) + 13 + i)
            xs = _requests(cfg, n_req, seed=17 + i)
            arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n_req))
            traces[cfg.name] = (xs, arrivals)

        # one warm frontend pass over a short prefix (compiles the
        # submit/coalesce/scatter glue for common combos), then the
        # timed run on a fresh frontend.
        for timed in (False, True):
            frontend = serving.ServingFrontend()
            for name, plan in plans.items():
                frontend.register(name, plan, max_delay=MAX_DELAY_S)
            merged = sorted(
                (float(a), name, x)
                for name, (xs, arr) in traces.items()
                for a, x in zip(arr, xs if timed else xs[:8]))
            with frontend:
                fe = _frontend_real(frontend, merged)
        naive = {name: _naive_real(plans[name], *traces[name])
                 for name in plans}

        best_name = max(naive, key=lambda n: naive[n]["throughput_rps"])
        best_naive = naive[best_name]["throughput_rps"]
        row = {
            "load": load,
            "models": [c.name for c in configs],
            "requests_per_model": n_req,
            "arrival_rps_per_model": lam,
            "aggregate_throughput_rps": fe["throughput_rps"],
            "best_naive_throughput_rps": best_naive,
            "best_naive_model": best_name,
            "aggregate_gain": fe["throughput_rps"] / max(best_naive, 1e-12),
            "launches": frontend.stats["launches"],
            "per_model": {
                name: {**fe["per_model"][name],
                       "naive_throughput_rps":
                           naive[name]["throughput_rps"],
                       "naive_latency_p95_ms":
                           naive[name]["latency_p95_ms"],
                       "bucket_schedules": schedules[name]}
                for name in plans},
        }
        rows.append(row)
        per = "  ".join(
            f"{name} p95 {row['per_model'][name]['latency_p95_ms']:7.2f} ms"
            for name in plans)
        print(f"load={load:<5.1f} aggregate {row['aggregate_throughput_rps']:8.1f}"
              f" req/s vs best naive [{best_name}] {best_naive:8.1f} req/s "
              f"({row['aggregate_gain']:.2f}x)  {per}", flush=True)

    from benchmarks.common import topology
    for r in rows:
        r.update(topology())     # guard only compares matching topology
    summary = {
        "backend": jax.default_backend(),
        "multi_model_loads": list(LOADS),   # serving_engine owns "loads"
        "multi_model_rows": rows,
        "aggregate_not_slower_everywhere": all(
            r["aggregate_gain"] >= 1.0 - 1e-9 for r in rows),
    }
    save("multi_model", summary)
    merge_root_json(summary)
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(ap.parse_args().fast)
