"""Benchmark driver: one benchmark per paper table/figure.

  python -m benchmarks.run [--fast]

table2_compression    Table II  (acc + CR, hybrid vs CSR-only vs dense4)
fig9_pareto           Fig. 9   (EC4T vs EC2T accuracy↔sparsity fronts)
fig11_entropy_bytes   Fig. 11  (entropy -> data-movement bytes)
acm_vs_mac            §III-A   (multiply counts + HBM bytes + kernel check)
serving_roofline      Tables VI-VIII analogue (from dry-run artifacts)
fused_serving         §V pipeline analogue (megakernel vs per-layer
                      wall-clock; also writes BENCH_fused_serving.json at
                      the repo root for cross-PR perf tracking)
int8_fused            §VI-C analogue (int8 inter-layer activations:
                      fp32-fused vs int8-per-layer vs int8-fused; extends
                      BENCH_fused_serving.json with int8_rows)
serving_engine        ragged Poisson arrivals through the micro-batched
                      serving engine vs naive per-request launches;
                      extends BENCH_fused_serving.json with
                      serving_engine_rows
multi_model           >=2 packs behind one async ServingFrontend on the
                      real clock vs the best single-pack naive baseline;
                      extends BENCH_fused_serving.json with
                      multi_model_rows
slo_traces            bursty/diurnal traces through SLO-tiered models with
                      bounded queues, admission control and a 10%-fault
                      leg; extends BENCH_fused_serving.json with
                      slo_trace_rows
model_churn           N compact packs behind the two-tier PackCache under
                      Zipf popularity: resident-bytes high-water vs the
                      hot budget, cold-start p95, hot-path p95 vs the
                      uncached engine, compression ratio, evict->reload
                      bit-identity; extends BENCH_fused_serving.json with
                      model_churn_rows
multi_stream          scale-out serving: N replicated execution streams
                      (deterministic multi-server replay) vs the
                      single-stream engine at offered loads 1-10, plus
                      bit-exactness legs for the threaded multi-stream
                      frontend and the column-sharded plan; extends
                      BENCH_fused_serving.json with multi_stream_rows
integrity             checksummed-pack robustness: background-scrubber
                      hot-path overhead (paired p95, <=1.10x bound) and
                      detection->recovery under seeded per-launch bit
                      flips (detection_frac, recovery p95, bit-identical
                      outputs vs a no-fault run); extends
                      BENCH_fused_serving.json with integrity_rows
lm_serving            4-bit transformer prefill/decode as an LMProgram
                      behind the ServingFrontend vs the direct models.lm
                      greedy loop (two smoke archs, per-phase tokens/s,
                      bit-identical parity gates); extends
                      BENCH_fused_serving.json with lm_serving_rows
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer training steps (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    steps = 60 if args.fast else 200

    from benchmarks import (bench_acm_vs_mac, bench_compression,
                            bench_entropy_energy, bench_fused_serving,
                            bench_int8_fused, bench_integrity,
                            bench_lm_serving, bench_model_churn,
                            bench_multi_model, bench_multi_stream,
                            bench_pareto, bench_serving_engine,
                            bench_serving_roofline, bench_slo_traces)
    benches = {
        "acm_vs_mac": lambda: bench_acm_vs_mac.run(),
        "table2_compression": lambda: bench_compression.run(steps=steps),
        "fig9_pareto": lambda: bench_pareto.run(steps=steps),
        "fig11_entropy_bytes": lambda: bench_entropy_energy.run(steps=steps),
        "serving_roofline": lambda: bench_serving_roofline.run(),
        "fused_serving": lambda: bench_fused_serving.run(fast=args.fast),
        "int8_fused": lambda: bench_int8_fused.run(fast=args.fast),
        "serving_engine": lambda: bench_serving_engine.run(fast=args.fast),
        "multi_model": lambda: bench_multi_model.run(fast=args.fast),
        "slo_traces": lambda: bench_slo_traces.run(fast=args.fast),
        "model_churn": lambda: bench_model_churn.run(fast=args.fast),
        "multi_stream": lambda: bench_multi_stream.run(fast=args.fast),
        "integrity": lambda: bench_integrity.run(fast=args.fast),
        "lm_serving": lambda: bench_lm_serving.run(fast=args.fast),
    }
    if args.only is not None and args.only not in benches:
        # a typo used to silently run ZERO benchmarks and still print
        # "all benchmarks complete" — fail loudly, list what exists.
        print(f"--only {args.only!r}: no such benchmark; valid keys:",
              file=sys.stderr)
        for key in benches:
            print(f"  {key}", file=sys.stderr)
        return 2
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        fn()
        print(f"({name}: {time.time()-t0:.1f}s)")
    print("\nall benchmarks complete; json in results/bench/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
