"""EC2T — the paper's baseline (Marban et al. [16]): entropy-constrained
*ternary* training.  Same STE + ECL machinery, codebook {-a, 0, +a} with a
single trainable scale per tensor.  FantastIC4 generalises this to 16
subset-sum centroids; fig. 9 shows the 4-bit version reaching a better
accuracy↔sparsity Pareto front — bench_pareto reproduces that comparison.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_mlps import MLPConfig
from repro.core import ecl
from repro.data import synthetic
from repro.models import mlp as M
from repro.optim import adam, schedule


def _fake_quant_ternary(w, a, probs, lam):
    book = jnp.stack([jnp.zeros_like(a), a, -a])          # (3,)
    codes = jax.lax.stop_gradient(ecl.assign_general(w, book, probs, lam))
    w_hat = book[codes]
    return w_hat + (w - jax.lax.stop_gradient(w)), codes


def train_mlp_ec2t(cfg_mlp: MLPConfig, *, lam: float, steps: int = 250,
                   lr: float = 5e-3, seed: int = 0, lam_ramp: int = 60):
    data_cfg = synthetic.ClsDataCfg(d_in=cfg_mlp.d_in,
                                    n_classes=cfg_mlp.features[-1],
                                    batch=128, margin=3.0, seed=seed)
    key = jax.random.PRNGKey(seed)
    params, bn = M.mlp_init(key, cfg_mlp)
    # replace 4-bit parameterisation with ternary: {"w", "a"}
    for layer in params["layers"]:
        w = layer["kernel"]["w"]
        layer["kernel"] = {"w": w, "a": jnp.mean(jnp.abs(w)) * 2.0}
    probs = [jnp.full((3,), 1 / 3) for _ in params["layers"]]
    opt = adam.init(params)

    def fwd(params, probs, bn, x, lam_t, train):
        new_bn = {"layers": []}
        n = len(params["layers"])
        codes_all = []
        for i, layer in enumerate(params["layers"]):
            wq, codes = _fake_quant_ternary(layer["kernel"]["w"],
                                            layer["kernel"]["a"],
                                            probs[i], lam_t)
            codes_all.append(codes)
            x = x @ wq + layer["bias"]
            st = {}
            if "bn_gamma" in layer:
                if train:
                    mu, var = x.mean(0), x.var(0)
                    st = {"mean": 0.9 * bn["layers"][i]["mean"] + 0.1 * mu,
                          "var": 0.9 * bn["layers"][i]["var"] + 0.1 * var}
                else:
                    mu, var = bn["layers"][i]["mean"], bn["layers"][i]["var"]
                    st = bn["layers"][i]
                x = ((x - mu) * jax.lax.rsqrt(var + 1e-5)
                     * layer["bn_gamma"] + layer["bn_beta"])
            new_bn["layers"].append(st)
            if i < n - 1:
                x = jax.nn.relu(x)
        return x, new_bn, codes_all

    @jax.jit
    def step(params, probs, bn, opt, x, y, lam_t):
        def loss_fn(params):
            logits, bn2, codes = fwd(params, probs, bn, x, lam_t, True)
            return M.cross_entropy(logits, y), (bn2, codes)
        (loss, (bn2, codes)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt, _ = adam.apply(params, g, opt, adam.AdamConfig(lr=lr))
        probs = [0.9 * p + 0.1 * jnp.bincount(
            c.reshape(-1).astype(jnp.int32), length=3) / c.size
            for p, c in zip(probs, codes)]
        return params, probs, bn2, opt

    for i in range(steps):
        b = synthetic.cls_batch(data_cfg, i)
        lam_t = float(schedule.lambda_ramp(i, lam=lam, ramp_steps=lam_ramp))
        params, probs, bn, opt = step(params, probs, bn, opt,
                                      jnp.asarray(b["x"]),
                                      jnp.asarray(b["labels"]), lam_t)

    accs, spars, total = [], 0.0, 0
    for j in range(5):
        b = synthetic.cls_batch(data_cfg, 10_000 + j)
        logits, _, codes = fwd(params, probs, bn, jnp.asarray(b["x"]),
                               lam, False)
        accs.append(float(M.accuracy(logits, jnp.asarray(b["labels"]))))
    for i, layer in enumerate(params["layers"]):
        book = jnp.stack([jnp.zeros(()), layer["kernel"]["a"],
                          -layer["kernel"]["a"]])
        codes = ecl.assign_general(layer["kernel"]["w"], book, probs[i], lam)
        spars += float((codes == 0).sum())
        total += codes.size
    return {"acc": float(np.mean(accs)), "sparsity": spars / total}
