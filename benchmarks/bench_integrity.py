"""Integrity benchmark: scrub overhead + detection→recovery under flips.

The integrity subsystem (runtime.integrity, ROADMAP robustness item)
promises two things that are cheap to claim and easy to silently lose:

* **the scrubber is (nearly) free on the hot path** — the background
  thread re-verifying hot plans against cold-tier checksums must ride
  the idle gaps between launches, not steal them.  Leg 1 serves the
  same single-row request stream with the scrubber off and on
  (idle-aware cadence, ``scrub_interval_s = 5 ms``) and reports the
  paired p95 ratio — the acceptance bound is **≤ 1.10×**, and the row
  is guarded multiplicatively (``scrub_overhead_ratio``) by
  scripts/check_bench_rows.py so a chatty scrubber shows up as a perf
  regression, not an anecdote.
* **every corrupted launch is detected and recovered, bit-exactly** —
  leg 2 wraps the cached plan in a seeded :class:`FaultInjector`
  flipping one random bit per fired launch (``flip_rate`` ∈ {1%, 5%},
  hot targets only — packed bit-planes and epilogue arrays; the cold
  tier stays intact, as the recovery path requires) under a
  ``GuardedPlan`` with per-launch checksum verification.  Reported per
  flip rate: ``detection_frac`` (detected / injected — must be 1.0,
  guarded additively), ``recovery_p95_ms`` (evict → cold re-decode →
  re-verify, from the frontend's ``integrity`` stats), and the
  acceptance assert that the full served output stream is
  **bit-identical on the int8 grid** to a no-fault run of the same
  pack (lossless cold tier + captured ``act_scales`` ⇒ re-resolution
  is byte-exact, so recovery leaves no trace in the numbers).

Plans resolve in ``mode="oracle"`` with int8 inter-layer activations:
the benchmark measures the *integrity machinery* (CRC verify, flip
handling, evict/re-decode), not kernel wall-clock.  Layer dims are kept
even so no zero pad row exists and every injected bit lands on checksum-
covered state — ``detection_frac`` is then exact, not probabilistic.
Extends the repo-root ``BENCH_fused_serving.json`` with
``integrity_rows`` (keyed by ``(model, flip_rate)``); also writes
results/bench/integrity.json.
"""
from __future__ import annotations

import time
from types import SimpleNamespace

import numpy as np

from benchmarks.bench_fused_serving import _rand_pack, merge_root_json
from benchmarks.common import save, topology
from repro import serving

# even dims only: odd K appends a zero pad row the content CRC does not
# cover, which would make a pad-row flip undetectable by design.
CFG = SimpleNamespace(d_in=16, features=(16, 8))
MODEL = "synthetic-16-16-8"
PLAN_KWARGS = {"mode": "oracle", "act_dtype": "int8"}
# one full-fleet scrub pass per 200 ms is still orders of magnitude
# above real soft-error rates; at this cadence the scrubber thread wakes
# ~5x/s, so its GIL/scheduler footprint on in-flight launches is noise.
SCRUB_INTERVAL_S = 0.2
# client think-time between requests.  A closed loop with zero gaps is a
# utilization-1.0 client: the idle-aware scrubber then NEVER finds an
# idle instant and its bounded-starvation fallback forces every scrub
# into a launch's critical path — the one regime the design explicitly
# trades away.  A small think-time models the live trickle-load service
# the scrubber targets (and both arms pace identically, so the ratio
# stays a fair A/B).
THINK_S = 2e-3
SCRUB_BOUND = 1.10
FLIP_RATES = (0.01, 0.05)
FLIP_SEED = 11


def _serve_stream(frontend, xs, think_s: float = 0.0):
    """Submit the rows one at a time (latency mode), with ``think_s``
    of client idle between requests; returns (outputs, per-request
    seconds)."""
    ys, lat = [], []
    for x in xs:
        t0 = time.perf_counter()
        y = np.asarray(frontend.submit(MODEL, x).result(timeout=60).y)
        lat.append(time.perf_counter() - t0)
        ys.append(y)
        if think_s:
            time.sleep(think_s)
    return ys, lat


def _p95_ms(samples) -> float:
    return float(np.percentile(np.asarray(samples), 95) * 1e3)


def _scrub_arm(pack, xs, scrub: bool) -> float:
    """One arm of the paired scrub-overhead measurement: p95 ms of the
    request stream with the scrubber off/on.  Per-launch verification is
    off in BOTH arms so the ratio isolates the background thread."""
    fe = serving.ServingFrontend(
        cache=serving.PackCache(),
        scrub_interval_s=SCRUB_INTERVAL_S if scrub else None)
    fe.register_pack(MODEL, pack, plan_kwargs=PLAN_KWARGS,
                     integrity=serving.IntegrityPolicy(verify_launch=False),
                     max_delay=1e-4)
    with fe:
        _serve_stream(fe, xs[:16])               # warm: resolve + compile
        _, lat = _serve_stream(fe, xs, think_s=THINK_S)
        if scrub:
            # liveness: the thread must actually be scrubbing, not
            # wedged — the engine is idle now, so the next wake scrubs.
            deadline = time.perf_counter() + 40 * SCRUB_INTERVAL_S
            while not fe.stats["scrub"]["cycles"] and \
                    time.perf_counter() < deadline:
                time.sleep(SCRUB_INTERVAL_S / 4)
            assert fe.stats["scrub"]["cycles"] > 0, \
                "scrubber never completed a cycle"
    return _p95_ms(lat)


def _scrub_leg(pack, xs, pairs: int) -> dict:
    """Interleaved off/on trials; the reported ratio is the MEDIAN of
    the per-pair p95 ratios.  Pairing matters more than a min estimator
    here: host load on a shared box drifts over the minutes a leg takes,
    and adjacent off/on arms see the same load while a cross-trial min
    compares different load regimes."""
    offs, ons = [], []
    for _ in range(pairs):
        offs.append(_scrub_arm(pack, xs, scrub=False))
        ons.append(_scrub_arm(pack, xs, scrub=True))
    ratios = [on / max(off, 1e-9) for off, on in zip(offs, ons)]
    return {"off_p95_ms": float(np.median(offs)),
            "on_p95_ms": float(np.median(ons)),
            "scrub_overhead_ratio": float(np.median(ratios))}


def _recovery_leg(pack, xs, flip_rate: float, baseline) -> dict:
    """Serve the stream under per-launch bit flips; every flip must be
    detected, recovered from the (intact) cold tier, and the outputs
    must match the no-fault baseline bit-for-bit."""
    injector = None

    def wrap(plan):
        nonlocal injector
        injector = serving.FaultInjector(
            plan, rate=0.0, seed=FLIP_SEED, flip_rate=flip_rate,
            flip_targets=("packed", "epilogue"))
        return injector

    fe = serving.ServingFrontend(cache=serving.PackCache())
    fe.register_pack(MODEL, pack, plan_kwargs=PLAN_KWARGS, wrap=wrap,
                     integrity=True, max_delay=1e-4)
    with fe:
        ys, _ = _serve_stream(fe, xs)
        integ = dict(fe.stats["integrity"])
        quarantined = list(fe.stats["quarantined"])
    flipped = injector.flipped
    assert flipped > 0, \
        f"flip_rate={flip_rate}: injector never fired; pick another seed"
    assert not quarantined, f"unexpected quarantine: {quarantined}"
    bit_identical = all(np.array_equal(a, b) for a, b in zip(ys, baseline))
    rec = integ["recovery_s"]
    return {
        "flipped": flipped,
        "detected": integ["detected"],
        "recovered": integ["recovered"],
        "detection_frac": integ["detected"] / flipped,
        "recovery_p95_ms": _p95_ms(rec) if rec else 0.0,
        "bit_identical": bool(bit_identical),
    }


def run(fast: bool = False) -> dict:
    n_req = 120 if fast else 240
    pairs = 5
    pack = _rand_pack(CFG, seed=0)
    rng = np.random.default_rng(7)
    xs = rng.normal(size=(n_req, 1, CFG.d_in)).astype(np.float32)

    # no-fault reference: the same pack through the same cold tier
    # (compress → decode → plan), so recovery has a byte-exact target.
    ref_plan = serving.build_plan(
        serving.decode_pack(serving.compress_pack(pack)), **PLAN_KWARGS)
    baseline = [np.asarray(ref_plan.run(x)) for x in xs]

    print(f"scrub overhead ({pairs} paired trials, "
          f"interval {SCRUB_INTERVAL_S*1e3:.0f} ms):")
    # one retry on a shared host: a load spike across a whole leg can
    # push even the paired median over the bound (same rationale as the
    # widened CI regression bound in scripts/ci.sh); a REAL overhead
    # regression fails both legs.
    for attempt in (0, 1):
        scrub = _scrub_leg(pack, xs, pairs)
        print(f"  off p95 {scrub['off_p95_ms']:.3f} ms  "
              f"on p95 {scrub['on_p95_ms']:.3f} ms  "
              f"ratio x{scrub['scrub_overhead_ratio']:.3f} "
              f"(bound x{SCRUB_BOUND:.2f})")
        if scrub["scrub_overhead_ratio"] <= SCRUB_BOUND:
            break
        print("  over bound; retrying once (shared-host noise guard)")
    assert scrub["scrub_overhead_ratio"] <= SCRUB_BOUND, \
        "scrubber-on hot-path p95 exceeded the overhead bound"

    rows = [{"model": MODEL, "flip_rate": 0.0, "requests": n_req,
             "mode": PLAN_KWARGS["mode"], **scrub}]
    for fr in FLIP_RATES:
        leg = _recovery_leg(pack, xs, fr, baseline)
        print(f"  flip_rate={fr}: flipped={leg['flipped']} "
              f"detected={leg['detected']} recovered={leg['recovered']} "
              f"detection_frac={leg['detection_frac']:.2f} "
              f"recovery_p95={leg['recovery_p95_ms']:.2f} ms "
              f"bit_identical={leg['bit_identical']}")
        assert leg["detection_frac"] == 1.0, \
            f"flip_rate={fr}: {leg['flipped'] - leg['detected']} " \
            "injected flips went undetected"
        assert leg["recovered"] == leg["detected"], \
            f"flip_rate={fr}: detection without cold-tier recovery"
        assert leg["bit_identical"], \
            f"flip_rate={fr}: recovered outputs drifted off the " \
            "no-fault int8 grid"
        rows.append({"model": MODEL, "flip_rate": fr, "requests": n_req,
                     "mode": PLAN_KWARGS["mode"], **leg})

    for r in rows:
        r.update(topology())     # guard only compares matching topology
    payload = {"config": {"d_in": CFG.d_in,
                          "features": list(CFG.features),
                          "requests": n_req,
                          "scrub_interval_ms": SCRUB_INTERVAL_S * 1e3,
                          "flip_seed": FLIP_SEED},
               "rows": rows}
    save("integrity", payload)
    merge_root_json({"integrity_rows": rows})
    return payload


if __name__ == "__main__":
    run()
