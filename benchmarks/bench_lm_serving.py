"""4-bit transformer prefill/decode through the LM serving engine.

Two smoke archs — smollm-360m (swiglu, tied head) and h2o-danube-1.8b
(sliding-window attention, untied head) — are frozen to packed int4
codes and served as :class:`repro.serving.lm.LMProgram` programs behind
a ``ServingFrontend``: every sequence prefilled as one wire row, then
lockstep single-token decode steps (each flush reaches the per-block FFN
plans as an ``m = n_seqs`` weight-stationary bucket).  The A/B baseline
is the direct ``models.lm`` greedy loop over the *same* frozen tree
(eager ``lm_apply``, per-request — no batcher, no plans).

Parity gates every row: the engine's tokens must be bit-identical to the
program's own ``generate`` loop (same kernels, no wire framing) AND to
the direct-loop baseline's tokens.

Reported per (model, phase): prefill tokens/s and decode token-steps/s
for both paths plus their ``engine_over_direct`` ratio — self-normalized
A/B on the same host, which is what the cross-PR guard tracks.  Extends
the repo-root ``BENCH_fused_serving.json`` with a ``lm_serving_rows``
section (guarded by scripts/check_bench_rows.py on row identity and
``engine_over_direct``); also writes results/bench/lm_serving.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fused_serving import merge_root_json
from benchmarks.common import save, topology
from repro import serving
from repro.configs import get_config
from repro.core import qat
from repro.models import lm
from repro.nn import transformer as T
from repro.nn.module import QuantCtx

ARCHS = ("smollm-360m", "h2o-danube-1.8b")
PROMPT_LEN, MAX_NEW = 8, 8      # 16 total: engages danube's smoke window


def _direct_loop(frozen, cfg, prompt, new):
    """Per-phase-timed reference: the models.lm greedy loop (eager
    lm_apply over the frozen tree, full-length KV cache)."""
    ctx = QuantCtx(quant=False, compute_dtype=jnp.float32)
    b, s = prompt.shape
    cache = T.init_cache(cfg, b, s + new, dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    t0 = time.perf_counter()
    nxt, cache = lm.greedy_step(frozen, 0, jnp.asarray(prompt), ctx, cfg,
                                positions=pos, cache=cache)
    jax.block_until_ready(nxt)
    t_prefill = time.perf_counter() - t0
    outs = [nxt]
    t0 = time.perf_counter()
    for t in range(new - 1):
        p_t = jnp.full((b, 1), s + t, jnp.int32)
        nxt, cache = lm.greedy_step(frozen, 0, nxt, ctx, cfg,
                                    positions=p_t, cache=cache)
        outs.append(nxt)
    jax.block_until_ready(outs[-1])
    t_decode = time.perf_counter() - t0
    return (np.asarray(jnp.concatenate(outs, axis=1), np.int64),
            t_prefill, t_decode)


def _serve_engine(prog, prompt, new):
    """Per-phase-timed engine leg: wire rows through a ServingFrontend."""
    b = prompt.shape[0]
    toks = []
    frontend = serving.ServingFrontend()
    with frontend:
        frontend.register("lm", prog, max_delay=1e-3)
        t0 = time.perf_counter()
        futs = [frontend.submit(
                    "lm", prog.encode_prefill(500 + i, prompt[i])[None])
                for i in range(b)]
        toks.append([int(f.result(120.0).y[0, 0]) for f in futs])
        t_prefill = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(new - 1):
            futs = [frontend.submit(
                        "lm", prog.encode_decode(500 + i)[None])
                    for i in range(b)]
            toks.append([int(f.result(120.0).y[0, 0]) for f in futs])
        t_decode = time.perf_counter() - t0
    for i in range(b):
        prog.release(500 + i)
    return np.asarray(toks, np.int64).T, t_prefill, t_decode


def _bench_arch(arch: str, b: int) -> list:
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = T.lm_init(key, cfg)
    qstate = qat.build_qstate(params)
    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    prompt = np.asarray(jax.random.randint(
        key, (b, PROMPT_LEN), 0, cfg.vocab))
    prog = serving.LMProgram(frozen, cfg, max_prompt=PROMPT_LEN,
                             max_new=MAX_NEW,
                             max_bucket=1 << (max(b, 8) - 1).bit_length())

    # warmup both paths (tracing/compiles), keeping the parity references
    _direct_loop(frozen, cfg, prompt, MAX_NEW)
    ref, t_dp, t_dd = _direct_loop(frozen, cfg, prompt, MAX_NEW)
    gen = np.asarray(prog.generate(prompt, MAX_NEW), np.int64)

    engine, t_ep, t_ed = _serve_engine(prog, prompt, MAX_NEW)
    if not np.array_equal(engine, gen):
        raise RuntimeError(f"{arch}: engine decode is not bit-identical "
                           "to LMProgram.generate")
    if not np.array_equal(engine, ref):
        raise RuntimeError(f"{arch}: engine tokens diverged from the "
                           "direct models.lm greedy loop")

    sched = prog.describe()["ffn_schedules"]
    topo = topology()
    n_steps = b * (MAX_NEW - 1)
    rows = [
        {"model": arch, "phase": "prefill", "batch": b,
         "prompt_len": PROMPT_LEN,
         "engine_tok_s": b * PROMPT_LEN / t_ep,
         "direct_tok_s": b * PROMPT_LEN / t_dp,
         "engine_over_direct": t_dp / t_ep,
         "schedules": sched, **topo},
        {"model": arch, "phase": "decode", "batch": b,
         "steps": MAX_NEW - 1,
         "engine_steps_s": n_steps / t_ed,
         "direct_steps_s": n_steps / t_dd,
         "engine_over_direct": t_dd / t_ed,
         "schedules": sched, **topo},
    ]
    for r in rows:
        ratio = r["engine_over_direct"]
        print(f"  {arch:18s} {r['phase']:7s} engine/direct = {ratio:5.2f}x "
              f"(schedules {sched})")
    prog.forget()
    return rows


def run(fast: bool = False) -> dict:
    b = 2 if fast else 4
    rows = []
    for arch in ARCHS:
        rows.extend(_bench_arch(arch, b))
    payload = {"rows": rows, "batch": b, "prompt_len": PROMPT_LEN,
               "max_new": MAX_NEW}
    save("lm_serving", payload)
    merge_root_json({"lm_serving_rows": rows})
    return payload


if __name__ == "__main__":
    run()
