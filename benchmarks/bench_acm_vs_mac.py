"""§III-A / §VI-C analogue: ACM vs MAC operation counts + data movement.

The paper's claim: accumulate-then-multiply needs only 4 multiplies per
output element (vs K for MAC) and 4-bit weights cut data movement 8×.
On TPU the multiplier count is not the scarce resource (DESIGN.md §2), so
we report BOTH the paper's op-count model (faithful) and the TPU-relevant
translation (HBM bytes per weight, VMEM decode ops per tile) for the
paper's layer shapes, plus a correctness run of the actual Pallas kernel
on each shape (interpret mode).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import save
from repro.core import acm, bitplanes
from repro.kernels import autotune, ops, ref

# the paper's hardware-conform layer shapes (MLP-GSC / MLP-HR)
LAYERS = [(512, 512), (512, 256), (256, 256), (256, 128), (128, 128),
          (128, 12)]


def run():
    rows = []
    batch = 64
    rng = np.random.default_rng(0)
    for (k, n) in LAYERS:
        counts = acm.acm_flop_count(batch, k, n, sparsity=0.6)
        x = jnp.asarray(rng.normal(size=(batch, k)), jnp.float32)
        codes = jnp.asarray(rng.integers(0, 16, size=(k, n)), jnp.uint8)
        packed = bitplanes.pack_codes_rows(codes)
        omega = jnp.asarray(rng.normal(size=4) * 0.1, jnp.float32)
        # same tuned blocks as every serving entry point (block_*=None
        # resolves through the autotuner); recorded per row for the report.
        # backend="interpret" matches the interpret=True kernel call below
        # and keeps this off the real backend's timed-sweep cache slot.
        blocks = autotune.get_block_config(batch, k, n, dtype="float32",
                                           fused=False, backend="interpret")
        y_kernel = ops.fantastic4_matmul(x, packed, omega, use_kernel=True,
                                         interpret=True)
        y_ref = ref.fantastic4_matmul_ref(x, packed, omega)
        err = float(jnp.max(jnp.abs(y_kernel - y_ref)))
        rows.append({
            "layer": f"{k}x{n}", "batch": batch,
            "blocks": list(blocks.as_tuple()),
            "blocks_source": blocks.source,
            "mac_multiplies": counts["mac_mul"],
            "acm_multiplies": counts["acm_mul"],
            "multiply_reduction": counts["mul_reduction"],
            "weight_bytes_fp32": k * n * 4,
            "weight_bytes_4bit": k * n // 2,
            "hbm_reduction": 8.0,
            "kernel_max_err": err,
        })
        print(f"{k:4d}x{n:<4d} mul {counts['mac_mul']:.2e}->"
              f"{counts['acm_mul']:.2e} ({counts['mul_reduction']:.0f}x) "
              f"bytes {k*n*4}->{k*n//2} (8x)  kernel err {err:.2e}",
              flush=True)
        assert err < 1e-3
    save("acm_vs_mac", rows)
    return rows


if __name__ == "__main__":
    run()
