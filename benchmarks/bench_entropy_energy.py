"""Fig. 11 analogue: execution cost as a function of weight entropy.

The paper measures dynamic power (VCD/SAIF) falling quasi-linearly with
entropy.  On TPU the corresponding physical quantity is *bytes moved*
(energy ∝ bytes at fixed process): we sweep entropy via λ on a trained
MLP-HR and report, per entropy level, the weight bytes that off-chip →
on-chip transfer and the serving HBM traffic actually touch — compressed
(hybrid format) vs uncompressed.  The monotone entropy→bytes relation is
the claim being reproduced.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save, train_mlp
from repro.configs.paper_mlps import MLP_HR
from repro.core import ecl, formats


def run(steps: int = 200):
    rows = []
    for lam in (0.0, 0.05, 0.2, 0.5, 1.0):
        params, qs, bn, metrics = train_mlp(MLP_HR, lam=lam, steps=steps)
        comp_bits = 0
        total = 0
        ent_weighted = 0.0
        for layer, lq in zip(params["layers"], qs["layers"]):
            node = layer["kernel"]
            codes = np.asarray(ecl.assign(node["w"], node["omega"],
                                          lq["kernel"]["probs"], lam))
            nnz = int(np.count_nonzero(codes))
            comp_bits += min(formats.analytic_size_bits(codes.shape, nnz, f)
                             for f in formats.FORMATS)
            h = float(ecl.entropy_bits(ecl.histogram(codes)))
            ent_weighted += h * codes.size
            total += codes.size
        rows.append({
            "lam": lam, "entropy_bits": ent_weighted / total,
            "acc": metrics["acc"],
            "weight_bytes_compressed": comp_bits / 8,
            "weight_bytes_4bit": total / 2,
            "weight_bytes_fp32": total * 4,
            "movement_reduction_vs_fp32": total * 4 / (comp_bits / 8),
        })
        print(f"λ={lam:<5} H={rows[-1]['entropy_bits']:.2f}b/w "
              f"bytes={rows[-1]['weight_bytes_compressed']:.0f} "
              f"({rows[-1]['movement_reduction_vs_fp32']:.1f}x less than fp32)",
              flush=True)
    hs = [r["entropy_bits"] for r in rows]
    bs = [r["weight_bytes_compressed"] for r in rows]
    assert all(b1 >= b2 - 1 for b1, b2 in zip(bs, bs[1:])) or \
        np.corrcoef(hs, bs)[0, 1] > 0.8, "bytes should fall with entropy"
    save("fig11_entropy_bytes", rows)
    return rows


if __name__ == "__main__":
    run()
