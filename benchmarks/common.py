"""Shared benchmark utilities: MLP training harness over synthetic tasks."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_mlps import MLPConfig
from repro.core import qat
from repro.data import synthetic
from repro.models import mlp as M
from repro.nn.module import QuantCtx
from repro.optim import adam, schedule

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "bench")


def save(name: str, payload):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, name + ".json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)


def topology() -> dict:
    """The host execution topology every guarded bench row is tagged
    with: the regression guard (scripts/check_bench_rows.py) only
    compares a row against a snapshot taken on the SAME topology — a
    1-device interpret number vs an 8-device one is a hardware change,
    not a perf regression."""
    return {"n_devices": int(jax.device_count()),
            "backend": str(jax.default_backend())}


def train_mlp(cfg_mlp: MLPConfig, *, lam: float, steps: int = 250,
              lr: float = 5e-3, seed: int = 0, lam_ramp: int = 60,
              quant: bool = True):
    """EC4T-train an MLP on its synthetic task; returns (params, qstate,
    bn, final metrics dict)."""
    data_cfg = synthetic.ClsDataCfg(d_in=cfg_mlp.d_in,
                                    n_classes=cfg_mlp.features[-1],
                                    batch=128, margin=3.0, seed=seed)
    key = jax.random.PRNGKey(seed)
    params, bn = M.mlp_init(key, cfg_mlp)
    qs = qat.build_qstate(params)
    opt = adam.init(params)

    @jax.jit
    def step(params, qs, bn, opt, x, y, lam_t):
        ctx = QuantCtx(quant=quant, lam=lam_t, compute_dtype=jnp.float32)

        def loss_fn(params):
            logits, bn2 = M.mlp_apply(params, qs, bn, x, ctx, train=True)
            return M.cross_entropy(logits, y), (bn2, logits)
        (loss, (bn2, logits)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt, _ = adam.apply(params, g, opt, adam.AdamConfig(lr=lr))
        qs = qat.update_qstate(params, qs, lam_t)
        return params, qs, bn2, opt, loss, M.accuracy(logits, y)

    for i in range(steps):
        b = synthetic.cls_batch(data_cfg, i)
        lam_t = float(schedule.lambda_ramp(i, lam=lam, ramp_steps=lam_ramp))
        params, qs, bn, opt, loss, acc = step(
            params, qs, bn, opt, jnp.asarray(b["x"]),
            jnp.asarray(b["labels"]), lam_t)

    # held-out eval (fresh seeds)
    ctx = QuantCtx(quant=quant, lam=lam, compute_dtype=jnp.float32)
    accs = []
    for j in range(5):
        b = synthetic.cls_batch(data_cfg, 10_000 + j)
        logits, _ = M.mlp_apply(params, qs, bn, jnp.asarray(b["x"]), ctx,
                                train=False)
        accs.append(float(M.accuracy(logits, jnp.asarray(b["labels"]))))
    st = qat.stats(params, qs, lam)
    metrics = {"acc": float(np.mean(accs)),
               "sparsity": float(st["sparsity"]),
               "entropy_bits": float(st["entropy_bits_per_weight"])}
    return params, qs, bn, metrics
