"""Fused megakernel vs chained per-layer serving wall-clock (tentpole perf).

For each paper MLP stack and batch in {1, 16, 64, 256}:

* ``per_layer_ms`` — the ``mode="per_layer"`` plan: L ``pallas_call``
  launches, every inter-layer activation round-trips HBM.
* ``fused_ms``     — the ``mode="fused"`` plan: one megakernel launch,
  activations resident in VMEM scratch.  Every row carries a
  ``schedule`` label (``"ws" | "batch_tiled" | "db" | "stream"``) naming
  the kernel schedule the plan's bucket actually bound for that batch —
  a b≤8 ``fused_ms`` number silently reflecting the ws path was exactly
  the ambiguity the label removes.

Both paths flow through ``serving.ExecutionPlan`` — the same resolution
(autotuned blocks, per-bucket schedule binding, VMEM-fit, bucket entries)
every other entry point uses — and run the *actual Pallas kernel body*
(interpret mode off-TPU), so the comparison is launch-count +
data-movement, apples to apples.  A correctness check against the
jnp-oracle plan gates every row.

A second section, ``schedule_rows``, is the measured per-(bucket,
schedule) wall-clock table: every eligible schedule timed at every probe
bucket, the data behind the plan's bucket→schedule bindings.  Off-TPU
these numbers measure the *interpreter*, whose per-grid-step overhead
penalises the layer-streamed schedules (ws, stream) — they are recorded
to document the host's crossover, not as hardware truth (see README
"Schedule selection" caveats).

Writes results/bench/fused_serving.json and — so the perf trajectory is
tracked from this PR onward — ``BENCH_fused_serving.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS, save
from repro import serving
from repro.configs.paper_mlps import MLP_GSC, MLP_HR
from repro.core import bitplanes as bp

BATCHES = (1, 16, 64, 256)
REPO_ROOT = os.path.dirname(os.path.dirname(RESULTS))
ROOT_JSON = os.path.join(REPO_ROOT, "BENCH_fused_serving.json")


def merge_root_json(section: dict) -> None:
    """Read-merge-write ``section`` into the repo-root perf-trajectory
    file: this bench owns the fp32 ``rows``, bench_int8_fused owns
    ``int8_rows``, and either may run alone (``--only ...``)."""
    merged = {}
    if os.path.exists(ROOT_JSON):
        try:
            with open(ROOT_JSON) as f:
                merged = json.load(f)
        except ValueError:
            merged = {}
    merged.update(section)
    with open(ROOT_JSON, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"wrote {ROOT_JSON}")


def _rand_pack(cfg, seed=0):
    """Synthetic frozen pack at BN-realistic magnitudes (no training — the
    benchmark measures the serving path, not EC4T)."""
    rng = np.random.default_rng(seed)
    dims = (cfg.d_in,) + tuple(cfg.features)
    layers = []
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        codes = rng.integers(0, 16, size=(k + (k % 2), n)).astype(np.uint8)
        if k % 2:
            codes[-1] = 0         # pack invariant: odd K pads a zero row
        layers.append({
            "packed": bp.pack_codes_rows(jnp.asarray(codes)),
            "omega": jnp.asarray(rng.normal(size=4) / np.sqrt(k), jnp.float32),
            "alpha1": jnp.asarray(rng.normal(size=n) * 0.5, jnp.float32),
            "bias": jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32),
            "alpha2": jnp.asarray(np.float32(1.0)),
            "shape": (k, n),
            "activation": "relu" if i < len(dims) - 2 else None,
        })
    return {"layers": layers, "act_bits": None}


def _time_pair(fn_a, fn_b, repeats: int) -> tuple:
    """Interleaved best-of-N wall clock for two variants.

    Interleaving decorrelates slow host-load drift from the A/B comparison,
    and min is the noise-robust estimator on a shared host (every positive
    deviation is scheduler/interference, not the op)."""
    jax.block_until_ready(fn_a())             # compile + warm
    jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    return min(ta), min(tb)


# probe buckets for the measured per-(bucket, schedule) table: latency,
# the ws-prior boundary, and two mid-size buckets where the streaming
# schedule competes.
SCHED_BUCKETS = (1, 8, 32, 128)


def _best_of(fn, repeats: int) -> float:
    jax.block_until_ready(fn())               # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return min(times)


def schedule_table(cfg, pack, plan, repeats: int) -> list:
    """Measured wall-clock of every eligible schedule at every probe
    bucket — the data behind the plan's bucket→schedule bindings (and the
    record of why a schedule did/didn't win on this host)."""
    from repro.kernels import ops as kops

    rows = []
    for b in SCHED_BUCKETS:
        if b not in plan.buckets:
            continue
        eligible = plan._eligible_schedules(b)
        if not eligible:                 # nothing fits: per-layer bucket
            continue
        rng = np.random.default_rng(b)
        x = jnp.asarray(rng.normal(size=(b, cfg.d_in)), jnp.float32)
        bound = plan.buckets[b]
        for sched in eligible:
            if sched == "stream":
                # probe the schedule in its streaming regime (≥2 batch
                # tiles where the bucket allows), not as a one-tile
                # degenerate case of ws — but only at a tile whose
                # streamed working set actually fits: past the budget the
                # kernel wrapper silently runs the per-layer chain, and a
                # chain time under a "stream" label is the exact mislabel
                # the schedule bindings exist to prevent.
                bm = max(8, b // 2)
                while bm > 8 and not plan._schedule_fits(sched, b, bm):
                    bm //= 2
            else:
                bm = bound.block_m or min(b, plan.block_m or 128)
            if not plan._schedule_fits(sched, b, bm):
                continue
            t = _best_of(lambda: kops.fantastic4_mlp_fused(
                x, pack["layers"], use_kernel=True,
                interpret=plan.interpret, block_m=bm,
                schedule=sched), repeats)
            rows.append({"model": cfg.name, "bucket": b,
                         "schedule": sched, "block_m": bm,
                         "ms": t * 1e3,
                         "bound": sched == plan.schedule_for(b)})
        won = plan.schedule_for(b)
        best = min((r for r in rows if r["model"] == cfg.name
                    and r["bucket"] == b), key=lambda r: r["ms"])
        print(f"{cfg.name:12s} bucket={b:<4d} bound={won:12s} "
              f"measured-best={best['schedule']:12s} "
              f"({best['ms']:.2f} ms)", flush=True)
    return rows


def run(fast: bool = False):
    repeats = 5 if fast else 15
    rows = []
    sched_rows = []
    bucket_schedules = {}
    for cfg in (MLP_GSC, MLP_HR):
        pack = _rand_pack(cfg)
        plan_fused = serving.build_plan(pack, mode="fused")
        plan_layer = serving.build_plan(pack, mode="per_layer")
        plan_oracle = serving.build_plan(pack, mode="oracle")
        desc = plan_fused.describe()
        bucket_schedules[cfg.name] = {
            "buckets": {str(b): s for b, s in
                        desc["bucket_schedules"].items()},
            "ws_crossover_rows": desc["ws_crossover_rows"],
            "ws_prior_rows": desc["ws_prior_rows"],
            "ws_prior_source": desc["ws_prior_source"]}
        for batch in BATCHES:
            rng = np.random.default_rng(batch)
            x = jnp.asarray(rng.normal(size=(batch, cfg.d_in)), jnp.float32)
            y_f = plan_fused.run(x)
            y_o = plan_oracle.run(x)
            err = float(jnp.max(jnp.abs(y_f - y_o)))
            # mixed gate: 1e-3 absolute for O(1) logits, relative slack for
            # packs whose activations drift larger (f32 accumulation noise)
            assert err < 1e-3 + 1e-5 * float(jnp.max(jnp.abs(y_o))), \
                (cfg.name, batch, err)
            t_layer, t_fused = _time_pair(
                lambda: plan_layer.run(x),
                lambda: plan_fused.run(x), repeats)
            row = {"model": cfg.name, "batch": batch,
                   "schedule": plan_fused.schedule_for(batch),
                   "per_layer_ms": t_layer * 1e3,
                   "fused_ms": t_fused * 1e3,
                   "speedup": t_layer / max(t_fused, 1e-12),
                   "max_abs_err": err,
                   "launches_per_layer": len(pack["layers"]),
                   "launches_fused": 1}
            rows.append(row)
            print(f"{cfg.name:12s} b={batch:<4d} per-layer "
                  f"{row['per_layer_ms']:8.2f} ms  fused "
                  f"{row['fused_ms']:8.2f} ms [{row['schedule']}]  "
                  f"({row['speedup']:.2f}x)  err {err:.1e}", flush=True)
        sched_rows.extend(schedule_table(cfg, pack, plan_fused,
                                         repeats=3 if fast else 7))

    from benchmarks.common import topology
    for r in rows + sched_rows:
        r.update(topology())     # guard only compares matching topology
    payload = {"backend": jax.default_backend(), "batches": list(BATCHES),
               "rows": rows,
               "schedule_rows": sched_rows,
               "bucket_schedules": bucket_schedules,
               "schedule_caveat": (
                   "off-TPU schedule_rows time the Pallas *interpreter*: "
                   "per-grid-step overhead penalises the layer-streamed "
                   "schedules (ws/stream), so their crossover here is a "
                   "property of the host, not the hardware — re-tune on "
                   "a real backend before trusting bindings"),
               "fused_not_slower_at_64": all(
                   r["speedup"] >= 0.95 for r in rows if r["batch"] == 64)}
    save("fused_serving", payload)
    merge_root_json(payload)
    return payload


if __name__ == "__main__":
    run()
