"""Scale-out serving: N replicated execution streams, A/B'd against one.

The single-stream engine (bench_serving_engine) is arrival-rate-bound at
low load and service-rate-bound under backlog; replicating the execution
stream moves the service-rate ceiling.  This benchmark replays the same
seeded Poisson trace through ``serving.replay`` at ``n_streams`` ∈
{1, 2, 4} over one fused ``ExecutionPlan`` and reports the aggregate
throughput gain vs the 1-stream baseline at each offered load.

The virtual clock uses a *monotone* per-bucket service-time table (the
running max of the calibrated table over increasing buckets): on a noisy
interpret host a larger bucket occasionally times faster than a smaller
one, and a non-monotone table would let the multi-stream replay "win" by
bucket-split luck rather than by parallel service.  The same table drives
every leg, so the A/B is deterministic.

Every leg runs with ``max_bucket=16``: uncapped, deep backlog coalesces
into ever-larger tiles whose sub-linear per-row cost lets ONE stream
absorb any load — mathematically tidy, but it is exactly the
latency-unbounded regime serving avoids (a 256-row tile is a 256-row
p95).  Under a bounded bucket the single stream has a hard service-rate
ceiling and replication is what moves it, which is the regime this
benchmark exists to measure.

Two parity legs gate the rows:

* **threads** — a real ``ServingFrontend(streams=2)`` (dispatch thread +
  2 workers, join-shortest-estimated-work) serves ragged int8 traffic;
  every result must be bit-identical to the per-request ``plan.run``.
* **sharded** — a subprocess with ``--xla_force_host_platform_device_count=4``
  builds the same seeded pack as ``mode="sharded"`` over ``fit_mesh()``
  and checks the column-split program is bit-identical to the per-layer
  chain on the int8 grid.

Extends the repo-root ``BENCH_fused_serving.json`` with a
``multi_stream_rows`` section (guarded by scripts/check_bench_rows.py on
row identity and ``aggregate_gain``); also writes
results/bench/multi_stream.json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fused_serving import _rand_pack, merge_root_json
from benchmarks.bench_serving_engine import (MAX_DELAY_S, _requests,
                                             _service_table)
from benchmarks.common import save, topology
from repro import serving
from repro.configs.paper_mlps import MLP_GSC

# offered load as a fraction of ONE capped stream's peak row service rate
# (MAX_BUCKET rows per t_16): 0.3/1.0 bracket the keep-up regime, 4/10
# oversubscribe a single stream so replication is load-bearing.  Defining
# load against t_single (as bench_serving_engine does) would leave the
# capped stream ~13x underutilized at "load 10".
LOADS = (0.3, 1.0, 4.0, 10.0)
STREAMS = (1, 2, 4)
MAX_BUCKET = 16                          # latency-bounded tiles (docstring)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# run in a subprocess: device count is fixed at backend init, so a
# 4-device mesh needs its own XLA_FLAGS before the first jax import.
_SHARDED_PARITY_CODE = r'''
import json
import jax
import jax.numpy as jnp
import numpy as np
from benchmarks.bench_fused_serving import _rand_pack
from repro import serving
from repro.configs.paper_mlps import MLP_HR
from repro.launch.mesh import fit_mesh

cfg = MLP_HR
pack = _rand_pack(cfg)
calib_x = jnp.asarray(np.random.default_rng(3).normal(size=(32, cfg.d_in)),
                      jnp.float32)
scales = serving.calibrate_act_scales(pack, calib_x)
mesh = fit_mesh()
ref = serving.build_plan(pack, mode="per_layer", act_dtype="int8",
                         calib=scales)
shp = serving.build_plan(pack, mode="sharded", mesh=mesh, act_dtype="int8",
                         calib=scales)
ok = True
for b in (1, 8):
    x = jnp.asarray(np.random.default_rng(b).normal(size=(b, cfg.d_in)),
                    jnp.float32)
    ok = ok and bool(np.array_equal(np.asarray(ref.run(x)),
                                    np.asarray(shp.run(x))))
print(json.dumps({
    "n_devices": int(jax.device_count()),
    "mesh": dict(zip(mesh.axis_names,
                     [int(s) for s in mesh.devices.shape])),
    "sharding": shp.describe()["sharding"],
    "bit_identical": ok}))
'''


def _monotone(table: dict) -> dict:
    """Service time non-decreasing in bucket rows (running max)."""
    mono, t = {}, 0.0
    for b in sorted(table):
        t = max(t, table[b])
        mono[b] = t
    return mono


def _frontend_parity(pack, cfg, n_req: int) -> bool:
    """Real threads: streams=2 frontend vs per-request plan.run, int8."""
    calib_x = jnp.asarray(
        np.random.default_rng(3).normal(size=(32, cfg.d_in)), jnp.float32)
    plan = serving.build_plan(
        pack, mode="fused", act_dtype="int8",
        calib=serving.calibrate_act_scales(pack, calib_x))
    xs = _requests(cfg, n_req, seed=5)
    fe = serving.ServingFrontend(streams=2).start()
    try:
        fe.register("gsc", plan, max_delay=1e-3)
        futs = [fe.submit("gsc", x) for x in xs]
        outs = [f.result(timeout=120) for f in futs]
    finally:
        fe.close()
    used = {getattr(o, "stream", None) for o in outs}
    print(f"threads parity: {len(outs)} requests over streams {sorted(used)}",
          flush=True)
    for x, out in zip(xs, outs):
        if isinstance(out, serving.Rejected):
            return False
        np.testing.assert_array_equal(np.asarray(out.y),
                                      np.asarray(plan.run(x)))
    return True


def _sharded_parity() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (REPO_ROOT, os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run([sys.executable, "-c", _SHARDED_PARITY_CODE],
                          cwd=REPO_ROOT, env=env, capture_output=True,
                          text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded parity leg failed:\n{proc.stderr}")
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    print(f"sharded parity: {out['n_devices']} devices, mesh {out['mesh']}, "
          f"col-split layers {out['sharding']['col_sharded_layers']}, "
          f"bit_identical={out['bit_identical']}", flush=True)
    return out


def run(fast: bool = False):
    n_req = 64 if fast else 256
    cfg = MLP_GSC
    pack = _rand_pack(cfg)
    plan = serving.build_plan(pack, mode="fused")
    table = _monotone(_service_table(plan, repeats=3 if fast else 5))
    xs = _requests(cfg, n_req, seed=13)
    avg_rows = sum(int(x.shape[0]) for x in xs) / len(xs)
    # one capped stream's peak service rate, in requests/s
    cap_rps = MAX_BUCKET / max(table[MAX_BUCKET], 1e-9) / avg_rows

    rows = []
    for load in LOADS:
        lam = load * cap_rps
        rng = np.random.default_rng(int(load * 100) + 29)
        arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n_req))
        base = None
        for n in STREAMS:
            rep = serving.replay(plan, xs, arrivals, max_delay=MAX_DELAY_S,
                                 max_bucket=MAX_BUCKET, service_times=table,
                                 n_streams=n)
            if n == 1:
                base = rep
            else:
                # replicated streams run the same plan: the scattered
                # results must be identical at any N, only timing moves.
                for a, b in zip(base["results"], rep["results"]):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
            row = {"model": cfg.name, "load": load, "streams": n,
                   "max_bucket": MAX_BUCKET,
                   "throughput_rps": rep["throughput_rps"],
                   "baseline_throughput_rps": base["throughput_rps"],
                   "aggregate_gain": rep["throughput_rps"]
                   / max(base["throughput_rps"], 1e-12),
                   "latency_p95_ms": rep["latency_p95_ms"],
                   "stream_launches": rep["stream_launches"],
                   **topology()}
            rows.append(row)
            print(f"{cfg.name:12s} load={load:<5.1f} streams={n} "
                  f"{row['throughput_rps']:8.1f} req/s "
                  f"({row['aggregate_gain']:.2f}x)  p95 "
                  f"{row['latency_p95_ms']:7.2f} ms  "
                  f"launches={row['stream_launches']}", flush=True)

    not_slower = all(r["aggregate_gain"] >= 1.0 - 1e-9 for r in rows)
    strictly = all(r["aggregate_gain"] > 1.0 for r in rows
                   if r["load"] >= 4 and r["streams"] >= 2)
    assert not_slower, "multi-stream replay slower than single-stream"
    assert strictly, "no multi-stream gain under backlog (load >= 4)"

    threads_ok = _frontend_parity(pack, cfg, n_req=24 if fast else 48)
    assert threads_ok, "streams=2 frontend results diverged from plan.run"
    sharded = _sharded_parity()
    assert sharded["bit_identical"], \
        "sharded plan diverged from the per-layer chain on the int8 grid"

    summary = {
        "backend": jax.default_backend(),
        "multi_stream_loads": list(LOADS),
        "multi_stream_rows": rows,
        "multi_stream_not_slower_everywhere": not_slower,
        "multi_stream_gain_under_backlog": strictly,
        "frontend_threads_bit_identical": threads_ok,
        "sharded_parity": sharded,
    }
    save("multi_stream", summary)
    merge_root_json(summary)
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    run(ap.parse_args().fast)
