"""Model-churn benchmark: N compact packs behind the two-tier PackCache.

The fleet story of ROADMAP item 5: many compact models registered, only
a few hot at once.  ``serving.PackCache`` keeps every registered model
in its 4-bit/Huffman **cold** form (``compress_pack`` →
``CompressedTensor`` per layer) and resolves an ``ExecutionPlan`` only
on first traffic, evicting LRU plans back to compressed form under a
count/byte budget.  This benchmark drives a Zipf-distributed request
stream (model popularity rank ``r`` drawn ∝ r^-s, the standard
many-model serving skew) over ``N_MODELS`` synthetic packs at a hot
budget far below N and reports what the cache hierarchy promises:

* **resident-bytes high-water mark** — must stay at/below the
  ``hot_budget``-plan bound (evict-before-resolve: decoding the miss
  never overlaps the victim);
* **cold-start p95** — first-traffic decode + calibrate + plan resolve;
* **hot-path p95 vs the uncached engine** — the same request stream
  against permanently-resident plans; the cache's hit path is one lock
  + OrderedDict touch, so the ratio must be ~1;
* **compression ratio** — cold-tier bytes vs fp32 dense bytes;
* **evict → reload bit-identity** on the int8 grid (lossless codecs +
  captured ``act_scales`` ⇒ re-resolution is byte-exact).

Plans resolve in ``mode="oracle"``: the benchmark measures the *cache
hierarchy* (decode, resolve, eviction, lookup overhead), not kernel
wall-clock — the kernel A/B numbers live in bench_fused_serving /
bench_int8_fused.  Extends the repo-root ``BENCH_fused_serving.json``
with ``model_churn_rows`` (keyed by ``(models, hot_budget)``, guarded by
``scripts/check_bench_rows.py``); also writes
results/bench/model_churn.json.
"""
from __future__ import annotations

import time
from types import SimpleNamespace

import numpy as np

from benchmarks.bench_fused_serving import _rand_pack, merge_root_json
from benchmarks.common import save
from repro.serving import pack_cache as pc

CFG = SimpleNamespace(d_in=64, features=(96, 64, 10))
N_MODELS = 16
HOT_BUDGET = 4
ZIPF_S = 1.1
PLAN_KWARGS = {"mode": "oracle"}
CLOCK = time.perf_counter


def _zipf_stream(n_models: int, n_requests: int, rng) -> np.ndarray:
    """Model index per request, popularity ∝ (rank+1)^-ZIPF_S."""
    p = (np.arange(1, n_models + 1, dtype=np.float64)) ** (-ZIPF_S)
    p /= p.sum()
    return rng.choice(n_models, size=n_requests, p=p)


def _drive(cache_plans, stream, xs, resolves_fn=None) -> dict:
    """Run the request stream; split latencies by cold (a resolve
    happened inside the call) vs hot."""
    cold, hot = [], []
    for req, i in enumerate(stream):
        before = resolves_fn() if resolves_fn else 0
        t0 = CLOCK()
        y = cache_plans[i].run(xs[req])
        np.asarray(y)                      # materialize
        dt = CLOCK() - t0
        was_cold = resolves_fn and resolves_fn() > before
        (cold if was_cold else hot).append(dt)
    return {"cold_s": cold, "hot_s": hot}


def _p95_ms(samples) -> float:
    return float(np.percentile(np.asarray(samples), 95) * 1e3) \
        if samples else 0.0


def _bit_identity_leg(packs) -> bool:
    """max_hot=1 on the int8 grid: serve m0, force its eviction via m1,
    reload m0 — outputs must be byte-exact (acceptance criterion)."""
    cache = pc.PackCache(max_hot=1, plan_kwargs={"act_dtype": "int8"})
    p0 = cache.add("m0", packs[0])
    p1 = cache.add("m1", packs[1])
    rng = np.random.default_rng(99)
    x = rng.normal(size=(4, CFG.d_in)).astype(np.float32)
    y1 = np.asarray(p0.run(x))
    np.asarray(p1.run(x))                  # evicts m0
    ok = not cache.has_hot("m0")
    y2 = np.asarray(p0.run(x))
    return bool(ok and np.array_equal(y1, y2))


def run(fast: bool = False) -> dict:
    n_requests = 240 if fast else 1200
    rng = np.random.default_rng(0)
    packs = [_rand_pack(CFG, seed=i) for i in range(N_MODELS)]

    # uncached reference: every plan permanently resident (the pre-cache
    # registry behavior) — baseline for hot-path latency and the
    # resident-bytes bound
    ref_plans = [pc.build_plan(p, **PLAN_KWARGS) for p in packs]
    plan_bytes = max(pc.plan_resident_bytes(p) for p in ref_plans)
    resident_bound = HOT_BUDGET * plan_bytes

    stream = _zipf_stream(N_MODELS, n_requests, rng)
    xs = [rng.normal(size=(int(rng.integers(1, 5)), CFG.d_in))
          .astype(np.float32) for _ in range(n_requests)]

    rows = []
    for hot_budget in (HOT_BUDGET, N_MODELS):
        cache = pc.PackCache(max_hot=hot_budget, plan_kwargs=PLAN_KWARGS)
        proxies = [cache.add(f"m{i}", packs[i]) for i in range(N_MODELS)]
        timed = _drive(proxies, stream, xs,
                       resolves_fn=lambda: cache.stats["resolves"])
        uncached = _drive(ref_plans, stream, xs)
        hot_p95 = _p95_ms(timed["hot_s"])
        unc_p95 = _p95_ms(uncached["hot_s"])
        cr = float(np.mean([pc.compress_pack(p).compression_ratio
                            for p in packs])) if hot_budget == HOT_BUDGET \
            else rows[0]["compression_ratio"]
        row = {
            "models": N_MODELS,
            "hot_budget": hot_budget,
            "requests": n_requests,
            "zipf_s": ZIPF_S,
            "mode": PLAN_KWARGS["mode"],
            "resolves": cache.stats["resolves"],
            "evictions": cache.stats["evictions"],
            "resident_hwm_bytes": cache.stats["resident_high_water"],
            "resident_bound_bytes": resident_bound,
            "resident_over_bound":
                cache.stats["resident_high_water"] / resident_bound,
            "cold_start_p95_ms": _p95_ms(cache.stats["cold_start_s"]),
            "hot_p95_ms": hot_p95,
            "uncached_p95_ms": unc_p95,
            "hot_over_uncached": hot_p95 / max(unc_p95, 1e-9),
            "compression_ratio": cr,
            "bit_identical_reload": _bit_identity_leg(packs),
        }
        rows.append(row)
        print(f"  models={N_MODELS} hot={hot_budget}: "
              f"resolves={row['resolves']} evictions={row['evictions']} "
              f"hwm={row['resident_hwm_bytes']/1e3:.1f}kB "
              f"(bound {resident_bound/1e3:.1f}kB, "
              f"x{row['resident_over_bound']:.2f}) "
              f"cold_p95={row['cold_start_p95_ms']:.2f}ms "
              f"hot_p95={hot_p95:.3f}ms (uncached {unc_p95:.3f}ms, "
              f"x{row['hot_over_uncached']:.2f}) "
              f"CR={cr:.2f} bitid={row['bit_identical_reload']}")

    budgeted = rows[0]
    assert budgeted["resident_over_bound"] <= 1.0 + 1e-9, \
        "resident high-water exceeded the hot-budget bound"
    assert budgeted["bit_identical_reload"], \
        "evict -> reload was not bit-identical on the int8 grid"

    from benchmarks.common import topology
    for r in rows:
        r.update(topology())     # guard only compares matching topology
    payload = {"config": {"d_in": CFG.d_in, "features": list(CFG.features),
                          "models": N_MODELS, "zipf_s": ZIPF_S,
                          "requests": n_requests},
               "rows": rows}
    save("model_churn", payload)
    merge_root_json({"model_churn_rows": rows})
    return payload


if __name__ == "__main__":
    run()
