#!/usr/bin/env bash
# Tier-1 gate + fast benchmark refresh, with a wall-clock budget.
#
#   scripts/ci.sh                 # full: pytest then benchmarks (budgeted)
#   CI_BENCH_BUDGET_S=300 scripts/ci.sh
#   CI_SKIP_BENCH=1 scripts/ci.sh # tests only
#
# The benchmark leg reruns `benchmarks/run.py --fast` in interpret mode —
# including bench_serving_engine (ragged-arrival engine vs naive),
# bench_multi_model (>=2 packs behind the async ServingFrontend on the
# real clock), bench_slo_traces (bursty/diurnal traces through SLO
# tiers with bounded queues, admission control and a 10%-fault leg) and
# bench_model_churn (16 packs behind the two-tier PackCache under Zipf
# popularity: resident-bytes high-water vs the hot budget, cold-start
# p95, cache-hit vs uncached latency, evict->reload bit-identity) and
# bench_multi_stream (the same Poisson trace at n_streams in {1,2,4}
# under a bounded bucket, plus threaded-frontend and 4-device-sharded
# bit-exact parity legs) and bench_integrity (background-scrubber
# hot-path overhead plus detection->recovery under seeded per-launch
# bit flips, outputs bit-identical to a no-fault run) and
# bench_lm_serving (4-bit transformer prefill/decode as an LMProgram
# behind the ServingFrontend vs the direct greedy loop, parity-gated
# bit-identical) — and rewrites
# BENCH_fused_serving.json at the
# repo root (fp32 rows + int8_rows + serving_engine_rows +
# schedule_rows + multi_model_rows + slo_trace_rows + model_churn_rows
# + multi_stream_rows + integrity_rows + lm_serving_rows, every guarded
# row topology-tagged), so every PR
# leaves the cross-PR perf trajectory current.  A benchmark overrun (budget exceeded) fails
# CI loudly rather than silently shipping a stale perf file, and
# scripts/check_bench_rows.py fails the run if the refreshed JSON lost rows
# the committed baseline had, dropped a row's kernel-schedule label, or
# regressed a guarded metric more than CI_BENCH_REGRESSION_PCT (default
# 25%; <=0 disables the regression leg only; slo_trace_rows rate metrics
# are guarded additively in percentage points, model_churn_rows ratios
# multiplicatively).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${CI_SKIP_BENCH:-0}" != "1" ]]; then
    budget="${CI_BENCH_BUDGET_S:-1200}"
    # Regression bound: 25% is the contract on real backends, but on the
    # shared interpret host small-batch rows swing up to ~47% run-to-run
    # (measured: ratio metrics across two back-to-back --fast runs), so CI
    # widens the bound rather than flaking on host load.  Tighten this
    # once the benches run on hardware with stable clocks.
    export CI_BENCH_REGRESSION_PCT="${CI_BENCH_REGRESSION_PCT:-60}"
    rows_snapshot="$(mktemp)"
    trap 'rm -f "$rows_snapshot"' EXIT
    python scripts/check_bench_rows.py snapshot "$rows_snapshot"
    echo "== benchmarks (--fast, budget ${budget}s) =="
    timeout --signal=INT "$budget" python -m benchmarks.run --fast
    echo "== bench row-loss guard =="
    python scripts/check_bench_rows.py check "$rows_snapshot"
fi

echo "CI OK"
