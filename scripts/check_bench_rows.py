"""Guard the cross-PR perf trajectory carried by BENCH_fused_serving.json.

    python scripts/check_bench_rows.py snapshot ROWS_FILE   # before benches
    python scripts/check_bench_rows.py check ROWS_FILE      # after benches

``snapshot`` records, for every row present in the current repo-root JSON,
its identity, its guarded metric values, and the row's host topology
(``n_devices`` + ``backend``) when the bench tagged it.  ``check`` then
fails loudly if, after the benchmarks reran:

* any recorded row identity is missing — a benchmark that silently stopped
  emitting a section would ship a shrunken perf file and break the
  PR-over-PR comparison;
* any row lost a required label (e.g. the kernel ``schedule`` that
  produced a ``fused_ms`` number, or a ``multi_model_rows`` per-model
  ``bucket_schedules`` table) — unlabeled numbers are ambiguous between
  kernel paths;
* any guarded metric regressed more than ``CI_BENCH_REGRESSION_PCT``
  (default 25) percent against the snapshot.

Everything a family guards lives in ONE entry of the ``FAMILIES`` table
below: its identity ``keys``, its ``metrics`` as (name, direction)
pairs, and any required ``labels`` / ``nested_labels``.  Adding a new
bench section to the guard is a one-entry diff.

Metric directions:

* ``higher_ratio`` / ``lower_ratio`` — MULTIPLICATIVE bounds, for the
  self-normalized A/B ratios the perf trajectory actually promises
  (fused-vs-per-layer ``speedup``, engine-vs-naive ``throughput_gain``,
  N-streams-vs-one ``aggregate_gain``, the LM engine-vs-direct-loop
  ratio, the churn/cache ratios): on a shared host absolute wall-clock
  tracks machine load, while a ratio compares two paths measured
  interleaved on the same host.
* ``higher_abs`` / ``lower_abs`` — ADDITIVE bounds in percentage POINTS,
  for rate metrics living in [0, 1] (``within_slo_frac``, ``shed_rate``,
  ``detection_frac``): a multiplicative bound on a near-zero shed rate
  would trip on any nonzero value while letting a 0.9 -> 0.4 goodput
  drop through.

Metrics absent on a row are skipped, not treated as regressions (e.g.
``integrity_rows``: the flip_rate=0 row carries the scrub metric, the
flip rows the detection metric).  ``schedule_rows`` carries
interpreter-grade timings recorded for documentation, not hardware
truth — identity-guarded only (no metrics entry).  Set the env var to 0
or less to disable the regression leg (e.g. on a deliberately slower
host); the row-loss and label guards always run.  ``scripts/ci.sh``
widens the bound on interpret hosts — see the measurement note there.

Topology gating: every guarded bench tags its rows with the host
execution topology (``n_devices``, ``backend`` — see
``benchmarks.common.topology``).  The regression leg only compares a
row against a snapshot taken on the SAME topology — a 1-device
interpret number vs an 8-device one is a hardware change, not a perf
regression.  The row-loss and label guards are topology-independent
and always apply.
"""
from __future__ import annotations

import json
import os
import sys

ROOT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fused_serving.json")

# The whole guard, one entry per bench-row family:
#   keys          identity columns (row loss is checked per identity)
#   metrics       ((name, direction), ...) regression-guarded values
#   labels        row fields that must be present and truthy
#   nested_labels (outer_field, inner_field): every entry of the row's
#                 ``outer_field`` dict must carry a truthy ``inner_field``
FAMILIES = {
    "rows": {
        "keys": ("model", "batch"),
        "metrics": (("speedup", "higher_ratio"),),
        "labels": ("schedule",),
    },
    "int8_rows": {
        "keys": ("model", "batch"),
        "metrics": (("int8_fused_speedup_vs_layer", "higher_ratio"),),
        "labels": ("schedule",),
    },
    "serving_engine_rows": {
        "keys": ("model", "load"),
        "metrics": (("throughput_gain", "higher_ratio"),),
    },
    "schedule_rows": {
        "keys": ("model", "bucket", "schedule"),
    },
    "multi_model_rows": {
        "keys": ("load",),
        "metrics": (("aggregate_gain", "higher_ratio"),),
        "nested_labels": ("per_model", "bucket_schedules"),
    },
    "slo_trace_rows": {
        "keys": ("trace", "tier"),
        "metrics": (("within_slo_frac", "higher_abs"),
                    ("goodput_fault", "higher_abs"),
                    ("shed_rate", "lower_abs")),
    },
    "model_churn_rows": {
        "keys": ("models", "hot_budget"),
        "metrics": (("compression_ratio", "higher_ratio"),
                    ("hot_over_uncached", "lower_ratio"),
                    ("resident_over_bound", "lower_ratio")),
    },
    "multi_stream_rows": {
        "keys": ("model", "load", "streams"),
        "metrics": (("aggregate_gain", "higher_ratio"),),
    },
    "integrity_rows": {
        "keys": ("model", "flip_rate"),
        "metrics": (("detection_frac", "higher_abs"),
                    ("scrub_overhead_ratio", "lower_ratio")),
    },
    "lm_serving_rows": {
        "keys": ("model", "phase"),
        "metrics": (("engine_over_direct", "higher_ratio"),),
    },
}


def _load(path: str = ROOT_JSON) -> dict:
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            return json.load(f)
    except ValueError:
        return {}


def _row_topology(row: dict):
    """The (n_devices, backend) tag a bench stamped on the row, or None
    for rows written before topology tagging existed."""
    if "n_devices" not in row and "backend" not in row:
        return None
    return {"n_devices": row.get("n_devices"),
            "backend": row.get("backend")}


def row_records(path: str = ROOT_JSON) -> list:
    """[[section, *key_values, metrics_dict_or_None, topology_or_None],
    ...] for every row."""
    data = _load(path)
    records = []
    for section, spec in FAMILIES.items():
        metrics = spec.get("metrics", ())
        for row in data.get(section, []):
            val = {m: row.get(m) for m, _ in metrics} if metrics else None
            records.append([section] + [row.get(k) for k in spec["keys"]]
                           + [val, _row_topology(row)])
    return records


def regression_pct() -> float:
    try:
        return float(os.environ.get("CI_BENCH_REGRESSION_PCT", "25"))
    except ValueError:
        return 25.0


def _as_metric_dict(val, metrics) -> dict:
    """Normalize a snapshot value: current snapshots store a metrics
    dict; older ones stored the single guarded metric as a scalar."""
    if isinstance(val, dict):
        return val
    if val is not None and metrics:
        return {metrics[0][0]: val}
    return {}


def check(rows_file: str, path: str = ROOT_JSON) -> int:
    with open(rows_file) as f:
        before = json.load(f)
    after = {tuple(r[:-2]): (r[-2], r[-1]) for r in row_records(path)}
    failures = []
    guarded_ids = set()
    pct = regression_pct()

    for rec in before:
        section = rec[0] if rec else None
        spec = FAMILIES.get(section)
        if spec is None:
            continue                     # section retired: nothing to hold
        n_keys = len(spec["keys"])
        if len(rec) == n_keys + 3:
            rid, old_val, old_topo = tuple(rec[:-2]), rec[-2], rec[-1]
        elif len(rec) == n_keys + 2:
            # pre-topology snapshot: metric but no host tag
            rid, old_val, old_topo = tuple(rec[:-1]), rec[-1], None
        else:
            # pre-metric snapshot (older format): identity only
            rid, old_val, old_topo = tuple(rec), None, None
        guarded_ids.add(rid)
        if rid not in after:
            failures.append(f"lost row {rid}")
            continue
        new_val, new_topo = after[rid]
        if old_topo and new_topo and old_topo != new_topo:
            # host topology changed between snapshot and rerun: the
            # wall-clock-derived metrics are not comparable.  Row-loss
            # and label guards still apply.
            continue
        if pct <= 0:
            continue
        metrics = spec.get("metrics", ())
        old_vals = _as_metric_dict(old_val, metrics)
        new_vals = _as_metric_dict(new_val, metrics)
        tol = pct / 100.0
        for metric, direction in metrics:
            ov, nv = old_vals.get(metric), new_vals.get(metric)
            if not isinstance(ov, (int, float)) or \
                    not isinstance(nv, (int, float)):
                continue
            if direction.endswith("_ratio"):         # multiplicative
                worse = (nv > ov * (1 + tol) if direction == "lower_ratio"
                         else nv < ov * (1 - tol))
                bound = f"> {pct:.0f}% bound"
            else:                                    # additive, pct points
                worse = (nv > ov + tol if direction == "lower_abs"
                         else nv < ov - tol)
                bound = f"> {pct:.0f} pct-point bound"
            if worse:
                failures.append(
                    f"{rid}: {metric} regressed {ov:.3f} -> "
                    f"{nv:.3f} ({bound})")

    data = _load(path)
    for section, spec in FAMILIES.items():
        for row in data.get(section, []):
            rid = [section] + [row.get(k) for k in spec["keys"]]
            for label in spec.get("labels", ()):
                if not row.get(label):
                    failures.append(f"{rid}: missing {label} label")
            if "nested_labels" in spec:
                outer, inner = spec["nested_labels"]
                for name, entry in (row.get(outer) or {}).items():
                    if not entry.get(inner):
                        failures.append(
                            f"{rid + [name]}: missing {inner} labels")

    if failures:
        print("BENCH_fused_serving.json failed the bench guard:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    new_rows = len(after) - len(guarded_ids & set(after))
    print(f"bench rows OK ({len(before)} guarded, {max(new_rows, 0)} new; "
          f"regression bound {regression_pct():.0f}%)")
    return 0


def main(argv) -> int:
    if len(argv) != 3 or argv[1] not in ("snapshot", "check"):
        print(__doc__)
        return 2
    cmd, rows_file = argv[1], argv[2]
    if cmd == "snapshot":
        records = row_records()
        with open(rows_file, "w") as f:
            json.dump(records, f)
        print(f"snapshotted {len(records)} bench rows -> {rows_file}")
        return 0
    return check(rows_file)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
