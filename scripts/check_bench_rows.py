"""Guard the cross-PR perf trajectory: BENCH_fused_serving.json must never
lose rows a previous run had.

    python scripts/check_bench_rows.py snapshot ROWS_FILE   # before benches
    python scripts/check_bench_rows.py check ROWS_FILE      # after benches

``snapshot`` records the identity of every row present in the current
repo-root JSON (per section: fp32 ``rows`` and ``int8_rows`` keyed by
(model, batch), ``serving_engine_rows`` by (model, load)).  ``check``
fails loudly if any recorded identity is missing afterwards — a benchmark
that silently stopped emitting a section would otherwise ship a shrunken
perf file and break the PR-over-PR comparison.
"""
from __future__ import annotations

import json
import os
import sys

ROOT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fused_serving.json")

SECTIONS = {
    "rows": ("model", "batch"),
    "int8_rows": ("model", "batch"),
    "serving_engine_rows": ("model", "load"),
}


def row_ids(path: str = ROOT_JSON) -> list:
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            data = json.load(f)
    except ValueError:
        return []
    ids = []
    for section, keys in SECTIONS.items():
        for row in data.get(section, []):
            ids.append([section] + [row.get(k) for k in keys])
    return ids


def main(argv) -> int:
    if len(argv) != 3 or argv[1] not in ("snapshot", "check"):
        print(__doc__)
        return 2
    cmd, rows_file = argv[1], argv[2]
    if cmd == "snapshot":
        with open(rows_file, "w") as f:
            json.dump(row_ids(), f)
        print(f"snapshotted {len(row_ids())} bench rows -> {rows_file}")
        return 0
    with open(rows_file) as f:
        before = [tuple(r) for r in json.load(f)]
    after = {tuple(r) for r in row_ids()}
    missing = [r for r in before if r not in after]
    if missing:
        print("BENCH_fused_serving.json lost previously present rows:")
        for r in missing:
            print(f"  {r}")
        return 1
    print(f"bench rows OK ({len(before)} preserved, "
          f"{len(after) - len(set(before))} new)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
