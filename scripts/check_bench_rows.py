"""Guard the cross-PR perf trajectory carried by BENCH_fused_serving.json.

    python scripts/check_bench_rows.py snapshot ROWS_FILE   # before benches
    python scripts/check_bench_rows.py check ROWS_FILE      # after benches

``snapshot`` records, for every row present in the current repo-root JSON,
its identity (per section: fp32 ``rows`` and ``int8_rows`` keyed by
(model, batch), ``serving_engine_rows`` by (model, load), ``schedule_rows``
by (model, bucket, schedule), ``multi_model_rows`` by (load,),
``slo_trace_rows`` by (trace, tier), ``model_churn_rows`` by
(models, hot_budget), ``multi_stream_rows`` by (model, load, streams),
``integrity_rows`` by (model, flip_rate))
and its guarded metric(s), plus the row's host topology (``n_devices``
+ ``backend``) when the bench tagged it.
``check`` then fails loudly if, after the benchmarks reran:

* any recorded row identity is missing — a benchmark that silently stopped
  emitting a section would ship a shrunken perf file and break the
  PR-over-PR comparison;
* any ``rows`` / ``int8_rows`` row lost its ``schedule`` label — the label
  says which kernel schedule produced the number, without it a b≤8
  ``fused_ms`` entry is ambiguous between the ws and batch-tiled paths;
  likewise any ``multi_model_rows`` per-model entry missing its
  ``bucket_schedules`` table (the aggregate number is only meaningful
  against the schedules each model's buckets bound);
* any guarded metric regressed more than ``CI_BENCH_REGRESSION_PCT``
  (default 25) percent against the snapshot.  The guarded metrics are the
  rows' *self-normalized A/B ratios* (fused-vs-per-layer ``speedup``,
  ``int8_fused_speedup_vs_layer``, engine-vs-naive ``throughput_gain``,
  N-streams-vs-one ``aggregate_gain`` in ``multi_stream_rows``)
  rather than absolute ms/rps: on a shared host absolute wall-clock
  tracks machine load (and the engine's low-load throughput is
  arrival-rate-bound by construction), while the ratios compare two
  paths measured interleaved on the same host and are what the perf
  trajectory actually promises.  ``slo_trace_rows`` rate metrics
  (``within_slo_frac``, ``goodput_fault``, ``shed_rate``) live in [0, 1]
  and are guarded ADDITIVELY — the bound is percentage points, not a
  ratio.  ``model_churn_rows`` carries three self-normalized ratios
  (cold-tier ``compression_ratio``, cache-hit-vs-uncached
  ``hot_over_uncached``, high-water-vs-budget ``resident_over_bound``)
  guarded multiplicatively (``*_ratio`` directions) — the latter two are
  cache-mechanics invariants, so a blow-up there is a real bug, not
  host noise.  ``integrity_rows`` guards ``detection_frac`` additively
  (a [0, 1] rate pinned at 1.0 — every injected bit flip must be
  caught) and ``scrub_overhead_ratio`` multiplicatively (paired
  scrubber-on/off p95).  Set the env var to 0 or less to disable
  the regression leg (e.g. on a deliberately slower host); the row-loss
  and label guards always run.  ``scripts/ci.sh`` widens the bound on
  interpret hosts — see the measurement note there.

Topology gating: every guarded bench tags its rows with the host
execution topology (``n_devices``, ``backend`` — see
``benchmarks.common.topology``).  The regression leg only compares a
row against a snapshot taken on the SAME topology — a 1-device
interpret number vs an 8-device one is a hardware change, not a perf
regression.  The row-loss and label guards are topology-independent
and always apply.
"""
from __future__ import annotations

import json
import os
import sys

ROOT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fused_serving.json")

SECTIONS = {
    "rows": ("model", "batch"),
    "int8_rows": ("model", "batch"),
    "serving_engine_rows": ("model", "load"),
    "schedule_rows": ("model", "bucket", "schedule"),
    "multi_model_rows": ("load",),
    "slo_trace_rows": ("trace", "tier"),
    "model_churn_rows": ("models", "hot_budget"),
    "multi_stream_rows": ("model", "load", "streams"),
    "integrity_rows": ("model", "flip_rate"),
}

# guarded metric per section and the direction that counts as regression.
# schedule_rows carries interpreter-grade timings recorded for
# documentation, not hardware truth — identity-guarded only.
METRICS = {
    "rows": ("speedup", "higher_is_better"),
    "int8_rows": ("int8_fused_speedup_vs_layer", "higher_is_better"),
    "serving_engine_rows": ("throughput_gain", "higher_is_better"),
    "multi_model_rows": ("aggregate_gain", "higher_is_better"),
    "multi_stream_rows": ("aggregate_gain", "higher_is_better"),
}

# sections guarded on several metrics at once.  ``*_abs`` directions are
# ADDITIVE (pct as percentage POINTS) for rate metrics living in [0, 1]
# — a multiplicative bound on a near-zero shed rate would trip on any
# nonzero value while letting a 0.9 -> 0.4 goodput drop through.
# ``*_ratio`` directions are MULTIPLICATIVE, for self-normalized A/B
# ratios where relative movement is what matters.
MULTI_METRICS = {
    "slo_trace_rows": (
        ("within_slo_frac", "higher_abs"),
        ("goodput_fault", "higher_abs"),
        ("shed_rate", "lower_abs"),
    ),
    "model_churn_rows": (
        ("compression_ratio", "higher_ratio"),
        ("hot_over_uncached", "lower_ratio"),
        ("resident_over_bound", "lower_ratio"),
    ),
    # integrity_rows: detection_frac is a [0, 1] rate (must stay at 1.0
    # — additive pct-point bound); scrub_overhead_ratio is a paired
    # on/off p95 ratio (multiplicative).  The flip_rate=0 row carries
    # the scrub metric, the flip rows the detection metric; absent
    # metrics on a row are skipped, not treated as regressions.
    "integrity_rows": (
        ("detection_frac", "higher_abs"),
        ("scrub_overhead_ratio", "lower_ratio"),
    ),
}

# sections whose rows must name the kernel schedule that produced them
LABELED = ("rows", "int8_rows")


def _load(path: str = ROOT_JSON) -> dict:
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            return json.load(f)
    except ValueError:
        return {}


def _row_topology(row: dict):
    """The (n_devices, backend) tag a bench stamped on the row, or None
    for rows written before topology tagging existed."""
    if "n_devices" not in row and "backend" not in row:
        return None
    return {"n_devices": row.get("n_devices"),
            "backend": row.get("backend")}


def row_records(path: str = ROOT_JSON) -> list:
    """[[section, *key_values, metric_or_None, topology_or_None], ...]
    for every row."""
    data = _load(path)
    records = []
    for section, keys in SECTIONS.items():
        metric = METRICS.get(section, (None,))[0]
        multi = MULTI_METRICS.get(section)
        for row in data.get(section, []):
            if multi:
                val = {m: row.get(m) for m, _ in multi}
            else:
                val = row.get(metric) if metric else None
            records.append([section] + [row.get(k) for k in keys]
                           + [val, _row_topology(row)])
    return records


def regression_pct() -> float:
    try:
        return float(os.environ.get("CI_BENCH_REGRESSION_PCT", "25"))
    except ValueError:
        return 25.0


def check(rows_file: str, path: str = ROOT_JSON) -> int:
    with open(rows_file) as f:
        before = json.load(f)
    after = {tuple(r[:-2]): (r[-2], r[-1]) for r in row_records(path)}
    failures = []
    guarded_ids = set()

    for rec in before:
        section = rec[0] if rec else None
        if section not in SECTIONS:
            continue                     # section retired: nothing to hold
        n_keys = len(SECTIONS[section])
        if len(rec) == n_keys + 3:
            rid, old_val, old_topo = tuple(rec[:-2]), rec[-2], rec[-1]
        elif len(rec) == n_keys + 2:
            # pre-topology snapshot: metric but no host tag
            rid, old_val, old_topo = tuple(rec[:-1]), rec[-1], None
        else:
            # pre-metric snapshot (older format): identity only
            rid, old_val, old_topo = tuple(rec), None, None
        guarded_ids.add(rid)
        if rid not in after:
            failures.append(f"lost row {rid}")
            continue
        new_val, new_topo = after[rid]
        if old_topo and new_topo and old_topo != new_topo:
            # host topology changed between snapshot and rerun: the
            # wall-clock-derived metrics are not comparable.  Row-loss
            # and label guards above/below still apply.
            continue
        pct = regression_pct()
        if section in MULTI_METRICS:
            if pct <= 0 or not isinstance(old_val, dict):
                continue
            new_vals = new_val if isinstance(new_val, dict) else {}
            tol = pct / 100.0
            for metric, direction in MULTI_METRICS[section]:
                ov, nv = old_val.get(metric), new_vals.get(metric)
                if not isinstance(ov, (int, float)) or \
                        not isinstance(nv, (int, float)):
                    continue
                if direction.endswith("_ratio"):     # multiplicative
                    worse = (nv > ov * (1 + tol)
                             if direction == "lower_ratio"
                             else nv < ov * (1 - tol))
                    bound = f"> {pct:.0f}% bound"
                else:                                # additive, pct points
                    worse = (nv > ov + tol if direction == "lower_abs"
                             else nv < ov - tol)
                    bound = f"> {pct:.0f} pct-point bound"
                if worse:
                    failures.append(
                        f"{rid}: {metric} regressed {ov:.3f} -> "
                        f"{nv:.3f} ({bound})")
            continue
        if pct <= 0 or old_val is None or section not in METRICS:
            continue
        metric, direction = METRICS[section]
        if not isinstance(old_val, (int, float)) or \
                not isinstance(new_val, (int, float)):
            continue
        if direction == "lower_is_better":
            if new_val > old_val * (1 + pct / 100.0):
                failures.append(
                    f"{rid}: {metric} regressed {old_val:.3f} -> "
                    f"{new_val:.3f} (> {pct:.0f}% bound)")
        else:
            if new_val < old_val * (1 - pct / 100.0):
                failures.append(
                    f"{rid}: {metric} regressed {old_val:.3f} -> "
                    f"{new_val:.3f} (> {pct:.0f}% bound)")

    data = _load(path)
    for section in LABELED:
        for row in data.get(section, []):
            if not row.get("schedule"):
                keys = SECTIONS[section]
                rid = [section] + [row.get(k) for k in keys]
                failures.append(f"{rid}: missing schedule label")
    for row in data.get("multi_model_rows", []):
        for model, entry in (row.get("per_model") or {}).items():
            if not entry.get("bucket_schedules"):
                failures.append(
                    f"['multi_model_rows', {row.get('load')}, {model}]: "
                    "missing bucket_schedules labels")

    if failures:
        print("BENCH_fused_serving.json failed the bench guard:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    new_rows = len(after) - len(guarded_ids & set(after))
    print(f"bench rows OK ({len(before)} guarded, {max(new_rows, 0)} new; "
          f"regression bound {regression_pct():.0f}%)")
    return 0


def main(argv) -> int:
    if len(argv) != 3 or argv[1] not in ("snapshot", "check"):
        print(__doc__)
        return 2
    cmd, rows_file = argv[1], argv[2]
    if cmd == "snapshot":
        records = row_records()
        with open(rows_file, "w") as f:
            json.dump(records, f)
        print(f"snapshotted {len(records)} bench rows -> {rows_file}")
        return 0
    return check(rows_file)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
