"""GPipe pipeline (shard_map + ppermute) == sequential reference."""
from conftest import run_with_devices

from repro.runtime.pipeline_parallel import bubble_fraction


def test_bubble_formula():
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(1, 8) == 0.0


def test_pipeline_matches_sequential():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import pipeline_parallel as pp
mesh = jax.make_mesh((4,), ("pipe",))
L, d = 8, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, d, d)) * (d ** -0.5)

def layer_fn(stage_ws, x):      # stage_ws: (L/S, d, d)
    def body(x, w):
        return jnp.tanh(x @ w), None
    y, _ = jax.lax.scan(body, x, stage_ws)
    return y

x = jax.random.normal(jax.random.PRNGKey(1), (16, d))
ref = layer_fn(ws, x)
stage_ws = pp.stage_split(ws, 4)
with mesh:
    out = pp.pipeline_apply(layer_fn, stage_ws, x, mesh=mesh, n_micro=4)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("pipeline OK")
""", n_devices=4)
