"""Trip-count-aware HLO walker vs hand counts and XLA cost analysis."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import cost_analysis
from repro.launch import hlo_analysis as H


def test_scan_flops_scale_with_trip_count():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    r = H.analyze(c.as_text())
    base = 2 * 128 ** 3
    assert 10 * base <= r["flops"] <= 11 * base


def test_loop_free_matches_xla():
    def g(a, b):
        return jnp.tanh(a @ b) @ b
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(g).lower(a, a).compile()
    r = H.analyze(c.as_text())
    xla = cost_analysis(c)
    assert abs(r["flops"] - xla["flops"]) / xla["flops"] < 0.02
    assert abs(r["bytes"] - xla["bytes accessed"]) / xla["bytes accessed"] < 0.2


def test_collectives_counted(tmp_path):
    from conftest import run_with_devices
    run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis as H
mesh = jax.make_mesh((4,), ("data",))
def f(x):
    return jax.lax.with_sharding_constraint(
        x.sum(0, keepdims=True), NamedSharding(mesh, P(None, None)))
x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=NamedSharding(mesh, P("data", None)),
                out_shardings=NamedSharding(mesh, P(None, None))).lower(x).compile()
r = H.analyze(c.as_text())
assert r["collectives"]["total"] > 0, r["collectives"]
print("collective bytes:", r["collectives"]["total"])
""", n_devices=4)
