"""Scale-out serving: column-sharded plans over a ('data','model') mesh,
replicated execution streams (replay + threaded frontend), the serving-pack
partition rules behind both, and fit_mesh."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import REPO, run_with_devices
from repro import serving
from repro.launch.mesh import fit_mesh
from repro.runtime.sharding import Rules, serving_pack_specs
from test_serving_plans import _rand_pack

# layer widths 12 / 7 / 6 on a model=2 axis: split, replicated (odd),
# split — the divisibility fallback inside one stack.
DIMS = (16, 12, 7, 6)


# ---------------------------------------------------------------- rules

def test_serving_pack_specs_column_rule_and_fallbacks():
    pack = _rand_pack(DIMS)
    rules = Rules(("data", "model"), {"data": 2, "model": 2}, None)
    specs = serving_pack_specs(pack["layers"], rules)
    # divisible widths: Megatron column split over the output features,
    # epilogue vectors follow their layer's slice
    for i in (0, 2):
        assert specs[i]["packed"] == P(None, "model")
        assert specs[i]["alpha1"] == P("model")
        assert specs[i]["bias"] == P("model")
    # width 7 does not divide by model=2: whole layer replicates
    assert specs[1]["packed"] == P(None, None)
    assert specs[1]["alpha1"] == P(None)
    assert specs[1]["bias"] == P(None)
    for s in specs:
        # omega is the shared full-precision recombination vector and
        # alpha2 a scalar: always replicated
        assert all(a is None for a in s["omega"])
        assert s["alpha2"] == P()


# ------------------------------------------------------- sharded plans

def test_sharded_plan_single_device_bit_identical():
    pack = _rand_pack(DIMS)
    ref = serving.build_plan(pack, mode="per_layer")
    shp = serving.build_plan(pack, mode="sharded", mesh=fit_mesh())
    for b in (1, 5):
        x = jnp.asarray(np.random.default_rng(b).normal(size=(b, DIMS[0])),
                        jnp.float32)
        np.testing.assert_array_equal(np.asarray(ref.run(x)),
                                      np.asarray(shp.run(x)))
    desc = shp.describe()["sharding"]
    assert desc["n_devices"] == 1


def test_sharded_plan_requires_mesh():
    with pytest.raises(ValueError, match="mesh"):
        serving.build_plan(_rand_pack(DIMS), mode="sharded")


def test_sharded_plan_multidevice_bit_identical():
    """4 fake devices, (data=2, model=2): the column-split program must be
    bit-identical to the per-layer chain — fp32 and the int8 grid — with
    the odd-width layer falling back to replication."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro import serving
from repro.core import bitplanes as bp
from repro.launch.mesh import fit_mesh

dims = (16, 12, 7, 6)
rng = np.random.default_rng(0)
layers = []
for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
    codes = rng.integers(0, 16, size=(k + (k % 2), n)).astype(np.uint8)
    if k % 2:
        codes[-1] = 0          # pack invariant: odd K pads a zero row
    layers.append({
        "packed": bp.pack_codes_rows(jnp.asarray(codes)),
        "omega": jnp.asarray(rng.normal(size=4) / np.sqrt(k), jnp.float32),
        "alpha1": jnp.asarray(rng.normal(size=n) * 0.5, jnp.float32),
        "bias": jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32),
        "alpha2": jnp.asarray(np.float32(1.0)),
        "shape": (k, n),
        "activation": "relu" if i < len(dims) - 2 else None,
    })
pack = {"layers": layers, "act_bits": None}

mesh = fit_mesh()
assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \\
    {"data": 2, "model": 2}, mesh

for extra in ({}, {"act_dtype": "int8"}):
    ref = serving.build_plan(pack, mode="per_layer", **extra)
    shp = serving.build_plan(pack, mode="sharded", mesh=mesh, **extra)
    desc = shp.describe()["sharding"]
    assert desc["n_devices"] == 4, desc
    assert 1 in desc["replicated_layers"], desc       # width 7 fallback
    for b in (1, 4, 6):
        x = jnp.asarray(np.random.default_rng(b).normal(size=(b, dims[0])),
                        jnp.float32)
        ya, yb = np.asarray(ref.run(x)), np.asarray(shp.run(x))
        assert np.array_equal(ya, yb), (extra, b, np.abs(ya - yb).max())
print("sharded-parity-ok")
""", n_devices=4)


# ----------------------------------------------------------- fit_mesh

def test_fit_mesh_shapes_and_errors():
    out = run_with_devices("""
import jax
from repro.launch.mesh import describe, fit_mesh
shapes = {n: tuple(fit_mesh(n).devices.shape) for n in (1, 2, 4, 6, 8)}
assert shapes == {1: (1, 1), 2: (2, 1), 4: (2, 2), 6: (3, 2), 8: (4, 2)}, \\
    shapes
assert tuple(fit_mesh(8, model=4).devices.shape) == (2, 4)
assert fit_mesh().devices.size == 8                 # default: all devices
assert fit_mesh(100).devices.size == 8              # capped at the host
for bad in (lambda: fit_mesh(0), lambda: fit_mesh(8, model=3)):
    try:
        bad()
    except ValueError:
        pass
    else:
        raise AssertionError("expected ValueError")
print("fit-mesh-ok")
""", n_devices=8)
    assert "fit-mesh-ok" in out


def test_fit_mesh_single_device_host():
    mesh = fit_mesh()
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \
        {"data": 1, "model": 1}


# ------------------------------------------------------ replay streams

def test_replay_n_streams_results_identical_and_not_slower():
    plan = serving.build_plan(_rand_pack(DIMS), mode="oracle")
    rng = np.random.default_rng(7)
    xs = [jnp.asarray(rng.normal(size=(1 + i % 3, DIMS[0])), jnp.float32)
          for i in range(24)]
    arrivals = np.cumsum(rng.exponential(2e-4, size=len(xs)))
    table = {b: 1e-3 * b for b in plan.bucket_sizes}
    legs = {n: serving.replay(plan, xs, arrivals, max_delay=1e-3,
                              max_bucket=4, service_times=table, n_streams=n)
            for n in (1, 2, 3)}
    for n, rep in legs.items():
        assert rep["n_streams"] == n
        assert len(rep["stream_launches"]) == n
        for a, b in zip(legs[1]["results"], rep["results"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert legs[2]["throughput_rps"] >= legs[1]["throughput_rps"] - 1e-9
    assert legs[3]["throughput_rps"] >= legs[2]["throughput_rps"] - 1e-9


def test_replay_n_streams_validates():
    plan = serving.build_plan(_rand_pack(DIMS), mode="oracle")
    with pytest.raises(ValueError, match="n_streams"):
        serving.replay(plan, [jnp.zeros((1, DIMS[0]))], [0.0], n_streams=0)


# ---------------------------------------------------- frontend streams

def test_frontend_streams_parity_and_stats():
    plan = serving.build_plan(_rand_pack(DIMS), mode="oracle")
    fe = serving.ServingFrontend(streams=2)
    assert fe.streams == 2
    fe.register("m", plan, max_delay=1e-3)
    rng = np.random.default_rng(1)
    xs = [rng.normal(size=(1 + i % 2, DIMS[0])).astype(np.float32)
          for i in range(24)]
    with fe:
        futs = [fe.submit("m", x) for x in xs]
        outs = [f.result(60.0) for f in futs]
    for x, out in zip(xs, outs):
        assert not isinstance(out, serving.Rejected), out
        assert out.stream in (0, 1)
        np.testing.assert_array_equal(out.y, np.asarray(plan.run(x)))
    st = fe.stats
    assert len(st["streams"]) == 2
    assert sum(s["launches"] for s in st["streams"]) == st["launches"]
    assert st["by_model"]["m"]["requests"] == len(xs)


def test_frontend_single_stream_has_no_stream_workers():
    fe = serving.ServingFrontend()
    assert fe.streams == 1
    plan = serving.build_plan(_rand_pack(DIMS), mode="oracle")
    fe.register("m", plan)
    with fe:
        out = fe.submit("m", np.zeros((1, DIMS[0]), np.float32)).result(30.0)
    assert out.stream == 0
    assert len(fe.stats["streams"]) == 1


def test_join_shortest_work_and_stream_quarantine():
    """Deterministic unit checks on the dispatch policy: argmin estimated
    work with index tie-break, and quarantine removing a stream from the
    active set while recording why."""
    fe = serving.ServingFrontend(streams=3)
    fe._stream_load[:] = [0.5, 0.1, 0.9]
    assert fe._assign_stream() == 1
    fe._stream_load[:] = [0.2, 0.2, 0.2]
    assert fe._assign_stream() == 0               # tie -> lowest index
    fe._quarantine_stream(0, RuntimeError("injected"))
    assert fe._assign_stream() == 1
    st = fe.stats["streams"][0]
    assert st["quarantined"] and "injected" in st["error"]
    assert fe._stream_load[0] == 0.0
    # idempotent: a second report must not double-account
    fe._quarantine_stream(0, RuntimeError("again"))
    assert "injected" in fe.stats["streams"][0]["error"]


# ------------------------------------------------------------ run.py

def test_bench_runner_rejects_unknown_only_key():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join((REPO, os.path.join(REPO, "src")))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "not_a_bench"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 2
    blob = proc.stdout + proc.stderr
    assert "not_a_bench" in blob
    assert "multi_stream" in blob                 # lists the valid keys
