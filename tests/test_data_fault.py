"""Data determinism + skip-ahead; fault-tolerant loop: checkpoint cadence,
preemption, retry, resume."""
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline, synthetic
from repro.runtime import fault


def test_lm_batches_deterministic():
    cfg = synthetic.LMDataCfg(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1 = synthetic.lm_batch(cfg, 5)
    b2 = synthetic.lm_batch(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic.lm_batch(cfg, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 100


def test_lm_stream_has_structure():
    """labels are (mostly) a deterministic function of tokens — CE can drop
    below log(V) during the example training runs."""
    cfg = synthetic.LMDataCfg(vocab=50, seq_len=64, global_batch=8, seed=0)
    b = synthetic.lm_batch(cfg, 0)
    # given token t, label is (a*t + 7 + small noise) % V: check correlation
    pred = (31337 % 50 * b["tokens"] + 7) % 50
    close = np.abs((b["labels"] - pred) % 50) <= 1
    assert close.mean() > 0.9


def test_feed_skip_ahead_matches_direct():
    cfg = synthetic.LMDataCfg(vocab=64, seq_len=8, global_batch=2, seed=1)
    feed = pipeline.ShardedFeed(lambda s: synthetic.lm_batch(cfg, s),
                                start_step=10)
    got = next(feed)
    feed.close()
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  synthetic.lm_batch(cfg, 10)["tokens"])


def _toy_step(state, batch):
    loss = jnp.sum(batch["x"]) * 0.0 + state["w"]
    return {"w": state["w"] + 1.0}, {"loss": loss}


def _batches():
    while True:
        yield {"x": jnp.ones((2,))}


def test_loop_checkpoints_and_resumes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    loop = fault.FaultTolerantLoop(_toy_step, mgr, ckpt_every=3,
                                   metrics_every=2)
    state = {"w": jnp.zeros(())}
    state, step, reason = loop.run(state, _batches(), total_steps=7)
    assert reason == "done" and step == 7
    assert mgr.latest_step() == 7
    # fresh loop resumes from 7
    state2, start = loop.resume_or({"w": jnp.zeros(())})
    assert start == 7 and float(state2["w"]) == 7.0
    state2, step2, _ = loop.run(state2, _batches(), start_step=start,
                                total_steps=10)
    assert step2 == 10 and float(state2["w"]) == 10.0


def test_loop_retries_transient_then_fails_hard(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] == 2:       # fail exactly once (transient)
            raise jax.errors.JaxRuntimeError("injected")
        return _toy_step(state, batch)

    loop = fault.FaultTolerantLoop(flaky, mgr, ckpt_every=100, max_retries=2)
    state, step, reason = loop.run({"w": jnp.zeros(())}, _batches(),
                                   total_steps=3)
    assert reason == "done" and step == 3 and float(state["w"]) == 3.0

    def always_fails(state, batch):
        raise jax.errors.JaxRuntimeError("hard")
    mgr2 = CheckpointManager(str(tmp_path / "hard"))
    loop2 = fault.FaultTolerantLoop(always_fails, mgr2, max_retries=1)
    state, step, reason = loop2.run({"w": jnp.zeros(())}, _batches(),
                                    total_steps=3)
    assert reason == "failed" and step == 0
    assert mgr2.latest_step() == 0     # state-at-failure checkpointed


def test_preemption_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))

    def slow_step(state, batch):
        time.sleep(0.02)
        return _toy_step(state, batch)

    loop = fault.FaultTolerantLoop(slow_step, mgr, ckpt_every=10**6)
    killer = threading.Timer(0.15, lambda: os.kill(os.getpid(), signal.SIGTERM))
    killer.start()
    state, step, reason = loop.run({"w": jnp.zeros(())}, _batches(),
                                   total_steps=10**6)
    assert reason == "preempted"
    assert mgr.latest_step() == step
