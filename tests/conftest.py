import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _isolate_autotune_cache(tmp_path, monkeypatch):
    """Keep every test's block-autotuner resolution away from the user's
    persistent ~/.cache JSON (kernel paths consult it implicitly)."""
    from repro.kernels import autotune
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "autotune.json"))
    autotune.clear_memory_cache()
    yield
    autotune.clear_memory_cache()


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake host devices.

    Multi-device tests must not pollute this process's jax device state
    (smoke tests and benches see 1 device, per the assignment).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout
