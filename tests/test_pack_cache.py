"""PackCache: cold-tier roundtrip, lazy resolve, LRU budgets, evict →
reload bit-identity, and the plan-memo coordination regression."""
import threading

import numpy as np
import pytest

from repro.serving import pack_cache as pc
from repro.serving.plans import _PLAN_MEMO, build_plan, get_plan
from test_serving_plans import _rand_pack

DIMS = (16, 12, 4)


def _pack(seed=0, dims=DIMS):
    return _rand_pack(dims, seed=seed)


# ----------------------------------------------------------- cold form

def test_compress_decode_roundtrip_is_exact():
    pack = _pack()
    cold = pc.compress_pack(pack)
    assert cold.size_bytes < cold.fp32_bytes
    assert cold.d_in == DIMS[0] and cold.d_out == DIMS[-1]
    back = pc.decode_pack(cold)
    assert len(back["layers"]) == len(pack["layers"])
    for l1, l2 in zip(pack["layers"], back["layers"]):
        np.testing.assert_array_equal(np.asarray(l1["packed"]),
                                      np.asarray(l2["packed"]))
        for key in ("omega", "alpha1", "bias", "alpha2"):
            np.testing.assert_array_equal(np.asarray(l1[key]),
                                          np.asarray(l2[key]))
        assert tuple(l1["shape"]) == tuple(l2["shape"])
        assert l1["activation"] == l2["activation"]


def test_roundtrip_exact_with_odd_contraction_dim():
    pack = _pack(dims=(33, 7, 5))     # odd k: pad row must strip/re-pad
    back = pc.decode_pack(pc.compress_pack(pack))
    for l1, l2 in zip(pack["layers"], back["layers"]):
        np.testing.assert_array_equal(np.asarray(l1["packed"]),
                                      np.asarray(l2["packed"]))


def test_payload_serialization_roundtrip():
    cold = pc.compress_pack(_pack(seed=5))
    payload = pc.cold_pack_to_payload(cold)
    back = pc.cold_pack_from_payload(payload)
    assert back.shapes == cold.shapes
    assert back.act_bits == cold.act_bits
    for l1, l2 in zip(cold.layers, back.layers):
        assert l1.codes.format == l2.codes.format
        assert l1.activation == l2.activation
        np.testing.assert_array_equal(pc.formats.decode(l1.codes),
                                      pc.formats.decode(l2.codes))


# ------------------------------------------------------------ laziness

def test_add_is_lazy_and_first_traffic_resolves():
    cache = pc.PackCache(max_hot=4)
    proxy = cache.add("m", _pack())
    assert not cache.has_hot("m")
    assert cache.stats["resolves"] == 0
    assert proxy.d_in == DIMS[0] and proxy.bucket_sizes  # static, no decode
    assert not cache.has_hot("m")
    x = np.ones((2, DIMS[0]), np.float32)
    y = proxy.run(x)
    assert cache.has_hot("m")
    assert cache.stats["resolves"] == 1
    ref = build_plan(_pack()).run(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_lru_count_budget_high_water_never_exceeded():
    cache = pc.PackCache(max_hot=2)
    x = np.ones((1, DIMS[0]), np.float32)
    for i in range(5):
        cache.add(f"m{i}", _pack(seed=i)).run(x)
        assert len(cache.hot_ids()) <= 2
    # evict-before-resolve: at no point were 3 plans resident, so the
    # high-water mark equals the steady 2-plan footprint (identical dims
    # ⇒ identical per-plan bytes)
    assert cache.stats["evictions"] == 3
    assert cache.stats["resident_high_water"] == \
        cache.stats["resident_bytes"]
    assert cache.hot_ids() == ["m3", "m4"]        # LRU → MRU


def test_lru_touch_order_protects_hot_model():
    cache = pc.PackCache(max_hot=2)
    x = np.ones((1, DIMS[0]), np.float32)
    a, b = cache.add("a", _pack(seed=1)), cache.add("b", _pack(seed=2))
    a.run(x)
    b.run(x)
    a.run(x)                      # touch a: b becomes LRU
    cache.add("c", _pack(seed=3)).run(x)
    assert cache.has_hot("a") and cache.has_hot("c")
    assert not cache.has_hot("b")


def test_byte_budget_evicts_down():
    cache = pc.PackCache()
    x = np.ones((1, DIMS[0]), np.float32)
    cache.add("a", _pack(seed=1)).run(x)
    one_plan = cache.stats["resident_bytes"]
    cache.hot_bytes = int(one_plan * 1.5)     # room for one, not two
    cache.add("b", _pack(seed=2)).run(x)
    assert cache.hot_ids() == ["b"]
    assert cache.stats["resident_bytes"] <= cache.hot_bytes


def test_evict_reload_bit_identical_int8():
    """The acceptance-criteria parity: evict → reload on the int8 grid
    returns the exact same bytes (lossless codecs + captured act_scales
    + deterministic resolution)."""
    cache = pc.PackCache(max_hot=1, plan_kwargs={"act_dtype": "int8"})
    proxy = cache.add("m", _pack())
    rng = np.random.default_rng(7)
    x = rng.normal(size=(3, DIMS[0])).astype(np.float32)
    y1 = np.asarray(proxy.run(x))
    scales1 = list(proxy.act_scales)
    assert cache.evict("m")
    assert not cache.has_hot("m")
    y2 = np.asarray(proxy.run(x))
    np.testing.assert_array_equal(y1, y2)
    assert list(proxy.act_scales) == scales1      # calib survived eviction


def test_update_hot_swaps_without_breaking_handles():
    cache = pc.PackCache()
    proxy = cache.add("m", _pack(seed=1))
    x = np.ones((2, DIMS[0]), np.float32)
    y_old = np.asarray(proxy.run(x))
    new_pack = _pack(seed=9)
    cache.update("m", new_pack)
    assert not cache.has_hot("m")                 # stale plan evicted
    y_new = np.asarray(proxy.run(x))              # same handle, new weights
    ref = np.asarray(build_plan(_pack(seed=9)).run(x))
    np.testing.assert_allclose(y_new, ref, atol=1e-5, rtol=1e-5)
    assert not np.array_equal(y_old, y_new)
    assert cache.stats["updates"] == 1


def test_unknown_model_raises_keyerror():
    cache = pc.PackCache()
    with pytest.raises(KeyError, match="nope"):
        cache.plan("nope")
    with pytest.raises(ValueError, match="max_hot"):
        pc.PackCache(max_hot=0)
    cache.add("m", _pack())
    with pytest.raises(ValueError, match="already cached"):
        cache.add("m", _pack())


# ----------------------------------------------- plan-memo coordination

def test_get_plan_returns_cache_managed_plan_not_duplicate():
    """Regression (satellite 2): a compat-path get_plan on a
    cache-managed pack must hit the adopted entry, not silently
    re-resolve a duplicate beside it."""
    cache = pc.PackCache()
    proxy = cache.add("m", _pack())
    plan = proxy.resolve()
    assert get_plan(plan.pack) is plan


def test_adopted_plan_survives_memo_churn_and_dies_on_evict():
    """Pinned entries are exempt from the memo's insertion-order
    eviction (the pre-fix bug: 32 unrelated get_plan calls dropped a
    plan a frontend still served), and are released by cache eviction —
    the memo can neither duplicate nor outlive a cache-managed plan."""
    cache = pc.PackCache()
    proxy = cache.add("m", _pack())
    plan = proxy.resolve()
    for i in range(_PLAN_MEMO.max_entries + 5):   # churn the memo hard
        get_plan(_pack(seed=100 + i), mode="oracle")
    assert get_plan(plan.pack) is plan            # pin held
    cache.evict("m")
    held = [key for key, (objs, _) in _PLAN_MEMO._entries.items()
            if any(o is plan.pack for o in objs)]
    assert held == []                             # released, not leaked
    plan2 = proxy.resolve()                       # fresh resolve works
    assert plan2 is not plan


def test_forget_plan_releases_operand_memos():
    from repro.kernels import ops as kops
    cache = pc.PackCache(plan_kwargs={"act_dtype": "int8"})
    proxy = cache.add("m", _pack())
    x = np.ones((2, DIMS[0]), np.float32)
    proxy.run(x)
    plan = proxy.resolve()
    layers = plan.layers
    # the operand memos may or may not be populated depending on the
    # resolved mode; the contract is that *after* eviction nothing keyed
    # on this pack's layer list remains
    cache.evict("m")
    for memo in (kops._INT8_FOLD_MEMO, kops._WS_OPERAND_MEMO):
        leaked = [key for key, (objs, _) in memo._entries.items()
                  if any(o is layers for o in objs)]
        assert leaked == []


# --------------------------------------------------------- concurrency

def test_racing_resolve_and_evict_never_fails():
    """Requests racing eviction of the same model must either hit the
    hot plan or re-resolve — never a KeyError or a wrong result."""
    cache = pc.PackCache(max_hot=2)
    proxies = [cache.add(f"m{i}", _pack(seed=i)) for i in range(4)]
    x = np.ones((1, DIMS[0]), np.float32)
    refs = [np.asarray(build_plan(_pack(seed=i)).run(x)) for i in range(4)]
    errors = []
    stop = threading.Event()

    def hammer(i):
        try:
            while not stop.is_set():
                y = np.asarray(proxies[i].run(x))
                np.testing.assert_allclose(y, refs[i], atol=1e-5,
                                           rtol=1e-5)
        except Exception as exc:                   # noqa: BLE001
            errors.append(exc)

    def churner():
        try:
            while not stop.is_set():
                for i in range(4):
                    cache.evict(f"m{i}")
        except Exception as exc:                   # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    threads.append(threading.Thread(target=churner))
    for t in threads:
        t.start()
    stop_timer = threading.Timer(1.5, stop.set)
    stop_timer.start()
    for t in threads:
        t.join(30.0)
    stop_timer.cancel()
    assert errors == []
    assert cache.stats["evictions"] > 0           # the race actually ran
