"""Chunked online-softmax vs dense reference; MLA forms; SWA ring cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as A
from repro.nn.layers import rope_cos_sin
from repro.nn.module import FP32_CTX


def _qkv(seed, b, sq, skv, h, g, d):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, g, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, g, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,sq,skv,h,g,d", [
    (1, 8, 8, 4, 4, 16), (2, 16, 16, 8, 2, 8), (2, 7, 13, 6, 3, 4),
    (1, 33, 65, 4, 1, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("chunk", [4, 7, 1024])
def test_chunked_matches_dense(b, sq, skv, h, g, d, causal, window, chunk):
    if causal and sq != skv:
        pytest.skip("causal needs aligned positions here")
    q, k, v = _qkv(b * sq + h, b, sq, skv, h, g, d)
    qp = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    kp = jnp.broadcast_to(jnp.arange(skv), (b, skv))
    out = A.softmax_attention(q, k, v, qp, kp, causal=causal, window=window,
                              chunk=chunk)
    ref = A.dense_attention_ref(q, k, v, qp, kp, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_fully_masked_rows_are_finite():
    q, k, v = _qkv(0, 1, 4, 4, 2, 2, 8)
    qp = jnp.zeros((1, 4), jnp.int32)          # all queries at position 0
    kp = jnp.broadcast_to(jnp.arange(4) + 10, (1, 4))  # keys all "future"
    out = A.softmax_attention(q, k, v, qp, kp, causal=True, chunk=2)
    assert np.all(np.isfinite(np.asarray(out)))


def test_mla_absorbed_equals_naive():
    cfg = A.MLACfg(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                   qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8)
    key = jax.random.PRNGKey(0)
    p = A.mla_init(key, cfg, quantize=False)
    x = jax.random.normal(key, (2, 1, 64))
    pos = jnp.zeros((2, 1), jnp.int32)
    cs = rope_cos_sin(pos, cfg.qk_rope_dim, 1e4)
    cache = A.init_mla_cache(2, 8, cfg, jnp.float32)
    y1, _ = A.mla_apply(p, 0, x, FP32_CTX, cfg, cos_sin=cs, positions=pos,
                        cache=cache, force_absorbed=True)
    y2, _ = A.mla_apply(p, 0, x, FP32_CTX, cfg, cos_sin=cs, positions=pos,
                        cache=cache, force_absorbed=False)
    np.testing.assert_allclose(y1, y2, atol=1e-5)


def test_swa_ring_buffer_decode():
    """A window-sized ring cache must reproduce full-cache SWA decoding."""
    d_model, nh, nkv, hd, W = 32, 4, 2, 8, 4
    key = jax.random.PRNGKey(1)
    p = A.gqa_init(key, d_model, nh, nkv, hd, False)
    S = 12
    x = jax.random.normal(key, (1, S, d_model))
    pos = jnp.arange(S)[None, :]
    cs = rope_cos_sin(pos, hd, 1e4)

    def decode_all(cache_size):
        cache = A.init_kv_cache(1, cache_size, nkv, hd, jnp.float32)
        outs = []
        for t in range(S):
            y, cache = A.gqa_apply(
                p, 0, x[:, t:t+1], FP32_CTX, n_heads=nh, n_kv=nkv,
                head_dim=hd, cos_sin=(cs[0][:, t:t+1], cs[1][:, t:t+1]),
                positions=pos[:, t:t+1], window=W, cache=cache)
            outs.append(y)
        return jnp.concatenate(outs, 1)

    np.testing.assert_allclose(decode_all(W), decode_all(S), atol=1e-5)
