"""Degenerate-input hardening for the cold tier's codecs (no hypothesis:
these are the exact edges a many-model cold tier hits — pruned-to-zero
layers, single-cluster layers, zero-width shards).

Regression anchors (all crashed before this sweep):
* ``_canonical_codes`` indexed ``order[0]`` with no symbols present, so
  an empty tensor crashed both ``encode_huffman`` and ``decode_huffman``
  with IndexError;
* ``encode_csr`` reshaped a zero-size array with ``reshape(0, -1)``
  (ValueError) and ``decode_csr`` divided by zero rows;
* ``analytic_size_bits`` — and through it ``select_format`` /
  ``encode_best`` — divided by zero on zero-row shapes."""
import numpy as np
import pytest

from repro.core import formats

EDGES = {
    "empty": np.zeros((0, 0), np.uint8),
    "empty_rows": np.zeros((0, 7), np.uint8),
    "all_zero": np.zeros((6, 9), np.uint8),
    "single_symbol": np.full((5, 8), 11, np.uint8),
    "single_element": np.array([[3]], np.uint8),
    "single_zero": np.zeros((1, 1), np.uint8),
    "two_symbols": np.tile(np.array([[0, 15]], np.uint8), (4, 4)),
}


@pytest.mark.parametrize("fmt", formats.FORMATS_EXT)
@pytest.mark.parametrize("name", sorted(EDGES))
def test_every_format_roundtrips_degenerate_inputs(fmt, name):
    codes = EDGES[name]
    ct = formats.encode(codes, fmt)
    assert ct.format == fmt
    assert ct.size_bytes >= 0          # size_bytes must not crash either
    out = formats.decode(ct)
    assert out.dtype == np.uint8
    np.testing.assert_array_equal(out.reshape(codes.shape), codes)


@pytest.mark.parametrize("name", sorted(EDGES))
def test_encode_best_and_ext_selection_roundtrip(name):
    codes = EDGES[name]
    best = formats.encode_best(codes)
    np.testing.assert_array_equal(
        formats.decode(best).reshape(codes.shape), codes)
    fmt_ext = formats.select_format_ext(codes)
    assert fmt_ext in formats.FORMATS_EXT
    ct = formats.encode(codes, fmt_ext)
    np.testing.assert_array_equal(
        formats.decode(ct).reshape(codes.shape), codes)


def test_huffman_single_symbol_uses_one_bit_codes():
    """One distinct symbol still needs length-1 codes (zero-length codes
    would make decode ambiguous); the payload must reflect that."""
    codes = np.full((4, 4), 7, np.uint8)
    ct = formats.encode_huffman(codes)
    assert int(ct.payload["nbits"][0]) == codes.size
    np.testing.assert_array_equal(formats.decode_huffman(ct), codes)


def test_huffman_empty_has_no_bits():
    ct = formats.encode_huffman(np.zeros((0, 3), np.uint8))
    assert int(ct.payload["nbits"][0]) == 0
    assert formats.decode_huffman(ct).size == 0


def test_analytic_sizes_finite_on_edges():
    for codes in EDGES.values():
        nnz = int(np.count_nonzero(codes))
        for fmt in formats.FORMATS:
            assert formats.analytic_size_bits(codes.shape, nnz, fmt) >= 0
        assert formats.analytic_size_bits_huffman(codes) >= 0
