"""Mamba2 SSD: chunked == naive recurrence; sequence == stepwise decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.nn import ssm
from repro.nn.module import FP32_CTX


def _naive(x, a, B, C, s0=None):
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, 2)
    Ch = jnp.repeat(C, rep, 2)
    st_ = jnp.zeros((b, h, p, n)) if s0 is None else s0
    ys = []
    for t in range(s):
        st_ = st_ * jnp.exp(a[:, t])[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x[:, t], Bh[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", st_, Ch[:, t]))
    return jnp.stack(ys, 1), st_


@given(st.integers(0, 100), st.integers(1, 3), st.integers(1, 20),
       st.sampled_from([2, 4, 8]), st.booleans())
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_equals_naive(seed, b, s, chunk, with_init):
    rng = np.random.default_rng(seed)
    h, p, g, n = 4, 3, 2, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    a = -jnp.asarray(np.abs(rng.normal(size=(b, s, h))), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    s0 = (jnp.asarray(rng.normal(size=(b, h, p, n)), jnp.float32)
          if with_init else None)
    y1, f1 = ssm.ssd_chunked(x, a, B, C, chunk, init_state=s0)
    y2, f2 = _naive(x, a, B, C, s0)
    np.testing.assert_allclose(y1, y2, atol=1e-4)
    np.testing.assert_allclose(f1, f2, atol=1e-4)


def test_block_sequence_equals_decode():
    cfg = ssm.SSMCfg(d_model=16, d_inner=32, n_heads=4, d_state=8,
                     n_groups=2, chunk=4)
    key = jax.random.PRNGKey(0)
    p = ssm.ssm_init(key, cfg, quantize=False)
    u = jax.random.normal(key, (2, 11, 16))
    yseq, fstate = ssm.ssm_apply(p, 0, u, FP32_CTX, cfg)
    stt = ssm.init_ssm_state(2, cfg)
    ys = []
    for t in range(11):
        yt, stt = ssm.ssm_step(p, 0, u[:, t:t+1], FP32_CTX, cfg, stt)
        ys.append(yt)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), yseq, atol=1e-4)
    np.testing.assert_allclose(stt["ssm"], fstate["ssm"], atol=1e-4)


def test_prefill_continuation():
    """apply(first half) state feeds apply(second half) == apply(all)."""
    cfg = ssm.SSMCfg(d_model=8, d_inner=16, n_heads=2, d_state=4, chunk=4)
    key = jax.random.PRNGKey(1)
    p = ssm.ssm_init(key, cfg, quantize=False)
    u = jax.random.normal(key, (1, 10, 8))
    full, _ = ssm.ssm_apply(p, 0, u, FP32_CTX, cfg)
    y1, st1 = ssm.ssm_apply(p, 0, u[:, :6], FP32_CTX, cfg)
    y2, _ = ssm.ssm_apply(p, 0, u[:, 6:], FP32_CTX, cfg, state=st1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full, atol=1e-4)
