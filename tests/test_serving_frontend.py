"""ServingFrontend: the threaded real-clock driver + multi-model
scheduling — correctness vs the plan, full-tile fast path, deadline
fairness under sustained cross-model load, the asyncio face, and the
registry's error contract."""
import asyncio
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from test_serving_plans import _rand_pack

DIMS_A = (16, 12, 4)
DIMS_B = (16, 8, 6)


def _oracle_plan(dims, seed=0):
    return serving.build_plan(_rand_pack(dims, seed=seed), mode="oracle")


def test_frontend_serves_correct_results_per_model():
    plan_a, plan_b = _oracle_plan(DIMS_A), _oracle_plan(DIMS_B, seed=3)
    fe = serving.ServingFrontend()
    fe.register("a", plan_a)
    fe.register("b", plan_b)
    rng = np.random.default_rng(0)
    reqs = [("a" if i % 3 else "b",
             rng.normal(size=(1 + i % 2, 16)).astype(np.float32))
            for i in range(12)]
    with fe:
        futs = [(mid, x, fe.submit(mid, x)) for mid, x in reqs]
        served = [(mid, x, f.result(30.0)) for mid, x, f in futs]
    for mid, x, s in served:
        ref = (plan_a if mid == "a" else plan_b).run(x)
        np.testing.assert_allclose(s.y, np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)
        assert s.model_id == mid
        assert s.latency >= 0
    assert fe.stats["by_model"]["a"]["requests"] == 8
    assert fe.stats["by_model"]["b"]["requests"] == 4


def test_full_tile_fast_path_ignores_deadline():
    """A full tile launches immediately even when no deadline is close —
    the driver must not sleep max_delay out on a burst."""
    plan = _oracle_plan(DIMS_A)
    fe = serving.ServingFrontend()
    fe.register("m", plan, max_delay=30.0,
                max_bucket=max(plan.bucket_sizes))
    top = max(plan.bucket_sizes)
    with fe:
        t0 = time.monotonic()
        futs = [fe.submit("m", np.zeros((1, 16), np.float32))
                for _ in range(top)]
        for f in futs:
            f.result(10.0)
        assert time.monotonic() - t0 < 10.0   # not the 30 s deadline
    assert fe.stats["by_model"]["m"]["launches"] >= 1


def test_multi_model_fairness_under_sustained_load():
    """One model under sustained load must not starve the other: the
    trickle model's deadline beats every backlogged request that arrived
    after it (deadline-FIFO across models)."""
    plan_a, plan_b = _oracle_plan(DIMS_A), _oracle_plan(DIMS_B, seed=3)
    fe = serving.ServingFrontend()
    fe.register("busy", plan_a, max_delay=2e-3, max_bucket=16)
    fe.register("quiet", plan_b, max_delay=2e-3)
    stop = threading.Event()
    busy_futs = []

    def hammer():
        while not stop.is_set():
            busy_futs.append(
                fe.submit("busy", np.zeros((1, 16), np.float32)))
            time.sleep(0.001)

    with fe:
        t = threading.Thread(target=hammer)
        t.start()
        try:
            time.sleep(0.2)                 # backlog + steady stream
            quiet_lat = []
            for _ in range(3):
                s = fe.submit(
                    "quiet", np.zeros((1, 16), np.float32)).result(30.0)
                quiet_lat.append(s.latency)
                time.sleep(0.05)
        finally:
            stop.set()
            t.join()
        last_busy = busy_futs[-1].result(30.0)
    # the quiet model was served *while* the busy stream kept landing...
    assert fe.stats["by_model"]["busy"]["requests"] > 50
    assert last_busy.finish > 0
    # ...and never waited anywhere near the busy backlog's drain time.
    assert max(quiet_lat) < 5.0
    assert fe.stats["by_model"]["quiet"]["launches"] == 3


def test_asyncio_face_serves_concurrent_awaits():
    plan = _oracle_plan(DIMS_A)
    fe = serving.ServingFrontend()
    fe.register("m", plan)
    rng = np.random.default_rng(5)
    xs = [rng.normal(size=(1, 16)).astype(np.float32) for _ in range(6)]

    async def go():
        with fe:
            return await asyncio.gather(
                *[fe.asubmit("m", x) for x in xs])

    served = asyncio.run(go())
    for x, s in zip(xs, served):
        np.testing.assert_allclose(s.y, np.asarray(plan.run(x)),
                                   atol=1e-4, rtol=1e-4)


def test_registry_and_lifecycle_errors():
    plan = _oracle_plan(DIMS_A)
    fe = serving.ServingFrontend()
    fe.register("m", plan)
    with pytest.raises(ValueError):
        fe.register("m", plan)              # duplicate id
    with pytest.raises(KeyError):
        fe.submit("nope", np.zeros((1, 16), np.float32))
    with pytest.raises(RuntimeError):
        fe.submit("m", np.zeros((1, 16), np.float32))   # not started
    assert "m" in fe.registry and len(fe.registry) == 1


class BoomPlan:
    """Plan proxy whose every launch raises — systematic model failure."""

    def __init__(self, plan):
        self._plan = plan

    def __getattr__(self, name):
        return getattr(self._plan, name)

    def entry(self, bucket):
        def boom(xb):
            raise ValueError("kernel exploded")
        return boom

    def run(self, x):
        raise ValueError("kernel exploded")


def test_dispatch_error_fails_futures_loudly():
    """A systematically failing launch must not hang its futures NOR kill
    the stream: after the retry ladder the model is quarantined — its
    futures carry the root cause, new submits to it resolve with a typed
    Rejected, and co-registered models keep serving."""
    plan_b = _oracle_plan(DIMS_B, seed=3)
    fe = serving.ServingFrontend()
    fe.register("m", BoomPlan(_oracle_plan(DIMS_A)))
    fe.register("ok", plan_b)
    with fe:
        fut = fe.submit("m", np.zeros((1, 16), np.float32))
        with pytest.raises(ValueError, match="kernel exploded"):
            fut.result(30.0)
        rejected = fe.submit("m", np.zeros((1, 16), np.float32))
        with pytest.raises(serving.Rejected, match="quarantined"):
            rejected.result(30.0)
        # the stream survives: the healthy model still serves.
        s = fe.submit("ok", np.zeros((1, 16), np.float32)).result(30.0)
        assert s.y.shape == (1, DIMS_B[-1])
    assert fe.stats["quarantined"] == ["m"]
    assert fe.stats["by_model"]["m"]["quarantined"] is True
    assert fe.stats["by_model"]["m"]["retries"] >= 1


def test_legacy_fatal_contract_without_retry_policy():
    """retry_policy=None restores the pre-ladder contract: first launch
    failure is stream-fatal, outstanding futures fail, submits refuse."""
    fe = serving.ServingFrontend(retry_policy=None)
    fe.register("m", BoomPlan(_oracle_plan(DIMS_A)))
    with fe:
        fut = fe.submit("m", np.zeros((1, 16), np.float32))
        with pytest.raises(ValueError, match="kernel exploded"):
            fut.result(30.0)
        with pytest.raises(RuntimeError, match="dispatch thread died"):
            fe.submit("m", np.zeros((1, 16), np.float32))


def test_asubmit_receives_root_cause_when_stream_dies():
    """An asubmit caller awaiting while the dispatch stream dies must
    receive the root-cause exception, not hang until timeout — the async
    twin of the sync-future contract pinned above.  The stream is killed
    through the dispatch machinery itself (a scheduler bug, not a launch
    failure), which stays stream-fatal even with the retry ladder on."""
    plan = _oracle_plan(DIMS_A)
    fe = serving.ServingFrontend()
    fe.register("m", plan, max_delay=0.05)

    def boom_pick(now):
        raise RuntimeError("scheduler bug")

    async def go():
        with fe:
            fe._pick = boom_pick          # dispatch machinery, not launch
            return await fe.asubmit("m", np.zeros((1, 16), np.float32))

    with pytest.raises(RuntimeError, match="scheduler bug"):
        asyncio.run(go())
    assert isinstance(fe._error, RuntimeError)


def test_registry_registration_path_is_equivalent():
    """Registering straight through frontend.registry (documented legal,
    including while running) must serve like frontend.register."""
    fe = serving.ServingFrontend()
    batcher = fe.registry.register("m", _oracle_plan(DIMS_A))
    with fe:
        s = fe.submit("m", np.zeros((1, 16), np.float32)).result(30.0)
    assert s.y.shape == (1, DIMS_A[-1])
    assert fe.stats["by_model"]["m"]["requests"] == 1
    assert not batcher._results       # registry default: no retention


def test_frontend_batchers_do_not_retain_results():
    """The frontend resolves futures from run_one's return value; the
    batcher must not ALSO hold every output forever (server leak)."""
    fe = serving.ServingFrontend()
    batcher = fe.register("m", _oracle_plan(DIMS_A))
    with fe:
        fe.submit("m", np.zeros((1, 16), np.float32)).result(30.0)
    assert not batcher._results


def test_close_drains_queued_requests():
    plan = _oracle_plan(DIMS_A)
    fe = serving.ServingFrontend()
    fe.register("m", plan, max_delay=30.0)  # nothing would be due
    fe.start()
    futs = [fe.submit("m", np.zeros((1, 16), np.float32))
            for _ in range(3)]
    fe.close(drain=True)
    for f in futs:
        assert f.result(0.0).y.shape == (1, DIMS_A[-1])


# ------------------------------------------------- lifecycle: unregister

def test_unregister_fails_outstanding_futures_with_typed_cause():
    """Satellite bugfix: a retired model's queued futures must resolve
    promptly with Rejected("unregistered"), its registry entry must go
    away (new submits are unknown-model KeyErrors), and other models
    keep serving."""
    fe = serving.ServingFrontend()
    fe.register("m", _oracle_plan(DIMS_A), max_delay=30.0)  # sits queued
    fe.register("other", _oracle_plan(DIMS_B, seed=3))
    with fe:
        futs = [fe.submit("m", np.zeros((1, 16), np.float32))
                for _ in range(3)]
        fe.unregister("m")
        for f in futs:
            with pytest.raises(serving.Rejected, match="unregistered"):
                f.result(10.0)
        assert "m" not in fe.registry
        with pytest.raises(KeyError):
            fe.submit("m", np.zeros((1, 16), np.float32))
        with pytest.raises(KeyError):
            fe.unregister("m")                    # idempotence is loud
        s = fe.submit("other", np.zeros((1, 16), np.float32)).result(30.0)
        assert s.model_id == "other"


def test_unregister_releases_plan_memo_entries():
    from repro.serving.plans import _PLAN_MEMO, get_plan

    plan = _oracle_plan(DIMS_A)
    get_plan(plan.pack)       # simulate a compat-path entry on this pack
    fe = serving.ServingFrontend()
    fe.register("m", plan)
    fe.start()
    fe.unregister("m")
    fe.close()
    held = [key for key, (objs, _) in _PLAN_MEMO._entries.items()
            if any(o is plan.pack for o in objs)]
    assert held == []


def test_quarantine_unregisters_but_keeps_typed_rejection():
    """Quarantine now retires the model through unregister() (no more
    process-lifetime plan leak) while the submit contract is unchanged:
    the typed 'quarantined' rejection, not 'unknown model'."""
    fe = serving.ServingFrontend(
        retry_policy=serving.RetryPolicy(max_retries=0, fallback=False))
    fe.register("m", BoomPlan(_oracle_plan(DIMS_A)))
    with fe:
        fut = fe.submit("m", np.zeros((1, 16), np.float32))
        with pytest.raises(ValueError, match="kernel exploded"):
            fut.result(30.0)                      # root cause, not generic
        assert "m" not in fe.registry             # actually retired
        rej = fe.submit("m", np.zeros((1, 16), np.float32))
        with pytest.raises(serving.Rejected, match="quarantined"):
            rej.result(10.0)
        # a fresh registration under the same id is a NEW model: it serves
        fe.register("m", _oracle_plan(DIMS_A))
        s = fe.submit("m", np.zeros((1, 16), np.float32)).result(30.0)
        assert s.y.shape == (1, DIMS_A[-1])


# ------------------------------------- pack-cache churn under the driver

class _FakeClock:
    """Deterministically auto-advancing clock: every read moves time
    forward, so deadlines fire from clock *reads* instead of wall sleeps
    — churn stress runs at CPU speed."""

    def __init__(self, step=1e-3):
        self._t = 0.0
        self._step = step
        self._lock = threading.Lock()

    def __call__(self):
        with self._lock:
            self._t += self._step
            return self._t


def test_cache_churn_race_never_drops_requests():
    """Eviction-correctness under concurrency (satellite 5): submits
    racing eviction of the same models must either hit the hot plan or
    trigger a re-resolve — never a dropped request, a KeyError, or a
    wrong answer."""
    n_models, n_reqs = 4, 48
    cache = serving.PackCache(max_hot=2)
    fe = serving.ServingFrontend(clock=_FakeClock(), cache=cache)
    refs = {}
    for i in range(n_models):
        pack = _rand_pack(DIMS_A, seed=i)
        fe.register_pack(f"m{i}", pack, plan_kwargs={"mode": "oracle"})
        x_i = np.full((1, 16), float(i + 1), np.float32)
        refs[f"m{i}"] = (x_i, np.asarray(
            serving.build_plan(_rand_pack(DIMS_A, seed=i),
                               mode="oracle").run(x_i)))
    stop = threading.Event()
    churn_errors = []

    def churner():
        try:
            while not stop.is_set():
                for i in range(n_models):
                    cache.evict(f"m{i}")
        except Exception as exc:                   # noqa: BLE001
            churn_errors.append(exc)

    t = threading.Thread(target=churner)
    t.start()
    try:
        with fe:
            futs = []
            for r in range(n_reqs):
                mid = f"m{r % n_models}"
                futs.append((mid, fe.submit(mid, refs[mid][0])))
            for mid, f in futs:
                s = f.result(60.0)                # never dropped/hung
                np.testing.assert_allclose(s.y, refs[mid][1],
                                           atol=1e-5, rtol=1e-5)
    finally:
        stop.set()
        t.join(30.0)
    assert churn_errors == []
    assert cache.stats["evictions"] > 0           # the race actually ran
    assert cache.stats["resolves"] > n_models     # re-resolves happened
