"""EC4T parameterisation: STE identity, eq.(2) centroid grads, state EMA."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes as bp, ecl, qat


def test_ste_passes_master_grads_through():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    omega = bp.init_omega_from_weights(w)
    probs = jnp.full((16,), 1 / 16, jnp.float32)
    u = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    g = jax.grad(lambda w: jnp.sum(qat.fake_quant(w, omega, probs, 0.01) * u))(w)
    np.testing.assert_allclose(g, u, atol=1e-6)     # straight-through


def test_omega_grad_matches_eq2():
    """dL/d omega_i == sum_j dL/dW_j * B_i[j] (paper eq. 2)."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    omega = bp.init_omega_from_weights(w)
    probs = jnp.full((16,), 1 / 16, jnp.float32)
    u = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    lam = 0.03
    g = jax.grad(lambda om: jnp.sum(qat.fake_quant(w, om, probs, lam) * u),
                 )(omega)
    codes = ecl.assign(w, omega, probs, lam)
    for i in range(4):
        bi = ((codes >> i) & 1).astype(jnp.float32)
        np.testing.assert_allclose(g[i], jnp.sum(u * bi), rtol=1e-4)


def test_fake_quant_output_in_codebook():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(40, 24)), jnp.float32)
    omega = bp.init_omega_from_weights(w)
    probs = jnp.full((16,), 1 / 16, jnp.float32)
    wq = qat.fake_quant(w, omega, probs, 0.02)
    book = np.asarray(bp.codebook(omega))
    dists = np.abs(np.asarray(wq)[..., None] - book).min(-1)
    assert dists.max() < 1e-5


def test_build_update_qstate_tree():
    rng = np.random.default_rng(3)
    params = {
        "a": qat.make_quant_param(jnp.asarray(rng.normal(size=(3, 8, 4)),
                                              jnp.float32)),
        "norm": jnp.ones((7,), jnp.float32),
    }
    qs = qat.build_qstate(params)
    assert qs["a"]["probs"].shape == (3, 16)
    assert qs["norm"].shape == (7,)             # lead-dim placeholder
    qs2 = qat.update_qstate(params, qs, lam=0.05, momentum=0.5)
    s = np.asarray(qs2["a"]["probs"]).sum(-1)
    np.testing.assert_allclose(s, 1.0, rtol=1e-5)
    st = qat.stats(params, qs2, 0.05)
    assert 0 <= float(st["sparsity"]) <= 1
    assert 0 <= float(st["entropy_bits_per_weight"]) <= 4.0


def test_freeze_tree_decode_matches_assign():
    rng = np.random.default_rng(4)
    params = {"lin": qat.make_quant_param(
        jnp.asarray(rng.normal(size=(16, 8)), jnp.float32))}
    qs = qat.build_qstate(params)
    frozen = qat.freeze_tree(params, qs, 0.02)
    codes = ecl.assign(params["lin"]["w"], params["lin"]["omega"],
                       qs["lin"]["probs"], 0.02)
    np.testing.assert_allclose(
        qat.decode_frozen(frozen["lin"]),
        bp.decode(codes, params["lin"]["omega"]), rtol=1e-6)
