"""MoE routing: dense-dispatch vs per-token reference; EP == dense
(subprocess, 8 devices); capacity dropping semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import moe
from repro.nn.layers import swiglu
from repro.nn.module import FP32_CTX
from conftest import run_with_devices


def _dense_ref(p, x, k, gate="softmax", scaling=1.0):
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]["w"]
    ids, w, _ = moe.route(logits, p["router"]["bias_correction"],
                          top_k=k, gate=gate, routed_scaling=scaling)
    out = jnp.zeros_like(xt)
    for i in range(xt.shape[0]):
        for j in range(k):
            e = ids[i, j]
            g = xt[i] @ p["experts"]["gate"][e]
            u = xt[i] @ p["experts"]["up"][e]
            out = out.at[i].add(w[i, j] * ((jax.nn.silu(g) * u)
                                           @ p["experts"]["down"][e]))
    if "shared" in p:
        out = out + swiglu(p["shared"], 0, xt, FP32_CTX)
    return out.reshape(x.shape)


def test_moe_matches_dense_reference():
    key = jax.random.PRNGKey(0)
    p = moe.moe_init(key, 16, 32, 4, quantize=False, n_shared=1)
    x = jax.random.normal(key, (3, 5, 16))
    y, _ = moe.moe_apply(p, 0, x, FP32_CTX, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(y, _dense_ref(p, x, 2), atol=1e-4)


def test_sigmoid_gate_matches_dense_reference():
    key = jax.random.PRNGKey(1)
    p = moe.moe_init(key, 16, 32, 8, quantize=False)
    x = jax.random.normal(key, (2, 4, 16))
    y, _ = moe.moe_apply(p, 0, x, FP32_CTX, top_k=3, gate="sigmoid",
                         routed_scaling=2.5, capacity_factor=8.0)
    np.testing.assert_allclose(
        y, _dense_ref(p, x, 3, "sigmoid", 2.5), atol=1e-4)


def test_capacity_drops_earliest_win():
    """With capacity 8 (the floor), surplus assignments to one expert are
    dropped; earlier tokens keep their slots (position-drop policy)."""
    d, e = 4, 2
    p = moe.moe_init(jax.random.PRNGKey(2), d, 8, e, quantize=False)
    # force every token to expert 0 with a huge router weight
    p["router"]["w"] = jnp.zeros((d, e)).at[:, 0].set(100.0)
    x = jnp.ones((1, 24, d))
    y, _ = moe.moe_apply(p, 0, x, FP32_CTX, top_k=1, capacity_factor=0.33)
    # capacity = max(8, ceil(24*0.33/2) rounded) = 8 slots for expert 0
    out_norm = jnp.linalg.norm(y[0], axis=-1)
    assert float(out_norm[0]) > 0            # first token routed
    assert float(out_norm[-1]) == 0          # last token dropped


def test_ep_equals_dense_multidevice():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.nn import moe
from repro.nn.module import FP32_CTX
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
d, ff, E, k = 16, 32, 8, 2
p = moe.moe_init(key, d, ff, E, quantize=False)
x = jax.random.normal(key, (8, 4, d))

def f_ep(p, x):
    return moe.moe_apply_ep(p, 0, x, FP32_CTX, mesh=mesh, top_k=k,
                            capacity_factor=8.0)[0]
def f_dense(p, x):
    return moe.moe_apply(p, 0, x, FP32_CTX, top_k=k, capacity_factor=8.0)[0]
with mesh:
    y_ep = jax.jit(f_ep)(p, x)
y_d = f_dense(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_d), atol=1e-4)
# gradients agree too (shard_map transpose psums expert grads)
ge = jax.jit(jax.grad(lambda p, x: jnp.sum(f_ep(p, x) ** 2)))
gd = jax.grad(lambda p, x: jnp.sum(f_dense(p, x) ** 2))
with mesh:
    g1 = ge(p, x)
g2 = gd(p, x)
for l1, l2 in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3)
print("EP==dense OK")
""", n_devices=8)


def test_expert_tp_equals_dense_multidevice():
    """grok-style few-expert TP path == dense reference (8 devices)."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.nn import moe
from repro.nn.module import FP32_CTX
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
d, ff, E, k = 16, 32, 3, 2            # E=3 does NOT divide model=4
p = moe.moe_init(key, d, ff, E, quantize=False)
x = jax.random.normal(key, (8, 4, d))

def f_tp(p, x):
    return moe.moe_apply_tp(p, 0, x, FP32_CTX, mesh=mesh, top_k=k,
                            capacity_factor=8.0)[0]
def f_dense(p, x):
    return moe.moe_apply(p, 0, x, FP32_CTX, top_k=k, capacity_factor=8.0)[0]
with mesh:
    y_tp = jax.jit(f_tp)(p, x)
np.testing.assert_allclose(np.asarray(y_tp), np.asarray(f_dense(p, x)),
                           atol=1e-4)
with mesh:
    g1 = jax.jit(jax.grad(lambda p, x: jnp.sum(f_tp(p, x) ** 2)))(p, x)
g2 = jax.grad(lambda p, x: jnp.sum(f_dense(p, x) ** 2))(p, x)
for l1, l2 in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3)
print("TP==dense OK")
""", n_devices=8)
