"""Graceful degradation under systematic failure and overload: a
quarantined model must not drag down co-served healthy models
(acceptance: healthy p95 within 2x its no-fault baseline), and bounded
queues must keep memory flat under a burst far above capacity."""
import numpy as np
import pytest

from repro import serving
from repro.runtime.fault import FaultInjector
from test_serving_plans import _rand_pack

DIMS_A = (16, 12, 4)
DIMS_B = (16, 8, 6)


def _oracle_plan(dims, seed=0):
    return serving.build_plan(_rand_pack(dims, seed=seed), mode="oracle")


def _p95(vals):
    return float(np.percentile(np.asarray(vals), 95))


def _serve_good(fe, n=12):
    lats = []
    for i in range(n):
        x = np.full((1, DIMS_B[0]), 0.1 * i, np.float32)
        lats.append(fe.submit("good", x).result(30.0).latency)
    return lats


def test_quarantine_isolates_healthy_model_p95():
    """Systematic failure in one model quarantines ONLY that model; the
    co-served healthy model's p95 stays within 2x its no-fault baseline.
    max_delay is large enough (50 ms) that the coalescing deadline, not
    host noise, dominates both runs."""
    # -- baseline: healthy model alone, no faulty neighbour
    fe0 = serving.ServingFrontend()
    fe0.register("good", _oracle_plan(DIMS_B, seed=3), max_delay=0.05)
    with fe0:
        base = _serve_good(fe0)

    # -- faulted: a systematically failing neighbour is co-served
    bad = FaultInjector(_oracle_plan(DIMS_A), rate=1.0)
    fe = serving.ServingFrontend(
        retry_policy=serving.RetryPolicy(max_retries=2, fallback=False))
    fe.register("good", _oracle_plan(DIMS_B, seed=3), max_delay=0.05)
    fe.register("bad", bad, max_delay=0.05)
    with fe:
        bad_fut = fe.submit("bad", np.zeros((1, DIMS_A[0]), np.float32))
        lats = _serve_good(fe)
        # the bad model's future carries the root cause ...
        with pytest.raises(serving.InjectedFault):
            bad_fut.result(30.0)
        # ... and later submits are rejected, typed, without a launch
        late = fe.submit("bad", np.zeros((1, DIMS_A[0]), np.float32))
        with pytest.raises(serving.Rejected, match="quarantined"):
            late.result(5.0)

    assert fe.stats["quarantined"] == ["bad"]
    assert fe.stats["by_model"]["good"]["quarantined"] is False
    assert fe.stats["by_model"]["good"]["launches"] == len(lats)
    assert _p95(lats) < 2.0 * max(_p95(base), 0.05)


def test_burst_overload_queue_stays_bounded():
    """A burst ~10x the bound: queued rows never exceed max_queued_rows,
    overflow is a typed prompt rejection, and every admitted request is
    served."""
    plan = _oracle_plan(DIMS_A)
    bound = 8
    fe = serving.ServingFrontend()
    fe.register("m", plan, max_delay=30.0, max_bucket=64,
                max_queued_rows=bound)
    batcher = fe.registry.batcher("m")
    fe.start()
    admitted, rejected = [], []
    for i in range(10 * bound):
        fut = fe.submit("m", np.zeros((1, DIMS_A[0]), np.float32))
        assert batcher.pending_rows <= bound
        exc = None
        if fut.done():
            exc = fut.exception(0.0)
        if exc is None:
            admitted.append(fut)
        else:
            assert isinstance(exc, serving.Rejected)
            assert exc.reason == "queue_full"
            rejected.append(fut)
    assert rejected                                 # overload really shed
    assert batcher.stats["rejected_full"] == len(rejected)
    fe.close(drain=True)
    for f in admitted:
        assert f.result(0.0).y.shape == (1, DIMS_A[-1])
    assert fe.stats["rejected"] == len(rejected)
