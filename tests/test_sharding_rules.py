"""Partition rules: expected specs per tensor role, divisibility fallbacks,
ZeRO-1 data-sharding, cache specs."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import specs as specs_mod
from repro.runtime.sharding import Rules


def _rules(arch, axes=("data", "model"), shape=(16, 16)):
    cfg = get_config(arch)
    return Rules(axes, dict(zip(axes, shape)), cfg), cfg


def test_glm4_specs():
    rules, cfg = _rules("glm4-9b")
    params = specs_mod.abstract_params(cfg)
    sp = rules.param_specs(params)
    # embed vocab-sharded (151552+pad % 16 == 0)
    assert sp["embed"]["table"] == P("model", None)
    st = sp["stacks"]["dense"]
    # 32 q heads % 16 ok but kv=2 % 16 not -> qkv replicated (fallback)
    assert st["attn"]["q"]["kernel"]["w"] == P(None, None, None)
    # mlp ff 13696 % 16 == 0 -> col/row sharded with leading scan dim
    assert st["mlp"]["gate"]["kernel"]["w"] == P(None, None, "model")
    assert st["mlp"]["down"]["kernel"]["w"] == P(None, "model", None)
    # omega/probs replicated
    assert st["mlp"]["gate"]["kernel"]["omega"] == P(None, None)


def test_codeqwen_attention_sharded():
    rules, cfg = _rules("codeqwen1.5-7b")
    params = specs_mod.abstract_params(cfg)
    sp = rules.param_specs(params)
    st = sp["stacks"]["dense"]
    # MHA 32 heads, kv=32: both % 16 == 0 -> sharded
    assert st["attn"]["q"]["kernel"]["w"] == P(None, None, "model")
    assert st["attn"]["k"]["kernel"]["w"] == P(None, None, "model")
    assert st["attn"]["o"]["kernel"]["w"] == P(None, "model", None)
    assert st["attn"]["q"]["bias"] == P(None, "model")


def test_deepseek_expert_parallel_grok_expert_tp():
    rules, cfg = _rules("deepseek-v3-671b")
    sp = rules.param_specs(specs_mod.abstract_params(cfg))
    ex = sp["stacks"]["moe"]["moe"]["experts"]
    assert ex["gate"]["w"] == P(None, "model", None, None)   # 256e % 16
    rules2, cfg2 = _rules("grok-1-314b")
    sp2 = rules2.param_specs(specs_mod.abstract_params(cfg2))
    ex2 = sp2["stacks"]["moe"]["moe"]["experts"]
    assert ex2["gate"]["w"] == P(None, None, None, "model")  # 8e: ff TP
    assert ex2["down"]["w"] == P(None, None, "model", None)


def test_zero1_shards_over_data():
    rules, cfg = _rules("glm4-9b")
    spec = rules.zero1_spec(P(None, None, "model"), (40, 4096, 13696))
    assert spec == P(None, "data", "model")     # first divisible None dim
    # indivisible dims skip to the next
    spec2 = rules.zero1_spec(P(None, None), (15, 4096))
    assert spec2 == P(None, "data")


def test_batch_spec_indivisible_replicates():
    rules, _ = _rules("smollm-360m")
    assert rules.batch_spec(2, batch_dim=256) == P("data", None)
    assert rules.batch_spec(2, batch_dim=1) == P(None, None)


def test_cache_specs():
    import functools
    import jax.numpy as jnp
    from repro.nn import transformer as T
    rules, cfg = _rules("glm4-9b")
    cache = jax.eval_shape(functools.partial(T.init_cache, cfg, 128, 1024))
    cs = rules.cache_specs(cache)
    kv = cs["dense"]["attn"]["k"]
    # (L, B, S, kv=2, hd): batch sharded, kv heads indivisible -> replicated
    assert kv == P(None, "data", None, None, None)
    rules2, cfg2 = _rules("codeqwen1.5-7b")
    cache2 = jax.eval_shape(functools.partial(T.init_cache, cfg2, 128, 1024))
    assert rules2.cache_specs(cache2)["dense"]["attn"]["k"] == \
        P(None, "data", None, "model", None)


def test_every_arch_param_spec_is_valid():
    """Every spec's sharded dims must divide the dim size (jit would reject
    otherwise) — checked abstractly for all 10 archs on both meshes."""
    import numpy as np
    for arch in ("qwen2-vl-2b", "smollm-360m", "h2o-danube-1.8b", "glm4-9b",
                 "codeqwen1.5-7b", "grok-1-314b", "deepseek-v3-671b",
                 "hymba-1.5b", "whisper-base", "mamba2-1.3b"):
        for axes, mshape in ((("data", "model"), (16, 16)),
                             (("pod", "data", "model"), (2, 16, 16))):
            rules, cfg = _rules(arch, axes, mshape)
            params = specs_mod.abstract_params(cfg)
            sp = rules.param_specs(params)
            flat_p = jax.tree_util.tree_leaves(params)
            flat_s = jax.tree_util.tree_leaves(
                sp, is_leaf=lambda x: isinstance(x, P))
            size = dict(zip(axes, mshape))
            for leaf, spec in zip(flat_p, flat_s):
                for dim, ax in zip(np.shape(leaf), tuple(spec)):
                    if ax is None:
                        continue
                    axs = (ax,) if isinstance(ax, str) else ax
                    total = int(np.prod([size[a] for a in axs]))
                    assert dim % total == 0, (arch, spec, np.shape(leaf))
