"""Adam correctness, schedules, int8 error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, schedule
from repro.optim.grad_compress import (GradCompressCfg, compress_grads,
                                       init_error_state)


def test_adam_converges_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0]), "nested": {"y": jnp.ones((3,))}}
    st = adam.init(params)
    cfg = adam.AdamConfig(lr=0.1, grad_clip=None)
    target = {"x": jnp.asarray([1.0, 2.0]), "nested": {"y": jnp.zeros((3,))}}

    def loss(p):
        return (jnp.sum((p["x"] - target["x"]) ** 2)
                + jnp.sum((p["nested"]["y"] - target["nested"]["y"]) ** 2))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, st, _ = adam.apply(params, g, st, cfg)
    assert float(loss(params)) < 1e-3


def test_adam_bias_correction_first_step():
    """After step 1, update ≈ lr·sign(grad) (bias-corrected moments)."""
    p = {"x": jnp.zeros((4,))}
    st = adam.init(p)
    g = {"x": jnp.asarray([1.0, -2.0, 3.0, -4.0])}
    cfg = adam.AdamConfig(lr=0.5, grad_clip=None)
    p2, _, _ = adam.apply(p, g, st, cfg)
    np.testing.assert_allclose(p2["x"], -0.5 * np.sign(g["x"]), rtol=1e-4)


def test_grad_clip_bounds_norm():
    p = {"x": jnp.zeros((3,))}
    st = adam.init(p)
    g = {"x": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, m = adam.apply(p, g, st, adam.AdamConfig(grad_clip=1.0))
    assert float(m["grad_norm"]) == 100.0     # reported pre-clip


def test_warmup_cosine_shape():
    lr = [float(schedule.warmup_cosine(s, base_lr=1.0, warmup=10, total=100))
          for s in range(100)]
    assert lr[0] < 0.2 and abs(lr[10] - 1.0) < 0.01
    assert lr[99] < 0.2 and all(np.isfinite(lr))


def test_lambda_ramp():
    assert float(schedule.lambda_ramp(0, lam=0.5, ramp_steps=10)) == 0.0
    assert abs(float(schedule.lambda_ramp(5, lam=0.5, ramp_steps=10)) - 0.25) < 1e-6
    assert float(schedule.lambda_ramp(20, lam=0.5, ramp_steps=10)) == 0.5


def test_grad_compress_error_feedback_is_unbiased_over_time():
    """Accumulated (compressed - true) drift stays bounded: the error
    buffer re-injects residuals, so the *sum* of applied grads tracks the
    sum of true grads (1-bit-Adam convergence argument)."""
    cfg = GradCompressCfg(min_size=16)
    rng = np.random.default_rng(0)
    g_true_sum = np.zeros((64, 64), np.float32)
    g_appl_sum = np.zeros((64, 64), np.float32)
    grads = {"w": jnp.zeros((64, 64))}
    err = init_error_state(grads, cfg)
    for t in range(30):
        g = rng.normal(size=(64, 64)).astype(np.float32)
        cg, err = compress_grads({"w": jnp.asarray(g)}, err, cfg)
        g_true_sum += g
        g_appl_sum += np.asarray(cg["w"])
    drift = np.abs(g_appl_sum - g_true_sum).max()
    one_step_q = np.abs(g_true_sum).max() / 127
    assert drift < 10 * one_step_q, (drift, one_step_q)


def test_grad_compress_skips_small_tensors():
    cfg = GradCompressCfg(min_size=1000)
    grads = {"small": jnp.asarray([1.234567])}
    err = init_error_state(grads, cfg)
    cg, _ = compress_grads(grads, err, cfg)
    np.testing.assert_array_equal(cg["small"], grads["small"])  # exact
