"""Oversized-batch dispatch: batches past the largest bucket must run the
largest bucket's *tuned* (path, block_m) — fit-guarded at the actual row
count — and the reporting (path_for / schedule_for) must name what
executes.  Plus the result() rid contract the same PR tightened."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.serving.plans import SCHEDULE_BY_PATH
from test_serving_plans import _rand_pack

DIMS = (33, 129, 71, 7)
EVEN_DIMS = (64, 96, 10)


def test_oversize_inherits_top_bucket_binding():
    """path_for/schedule_for past the largest bucket report the largest
    bucket's tuned winner (not a plan-level default no sweep ever bound),
    and run() executes exactly that binding."""
    plan = serving.build_plan(_rand_pack(EVEN_DIMS), mode="fused",
                              interpret=True, max_bucket=8)
    top = max(plan.bucket_sizes)
    top_bp = plan.buckets[top]
    obp = plan.oversize_binding(20)
    assert obp.path == top_bp.path
    assert obp.block_m == top_bp.block_m
    assert plan.path_for(20) == top_bp.path
    assert plan.schedule_for(20) == SCHEDULE_BY_PATH.get(
        top_bp.path, top_bp.path)
    # and the oversize run is correct through that binding
    x = jnp.asarray(np.random.default_rng(0).normal(size=(20, EVEN_DIMS[0])),
                    jnp.float32)
    oracle = serving.build_plan(_rand_pack(EVEN_DIMS), mode="oracle")
    np.testing.assert_allclose(plan.run(x), oracle.run(x),
                               atol=1e-3, rtol=1e-4)


def test_oversize_binding_is_memoized_and_label_stable():
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused",
                              interpret=True, max_bucket=4)
    assert plan.oversize_binding(9) is plan.oversize_binding(9)
    assert plan.path_for(9) == plan.oversize_binding(9).path


def test_oversize_stream_stack_stays_fused():
    """A stack whose whole-stack working set busts the batch-tiled budget
    used to drop oversize batches to the per-layer chain even though the
    streaming schedule (the top bucket's winner) serves them; the fit
    guard shrinks the inherited tile until the streamed set fits."""
    from repro.kernels.fantastic4_fused_mlp import (fused_mlp_vmem_bytes,
                                                    stream_mlp_vmem_bytes)
    dims = (256,) * 7
    pack = _rand_pack(dims, seed=11)
    shapes = tuple(zip(dims[:-1], dims[1:]))
    stack_b = fused_mlp_vmem_bytes(shapes, block_m=256)
    stream_b = stream_mlp_vmem_bytes(shapes, rows=48, block_m=8)
    assert stream_b < stack_b
    budget = (stream_b + stack_b) // 2
    plan = serving.build_plan(pack, mode="auto", interpret=True,
                              vmem_budget_bytes=budget, max_bucket=32)
    assert plan.buckets[32].path == "fused_stream"
    obp = plan.oversize_binding(40)
    assert obp.path == "fused_stream"
    assert plan.path_for(40) == "fused_stream"
    assert plan.schedule_for(40) == "stream"
    # the guard must have picked a tile whose streamed set fits 40 rows
    assert plan._schedule_fits("stream", 40, obp.block_m)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(40, dims[0])),
                    jnp.float32)
    oracle = serving.build_plan(pack, mode="oracle")
    np.testing.assert_allclose(plan.run(x), oracle.run(x),
                               atol=1e-3, rtol=1e-4)


def test_oversize_per_layer_mode_unchanged():
    plan = serving.build_plan(_rand_pack(EVEN_DIMS), mode="per_layer",
                              interpret=True, max_bucket=4)
    assert plan.path_for(9) == "per_layer"
    assert plan.schedule_for(9) == "per_layer"


def test_engine_oversize_request_uses_top_bucket_schedule():
    """The micro-batcher's oversized branch flows through plan.run, so an
    oversized request is served by the top bucket's schedule too — and
    stays row-for-row equal to serving it alone."""
    pack = _rand_pack(EVEN_DIMS)
    plan = serving.build_plan(pack, mode="fused", interpret=True,
                              max_bucket=4)
    b = serving.MicroBatcher(plan, max_bucket=4)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(9, EVEN_DIMS[0])),
                    jnp.float32)
    rid = b.submit(x)
    b.flush()
    c = b.result(rid)
    assert c.bucket == 9                   # exact rows, no bucket padding
    np.testing.assert_allclose(c.y, plan.run(x), atol=1e-5, rtol=1e-5)


# ------------------------------------------------------- result() contract


def test_result_rid_contract():
    plan = serving.build_plan(_rand_pack(EVEN_DIMS), mode="fused",
                              interpret=True)
    b = serving.MicroBatcher(plan)
    x = jnp.zeros((1, EVEN_DIMS[0]), jnp.float32)
    rid = b.submit(x)
    assert b.result(rid) is None           # still queued: None
    b.flush()
    assert b.result(rid) is not None       # served: pops the completion
    with pytest.raises(KeyError):          # consumed: loud, not None
        b.result(rid)
    with pytest.raises(KeyError):          # never issued: loud, not None
        b.result(12345)
