"""models/lm greedy decode: prefill+decode vs the one-shot forward.

The incremental serving path (prefill the prompt, then single-token
decode steps against the KV cache) must be argmax-identical to running
the whole growing sequence through ``lm_apply`` with no cache at every
step — on fp32 weights AND on the frozen 4-bit tree.  Plus the KV-cache
shape/window invariants for sliding-window-attention archs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import qat
from repro.models import lm
from repro.nn import transformer as T
from repro.nn.module import QuantCtx

CTX = QuantCtx(quant=False, compute_dtype=jnp.float32)


def _init(arch, seed=0):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(seed)
    params = T.lm_init(key, cfg)
    qstate = qat.build_qstate(params)
    return cfg, key, params, qstate


def _assert_teacher_forced_parity(params, qstate, cfg, prompt, out):
    """Every generated token must be the argmax of a fresh no-cache
    forward over everything before it."""
    seq = jnp.concatenate([prompt, out], axis=1)
    s = prompt.shape[1]
    for t in range(out.shape[1]):
        logits, _, _ = T.lm_apply(params, qstate, seq[:, :s + t], CTX, cfg)
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)
        np.testing.assert_array_equal(
            np.asarray(nxt, np.int64), np.asarray(out[:, t], np.int64),
            err_msg=f"decode step {t} diverged from the one-shot forward")


@pytest.mark.parametrize("weights", ["fp32", "frozen4bit"])
def test_generate_matches_one_shot_forward(weights):
    cfg, key, params, qstate = _init("smollm-360m")
    if weights == "frozen4bit":
        params, qstate = qat.freeze_tree(params, qstate, cfg.lam), 0
    prompt = jax.random.randint(key, (2, 6), 0, cfg.vocab)
    out = lm.generate(params, qstate, prompt, CTX, cfg, max_new=4)
    assert out.shape == (2, 4)
    _assert_teacher_forced_parity(params, qstate, cfg, prompt, out)


def test_swa_generate_crosses_window_matches_one_shot():
    """h2o-danube (SWA): decode far enough that the attention span slides
    past the prompt; cached decode must still match the no-cache forward
    (whose window masking is purely positional)."""
    cfg, key, params, qstate = _init("h2o-danube-1.8b")
    assert cfg.window and cfg.window == 16     # smoke caps the window
    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    prompt = jax.random.randint(key, (2, 10), 0, cfg.vocab)
    out = lm.generate(frozen, 0, prompt, CTX, cfg, max_new=10)
    # 10 + 10 > window: the last steps attend to a strict suffix
    _assert_teacher_forced_parity(frozen, 0, cfg, prompt, out)


def test_swa_cache_shapes_and_window_cap():
    """init_cache invariants: ``cap_window`` gives SWA archs an O(window)
    ring (decode-only usage); the default keeps full length so multi-token
    prefill writes never wrap.  Ring slots hold positions, not columns."""
    cfg = get_config("h2o-danube-1.8b").smoke()
    b, max_len = 2, 40
    full = T.init_cache(cfg, b, max_len, dtype=jnp.float32)
    capped = T.init_cache(cfg, b, max_len, dtype=jnp.float32,
                          cap_window=True)
    for cache, kv_len in ((full, max_len), (capped, cfg.window)):
        att = cache["dense"]["attn"]
        assert att["k"].shape == (cfg.n_layers, b, kv_len, cfg.n_kv,
                                  cfg.resolved_head_dim)
        assert att["v"].shape == att["k"].shape
        assert att["pos"].shape == (cfg.n_layers, kv_len)
        assert att["len"].shape == (cfg.n_layers,)
        # empty slots carry position -1: never matched by the mask
        assert int(jnp.max(att["pos"])) == -1


def test_swa_window_ring_decode_matches_full_cache():
    """Greedy decode against the window-capped ring (writes wrap at
    ``len % window``) is token-identical to decode against the
    full-length cache."""
    cfg, key, params, qstate = _init("h2o-danube-1.8b", seed=3)
    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    b, s, new = 2, 10, 10                     # s + new = 20 > window = 16
    prompt = jax.random.randint(key, (b, s), 0, cfg.vocab)

    full = lm.generate(frozen, 0, prompt, CTX, cfg, max_new=new)

    cache = T.init_cache(cfg, b, s + new, dtype=jnp.float32,
                         cap_window=True)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    nxt, cache = lm.greedy_step(frozen, 0, prompt, CTX, cfg,
                                positions=pos, cache=cache)
    outs = [nxt]
    for t in range(new - 1):
        p_t = jnp.full((b, 1), s + t, jnp.int32)
        nxt, cache = lm.greedy_step(frozen, 0, nxt, CTX, cfg,
                                    positions=p_t, cache=cache)
        outs.append(nxt)
    ring = jnp.concatenate(outs, axis=1)
    np.testing.assert_array_equal(np.asarray(ring), np.asarray(full))
