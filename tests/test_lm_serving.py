"""LMProgram: 4-bit transformer prefill/decode through the serving stack.

The tentpole acceptance tests: a frozen smoke transformer registered in
``ServingFrontend`` as a :class:`~repro.serving.lm.LMProgram` serves
end-to-end (register -> prefill -> N decode steps -> futures resolve)
with decode outputs bit-identical to the program's direct ``generate``
loop; the ``rows_per_request`` wire contract and the batcher's scatter
guard; integrity guarding of the program's per-block FFN packs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.configs import get_config
from repro.core import qat
from repro.models import lm as lm_mod
from repro.nn import transformer as T
from repro.nn.module import QuantCtx

B, S, NEW = 3, 6, 5


@pytest.fixture(scope="module")
def world():
    cfg = get_config("smollm-360m").smoke()
    key = jax.random.PRNGKey(0)
    params = T.lm_init(key, cfg)
    qstate = qat.build_qstate(params)
    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    prog = serving.LMProgram(frozen, cfg, max_prompt=S, max_new=NEW,
                             max_bucket=8, interpret=True)
    prompt = np.asarray(jax.random.randint(key, (B, S), 0, cfg.vocab))
    return cfg, frozen, prog, prompt


# ------------------------------------------------- protocol surface

def test_servable_protocol_surface(world):
    cfg, _, prog, _ = world
    assert prog.d_in == 2 + S and prog.d_out == 1
    assert prog.rows_per_request == 1
    assert list(prog.bucket_sizes) == sorted(set(prog.bucket_sizes))
    assert all(b & (b - 1) == 0 for b in prog.bucket_sizes)
    assert prog.bucket_for(1) == prog.bucket_sizes[0]
    assert prog.bucket_for(max(prog.bucket_sizes) + 1) is None
    d = prog.describe()
    assert d["program"] == "lm" and "ffn_schedules" in d
    # protocol attr the integrity/fault layers key on
    assert all("packed" in l for l in prog.layers)
    with pytest.raises(KeyError):
        prog.decode_step(99_999)


def test_rejects_non_dense_family():
    cfg = get_config("mamba2-1.3b").smoke()
    key = jax.random.PRNGKey(0)
    params = T.lm_init(key, cfg)
    frozen = qat.freeze_tree(params, qat.build_qstate(params), cfg.lam)
    with pytest.raises(ValueError, match="dense-family"):
        serving.LMProgram(frozen, cfg, max_prompt=4, max_new=4)


# ------------------------------------------------- end-to-end engine

def test_frontend_end_to_end_bit_identical(world):
    cfg, frozen, prog, prompt = world
    direct = prog.generate(prompt, NEW)

    toks = []
    frontend = serving.ServingFrontend()
    with frontend:
        frontend.register("lm", prog, max_delay=1e-3)
        futs = [frontend.submit(
                    "lm", prog.encode_prefill(100 + i, prompt[i])[None])
                for i in range(B)]
        toks.append([int(f.result(60.0).y[0, 0]) for f in futs])
        for _ in range(NEW - 1):
            futs = [frontend.submit(
                        "lm", prog.encode_decode(100 + i)[None])
                    for i in range(B)]
            toks.append([int(f.result(60.0).y[0, 0]) for f in futs])
    for i in range(B):
        prog.release(100 + i)
    engine = np.asarray(toks, np.int64).T

    # acceptance: engine == the program's own generate loop, bit for bit
    np.testing.assert_array_equal(engine, direct)
    # and token-parity with the reference models.lm greedy loop
    ref = lm_mod.generate(frozen, 0, jnp.asarray(prompt),
                          QuantCtx(quant=False,
                                   compute_dtype=jnp.float32),
                          cfg, max_new=NEW)
    np.testing.assert_array_equal(engine, np.asarray(ref, np.int64))


# --------------------------------------- wire contract + scatter guard

def test_rows_per_request_contract(world):
    """Satellite: a program that pins rows-per-request (the LM program's
    per-row sequence framing) makes the batcher refuse multi-row
    requests at intake."""
    _, _, prog, prompt = world
    batcher = serving.MicroBatcher(prog)
    two_rows = np.stack([prog.encode_prefill(900, prompt[0]),
                         prog.encode_decode(900)])
    prog.release(900)
    with pytest.raises(ValueError, match="rows_per_request"):
        batcher.submit(two_rows)
    assert batcher.stats["requests"] == 0


class _ShortOutputStub:
    """ServableProgram that violates the row-count contract on output."""
    d_in = 4
    d_out = 2
    bucket_sizes = (4,)
    rows_per_request = None

    def bucket_for(self, rows):
        return 4 if rows <= 4 else None

    def entry(self, bucket):
        def f(xb):
            return jnp.zeros((bucket // 2, self.d_out), jnp.float32)
        return f

    def run(self, x):
        return self.entry(4)(x)

    def describe(self):
        return {"kind": "stub"}


def test_scatter_guard_refuses_short_outputs():
    """Satellite regression: a program returning fewer rows than the
    bucket it was handed must raise instead of silently mis-scattering
    the tail requests."""
    batcher = serving.MicroBatcher(_ShortOutputStub(), max_delay=0.0)
    for _ in range(3):
        batcher.submit(np.zeros((1, 4), np.float32))
    with pytest.raises(RuntimeError, match="refusing to scatter"):
        batcher.flush()


# ------------------------------------------------- integrity guarding

def test_guarded_lm_program_detects_block_corruption(world):
    _, _, prog, _ = world
    g = serving.GuardedPlan(prog, model_id="lm")
    g.verify()                                  # clean pass
    layer = prog.layers[0]
    orig = layer["packed"]
    flipped = np.asarray(orig, np.uint8).copy()
    flipped[0, 0] ^= 0x08
    layer["packed"] = jnp.asarray(flipped)
    try:
        with pytest.raises(serving.IntegrityError):
            g.verify()
    finally:
        layer["packed"] = orig
    g.verify()                                  # restored -> clean again
