"""Per-arch smoke tests (assignment requirement): a reduced same-family
config runs one forward/train step on CPU; output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.core import qat
from repro.models import whisper as W
from repro.nn import transformer as T
from repro.nn.module import QuantCtx

ARCHS = [a for a in list_configs()]
CTX = QuantCtx(quant=True, lam=0.01, compute_dtype=jnp.float32)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "audio":
        p = W.whisper_init(key, cfg)
        q = qat.build_qstate(p)
        frames = jax.random.normal(key, (B, cfg.enc_len, cfg.d_model))
        enc = W.whisper_encode(p, q, frames, CTX, cfg)
        cross = W.precompute_cross(p, q, enc, CTX, cfg)
        logits, _ = W.whisper_decode(p, q, toks, cross, CTX, cfg)
    else:
        p = T.lm_init(key, cfg)
        q = qat.build_qstate(p)
        logits, _, _ = T.lm_apply(p, q, toks, CTX, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits[..., :cfg.vocab])))


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v3-671b",
                                  "mamba2-1.3b", "hymba-1.5b",
                                  "whisper-base"])
def test_one_train_step_reduces_loss_direction(arch):
    """One EC4T train step on the smoke config: finite grads, loss moves."""
    from repro.launch import steps as S_
    from repro.optim import adam, ec4t
    cfg = get_config(arch).smoke()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    key = jax.random.PRNGKey(1)
    init = W.whisper_init if cfg.family == "audio" else T.lm_init
    params = init(key, cfg)
    state = ec4t.init_train_state(params)
    loss_fn = S_._loss_fn(cfg, mesh=None, use_ep=False, remat="none")
    step = ec4t.make_train_step(loss_fn, adam.AdamConfig(lr=1e-3),
                                lam=cfg.lam)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.family == "audio":
        batch["embeds"] = jax.random.normal(key, (B, cfg.enc_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
        del batch["tokens"]
    losses = []
    for _ in range(3):
        state, metrics = jax.jit(step)(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses    # same batch => must descend


@pytest.mark.parametrize("arch", ARCHS)
def test_config_numbers_match_assignment(arch):
    spec = {
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
           cfg.vocab)
    assert got == spec, (got, spec)
    if arch == "grok-1-314b":
        assert (cfg.n_experts, cfg.top_k) == (8, 2)
    if arch == "deepseek-v3-671b":
        assert (cfg.n_experts, cfg.top_k, cfg.n_shared_experts) == (256, 8, 1)
        assert cfg.mla is not None
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
    if arch == "mamba2-1.3b":
        assert cfg.ssm_state == 128
