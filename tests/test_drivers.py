"""End-to-end launcher coverage: train.py main() (checkpoint + export) and
serve.py main() run to completion on smoke configs."""
import os

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_end_to_end(tmp_path):
    hist = train_mod.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "25",
        "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--ckpt-every", "10",
        "--export", str(tmp_path / "export"),
    ])
    assert len(hist) >= 2
    assert all(m["loss"] > 0 for m in hist)
    assert os.path.exists(tmp_path / "export" / "export.npz")
    assert os.path.exists(tmp_path / "export" / "report.json")
    # resume picks up from the checkpoint (no crash, fewer steps)
    hist2 = train_mod.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path / "ckpt"),
    ])
    assert hist2  # ran the remaining steps


def test_serve_driver_end_to_end():
    gen = serve_mod.main([
        "--arch", "mamba2-1.3b", "--smoke", "--batch", "2",
        "--prompt-len", "6", "--max-new", "5",
    ])
    assert gen.shape == (2, 5)
    assert (gen >= 0).all()
