"""CSR / bitmask / dense4 codecs: lossless roundtrip (property), size
accounting, per-layer format selection (paper contribution 4)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import formats


@st.composite
def code_matrices(draw):
    r = draw(st.integers(1, 40))
    c = draw(st.integers(1, 600))
    density = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    codes = rng.integers(1, 16, size=(r, c)).astype(np.uint8)
    mask = rng.random((r, c)) < density
    return np.where(mask, codes, 0).astype(np.uint8)


@given(code_matrices(), st.sampled_from(formats.FORMATS))
@settings(max_examples=60, deadline=None)
def test_roundtrip_lossless(codes, fmt):
    ct = formats.encode(codes, fmt)
    np.testing.assert_array_equal(formats.decode(ct), codes)


@given(code_matrices())
@settings(max_examples=40, deadline=None)
def test_analytic_size_matches_encoded(codes):
    nnz = int(np.count_nonzero(codes))
    for fmt in formats.FORMATS:
        ct = formats.encode(codes, fmt)
        assert ct.size_bits == formats.analytic_size_bits(
            codes.shape, nnz, fmt), fmt


@given(code_matrices())
@settings(max_examples=40, deadline=None)
def test_select_format_is_argmin(codes):
    best = formats.select_format(codes)
    nnz = int(np.count_nonzero(codes))
    sizes = {f: formats.analytic_size_bits(codes.shape, nnz, f)
             for f in formats.FORMATS}
    assert sizes[best] == min(sizes.values())


def test_format_crossover_regimes():
    """dense4 wins when dense, bitmask at moderate sparsity, CSR at >90% —
    the paper's §III-B.2 claim, reproduced on synthetic tensors."""
    rng = np.random.default_rng(0)
    def mat(sparsity):
        codes = rng.integers(1, 16, size=(256, 1024)).astype(np.uint8)
        mask = rng.random(codes.shape) < (1 - sparsity)
        return np.where(mask, codes, 0).astype(np.uint8)
    assert formats.select_format(mat(0.0)) == "dense4"
    assert formats.select_format(mat(0.6)) == "bitmask"
    assert formats.select_format(mat(0.97)) == "csr"


def test_compression_ratio_dense_is_8x():
    codes = np.random.default_rng(1).integers(1, 16, size=(128, 128)).astype(np.uint8)
    cr = formats.compression_ratio(codes, "dense4")
    assert abs(cr - 8.0) < 0.01
