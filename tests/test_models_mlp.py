"""Paper MLPs end-to-end: EC4T training actually learns, freeze/serve
consistency, compression-format selection after sparsity emerges."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_mlps import MLP_HR, MLPConfig
from repro.core import qat
from repro.data import synthetic
from repro.models import mlp as M
from repro.nn.module import QuantCtx
from repro.optim import adam


def _train(cfg_mlp, lam, steps=150, lr=5e-3):
    data_cfg = synthetic.ClsDataCfg(d_in=cfg_mlp.d_in,
                                    n_classes=cfg_mlp.features[-1],
                                    batch=128, margin=3.0, seed=0)
    key = jax.random.PRNGKey(0)
    params, bn = M.mlp_init(key, cfg_mlp)
    qs = qat.build_qstate(params)
    opt = adam.init(params)
    ctx = QuantCtx(quant=True, lam=lam, compute_dtype=jnp.float32)

    @jax.jit
    def step(params, qs, bn, opt, x, y):
        def loss_fn(params):
            logits, bn2 = M.mlp_apply(params, qs, bn, x, ctx, train=True)
            return M.cross_entropy(logits, y), (bn2, logits)
        (loss, (bn2, logits)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt, _ = adam.apply(params, g, opt, adam.AdamConfig(lr=lr))
        qs = qat.update_qstate(params, qs, lam)
        return params, qs, bn2, opt, loss, M.accuracy(logits, y)

    for i in range(steps):
        b = synthetic.cls_batch(data_cfg, i)
        params, qs, bn, opt, loss, acc = step(
            params, qs, bn, opt, jnp.asarray(b["x"]), jnp.asarray(b["labels"]))
    return params, qs, bn, float(loss), float(acc)


def test_ec4t_training_learns_and_compresses():
    params, qs, bn, loss, acc = _train(MLP_HR, lam=0.05)
    assert acc > 0.75, acc
    st = qat.stats(params, qs, 0.05)
    assert float(st["sparsity"]) > 0.2, float(st["sparsity"])
    assert float(st["entropy_bits_per_weight"]) < 3.0
    # frozen pack: formats should exploit the sparsity (not all dense4)
    pack = M.freeze_mlp(params, qs, bn, lam=0.05)
    summ = M.pack_compression_summary(pack)
    assert summ["compression_ratio"] > 8.0, summ   # beats trivial dense4
    assert any(f != "dense4" for f in summ["formats"]), summ["formats"]
    # serving path == eval fake-quant path
    x = jnp.asarray(np.random.default_rng(5).normal(
        size=(16, MLP_HR.d_in)), jnp.float32)
    ctx = QuantCtx(quant=True, lam=0.05, compute_dtype=jnp.float32)
    y_eval, _ = M.mlp_apply(params, qs, bn, x, ctx, train=False)
    y_serve = M.mlp_serve(pack, x, use_kernel=False)
    np.testing.assert_allclose(y_serve, y_eval, atol=1e-2, rtol=1e-2)


def test_freeze_mlp_odd_k_int8_fused_regression():
    """freeze_mlp's odd-K zero-row padding survives the int8 fused route.

    PR 1 only exercised the fp32 paths on odd-K packs; the int8 megakernel
    must absorb the padded code row the same way (zero codes decode to
    zero weights; the padded x column is zero), and stay bit-exact with
    the per-layer int8 chain.  Odd d_in AND odd hidden widths.
    """
    cfg = MLPConfig("odd-mlp", (65, 33, 5), d_in=17)
    params, bn = M.mlp_init(jax.random.PRNGKey(3), cfg)
    qs = qat.build_qstate(params)
    x = jnp.asarray(np.random.default_rng(8).normal(
        size=(12, cfg.d_in)), jnp.float32)
    ctx = QuantCtx(quant=True, lam=0.02, compute_dtype=jnp.float32)
    _, bn = M.mlp_apply(params, qs, bn, x, ctx, train=True)
    pack = M.freeze_mlp(params, qs, bn, lam=0.02)
    assert all(l["shape"][0] % 2 for l in pack["layers"])   # all odd K

    calib = M.calibrate_act_scales(pack, x)
    i8_fused = M.mlp_serve_int8(pack, calib, x, fused=True, interpret=True)
    i8_layer = M.mlp_serve_int8(pack, calib, x, use_kernel=True,
                                fused=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(i8_fused), np.asarray(i8_layer))

    # int8 still tracks the fp32 serving path on the frozen pack
    y32 = M.mlp_serve(pack, x, use_kernel=False)
    rel = float(jnp.linalg.norm(i8_fused - y32)
                / max(float(jnp.linalg.norm(y32)), 1e-6))
    assert rel < 0.05, rel


def test_lambda_sweep_pareto():
    """Fig. 9 mechanism: increasing lambda increases sparsity monotonically
    while accuracy degrades gracefully (stays above chance here)."""
    spars, accs = [], []
    for lam in (0.005, 0.3):
        params, qs, _, _, acc = _train(MLP_HR, lam=lam, steps=80)
        spars.append(float(qat.stats(params, qs, lam)["sparsity"]))
        accs.append(acc)
    assert spars[1] > spars[0]
    assert accs[1] > 0.5
