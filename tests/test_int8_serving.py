"""Paper §VI-C configuration: 8-bit activations between layers.

int8 serving must track the f32 serving path closely (the paper reports
'accurate enough to perform inference without harming prediction
performance') and the kernel path must agree with the oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_mlps import MLP_HR
from repro.core import qat
from repro.models import mlp as M
from repro.nn.module import QuantCtx


def _frozen_pack():
    key = jax.random.PRNGKey(0)
    p, bn = M.mlp_init(key, MLP_HR)
    q = qat.build_qstate(p)
    x = jax.random.normal(key, (64, MLP_HR.d_in))
    ctx = QuantCtx(quant=True, lam=0.05, compute_dtype=jnp.float32)
    _, bn = M.mlp_apply(p, q, bn, x, ctx, train=True)
    pack = M.freeze_mlp(p, q, bn, lam=0.05)
    return pack, x


def test_int8_activations_track_f32():
    pack, x = _frozen_pack()
    calib = M.calibrate_act_scales(pack, x)
    y32 = M.mlp_serve(pack, x, use_kernel=False)
    y8 = M.mlp_serve_int8(pack, calib, x)
    rel = float(jnp.linalg.norm(y8 - y32) / jnp.linalg.norm(y32))
    agree = float((y8.argmax(-1) == y32.argmax(-1)).mean())
    assert rel < 0.05, rel
    assert agree > 0.9, agree


def test_int8_kernel_matches_oracle():
    pack, x = _frozen_pack()
    calib = M.calibrate_act_scales(pack, x)
    y_o = M.mlp_serve_int8(pack, calib, x[:8], use_kernel=False)
    y_k = M.mlp_serve_int8(pack, calib, x[:8], use_kernel=True,
                           interpret=True)
    np.testing.assert_allclose(y_k, y_o, atol=1e-2, rtol=1e-2)


def test_int8_fused_bit_exact_with_per_layer_on_trained_pack():
    """The megakernel's int8 datapath == the per-layer chain, bitwise, on a
    real frozen pack (synthetic-pack coverage lives in
    test_serving_parity)."""
    pack, x = _frozen_pack()
    calib = M.calibrate_act_scales(pack, x)
    y_fused = M.mlp_serve_int8(pack, calib, x, fused=True, interpret=True)
    y_layer = M.mlp_serve_int8(pack, calib, x, use_kernel=True,
                               fused=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_layer))
    # double-buffered variant is the same datapath on a skewed schedule
    y_db = M.mlp_serve_int8(pack, calib, x, fused=True, interpret=True,
                            double_buffer=True)
    np.testing.assert_array_equal(np.asarray(y_db), np.asarray(y_fused))
