"""ExecutionPlan: mode/bucket resolution, entry caching, and parity of the
weight-stationary latency schedule against the batch-tiled megakernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.core import bitplanes as bp
from repro.kernels import ops


def _rand_pack(dims, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        codes = rng.integers(0, 16, size=(k + (k % 2), n)).astype(np.uint8)
        if k % 2:
            codes[-1] = 0
        layers.append({
            "packed": bp.pack_codes_rows(jnp.asarray(codes)),
            "omega": jnp.asarray(rng.normal(size=4) / np.sqrt(k), jnp.float32),
            "alpha1": jnp.asarray(rng.normal(size=n) * 0.5, jnp.float32),
            "bias": jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32),
            "alpha2": jnp.asarray(np.float32(1.0)),
            "shape": (k, n),
            "activation": "relu" if i < len(dims) - 2 else None,
        })
    return {"layers": layers, "act_bits": None}


DIMS = (33, 129, 71, 7)


def test_auto_resolves_fused_and_buckets_are_pow2():
    plan = serving.build_plan(_rand_pack(DIMS), mode="auto", interpret=True)
    d = plan.describe()
    assert d["resolved_mode"] == "fused"
    assert d["bucket_sizes"] == sorted(d["bucket_sizes"])
    assert all(b & (b - 1) == 0 for b in d["bucket_sizes"])
    assert d["bucket_sizes"][0] == 1
    assert max(d["bucket_sizes"]) <= max(d["block_m"], 1)


def test_vmem_overflow_resolves_to_per_layer_with_note():
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused", interpret=True,
                              vmem_budget_bytes=1)
    d = plan.describe()
    assert d["resolved_mode"] == "per_layer"
    assert any("VMEM" in n for n in d["notes"])
    # and it still serves correctly
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, DIMS[0])),
                    jnp.float32)
    oracle = serving.build_plan(_rand_pack(DIMS), mode="oracle")
    np.testing.assert_allclose(plan.run(x), oracle.run(x),
                               atol=1e-3, rtol=1e-4)


def test_bucket_paths_ws_db_and_plain():
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused", interpret=True,
                              double_buffer=True)
    paths = plan.describe()["bucket_paths"]
    assert paths[1] == "fused_ws" and paths[8] == "fused_ws"
    assert paths[16] == "fused_db"
    assert plan.path_for(9) in ("fused", "fused_db")
    # batch label reflects the resolved bucket, not the request flags
    assert "weight-stationary" in plan.mode_label(1)
    assert "double-buffered" in plan.mode_label(16)


def test_double_buffer_note_when_it_cannot_engage():
    plan = serving.build_plan(_rand_pack(DIMS), mode="per_layer",
                              interpret=True, double_buffer=True)
    assert any("double_buffer" in n for n in plan.notes)


def test_run_pads_to_bucket_and_slices_back():
    pack = _rand_pack(DIMS)
    plan = serving.build_plan(pack, mode="fused", interpret=True)
    oracle = serving.build_plan(pack, mode="oracle")
    for m in (1, 3, 5, 8, 13):
        x = jnp.asarray(np.random.default_rng(m).normal(size=(m, DIMS[0])),
                        jnp.float32)
        y = plan.run(x)
        assert y.shape == (m, DIMS[-1])
        np.testing.assert_allclose(y, oracle.run(x), atol=1e-3, rtol=1e-4)


def test_entry_is_cached_and_shape_checked():
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused", interpret=True)
    assert plan.entry(4) is plan.entry(4)
    with pytest.raises(KeyError):
        plan.entry(3)                      # not a bucket
    with pytest.raises(AssertionError):
        plan.entry(4)(jnp.zeros((5, DIMS[0]), jnp.float32))


def test_int8_calibration_happens_once_and_matches_chain():
    pack = _rand_pack(DIMS, seed=3)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, DIMS[0])),
                    jnp.float32)
    calib = serving.calibrate_act_scales(pack, x)
    plan = serving.build_plan(pack, mode="fused", act_dtype="int8",
                              calib=calib, interpret=True)
    y_plan = plan.run(x)
    y_chain = ops.fantastic4_mlp_chain_int8(
        x, pack["layers"], calib["act_scales"], use_kernel=True,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_chain))
    # without calib, the plan self-calibrates on a synthetic batch + notes it
    plan2 = serving.build_plan(pack, mode="fused", act_dtype="int8",
                               interpret=True)
    assert plan2.act_scales is not None
    assert any("calibration" in n for n in plan2.notes)


def test_ws_schedule_matches_batch_tiled_megakernel():
    """The weight-stationary latency path reproduces the batch-tiled
    megakernel: allclose on fp32, bit-for-bit on the int8 grid (they share
    decode + epilogue arithmetic; only the dataflow differs)."""
    for dims in (DIMS, (512, 512, 256, 12), (47, 96, 13)):
        pack = _rand_pack(dims, seed=sum(dims))
        x = jnp.asarray(np.random.default_rng(2).normal(size=(4, dims[0])),
                        jnp.float32)
        calib = serving.calibrate_act_scales(pack, x)
        y_ws = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                        weight_stationary=True)
        y_mk = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True)
        np.testing.assert_allclose(y_ws, y_mk, atol=1e-4, rtol=1e-5)
        i_ws = ops.fantastic4_mlp_fused(
            x, pack["layers"], interpret=True, weight_stationary=True,
            act_dtype="int8", act_scales=calib["act_scales"])
        i_mk = ops.fantastic4_mlp_fused(
            x, pack["layers"], interpret=True,
            act_dtype="int8", act_scales=calib["act_scales"])
        np.testing.assert_array_equal(np.asarray(i_ws), np.asarray(i_mk),
                                      err_msg=str(dims))


def test_ws_overbudget_falls_back_to_chain():
    pack = _rand_pack(DIMS, seed=5)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, DIMS[0])),
                    jnp.float32)
    y_fb = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                    weight_stationary=True,
                                    vmem_budget_bytes=1)
    y_ch = ops.fantastic4_mlp_chain(x, pack["layers"], use_kernel=True,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(y_fb), np.asarray(y_ch))


def test_get_plan_memoizes_per_pack_and_config():
    pack = _rand_pack(DIMS)
    a = serving.get_plan(pack, mode="fused", interpret=True)
    b = serving.get_plan(pack, mode="fused", interpret=True)
    c = serving.get_plan(pack, mode="per_layer", interpret=True)
    assert a is b
    assert a is not c
    other = _rand_pack(DIMS, seed=9)
    assert serving.get_plan(other, mode="fused", interpret=True) is not a


# ------------------- autotuner v2: per-bucket schedule binding (PR 4)

def test_ws_bucket_rows_opt_out_and_explicit_cap():
    """ws_bucket_rows=0 opts the ws schedule out entirely; an explicit
    positive value caps its eligibility at that row count."""
    plan0 = serving.build_plan(_rand_pack(DIMS), mode="fused",
                               interpret=True, ws_bucket_rows=0)
    assert not any(p == "fused_ws"
                   for p in plan0.describe()["bucket_paths"].values())
    plan2 = serving.build_plan(_rand_pack(DIMS), mode="fused",
                               interpret=True, ws_bucket_rows=2)
    paths = plan2.describe()["bucket_paths"]
    assert paths[1] == "fused_ws" and paths[2] == "fused_ws"
    assert paths[4] == "fused"


def test_measured_crossover_replaces_constant_prior(tmp_path, monkeypatch):
    """A persisted ws crossover for this backend+stack becomes the plan's
    prior: the WS_BUCKET_ROWS constant only answers when nothing was ever
    measured."""
    from repro.kernels import autotune
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "cache.json"))
    autotune.clear_memory_cache()
    try:
        pack = _rand_pack(DIMS, seed=12)
        plan = serving.build_plan(pack, mode="fused", interpret=True)
        d = plan.describe()
        assert d["ws_prior_source"] == "constant"
        assert d["ws_prior_rows"] == serving.plans.WS_BUCKET_ROWS
        assert d["bucket_schedules"][8] == "ws"

        autotune.record_ws_crossover(2, DIMS[0], DIMS[-1],
                                     backend="interpret",
                                     stack="stack129x71x7")
        plan2 = serving.build_plan(pack, mode="fused", interpret=True)
        d2 = plan2.describe()
        assert d2["ws_prior_source"] == "measured"
        assert d2["ws_prior_rows"] == 2
        assert d2["bucket_schedules"][1] == "ws"
        assert d2["bucket_schedules"][2] == "ws"
        assert d2["bucket_schedules"][4] == "batch_tiled"
        assert d2["ws_crossover_rows"] == 2
    finally:
        autotune.clear_memory_cache()


def test_opt_out_plan_never_records_a_crossover(tmp_path, monkeypatch):
    """A ws-opt-out (or capped) plan's bucket table reflects the caller's
    restriction, not a measurement — it must not write a 'measured'
    crossover that future default plans would trust."""
    from repro.kernels import autotune
    from repro.kernels.autotune import BlockConfig
    from repro.serving import plans as plans_mod
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "cache.json"))
    autotune.clear_memory_cache()
    monkeypatch.setattr(
        plans_mod.autotune, "get_schedule_config",
        lambda rows, k, n, *, schedules, prior, **kw: BlockConfig(
            min(8, rows), 0, 0, source="sweep", schedule=prior))
    try:
        pack = _rand_pack(DIMS, seed=17)
        # interpret=False exercises the recording branch; the fake tuner
        # keeps real kernels out of the non-interpret path.
        plan = serving.build_plan(pack, mode="fused", interpret=False,
                                  ws_bucket_rows=0, block_m=32)
        assert plan.ws_crossover_rows == 0
        assert autotune.get_ws_crossover(
            DIMS[0], DIMS[-1], backend="cpu",
            stack="stack129x71x7") is None, \
            "opt-out plan must not persist a crossover"
        plan2 = serving.build_plan(pack, mode="fused", interpret=False,
                                   block_m=32)
        assert autotune.get_ws_crossover(
            DIMS[0], DIMS[-1], backend="cpu",
            stack="stack129x71x7") == plan2.ws_crossover_rows
    finally:
        autotune.clear_memory_cache()


def test_plans_bind_measured_per_bucket_winners(monkeypatch):
    """ExecutionPlan consumes whatever the per-bucket tuner returns — a
    measured 'stream wins the mid buckets' table binds fused_stream
    entries whose per-bucket block_m reaches the kernel, and serving
    through them stays correct."""
    from repro.kernels.autotune import BlockConfig
    from repro.serving import plans as plans_mod

    calls = []

    def fake_schedule_config(rows, k, n, *, schedules, prior, **kw):
        calls.append((rows, tuple(schedules), prior))
        sched = "stream" if rows >= 16 else "ws"
        if sched not in schedules:
            sched = prior
        return BlockConfig(min(8, rows), 0, 0, source="sweep",
                           schedule=sched)

    monkeypatch.setattr(plans_mod.autotune, "get_schedule_config",
                        fake_schedule_config)
    pack = _rand_pack(DIMS, seed=13)
    plan = serving.build_plan(pack, mode="fused", interpret=True)
    d = plan.describe()
    assert calls and all(rows in plan.bucket_sizes for rows, _, _ in calls)
    assert d["bucket_schedules"][1] == "ws"
    assert d["bucket_schedules"][16] == "stream"
    assert d["bucket_sources"][16] == "sweep"
    assert d["bucket_block_m"][16] == 8     # per-bucket tile, not global
    assert d["ws_crossover_rows"] == 8      # largest ws-bound bucket
    assert "streaming" in plan.mode_label(16)
    assert plan.schedule_for(16) == "stream"
    # the stream binding serves correctly (block_m=8 -> 2 tiles at b=16)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(16, DIMS[0])),
                    jnp.float32)
    oracle = serving.build_plan(pack, mode="oracle")
    np.testing.assert_allclose(plan.run(x), oracle.run(x),
                               atol=1e-3, rtol=1e-4)


def test_stream_rescues_stack_too_big_for_batch_tiled():
    """A stack whose *total* working set busts the batch-tiled budget but
    whose per-layer streamed set fits resolves to fused with stream
    buckets instead of dropping all the way to per_layer."""
    from repro.kernels.fantastic4_fused_mlp import (fused_mlp_vmem_bytes,
                                                    stream_mlp_vmem_bytes)
    dims = (256,) * 7
    pack = _rand_pack(dims, seed=21)
    shapes = tuple(zip(dims[:-1], dims[1:]))
    stack_b = fused_mlp_vmem_bytes(shapes, block_m=256)
    stream_b = stream_mlp_vmem_bytes(shapes, rows=256, block_m=256)
    assert stream_b < stack_b, "test premise: stream must be the smaller set"
    budget = (stream_b + stack_b) // 2
    plan = serving.build_plan(pack, mode="auto", interpret=True,
                              vmem_budget_bytes=budget)
    d = plan.describe()
    assert d["resolved_mode"] == "fused"
    assert any("layer-streamed" in n for n in d["notes"])
    assert d["bucket_schedules"][32] == "stream"
    assert d["default_path"] == "per_layer"   # past the largest bucket
    x = jnp.asarray(np.random.default_rng(6).normal(size=(32, dims[0])),
                    jnp.float32)
    oracle = serving.build_plan(pack, mode="oracle")
    np.testing.assert_allclose(plan.run(x), oracle.run(x),
                               atol=1e-3, rtol=1e-4)


def test_overflow_default_path_honors_double_buffer():
    """Batches past the largest bucket run at exact size; a requested
    double buffer must reach them (it did before per-bucket binding)."""
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused",
                              interpret=True, double_buffer=True,
                              max_bucket=16)
    assert plan.default_path == "fused_db"
    assert plan.path_for(64) == "fused_db"
    plain = serving.build_plan(_rand_pack(DIMS), mode="fused",
                               interpret=True, max_bucket=16)
    assert plain.default_path == "fused"


def test_schedule_measure_fit_guards_candidates():
    """The sweep's measure closure returns inf for a (schedule, block_m)
    candidate whose working set busts the budget — otherwise the kernel
    wrapper's silent chain fallback could win the timing and the bucket
    would carry a fused label over per-layer execution."""
    from repro.kernels.fantastic4_fused_mlp import stream_mlp_vmem_bytes
    dims = (256,) * 7
    pack = _rand_pack(dims, seed=23)
    shapes = tuple(zip(dims[:-1], dims[1:]))
    lo = stream_mlp_vmem_bytes(shapes, rows=256, block_m=8)
    hi = stream_mlp_vmem_bytes(shapes, rows=256, block_m=256)
    assert lo < hi
    plan = serving.build_plan(pack, mode="auto", interpret=True,
                              vmem_budget_bytes=(lo + hi) // 2)
    measure = plan._schedule_measure(256)
    assert measure("stream", 256) == float("inf")
    assert measure("stream", 8) < float("inf")


def test_stream_entry_matches_batch_tiled_bitwise_int8():
    """The engine-facing contract behind re-binding a bucket to stream:
    on the int8 grid the streaming schedule is bit-identical to the
    batch-tiled megakernel, so a measured re-bind can never change
    results."""
    pack = _rand_pack((512, 512, 256, 12), seed=4)
    x = jnp.asarray(np.random.default_rng(9).normal(size=(48, 512)),
                    jnp.float32)
    calib = serving.calibrate_act_scales(pack, x)
    i_stream = ops.fantastic4_mlp_fused(
        x, pack["layers"], interpret=True, schedule="stream", block_m=16,
        act_dtype="int8", act_scales=calib["act_scales"])
    i_mk = ops.fantastic4_mlp_fused(
        x, pack["layers"], interpret=True,
        act_dtype="int8", act_scales=calib["act_scales"])
    np.testing.assert_array_equal(np.asarray(i_stream), np.asarray(i_mk))


def test_compat_wrappers_flow_through_plans():
    """mlp_serve/mlp_serve_int8 are thin shims over ExecutionPlan now —
    same results, no mode keywords reaching the kernels directly."""
    from repro.models import mlp as M
    pack = _rand_pack(DIMS, seed=8)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(5, DIMS[0])),
                    jnp.float32)
    plan = serving.build_plan(pack, mode="fused", interpret=True,
                              ws_bucket_rows=0)
    np.testing.assert_array_equal(
        np.asarray(M.mlp_serve(pack, x, interpret=True)),
        np.asarray(plan.run(x)))
