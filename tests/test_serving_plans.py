"""ExecutionPlan: mode/bucket resolution, entry caching, and parity of the
weight-stationary latency schedule against the batch-tiled megakernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.core import bitplanes as bp
from repro.kernels import ops


def _rand_pack(dims, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        codes = rng.integers(0, 16, size=(k + (k % 2), n)).astype(np.uint8)
        if k % 2:
            codes[-1] = 0
        layers.append({
            "packed": bp.pack_codes_rows(jnp.asarray(codes)),
            "omega": jnp.asarray(rng.normal(size=4) / np.sqrt(k), jnp.float32),
            "alpha1": jnp.asarray(rng.normal(size=n) * 0.5, jnp.float32),
            "bias": jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32),
            "alpha2": jnp.asarray(np.float32(1.0)),
            "shape": (k, n),
            "activation": "relu" if i < len(dims) - 2 else None,
        })
    return {"layers": layers, "act_bits": None}


DIMS = (33, 129, 71, 7)


def test_auto_resolves_fused_and_buckets_are_pow2():
    plan = serving.build_plan(_rand_pack(DIMS), mode="auto", interpret=True)
    d = plan.describe()
    assert d["resolved_mode"] == "fused"
    assert d["bucket_sizes"] == sorted(d["bucket_sizes"])
    assert all(b & (b - 1) == 0 for b in d["bucket_sizes"])
    assert d["bucket_sizes"][0] == 1
    assert max(d["bucket_sizes"]) <= max(d["block_m"], 1)


def test_vmem_overflow_resolves_to_per_layer_with_note():
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused", interpret=True,
                              vmem_budget_bytes=1)
    d = plan.describe()
    assert d["resolved_mode"] == "per_layer"
    assert any("VMEM" in n for n in d["notes"])
    # and it still serves correctly
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, DIMS[0])),
                    jnp.float32)
    oracle = serving.build_plan(_rand_pack(DIMS), mode="oracle")
    np.testing.assert_allclose(plan.run(x), oracle.run(x),
                               atol=1e-3, rtol=1e-4)


def test_bucket_paths_ws_db_and_plain():
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused", interpret=True,
                              double_buffer=True)
    paths = plan.describe()["bucket_paths"]
    assert paths[1] == "fused_ws" and paths[8] == "fused_ws"
    assert paths[16] == "fused_db"
    assert plan.path_for(9) in ("fused", "fused_db")
    # batch label reflects the resolved bucket, not the request flags
    assert "weight-stationary" in plan.mode_label(1)
    assert "double-buffered" in plan.mode_label(16)


def test_double_buffer_note_when_it_cannot_engage():
    plan = serving.build_plan(_rand_pack(DIMS), mode="per_layer",
                              interpret=True, double_buffer=True)
    assert any("double_buffer" in n for n in plan.notes)


def test_run_pads_to_bucket_and_slices_back():
    pack = _rand_pack(DIMS)
    plan = serving.build_plan(pack, mode="fused", interpret=True)
    oracle = serving.build_plan(pack, mode="oracle")
    for m in (1, 3, 5, 8, 13):
        x = jnp.asarray(np.random.default_rng(m).normal(size=(m, DIMS[0])),
                        jnp.float32)
        y = plan.run(x)
        assert y.shape == (m, DIMS[-1])
        np.testing.assert_allclose(y, oracle.run(x), atol=1e-3, rtol=1e-4)


def test_entry_is_cached_and_shape_checked():
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused", interpret=True)
    assert plan.entry(4) is plan.entry(4)
    with pytest.raises(KeyError):
        plan.entry(3)                      # not a bucket
    with pytest.raises(AssertionError):
        plan.entry(4)(jnp.zeros((5, DIMS[0]), jnp.float32))


def test_int8_calibration_happens_once_and_matches_chain():
    pack = _rand_pack(DIMS, seed=3)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, DIMS[0])),
                    jnp.float32)
    calib = serving.calibrate_act_scales(pack, x)
    plan = serving.build_plan(pack, mode="fused", act_dtype="int8",
                              calib=calib, interpret=True)
    y_plan = plan.run(x)
    y_chain = ops.fantastic4_mlp_chain_int8(
        x, pack["layers"], calib["act_scales"], use_kernel=True,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(y_plan), np.asarray(y_chain))
    # without calib, the plan self-calibrates on a synthetic batch + notes it
    plan2 = serving.build_plan(pack, mode="fused", act_dtype="int8",
                               interpret=True)
    assert plan2.act_scales is not None
    assert any("calibration" in n for n in plan2.notes)


def test_ws_schedule_matches_batch_tiled_megakernel():
    """The weight-stationary latency path reproduces the batch-tiled
    megakernel: allclose on fp32, bit-for-bit on the int8 grid (they share
    decode + epilogue arithmetic; only the dataflow differs)."""
    for dims in (DIMS, (512, 512, 256, 12), (47, 96, 13)):
        pack = _rand_pack(dims, seed=sum(dims))
        x = jnp.asarray(np.random.default_rng(2).normal(size=(4, dims[0])),
                        jnp.float32)
        calib = serving.calibrate_act_scales(pack, x)
        y_ws = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                        weight_stationary=True)
        y_mk = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True)
        np.testing.assert_allclose(y_ws, y_mk, atol=1e-4, rtol=1e-5)
        i_ws = ops.fantastic4_mlp_fused(
            x, pack["layers"], interpret=True, weight_stationary=True,
            act_dtype="int8", act_scales=calib["act_scales"])
        i_mk = ops.fantastic4_mlp_fused(
            x, pack["layers"], interpret=True,
            act_dtype="int8", act_scales=calib["act_scales"])
        np.testing.assert_array_equal(np.asarray(i_ws), np.asarray(i_mk),
                                      err_msg=str(dims))


def test_ws_overbudget_falls_back_to_chain():
    pack = _rand_pack(DIMS, seed=5)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, DIMS[0])),
                    jnp.float32)
    y_fb = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                    weight_stationary=True,
                                    vmem_budget_bytes=1)
    y_ch = ops.fantastic4_mlp_chain(x, pack["layers"], use_kernel=True,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(y_fb), np.asarray(y_ch))


def test_get_plan_memoizes_per_pack_and_config():
    pack = _rand_pack(DIMS)
    a = serving.get_plan(pack, mode="fused", interpret=True)
    b = serving.get_plan(pack, mode="fused", interpret=True)
    c = serving.get_plan(pack, mode="per_layer", interpret=True)
    assert a is b
    assert a is not c
    other = _rand_pack(DIMS, seed=9)
    assert serving.get_plan(other, mode="fused", interpret=True) is not a


def test_compat_wrappers_flow_through_plans():
    """mlp_serve/mlp_serve_int8 are thin shims over ExecutionPlan now —
    same results, no mode keywords reaching the kernels directly."""
    from repro.models import mlp as M
    pack = _rand_pack(DIMS, seed=8)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(5, DIMS[0])),
                    jnp.float32)
    plan = serving.build_plan(pack, mode="fused", interpret=True,
                              ws_bucket_rows=0)
    np.testing.assert_array_equal(
        np.asarray(M.mlp_serve(pack, x, interpret=True)),
        np.asarray(plan.run(x)))
