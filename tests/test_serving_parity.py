"""Cross-path serving parity harness (PR 2 tentpole test).

Five serving paths exist for a frozen pack and they must not drift:

    fp32:  oracle chain │ per-layer kernel │ fused megakernel
    int8:  oracle chain │ per-layer kernel │ fused megakernel

plus the double-buffered, weight-stationary and decode-amortized
streaming megakernel schedules and the VMEM-overflow fallback of each
fused path.  Contracts checked here:

* fp32 paths agree with the pure-jnp oracle to close tolerance (f32
  accumulation noise only).
* int8 *kernel* paths agree **exactly**: fused == per-layer chain ==
  double-buffered == streaming == over-budget fallback, bit for bit —
  they share the scale-folding arithmetic term for term (the §VI-C
  contract; asserted with ``assert_array_equal``).  The int8 oracle is a
  different fp implementation, so a quantization-boundary flip is
  possible there; it gets a relative gate instead.  The weight-stationary
  and streaming schedules' bitwise anchor is the batch-tiled megakernel
  (identical decode + epilogue; only the dataflow and K-padding width
  differ) — the streaming path is additionally pinned to a small block_m
  so multi-tile batches exercise the decode-amortization/ping-pong
  machinery, not the one-tile degenerate case.
* the fallback paths engage (budget=1) and change nothing.

The sweep is hypothesis-driven when hypothesis is installed; a
deterministic seeded sweep over random widths (odd-K included) and batches
{1, 16, 256} always runs, so the harness is tier-1 either way.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_mlps import MLPS
from repro.core import bitplanes as bp
from repro.kernels import ops
from repro.models import mlp as M

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _rand_pack(dims, seed=0):
    """Synthetic frozen pack at BN-realistic magnitudes (activations O(1),
    as freeze_mlp's folded constants make them)."""
    rng = np.random.default_rng(seed)
    layers = []
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        codes = rng.integers(0, 16, size=(k + (k % 2), n)).astype(np.uint8)
        if k % 2:
            codes[-1] = 0         # pack invariant: odd K pads a zero row
        layers.append({
            "packed": bp.pack_codes_rows(jnp.asarray(codes)),
            "omega": jnp.asarray(rng.normal(size=4) / np.sqrt(k), jnp.float32),
            "alpha1": jnp.asarray(rng.normal(size=n) * 0.5, jnp.float32),
            "bias": jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32),
            "alpha2": jnp.asarray(np.float32(1.0)),
            "shape": (k, n),
            "activation": "relu" if i < len(dims) - 2 else None,
        })
    return {"layers": layers, "act_bits": None}


def _check_parity(dims, batch, seed):
    pack = _rand_pack(dims, seed=seed)
    x = jnp.asarray(np.random.default_rng(seed + 1).normal(
        size=(batch, dims[0])), jnp.float32)
    calib = M.calibrate_act_scales(pack, x)

    # ---- fp32 paths vs oracle
    y_oracle = M.mlp_serve(pack, x, use_kernel=False)
    y_layer = M.mlp_serve(pack, x, fused=False, interpret=True)
    y_fused = M.mlp_serve(pack, x, fused=True, interpret=True)
    y_db = M.mlp_serve(pack, x, fused=True, interpret=True,
                       double_buffer=True)
    for name, y in (("per-layer", y_layer), ("fused", y_fused),
                    ("double-buffer", y_db)):
        np.testing.assert_allclose(
            y, y_oracle, atol=1e-3, rtol=1e-4,
            err_msg=f"fp32 {name} drifted from oracle ({dims}, b={batch})")

    # ---- weight-stationary schedule (the engine's latency bucket)
    y_ws = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                    weight_stationary=True)
    np.testing.assert_allclose(
        y_ws, y_oracle, atol=1e-3, rtol=1e-4,
        err_msg=f"fp32 weight-stationary drifted ({dims}, b={batch})")

    # ---- streaming schedule (mid-size buckets): block_m=8 forces
    # multiple batch tiles whenever batch > 8, so the once-per-layer
    # decode is genuinely reused across tiles, not trivially once.
    y_stream = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                        schedule="stream", block_m=8)
    np.testing.assert_allclose(
        y_stream, y_oracle, atol=1e-3, rtol=1e-4,
        err_msg=f"fp32 streaming drifted ({dims}, b={batch})")

    # ---- int8 kernel paths: exact agreement on the quantized datapath
    i8_layer = M.mlp_serve_int8(pack, calib, x, use_kernel=True,
                                fused=False, interpret=True)
    i8_fused = M.mlp_serve_int8(pack, calib, x, fused=True, interpret=True)
    i8_db = M.mlp_serve_int8(pack, calib, x, fused=True, interpret=True,
                             double_buffer=True)
    i8_ws = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                     weight_stationary=True,
                                     act_dtype="int8",
                                     act_scales=calib["act_scales"])
    i8_stream = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                         schedule="stream", block_m=8,
                                         act_dtype="int8",
                                         act_scales=calib["act_scales"])
    np.testing.assert_array_equal(
        np.asarray(i8_fused), np.asarray(i8_layer),
        err_msg=f"int8 fused != per-layer chain ({dims}, b={batch})")
    np.testing.assert_array_equal(
        np.asarray(i8_db), np.asarray(i8_fused),
        err_msg=f"int8 double-buffer != fused ({dims}, b={batch})")
    np.testing.assert_array_equal(
        np.asarray(i8_ws), np.asarray(i8_fused),
        err_msg=f"int8 weight-stationary != fused ({dims}, b={batch})")
    np.testing.assert_array_equal(
        np.asarray(i8_stream), np.asarray(i8_fused),
        err_msg=f"int8 streaming != fused ({dims}, b={batch})")

    # ---- int8 oracle: different fp implementation — relative gate only
    # (a quantization-boundary flip is legitimate there)
    i8_oracle = M.mlp_serve_int8(pack, calib, x, use_kernel=False)
    denom = max(float(jnp.max(jnp.abs(i8_oracle))), 1e-6)
    rel = float(jnp.max(jnp.abs(i8_oracle - i8_layer))) / denom
    assert rel < 5e-3, (dims, batch, rel)

    # ---- int8 tracks fp32 (the paper's 'without harming prediction')
    rel8 = float(jnp.linalg.norm(i8_fused - y_oracle)
                 / max(float(jnp.linalg.norm(y_oracle)), 1e-6))
    assert rel8 < 0.1, (dims, batch, rel8)

    # ---- VMEM-overflow fallback: engages and changes nothing
    fb32 = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                    vmem_budget_bytes=1)
    np.testing.assert_array_equal(np.asarray(fb32), np.asarray(y_layer))
    fb8 = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                   act_dtype="int8",
                                   act_scales=calib["act_scales"],
                                   vmem_budget_bytes=1)
    np.testing.assert_array_equal(np.asarray(fb8), np.asarray(i8_layer))
    # streaming schedule has its own fit (whole batch resident): a 1-byte
    # budget must drop it to the same per-layer chain, bit for bit
    fb_stream = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                         schedule="stream",
                                         vmem_budget_bytes=1)
    np.testing.assert_array_equal(np.asarray(fb_stream),
                                  np.asarray(y_layer))
    fb8_stream = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                          schedule="stream",
                                          act_dtype="int8",
                                          act_scales=calib["act_scales"],
                                          vmem_budget_bytes=1)
    np.testing.assert_array_equal(np.asarray(fb8_stream),
                                  np.asarray(i8_layer))


# deterministic hypothesis-style sweep: random widths (odd-K included in
# half the stacks by construction), always present in tier-1.
_SWEEP_RNG = np.random.default_rng(20260730)
_RANDOM_STACKS = []
for _case in range(4):
    _depth = int(_SWEEP_RNG.integers(2, 5))
    _dims = tuple(int(v) for v in _SWEEP_RNG.integers(5, 160, size=_depth + 1))
    _RANDOM_STACKS.append(_dims)
_RANDOM_STACKS.append((33, 129, 71, 7))       # guaranteed odd-K everywhere


@pytest.mark.parametrize("dims", _RANDOM_STACKS,
                         ids=["x".join(map(str, d)) for d in _RANDOM_STACKS])
@pytest.mark.parametrize("batch", [1, 16])
def test_parity_random_widths(dims, batch):
    _check_parity(dims, batch, seed=sum(dims) + batch)


@pytest.mark.parametrize("stack", sorted(MLPS))
@pytest.mark.parametrize("batch", [1, 16, 256])
def test_parity_paper_stacks(stack, batch):
    """Acceptance gate: every paper stack, batches 1-256, all paths."""
    dims = (MLPS[stack].d_in,) + tuple(MLPS[stack].features)
    _check_parity(dims, batch, seed=sorted(MLPS).index(stack) * 100 + batch)


def test_large_batch_random_odd_k():
    """batch=256 on a random odd-K stack (kept to one case — interpret
    mode makes big batches expensive; the paper stacks above cover 256)."""
    _check_parity((47, 96, 13), batch=256, seed=12)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(2, 4).flatmap(
               lambda depth: st.tuples(*[st.integers(4, 140)
                                         for _ in range(depth + 1)])),
           st.sampled_from([1, 16, 256]),
           st.integers(0, 2 ** 16))
    def test_parity_hypothesis(dims, batch, seed):
        _check_parity(tuple(dims), batch, seed)
