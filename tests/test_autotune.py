"""Autotuner: cache round-trip (cold sweep -> JSON persist -> warm hit),
heuristic shape-clamping, and the ops-level None-block integration."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplanes as bp
from repro.kernels import autotune, ops, ref


@pytest.fixture
def tuner_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


def test_cold_sweep_persists_and_warm_hit_skips_measure(tuner_cache):
    measured = []

    def fake_measure(cfg):
        measured.append(cfg)
        # prefer the largest block_k, then largest block_n, smallest block_m
        return 1.0 / (cfg.block_k * 1e6 + cfg.block_n * 1e3 + 1.0 / cfg.block_m)

    cold = autotune.get_block_config(64, 512, 256, dtype="float32",
                                     fused=False, backend="tpu",
                                     measure=fake_measure)
    assert measured, "cold call must run the sweep"
    assert cold.source == "sweep"
    assert os.path.exists(tuner_cache)
    raw = json.loads(tuner_cache.read_text())
    key = autotune.cache_key(64, 512, 256, dtype="float32", fused=False,
                             backend="tpu")
    assert raw[key]["block_m"] == cold.block_m

    # fresh process analogue: drop the memory cache, keep the JSON
    autotune.clear_memory_cache()
    measured2 = []
    warm = autotune.get_block_config(64, 512, 256, dtype="float32",
                                     fused=False, backend="tpu",
                                     measure=lambda c: measured2.append(c) or 0.0)
    assert not measured2, "warm hit must not re-measure"
    assert warm.same_blocks(cold)


def test_distinct_keys_do_not_collide(tuner_cache):
    a = autotune.get_block_config(8, 512, 256, dtype="float32", fused=False,
                                  backend="cpu")
    b = autotune.get_block_config(256, 512, 256, dtype="float32", fused=False,
                                  backend="cpu")
    c = autotune.get_block_config(8, 512, 256, dtype="float32", fused=True,
                                  backend="cpu")
    raw = json.loads(tuner_cache.read_text())
    assert len(raw) == 3
    assert a.block_m <= 8 or a.block_m == 8  # clamped to padded batch
    assert b.block_m >= a.block_m
    assert c is not None


def test_fused_stacks_with_same_ends_get_distinct_keys(tuner_cache):
    """MLP-GSC and MLP-HR share (M, K0=512, N_last=12); the fused cache key
    must still tell them apart via the hidden-width extra."""
    a = autotune.cache_key(64, 512, 12, dtype="float32", fused=True,
                           backend="tpu", extra="stack512x512x256x12")
    b = autotune.cache_key(64, 512, 12, dtype="float32", fused=True,
                           backend="tpu", extra="stack512x256x128x12")
    assert a != b
    autotune.get_block_config(64, 512, 12, dtype="float32", fused=True,
                              backend="tpu", extra="stack512x512x256x12")
    autotune.get_block_config(64, 512, 12, dtype="float32", fused=True,
                              backend="tpu", extra="stack512x256x128x12")
    raw = json.loads(tuner_cache.read_text())
    assert len(raw) == 2


def test_interpret_mode_does_not_poison_backend_key(tuner_cache):
    """Interpret-mode resolution (backend="interpret") must not occupy the
    real backend's cache slot, or the TPU timed sweep would never run."""
    autotune.get_block_config(64, 512, 256, dtype="float32", fused=False,
                              backend="interpret")
    measured = []
    swept = autotune.get_block_config(64, 512, 256, dtype="float32",
                                      fused=False, backend="tpu",
                                      measure=lambda c: measured.append(c)
                                      or 1.0)
    assert measured, "tpu-key resolution must still sweep"
    assert swept.source == "sweep"


def test_act_dtype_distinguishes_entries(tuner_cache):
    """The int8 fused kernel has a different body (per-layer quantize);
    its tuned blocks must not share a slot with the fp32 sweep."""
    a = autotune.cache_key(64, 512, 12, dtype="float32", fused=True,
                           backend="tpu", act_dtype="float32")
    b = autotune.cache_key(64, 512, 12, dtype="float32", fused=True,
                           backend="tpu", act_dtype="int8")
    assert a != b
    autotune.get_block_config(64, 512, 12, dtype="float32", fused=True,
                              backend="tpu", act_dtype="float32")
    autotune.get_block_config(64, 512, 12, dtype="float32", fused=True,
                              backend="tpu", act_dtype="int8")
    raw = json.loads(tuner_cache.read_text())
    assert len(raw) == 2


def test_stale_pre_act_dtype_cache_is_migrated(tuner_cache):
    """A PR-1-era JSON (keys without the act segment) must load cleanly:
    its entries resurface under act_dtype=float32 instead of crashing or
    being re-swept."""
    old_key = "tpu|m64|k512|n256|float32|fused0"
    old_fused = "tpu|m64|k512|n12|float32|fused1|stack512x256x12"
    tuner_cache.write_text(json.dumps({
        old_key: {"block_m": 32, "block_n": 128, "block_k": 256,
                  "source": "sweep"},
        old_fused: {"block_m": 64, "block_n": 1024, "block_k": 2048,
                    "source": "sweep"},
        "corrupt-entry": {"nope": 1},          # ignored, not fatal
    }))
    autotune.clear_memory_cache()
    measured = []
    cfg = autotune.get_block_config(64, 512, 256, dtype="float32",
                                    fused=False, backend="tpu",
                                    measure=lambda c: measured.append(c)
                                    or 1.0)
    assert not measured, "migrated entry must hit, not re-sweep"
    assert cfg.as_tuple() == (32, 128, 256)
    cfg2 = autotune.get_block_config(64, 512, 12, dtype="float32",
                                     fused=True, backend="tpu",
                                     extra="stack512x256x12",
                                     measure=lambda c: measured.append(c)
                                     or 1.0)
    assert not measured
    assert cfg2.as_tuple() == (64, 1024, 2048)
    # int8 lookups for the same shape/backend do NOT inherit the migrated
    # fp32 entry: the sweep must run afresh
    int8_measured = []
    int8_cfg = autotune.get_block_config(
        64, 512, 12, dtype="float32", fused=True, backend="tpu",
        act_dtype="int8", extra="stack512x256x12",
        measure=lambda c: int8_measured.append(c) or 1.0)
    assert int8_measured, "int8 key must not hit the migrated fp32 entry"
    assert int8_cfg.source == "sweep"


def test_migrate_key_roundtrip():
    new = autotune.cache_key(8, 16, 32, dtype="float32", fused=True,
                             backend="cpu", act_dtype="int8", extra="e")
    assert autotune._migrate_key(new) == new       # current format: no-op
    old = "cpu|m8|k16|n32|float32|fused1|e"
    assert autotune._migrate_key(old) == \
        "cpu|m8|k16|n32|float32|fused1|actfloat32|e"


def test_interpret_mode_act_dtype_keys_do_not_mask_backend(tuner_cache):
    """Interpret-mode int8 answers stay under backend="interpret" — the
    real backend's int8 sweep must still run later."""
    autotune.get_block_config(64, 512, 12, dtype="float32", fused=True,
                              backend="interpret", act_dtype="int8")
    measured = []
    swept = autotune.get_block_config(64, 512, 12, dtype="float32",
                                      fused=True, backend="tpu",
                                      act_dtype="int8",
                                      measure=lambda c: measured.append(c)
                                      or 1.0)
    assert measured, "tpu int8 key must still sweep"
    assert swept.source == "sweep"


def test_heuristic_clamps_to_problem_dims():
    cfg = autotune.heuristic_blocks(1, 784, 12, backend="tpu")
    assert cfg.block_m == 8               # batch 1 -> one f32 sublane tile
    assert cfg.block_n == 128             # 12 -> one lane tile, not 256
    assert cfg.block_k <= 896
    big = autotune.heuristic_blocks(4096, 4096, 4096, backend="tpu")
    assert big.as_tuple() == (128, 256, 512)  # falls back to seed defaults


def test_failed_candidates_fall_back_to_heuristic(tuner_cache):
    cfg = autotune.get_block_config(16, 64, 64, dtype="float32", fused=False,
                                    backend="tpu",
                                    measure=lambda c: float("inf"))
    assert cfg.source == "heuristic"


def test_elementwise_cache_key_distinct_from_matmul(tuner_cache):
    """ecl_quant's (block_r, block_c) entries live under k=0 + an op extra:
    they must never collide with a matmul shape's blocks (satellite
    cache-key contract)."""
    ew = autotune.cache_key(256, 0, 512, dtype="float32", fused=False,
                            backend="tpu", extra="eclquant")
    mm = autotune.cache_key(256, 0, 512, dtype="float32", fused=False,
                            backend="tpu")
    assert ew != mm
    autotune.get_elementwise_config(256, 512, backend="tpu")
    autotune.get_block_config(256, 0, 512, dtype="float32", fused=False,
                              backend="tpu")
    raw = json.loads(tuner_cache.read_text())
    assert len(raw) == 2
    assert ew in raw


def test_elementwise_cold_sweep_persists_and_warm_hit(tuner_cache):
    measured = []

    def fake_measure(cfg):
        measured.append(cfg)
        return 1.0 / (cfg.block_m * 1e3 + cfg.block_n)

    cold = autotune.get_elementwise_config(300, 700, backend="tpu",
                                           measure=fake_measure)
    assert measured and cold.source == "sweep"
    assert cold.block_k == 0               # elementwise sentinel
    autotune.clear_memory_cache()
    warm = autotune.get_elementwise_config(
        300, 700, backend="tpu",
        measure=lambda c: measured.append(("again", c)) or 0.0)
    assert not any(isinstance(m, tuple) for m in measured), \
        "warm hit must not re-measure"
    assert warm.same_blocks(cold)


def test_elementwise_heuristic_clamps_to_problem():
    cfg = autotune.heuristic_elementwise_blocks(5, 30, backend="tpu")
    assert cfg.block_m == 8 and cfg.block_n == 128
    big = autotune.heuristic_elementwise_blocks(4096, 4096, backend="tpu")
    assert 9 * big.block_m * big.block_n <= 4 << 20


def test_ecl_quant_autotuned_blocks_match_ref(tuner_cache):
    """ops.ecl_quant with block_r/block_c=None (the new default) resolves
    via the autotuner and stays bit-accurate vs the oracle."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(100, 30)), jnp.float32)
    omega = jnp.asarray(rng.normal(size=4) * 0.3, jnp.float32)
    probs = jnp.asarray(rng.dirichlet(np.ones(16)), jnp.float32)
    penalty = 0.05 * -jnp.log2(jnp.clip(probs, 1e-8, 1.0))
    ck, wk = ops.ecl_quant(w, omega, penalty, use_kernel=True,
                           interpret=True)
    cr, wr = ref.ecl_quant_ref(w, omega, penalty)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_allclose(wk, wr, atol=1e-5)
    raw = json.loads(tuner_cache.read_text())
    assert any("eclquant" in k for k in raw), \
        "interpret-mode resolution must land under the eclquant key"


# --------------------------- autotuner v2: (bucket, schedule) tuning unit

def test_schedule_sweep_picks_winner_and_persists(tuner_cache):
    """Cold per-bucket sweep measures every eligible (schedule, block_m)
    pair, binds the fastest, persists it with its schedule field; the warm
    hit (fresh process analogue) never re-measures."""
    seen = []

    def fake_measure(sched, bm):
        seen.append((sched, bm))
        return {"stream": 1.0, "batch_tiled": 2.0,
                "db": 3.0, "ws": 4.0}[sched] + 1e-3 / bm

    cold = autotune.get_schedule_config(
        32, 512, 12, schedules=("batch_tiled", "db", "stream", "ws"),
        prior="batch_tiled", backend="tpu", stack="stack512x12",
        measure=fake_measure)
    assert seen, "cold call must sweep"
    assert {s for s, _ in seen} == {"batch_tiled", "db", "stream", "ws"}
    assert cold.schedule == "stream" and cold.source == "sweep"
    # ws holds the whole bucket: exactly one candidate, block_m = padded rows
    assert [bm for s, bm in seen if s == "ws"] == [32]
    # db tiles need two sublane groups: candidates stay multiples of 16
    assert all(bm % 16 == 0 for s, bm in seen if s == "db")

    raw = json.loads(tuner_cache.read_text())
    key = autotune.bucket_cache_key(32, 512, 12, backend="tpu",
                                    stack="stack512x12")
    assert raw[key]["schedule"] == "stream"

    autotune.clear_memory_cache()
    warm = autotune.get_schedule_config(
        32, 512, 12, schedules=("batch_tiled", "db", "stream", "ws"),
        prior="batch_tiled", backend="tpu", stack="stack512x12",
        measure=lambda s, bm: seen.append(("again", s)) or 0.0)
    assert not any(s == "again" for s, _ in seen), "warm hit re-measured"
    assert warm.schedule == "stream" and warm.same_blocks(cold)


def test_schedule_entries_keyed_per_bucket(tuner_cache):
    """Bucket 8 and bucket 32 are distinct tuning units — the whole point
    of v2 — and neither collides with the legacy single fused entry."""
    a = autotune.bucket_cache_key(8, 512, 12, backend="tpu",
                                  stack="stack512x12")
    b = autotune.bucket_cache_key(32, 512, 12, backend="tpu",
                                  stack="stack512x12")
    legacy = autotune.cache_key(8, 512, 12, dtype="float32", fused=True,
                                backend="tpu", extra="stack512x12")
    assert len({a, b, legacy}) == 3
    for rows in (8, 32):
        autotune.get_schedule_config(
            rows, 512, 12, schedules=("batch_tiled", "ws"), prior="ws",
            backend="tpu", stack="stack512x12",
            measure=lambda s, bm: 1.0 if s == "ws" else 2.0)
    raw = json.loads(tuner_cache.read_text())
    assert len(raw) == 2 and a in raw and b in raw


def test_schedule_prior_answers_without_measure_and_is_not_cached(
        tuner_cache):
    """Interpret tier: the prior answers, block_m falls back to the
    heuristic — and the answer must NOT enter the cache (priors depend on
    the caller's eligibility/requests; caching one plan's prior would
    shadow another plan's, and would mask a future real sweep)."""
    cfg = autotune.get_schedule_config(
        4, 512, 12, schedules=("batch_tiled", "ws"), prior="ws",
        backend="interpret", stack="stack512x12")
    assert cfg.schedule == "ws" and cfg.source == "heuristic"
    assert not os.path.exists(tuner_cache) or \
        autotune.bucket_cache_key(4, 512, 12, backend="interpret",
                                  stack="stack512x12") \
        not in json.loads(tuner_cache.read_text())
    # a different caller's restricted eligibility gets ITS prior, not the
    # first caller's answer
    cfg2 = autotune.get_schedule_config(
        4, 512, 12, schedules=("batch_tiled",), prior="batch_tiled",
        backend="interpret", stack="stack512x12")
    assert cfg2.schedule == "batch_tiled"


def test_schedule_migrates_legacy_single_entry_block(tuner_cache):
    """An old cache file holds one fused entry tuned at the largest bucket
    (m=256).  Per-bucket resolution without a measure must migrate its
    block_m (clamped to the bucket) instead of discarding it."""
    legacy_key = autotune.cache_key(256, 512, 12, dtype="float32",
                                    fused=True, backend="tpu",
                                    extra="stack512x12")
    tuner_cache.write_text(json.dumps({
        legacy_key: {"block_m": 64, "block_n": 1024, "block_k": 2048,
                     "source": "sweep"}}))
    autotune.clear_memory_cache()
    cfg = autotune.get_schedule_config(
        8, 512, 12, schedules=("batch_tiled", "ws"), prior="batch_tiled",
        backend="tpu", stack="stack512x12", legacy_m=256)
    assert cfg.source == "migrated"
    assert cfg.block_m == 8                 # min(legacy 64, padded rows 8)
    cfg2 = autotune.get_schedule_config(
        128, 512, 12, schedules=("batch_tiled",), prior="batch_tiled",
        backend="tpu", stack="stack512x12", legacy_m=256)
    assert cfg2.source == "migrated" and cfg2.block_m == 64
    # the legacy entry itself survives a later save untouched
    autotune.record_ws_crossover(8, 512, 12, backend="tpu",
                                 stack="stack512x12")
    raw = json.loads(tuner_cache.read_text())
    assert raw[legacy_key]["block_m"] == 64
    assert "schedule" not in raw[legacy_key]


def test_cached_schedule_outside_eligibility_is_bypassed_not_clobbered(
        tuner_cache):
    """A measured ws binding must survive a ws-opt-out caller: the
    restricted resolution answers from the prior but leaves the cache
    entry alone."""
    swept = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled", "ws"), prior="batch_tiled",
        backend="tpu", stack="stack512x12",
        measure=lambda s, bm: 1.0 if s == "ws" else 2.0)
    assert swept.schedule == "ws"
    restricted = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled",), prior="batch_tiled",
        backend="tpu", stack="stack512x12")
    assert restricted.schedule == "batch_tiled"
    key = autotune.bucket_cache_key(2, 512, 12, backend="tpu",
                                    stack="stack512x12")
    assert json.loads(tuner_cache.read_text())[key]["schedule"] == "ws"


def test_schedule_entries_keyed_per_act_dtype_and_backend(tuner_cache):
    keys = {autotune.bucket_cache_key(8, 512, 12, backend=b,
                                      act_dtype=a, stack="s")
            for b in ("tpu", "interpret") for a in ("float32", "int8")}
    assert len(keys) == 4


def test_restricted_sweep_does_not_shadow_broader_eligibility(tuner_cache):
    """A ws-opt-out plan sweeping FIRST must not pin the bucket for later
    default plans: the entry records the set it measured over, and a
    caller with broader eligibility re-sweeps (and its complete entry then
    serves both)."""
    first = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled", "stream"),
        prior="batch_tiled", backend="tpu", stack="s",
        measure=lambda s, bm: {"batch_tiled": 1.0, "stream": 2.0,
                               "ws": 0.5}[s])
    assert first.schedule == "batch_tiled"
    assert first.swept == ("batch_tiled", "stream")
    # broader caller: ws (never measured above) must get its sweep
    full = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled", "stream", "ws"),
        prior="batch_tiled", backend="tpu", stack="s",
        measure=lambda s, bm: {"batch_tiled": 1.0, "stream": 2.0,
                               "ws": 0.5}[s])
    assert full.schedule == "ws"
    # the complete entry now answers the restricted caller's *bypass*
    # path (ws forbidden -> recompute, uncached) and the full caller's hit
    again = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled", "stream", "ws"),
        prior="batch_tiled", backend="tpu", stack="s",
        measure=lambda s, bm: (_ for _ in ()).throw(AssertionError))
    assert again.schedule == "ws"
    key = autotune.bucket_cache_key(2, 512, 12, backend="tpu", stack="s")
    assert set(json.loads(tuner_cache.read_text())[key]["swept"]) == \
        {"batch_tiled", "stream", "ws"}


def test_incomparable_sweep_sets_converge_via_union(tuner_cache):
    """Two plans with incomparable eligible sets must not ping-pong
    re-sweeps: the second sweep covers the union, the stored entry then
    answers both."""
    times = {"batch_tiled": 2.0, "ws": 1.0, "stream": 3.0, "db": 4.0}
    calls = []

    def measure(s, bm):
        calls.append(s)
        return times[s]

    a = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled", "ws"), prior="batch_tiled",
        backend="tpu", stack="s", measure=measure)
    assert a.schedule == "ws"
    calls.clear()
    b = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled", "stream"),
        prior="batch_tiled", backend="tpu", stack="s", measure=measure)
    # caller B may not bind ws, so it gets its own best...
    assert b.schedule == "batch_tiled"
    # ...but the union sweep measured ws too and stored the union winner
    assert "ws" in calls
    key = autotune.bucket_cache_key(2, 512, 12, backend="tpu", stack="s")
    raw = json.loads(tuner_cache.read_text())[key]
    assert raw["schedule"] == "ws"
    assert set(raw["swept"]) == {"batch_tiled", "ws", "stream"}
    # caller A now hits without re-sweeping: convergence, no ping-pong
    a2 = autotune.get_schedule_config(
        2, 512, 12, schedules=("batch_tiled", "ws"), prior="batch_tiled",
        backend="tpu", stack="s",
        measure=lambda s, bm: (_ for _ in ()).throw(AssertionError))
    assert a2.schedule == "ws"


def test_record_ws_crossover_first_touch_keeps_existing_file(tuner_cache):
    """record_ws_crossover in a fresh process (nothing loaded yet) must
    merge with the on-disk cache, not clobber a committed TPU cache."""
    autotune.get_schedule_config(
        8, 512, 12, schedules=("batch_tiled", "ws"), prior="ws",
        backend="tpu", stack="s", measure=lambda s, bm: 1.0)
    autotune.clear_memory_cache()            # fresh-process analogue
    autotune.record_ws_crossover(4, 512, 12, backend="tpu", stack="s")
    raw = json.loads(tuner_cache.read_text())
    assert autotune.bucket_cache_key(8, 512, 12, backend="tpu",
                                     stack="s") in raw
    assert autotune.get_ws_crossover(512, 12, backend="tpu",
                                     stack="s") == 4


def test_ws_crossover_roundtrip(tuner_cache):
    assert autotune.get_ws_crossover(512, 12, backend="tpu",
                                     stack="stack512x12") is None
    autotune.record_ws_crossover(16, 512, 12, backend="tpu",
                                 stack="stack512x12")
    assert autotune.get_ws_crossover(512, 12, backend="tpu",
                                     stack="stack512x12") == 16
    # fresh process analogue: survives via the JSON file
    autotune.clear_memory_cache()
    assert autotune.get_ws_crossover(512, 12, backend="tpu",
                                     stack="stack512x12") == 16
    # other backends/stacks unaffected
    assert autotune.get_ws_crossover(512, 12, backend="cpu",
                                     stack="stack512x12") is None
    assert autotune.get_ws_crossover(512, 12, backend="tpu",
                                     stack="stack256x12") is None


def test_schedule_failed_sweep_falls_back_to_prior(tuner_cache):
    cfg = autotune.get_schedule_config(
        8, 64, 64, schedules=("batch_tiled", "ws"), prior="ws",
        backend="tpu", stack="s", measure=lambda s, bm: float("inf"))
    assert cfg.schedule == "ws" and cfg.source == "heuristic"


def test_ops_autotuned_blocks_match_ref(tuner_cache):
    """fantastic4_matmul with block_*=None (autotuned) stays bit-accurate."""
    rng = np.random.default_rng(0)
    m, k, n = 5, 130, 72
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 16, size=(k, n)), jnp.uint8)
    packed = bp.pack_codes_rows(codes)
    omega = jnp.asarray(rng.normal(size=4) * 0.2, jnp.float32)
    y_k = ops.fantastic4_matmul(x, packed, omega, use_kernel=True,
                                interpret=True, out_dtype=jnp.float32)
    y_r = ref.fantastic4_matmul_ref(x, packed, omega, out_dtype=jnp.float32)
    np.testing.assert_allclose(y_k, y_r, atol=1e-4, rtol=1e-4)
