"""Entropy-coded (canonical Huffman) format: lossless roundtrip, size ≈
entropy, and selection dominance in the low-entropy regime EC4T creates."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ecl, formats


@given(st.integers(0, 400), st.floats(0.05, 1.0))
@settings(max_examples=30, deadline=None)
def test_huffman_roundtrip(seed, skew):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.full(16, skew))
    codes = rng.choice(16, size=(23, 37), p=p).astype(np.uint8)
    ct = formats.encode_huffman(codes)
    np.testing.assert_array_equal(formats.decode_huffman(ct), codes)


def test_huffman_size_approaches_entropy():
    rng = np.random.default_rng(0)
    p = np.asarray([0.7] + [0.02] * 15)
    codes = rng.choice(16, size=(256, 256), p=p).astype(np.uint8)
    import jax.numpy as jnp
    h = float(ecl.entropy_bits(jnp.asarray(
        np.bincount(codes.reshape(-1), minlength=16) / codes.size,
        jnp.float32)))
    bits = formats.analytic_size_bits_huffman(codes)
    bits_per_w = bits / codes.size
    assert h <= bits_per_w <= h + 0.35, (h, bits_per_w)
    assert formats.encode_huffman(codes).size_bits == \
        formats.analytic_size_bits_huffman(codes) - 0  # matches analytic


def test_huffman_wins_at_low_entropy_dense():
    """Non-sparse but low-entropy codes: CSR/bitmask can't help (few
    zeros), huffman compresses anyway — the regime beyond the paper's
    formats that entropy-constrained training unlocks."""
    rng = np.random.default_rng(1)
    p = np.zeros(16); p[1] = 0.85; p[2:6] = 0.0375  # near-zero sparsity
    codes = rng.choice(16, size=(128, 512), p=p).astype(np.uint8)
    assert (codes == 0).mean() < 0.01
    best = formats.select_format_ext(codes)
    assert best == "huffman", best
    nnz = int(np.count_nonzero(codes))
    h_bits = formats.analytic_size_bits_huffman(codes)
    for f in formats.FORMATS:
        assert h_bits < formats.analytic_size_bits(codes.shape, nnz, f)
