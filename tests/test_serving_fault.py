"""Fault injection + the first two degradation rungs: the FaultInjector
plan wrapper, the batcher's requeue-on-failure (nothing lost), retry
parity under a 10% transient launch-failure rate (100% completion,
bit-identical to the no-fault run), and the poisoned-bucket fallback to
the per-layer chain.  Also the injector's seeded corruption mode (the
flip schedule must be reproducible run-to-run and independent of the
failure schedule) and the hung-launch watchdog (fake-clock stall
flagging + the real heartbeat)."""
import numpy as np
import pytest

from repro import serving
from repro.runtime.fault import FaultInjector, InjectedFault
from test_serving_plans import _rand_pack

DIMS = (16, 12, 4)


def _oracle_plan(dims=DIMS, seed=0):
    return serving.build_plan(_rand_pack(dims, seed=seed), mode="oracle")


def _rows(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=(1, DIMS[0])).astype(np.float32)
            for _ in range(n)]


# ------------------------------------------------------- the injector

def test_injector_proxies_plan_and_fires_probabilistically():
    plan = _oracle_plan()
    inj = FaultInjector(plan, rate=1.0)
    assert inj.d_in == plan.d_in                 # attribute proxy
    assert inj.bucket_for(3) == plan.bucket_for(3)
    assert inj.plan is plan
    with pytest.raises(InjectedFault):
        inj.entry(1)(np.zeros((1, DIMS[0]), np.float32))
    assert inj.injected == 1 and inj.launches == 1
    calm = FaultInjector(plan, rate=0.0)
    y = calm.entry(1)(np.zeros((1, DIMS[0]), np.float32))
    assert np.asarray(y).shape == (1, DIMS[-1])
    assert calm.injected == 0


def test_injector_scheduled_and_systematic_triggers():
    plan = _oracle_plan()
    nth = FaultInjector(plan, fail_nth=(1,))
    e = nth.entry(1)
    x = np.zeros((1, DIMS[0]), np.float32)
    e(x)                                          # launch 0: fine
    with pytest.raises(InjectedFault):
        e(x)                                      # launch 1: scheduled
    e(x)                                          # launch 2: fine
    byb = FaultInjector(plan, fail_buckets=(2,))
    byb.entry(1)(x)
    with pytest.raises(InjectedFault):
        byb.entry(2)(np.zeros((2, DIMS[0]), np.float32))


def test_injector_only_fused_spares_nonfused_bindings():
    """only_fused models a megakernel-specific fault: once the bucket is
    demoted to the per-layer chain, injection stops."""
    plan = serving.build_plan(_rand_pack(DIMS), mode="fused",
                              interpret=True)
    inj = FaultInjector(plan, fail_buckets=(1,), only_fused=True)
    x = np.zeros((1, DIMS[0]), np.float32)
    assert plan.buckets[1].path.startswith("fused")
    with pytest.raises(InjectedFault):
        inj.entry(1)(x)
    plan.demote_bucket(1)
    y = inj.entry(1)(x)                           # chain path: spared
    assert np.asarray(y).shape == (1, DIMS[-1])
    assert plan.buckets[1].source.startswith("degraded")


# ------------------------------------------- requeue: nothing is lost

def test_failed_launch_requeues_taken_requests_in_order():
    plan = _oracle_plan()
    inj = FaultInjector(plan, rate=1.0)
    b = serving.MicroBatcher(inj, max_delay=30.0)
    rids = [b.submit(x) for x in _rows(3)]
    before = b.pending_rows
    with pytest.raises(InjectedFault):
        b.run_one()
    assert b.pending_rows == before               # queue intact
    assert b.stats["launch_failures"] == 1
    assert b.last_failed_bucket == plan.bucket_for(3)
    inj.rate = 0.0                                # fault clears
    done = b.flush()
    assert [c.rid for c in done] == rids          # original FIFO order


def test_drop_all_empties_queue_and_reports_dropped():
    plan = _oracle_plan()
    b = serving.MicroBatcher(plan, max_delay=30.0)
    for x in _rows(3):
        b.submit(x)
    dropped = b.drop_all()
    assert len(dropped) == 3 and b.pending_rows == 0
    assert b.next_deadline() is None


# ------------------------------------ retry parity under 10% faults

def test_retry_parity_10pct_transient_faults_bit_identical():
    """Acceptance: at a 10% transient launch-failure rate every admitted
    request completes, bit-identical to the no-fault run — the retry
    relaunches the same bucket entry on the same host-side rows."""
    xs = _rows(24, seed=3)
    plan = _oracle_plan()

    def serve_all(wrapped):
        fe = serving.ServingFrontend(
            retry_policy=serving.RetryPolicy(max_retries=10))
        fe.register("m", wrapped, max_delay=1e-4)
        with fe:
            # sequential: each request is served alone in its own bucket,
            # so fault and no-fault runs launch identical (entry, input)
            # pairs and bitwise comparison is exact.
            return [fe.submit("m", x).result(30.0).y for x in xs]

    baseline = serve_all(plan)
    inj = FaultInjector(plan, rate=0.10, seed=42)
    faulted = serve_all(inj)
    assert inj.injected > 0                       # the rate actually bit
    assert len(faulted) == len(xs)                # 100% completion
    for a, b in zip(baseline, faulted):
        np.testing.assert_array_equal(a, b)       # bit-identical


def test_retry_parity_under_concurrent_load():
    """Same contract under coalescing: every request completes and is
    correct (allclose vs the plan run alone — the fp32 padding-parity
    tolerance) while faults land mid-stream."""
    xs = _rows(16, seed=9)
    plan = _oracle_plan()
    # coalescing means few launches; fail_nth pins a fault on the first
    # so the retry path is exercised deterministically.
    inj = FaultInjector(plan, rate=0.15, seed=7, fail_nth=(0,))
    fe = serving.ServingFrontend(
        retry_policy=serving.RetryPolicy(max_retries=10))
    fe.register("m", inj, max_delay=2e-3)
    with fe:
        futs = [fe.submit("m", x) for x in xs]
        served = [f.result(30.0) for f in futs]
    assert fe.stats["retries"] >= 1
    for x, s in zip(xs, served):
        np.testing.assert_allclose(s.y, np.asarray(plan.run(x)),
                                   atol=1e-5, rtol=1e-5)


# --------------------------------------- poisoned-bucket fallback

def test_poisoned_fused_bucket_falls_back_to_chain():
    pack = _rand_pack(DIMS)
    plan = serving.build_plan(pack, mode="fused", interpret=True)
    oracle = serving.build_plan(_rand_pack(DIMS), mode="oracle")
    inj = FaultInjector(plan, fail_buckets=(1,), only_fused=True)
    fe = serving.ServingFrontend(
        retry_policy=serving.RetryPolicy(max_retries=1))
    fe.register("m", inj, max_delay=1e-3)
    x = _rows(1, seed=5)[0]
    with fe:
        s = fe.submit("m", x).result(60.0)        # retries, then demotes
    assert plan.buckets[1].path == "per_layer"
    assert plan.buckets[1].source.startswith("degraded")
    assert fe.stats["fallbacks"] == 1
    assert fe.stats["retries"] >= 1
    assert "m" not in fe.stats["quarantined"]     # ladder stopped early
    np.testing.assert_allclose(s.y, np.asarray(oracle.run(x)),
                               atol=1e-3, rtol=1e-4)


# --------------------------------------- seeded flip reproducibility

def test_flip_schedule_reproducible_across_same_seed_runs():
    """Two same-seed injectors over identical plans must fire the same
    failures AND the same bit flips (target, layer, byte, bit) — the
    flip RNG is derived from (seed, salt), so enabling flips never
    perturbs the failure schedule either."""
    def drive(seed):
        plan = _oracle_plan(seed=3)
        inj = FaultInjector(plan, rate=0.15, seed=seed, flip_rate=0.3,
                            flip_targets=("packed", "epilogue"))
        x = np.zeros((1, DIMS[0]), np.float32)
        for _ in range(25):
            try:
                inj.run(x)
            except InjectedFault:
                pass
            except serving.IntegrityError:
                pass
        return list(inj.failures), list(inj.flips)

    fails_a, flips_a = drive(seed=7)
    fails_b, flips_b = drive(seed=7)
    assert fails_a == fails_b and flips_a == flips_b
    assert flips_a, "flip schedule never fired at flip_rate=0.3"
    fails_c, flips_c = drive(seed=8)
    assert (fails_c, flips_c) != (fails_a, flips_a)


def test_failure_schedule_unchanged_by_enabling_flips():
    """The flip RNG is salted off the failure RNG: turning flips on
    must not move WHICH launches fail."""
    def failures(flip_rate):
        plan = _oracle_plan(seed=4)
        inj = FaultInjector(plan, rate=0.2, seed=5, flip_rate=flip_rate)
        x = np.zeros((1, DIMS[0]), np.float32)
        for _ in range(30):
            try:
                inj.run(x)
            except (InjectedFault, serving.IntegrityError):
                pass
        return list(inj.failures)

    assert failures(0.0) == failures(0.5)


# --------------------------------------- hung-launch watchdog

def test_watchdog_flags_stalled_stream_on_fake_clock():
    t = [0.0]
    fe = serving.ServingFrontend(clock=lambda: t[0],
                                 stall_threshold_s=5.0)
    fe.register("m", _oracle_plan(), max_delay=1e-3)
    ss = fe.stats["streams"][0]
    # a launch that entered the device at t=0 and never came back
    with fe._cond:
        ss["last_launch_s"] = 0.0
        ss["inflight"] = True
    t[0] = 4.0
    assert fe.check_stalls() == [] and not ss["stalled"]
    t[0] = 6.0
    assert fe.check_stalls() == [0] and ss["stalled"]
    # the launch finally returns: the flag clears on the next poll
    with fe._cond:
        ss["inflight"] = False
    assert fe.check_stalls() == [] and not ss["stalled"]


def test_watchdog_disabled_without_threshold_and_heartbeat_is_live():
    fe = serving.ServingFrontend()
    fe.register("m", _oracle_plan(), max_delay=1e-3)
    assert fe.check_stalls() == []          # no threshold: never flags
    x = np.zeros((1, DIMS[0]), np.float32)
    with fe:
        fe.submit("m", x).result(30.0)
    ss = fe.stats["streams"][0]
    assert ss["last_launch_s"] is not None  # real launch stamped it
    assert ss["inflight"] is False
