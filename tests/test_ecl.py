"""ECL assignment properties: optimality, entropy/sparsity vs lambda."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bitplanes as bp, ecl


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_assign_minimises_cost(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    omega = jnp.asarray(rng.normal(size=4), jnp.float32)
    probs = jnp.asarray(rng.dirichlet(np.ones(16)), jnp.float32)
    lam = float(rng.uniform(0, 0.5))
    codes = ecl.assign(w, omega, probs, lam)
    book = np.asarray(bp.codebook(omega))
    pen = -np.log2(np.clip(np.asarray(probs), ecl.PROB_FLOOR, 1))
    scale = float(np.mean(np.asarray(w) ** 2))   # scale-invariant penalty
    cost = (np.asarray(w)[:, None] - book) ** 2 + lam * scale * pen
    np.testing.assert_array_equal(np.asarray(codes), cost.argmin(1))


def test_lam_zero_is_nearest_neighbour():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    omega = jnp.asarray([0.1, 0.2, 0.4, -0.8], jnp.float32)
    codes = ecl.assign(w, omega, jnp.full((16,), 1 / 16), 0.0)
    book = np.asarray(bp.codebook(omega))
    nn = np.abs(np.asarray(w)[:, None] - book).argmin(1)
    np.testing.assert_array_equal(np.asarray(codes), nn)


def test_sparsity_and_entropy_monotone_in_lambda():
    """Higher lambda => more mass on popular clusters => lower entropy,
    and (for zero-heavy inits) more exact zeros — the paper's fig. 9 axis."""
    rng = np.random.default_rng(8)
    w = jnp.asarray(rng.normal(size=(128, 128)) * 0.1, jnp.float32)
    omega = bp.init_omega_from_weights(w)
    ents, spars = [], []
    for lam in (0.0, 0.01, 0.05, 0.2):
        codes, probs = ecl.ecl_fit(w, omega, lam, iters=12)
        ents.append(float(ecl.entropy_bits(ecl.histogram(codes))))
        spars.append(float(ecl.sparsity(codes)))
    assert ents == sorted(ents, reverse=True), ents
    assert spars[-1] > spars[0], spars


def test_histogram_lead_dims():
    rng = np.random.default_rng(9)
    codes = jnp.asarray(rng.integers(0, 16, size=(3, 50)), jnp.uint8)
    h = ecl.histogram(codes, lead_ndim=1)
    assert h.shape == (3, 16)
    np.testing.assert_allclose(np.asarray(h).sum(-1), 1.0, rtol=1e-6)
    for i in range(3):
        np.testing.assert_allclose(h[i], ecl.histogram(codes[i]), rtol=1e-6)


def test_update_probs_ema():
    probs = jnp.full((16,), 1 / 16, jnp.float32)
    codes = jnp.zeros((100,), jnp.uint8)        # all zeros
    p2 = ecl.update_probs(probs, codes, momentum=0.5)
    assert float(p2[0]) > 0.5                   # pulled toward all-zero hist
    np.testing.assert_allclose(float(jnp.sum(p2)), 1.0, rtol=1e-5)
