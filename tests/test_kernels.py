"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

Every kernel runs its actual body (interpret=True executes the Pallas
program on CPU) and must match ref.py within float tolerance.  The literal
ACM bit-plane oracle (paper fig. 1) must agree with the decode-then-matmul
form — eq. (1)'s two sides.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplanes as bp
from repro.kernels import ops, ref

SHAPES = [(8, 16, 32), (17, 32, 24), (64, 64, 64), (33, 130, 72),
          (128, 256, 128), (1, 512, 96)]


def _mk(m, k, n, seed, dtype):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    codes = jnp.asarray(rng.integers(0, 16, size=(k, n)), jnp.uint8)
    packed = bp.pack_codes_rows(codes)
    omega = jnp.asarray(rng.normal(size=4) * 0.2, jnp.float32)
    return x, packed, omega


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fantastic4_matmul_vs_ref(m, k, n, dtype):
    x, packed, omega = _mk(m, k, n, m * k + n, dtype)
    y_k = ops.fantastic4_matmul(x, packed, omega, use_kernel=True,
                                interpret=True, out_dtype=jnp.float32,
                                block_m=32, block_n=64, block_k=64)
    y_r = ref.fantastic4_matmul_ref(x, packed, omega, out_dtype=jnp.float32)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(y_k, y_r, atol=tol, rtol=tol)


@pytest.mark.parametrize("activation", [None, "relu"])
def test_fantastic4_epilogue(activation):
    m, k, n = 16, 64, 48
    x, packed, omega = _mk(m, k, n, 5, jnp.float32)
    rng = np.random.default_rng(6)
    alpha1 = jnp.asarray(rng.normal(size=n), jnp.float32)
    bias = jnp.asarray(rng.normal(size=n), jnp.float32)
    alpha2 = jnp.float32(0.37)
    y_k = ops.fantastic4_matmul(x, packed, omega, bias=bias, alpha1=alpha1,
                                alpha2=alpha2, activation=activation,
                                use_kernel=True, interpret=True,
                                out_dtype=jnp.float32)
    y_r = ref.fantastic4_matmul_ref(x, packed, omega, bias=bias,
                                    alpha1=alpha1, alpha2=alpha2,
                                    activation=activation,
                                    out_dtype=jnp.float32)
    np.testing.assert_allclose(y_k, y_r, atol=1e-4, rtol=1e-4)


def test_acm_equals_mac_form():
    """eq. (1): MAC (decode->matmul) == ACM (bit-plane accumulate->scale)."""
    m, k, n = 24, 96, 40
    x, packed, omega = _mk(m, k, n, 11, jnp.float32)
    y_mac = ref.fantastic4_matmul_ref(x, packed, omega, out_dtype=jnp.float32)
    y_acm = ref.acm_bitplane_ref(x, packed, omega, out_dtype=jnp.float32)
    np.testing.assert_allclose(y_mac, y_acm, atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("r,c", [(8, 16), (100, 30), (256, 512), (1, 7)])
def test_ecl_quant_kernel_vs_ref(r, c):
    rng = np.random.default_rng(r * c)
    w = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    omega = jnp.asarray(rng.normal(size=4) * 0.3, jnp.float32)
    probs = jnp.asarray(rng.dirichlet(np.ones(16)), jnp.float32)
    penalty = 0.05 * -jnp.log2(jnp.clip(probs, 1e-8, 1.0))
    ck, wk = ops.ecl_quant(w, omega, penalty, use_kernel=True, interpret=True,
                           block_r=32, block_c=64)
    cr, wr = ref.ecl_quant_ref(w, omega, penalty)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_allclose(wk, wr, atol=1e-5)


def test_kernel_matches_training_path():
    """Frozen serving (kernel) == fake-quant eval forward on the same codes."""
    from repro.core import acm, ecl, qat
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(64, 48)) * 0.1, jnp.float32)
    node = qat.make_quant_param(w)
    qs = {"probs": jnp.full((16,), 1 / 16, jnp.float32)}
    lam = 0.02
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    y_train = acm.linear_qat(x, node, qs, lam)
    frozen = acm.freeze_linear(node, qs, lam)
    y_serve = acm.linear_serving(x, frozen, use_kernel=True, interpret=True)
    np.testing.assert_allclose(y_train, y_serve, atol=1e-4, rtol=1e-4)
