"""SLO tiers + bounded-queue admission control: tier resolution, the
typed Rejected contract (queue_full / deadline sheds resolve futures
promptly, never hang), the cost-model admission math, tier-weighted
dispatch (preemption bounded by weight — starvation-free), and the
serve() future-leak fix."""
import dataclasses

import numpy as np
import pytest

from repro import serving
from repro.serving import slo
from test_serving_plans import _rand_pack

DIMS = (16, 12, 4)


def _oracle_plan(dims=DIMS, seed=0):
    return serving.build_plan(_rand_pack(dims, seed=seed), mode="oracle")


# ---------------------------------------------------------------- tiers

def test_tier_registry_and_resolution():
    assert serving.resolve_tier(None).name == "standard"
    assert serving.resolve_tier("latency") is serving.TIERS["latency"]
    custom = dataclasses.replace(serving.TIERS["latency"], deadline=1.0)
    assert serving.resolve_tier(custom) is custom
    with pytest.raises(ValueError, match="unknown SLO tier"):
        serving.resolve_tier("gold-plated")
    # latency preempts but within a bounded credit; throughput batches
    lat, thr = serving.TIERS["latency"], serving.TIERS["throughput"]
    assert lat.max_delay < thr.max_delay
    assert lat.deadline < thr.deadline
    assert lat.weight > 0 and thr.weight == 0.0


def test_tier_scaled_units():
    t = slo.SLOTier("t", max_delay=1.0, deadline=10.0, weight=2.0)
    s = t.scaled(0.5)
    assert (s.max_delay, s.deadline, s.weight) == (0.5, 5.0, 1.0)
    assert s.name == "t"


def test_batcher_takes_max_delay_from_tier():
    plan = _oracle_plan()
    b = serving.MicroBatcher(plan, tier=serving.TIERS["latency"])
    assert b.max_delay == serving.TIERS["latency"].max_delay
    # explicit max_delay still overrides the tier's budget
    b2 = serving.MicroBatcher(plan, tier=serving.TIERS["latency"],
                              max_delay=0.5)
    assert b2.max_delay == 0.5
    # no tier: the pre-tier default, admission never gates
    b3 = serving.MicroBatcher(plan)
    assert b3.max_delay == 2e-3 and b3.tier.name == "standard"


# ------------------------------------------------------ bounded queues

def test_bounded_queue_rejects_typed_and_leaves_queue_intact():
    plan = _oracle_plan()
    b = serving.MicroBatcher(plan, max_queued_rows=4, max_delay=30.0)
    for _ in range(4):
        b.submit(np.zeros((1, DIMS[0]), np.float32))
    with pytest.raises(serving.Rejected) as ei:
        b.submit(np.zeros((2, DIMS[0]), np.float32))
    assert ei.value.reason == slo.REJECT_QUEUE_FULL
    assert b.pending_rows == 4                       # memory flat
    assert b.stats["rejected_full"] == 1
    assert b.stats["rejected_rows"] == 2
    assert b.stats["requests"] == 4                  # reject not counted
    done = b.flush()
    assert len(done) == 4                            # admitted all served


def test_frontend_queue_full_resolves_future_with_typed_reason():
    """A rejected submit must resolve its future promptly with the
    reason — the no-hang contract — while admitted requests still serve."""
    plan = _oracle_plan()
    fe = serving.ServingFrontend()
    # max_bucket above the bound so the full-tile trigger cannot drain
    # the queue mid-test; max_delay far out so nothing is due.
    fe.register("m", plan, max_delay=30.0, max_bucket=8,
                max_queued_rows=2)
    fe.start()
    ok = [fe.submit("m", np.zeros((1, DIMS[0]), np.float32))
          for _ in range(2)]
    rejected = fe.submit("m", np.zeros((1, DIMS[0]), np.float32))
    with pytest.raises(serving.Rejected, match="queue_full"):
        rejected.result(1.0)                         # prompt, not a hang
    assert rejected.exception(0.0).model_id == "m"
    assert fe.stats["rejected"] == 1
    assert fe.stats["by_model"]["m"]["rejected"] == 1
    fe.close(drain=True)
    for f in ok:
        assert f.result(0.0).y.shape == (1, DIMS[-1])


# -------------------------------------------------- admission control

def test_admission_controller_wait_estimate_math():
    plan = _oracle_plan()
    ctl = slo.AdmissionController(plan.bucket_for, max_bucket=4,
                                  service_times={1: 0.1, 2: 0.2, 4: 0.4})
    # 5 queued + 1 new = 6 rows -> one full 4-tile + a 2-bucket remainder
    assert ctl.wait_estimate(5, 1) == pytest.approx(0.4 + 0.2)
    # abstains (admit) when a needed bucket has no measurement
    ctl2 = slo.AdmissionController(plan.bucket_for, max_bucket=4,
                                   service_times={4: 0.4})
    assert ctl2.wait_estimate(0, 1) is None


def test_admission_ewma_tracks_observations():
    ctl = slo.AdmissionController(lambda m: m, max_bucket=4, alpha=0.5)
    ctl.observe(1, 1.0)
    assert ctl.estimate(1) == 1.0
    ctl.observe(1, 2.0)
    assert ctl.estimate(1) == pytest.approx(1.5)


def test_tiered_batcher_sheds_provably_late_requests():
    plan = _oracle_plan()
    tier = slo.SLOTier("tight", max_delay=1.0, deadline=0.05)
    b = serving.MicroBatcher(plan, tier=tier)
    b.admission.seed({1: 0.2})          # one launch alone busts the SLO
    with pytest.raises(serving.Rejected) as ei:
        b.submit(np.zeros((1, DIMS[0]), np.float32))
    assert ei.value.reason == slo.REJECT_DEADLINE
    assert ei.value.est_wait == pytest.approx(0.2)
    assert b.stats["shed_deadline"] == 1
    # a roomy tier admits the same request under the same cost model
    roomy = slo.SLOTier("roomy", max_delay=1.0, deadline=5.0)
    b2 = serving.MicroBatcher(plan, tier=roomy)
    b2.admission.seed({1: 0.2})
    assert b2.submit(np.zeros((1, DIMS[0]), np.float32)) == 0


def test_untired_batcher_never_sheds():
    """Legacy batchers (no tier) keep the admit-everything contract even
    with measured service times on file."""
    plan = _oracle_plan()
    b = serving.MicroBatcher(plan)
    b.admission.seed({1: 1e9})
    assert b.submit(np.zeros((1, DIMS[0]), np.float32)) == 0


def test_run_one_observes_service_time_into_cost_model():
    plan = _oracle_plan()
    b = serving.MicroBatcher(plan, max_delay=30.0)
    b.submit(np.zeros((1, DIMS[0]), np.float32))
    b.flush()
    est = b.admission.estimate(1)
    assert est is not None and est > 0


# ------------------------------------------- tier-weighted dispatch

def _fake_clock(state):
    return lambda: state["now"]


def test_pick_latency_tier_preempts_older_throughput_deadline():
    state = {"now": 0.0}
    reg = serving.ModelRegistry(clock=_fake_clock(state))
    fe = serving.ServingFrontend(reg)
    reg.register("thr", _oracle_plan(), tier="throughput")
    reg.register("lat", _oracle_plan(seed=1), tier="latency")
    x = np.zeros((1, DIMS[0]), np.float32)
    reg.batcher("thr").submit(x, now=0.0)       # deadline 0.008
    state["now"] = 0.010
    reg.batcher("lat").submit(x, now=0.010)     # deadline 0.0105
    state["now"] = 0.020                        # both fired (past due)
    picked, _ = fe._pick(0.020)
    # raw deadlines say thr (0.008 < 0.0105); the latency tier's 20 ms
    # credit flips it: 0.0105 - 0.020 < 0.008 - 0.
    assert picked == "lat"


def test_pick_weight_is_bounded_no_starvation():
    """A throughput request older than the latency tier's credit still
    wins — the preemption is bounded, so bulk traffic cannot starve."""
    state = {"now": 0.0}
    reg = serving.ModelRegistry(clock=_fake_clock(state))
    fe = serving.ServingFrontend(reg)
    reg.register("thr", _oracle_plan(), tier="throughput")
    reg.register("lat", _oracle_plan(seed=1), tier="latency")
    x = np.zeros((1, DIMS[0]), np.float32)
    reg.batcher("thr").submit(x, now=0.0)       # deadline 0.008
    reg.batcher("lat").submit(x, now=0.030)     # deadline 0.0305
    state["now"] = 0.040
    picked, _ = fe._pick(0.040)
    assert picked == "thr"          # 0.008 < 0.0305 - 0.020


def test_pick_default_tiers_remain_arrival_fifo():
    state = {"now": 0.0}
    reg = serving.ModelRegistry(clock=_fake_clock(state))
    fe = serving.ServingFrontend(reg)
    reg.register("a", _oracle_plan())
    reg.register("b", _oracle_plan(seed=1))
    x = np.zeros((1, DIMS[0]), np.float32)
    reg.batcher("b").submit(x, now=0.001)
    reg.batcher("a").submit(x, now=0.002)
    state["now"] = 0.5
    picked, _ = fe._pick(0.5)
    assert picked == "b"            # weight-0 tiers: oldest deadline


# -------------------------------------------------- serve() leak fix

def test_serve_cancels_earlier_futures_when_submit_raises():
    plan = _oracle_plan()
    fe = serving.ServingFrontend()
    fe.register("m", plan, max_delay=30.0)      # nothing fires mid-test
    with fe:
        good = np.zeros((1, DIMS[0]), np.float32)
        bad = np.zeros((1, DIMS[0] + 1), np.float32)     # wrong d_in
        with pytest.raises(ValueError, match="request must be"):
            fe.serve("m", [good, bad])
        with fe._cond:
            leaked = list(fe._futures.values())
        assert leaked and all(f.cancelled() for f in leaked)
