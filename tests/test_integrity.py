"""End-to-end integrity: checksummed packs at every tier (hot dict /
cold CompressedTensor / disk artifact), GuardedPlan launch verification
and output screening, the frontend's detect → evict → cold-re-decode
recovery rung (bit-identical on the int8 grid), scrub-time detection,
and quarantine with a typed "corrupted" rejection when the cold copy is
poisoned too."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.runtime import integrity
from repro.runtime.integrity import (GuardedPlan, IntegrityError,
                                     IntegrityPolicy, unwrap_chain)
from repro.serving import pack_cache as pc
from test_serving_plans import _rand_pack

DIMS = (16, 12, 4)      # even K everywhere: no pad row, every bit covered


def _flip_payload_bit(cold, li=0, which="codes"):
    ct = getattr(cold.layers[li], which)
    key, _ = ct.canonical_items()[0]
    ct.payload[key].view(np.uint8).reshape(-1)[0] ^= 1


def _corrupt_hot_layer(plan, li=0, field="packed"):
    """Copy-modify-reassign (jnp arrays are immutable) + drop the
    identity-keyed kernel operand memos, exactly as the injector does."""
    from repro.kernels import ops as kops
    host = np.asarray(plan.layers[li][field])
    if field == "packed":
        host = host.copy().astype(np.uint8)
        host.reshape(-1)[0] ^= 2
    else:
        host = host.copy()
        host.reshape(-1)[0] += np.float32(1.0)
    plan.layers[li][field] = jnp.asarray(host)
    kops.forget_pack_operands(plan.layers)


# ------------------------------------------------- checksums per tier

def test_layer_content_crc_deterministic_and_sensitive():
    pack = _rand_pack(DIMS, seed=3)
    crc0 = integrity.hot_layer_crc(pack["layers"][0])
    assert crc0 == integrity.hot_layer_crc(pack["layers"][0])
    for field in ("packed", "omega", "alpha1", "bias", "alpha2"):
        mutated = {**pack["layers"][0]}
        host = np.asarray(mutated[field]).copy()
        if field == "packed":
            host.reshape(-1)[0] ^= 1
        else:
            host = host.reshape(-1) if host.ndim else host[None]
            host[0] += 1.0
            host = host.reshape(np.asarray(mutated[field]).shape)
        mutated[field] = jnp.asarray(host)
        assert integrity.hot_layer_crc(mutated) != crc0, field


def test_crc_header_separates_dtype_and_shape():
    a = np.zeros(8, np.float32)
    assert integrity.crc_update(0, a, "x") != \
        integrity.crc_update(0, a.astype(np.float64), "x")
    assert integrity.crc_update(0, a, "x") != \
        integrity.crc_update(0, a.reshape(2, 4), "x")
    assert integrity.crc_update(0, a, "x") != \
        integrity.crc_update(0, a, "y")


def test_freeze_mlp_stamps_content_crc():
    import jax

    from repro.configs.paper_mlps import MLPConfig
    from repro.core import qat
    from repro.models import mlp as M
    cfg = MLPConfig("tiny", features=(8, 4), d_in=6)
    params, bn = M.mlp_init(jax.random.PRNGKey(0), cfg)
    pack = M.freeze_mlp(params, qat.build_qstate(params), bn, lam=0.02)
    for layer in pack["layers"]:
        assert layer["crc"] == integrity.hot_layer_crc(layer)


def test_compress_pack_verifies_stamped_crc():
    pack = _rand_pack(DIMS, seed=1)
    integrity.stamp_pack_crcs(pack)
    cold = pc.compress_pack(pack)          # consistent: fine
    for cl in cold.layers:
        assert cl.content_crc is not None and cl.payload_crc is not None
    pack["layers"][0]["crc"] ^= 1          # stamped lie
    with pytest.raises(IntegrityError) as ei:
        pc.compress_pack(pack)
    assert ei.value.kind == "content" and ei.value.layer == 0


def test_decode_pack_stamps_and_roundtrips():
    pack = _rand_pack(DIMS, seed=2)
    hot = pc.decode_pack(pc.compress_pack(pack))
    for orig, layer in zip(pack["layers"], hot["layers"]):
        assert layer["crc"] == integrity.hot_layer_crc(layer)
        np.testing.assert_array_equal(np.asarray(orig["packed"]),
                                      np.asarray(layer["packed"]))


def test_cold_payload_flip_caught_by_scrub_and_decode():
    cold = pc.compress_pack(_rand_pack(DIMS, seed=4))
    _flip_payload_bit(cold, li=1)
    with pytest.raises(IntegrityError) as ei:
        pc.verify_cold_pack(cold)          # payload CRC, no decode
    assert ei.value.kind == "cold" and ei.value.layer == 1
    with pytest.raises(IntegrityError):
        pc.decode_pack(cold)


def test_payload_roundtrip_preserves_crcs_and_checks_algo():
    cold = pc.compress_pack(_rand_pack(DIMS, seed=5))
    payload = pc.cold_pack_to_payload(cold)
    back = pc.cold_pack_from_payload(payload)
    for a, b in zip(cold.layers, back.layers):
        assert (a.content_crc, a.payload_crc) == \
            (b.content_crc, b.payload_crc)
    pc.decode_pack(back)                   # all digests verify
    payload["crc_algo"] = np.array("md5-not-really")
    with pytest.raises(IntegrityError) as ei:
        pc.cold_pack_from_payload(payload)
    assert ei.value.kind == "artifact"


# ------------------------------------------------- disk artifacts

def test_load_pack_truncated_npz_raises_typed_error(tmp_path):
    from repro.checkpoint.manager import export_pack, load_pack
    path = str(tmp_path / "pack")
    export_pack(path, _rand_pack(DIMS, seed=6))
    npz = os.path.join(path, "pack.npz")
    blob = open(npz, "rb").read()
    with open(npz, "wb") as f:
        f.write(blob[: len(blob) // 2])    # torn write
    with pytest.raises(IntegrityError) as ei:
        load_pack(path)
    assert ei.value.kind == "artifact" and "pack.npz" in str(ei.value)


def test_load_pack_flipped_bit_on_disk_fails_verification(tmp_path):
    from repro.checkpoint.manager import export_pack, load_pack
    path = str(tmp_path / "pack")
    export_pack(path, _rand_pack(DIMS, seed=7))
    npz = os.path.join(path, "pack.npz")
    data = dict(np.load(npz, allow_pickle=False))
    # largest compressed-codes payload array: flip one stored bit
    key = max((k for k in data if "//codes//" in k),
              key=lambda k: data[k].nbytes)
    data[key] = data[key].copy()
    data[key].view(np.uint8).reshape(-1)[0] ^= 1
    np.savez(npz.removesuffix(".npz"), **data)
    with pytest.raises(IntegrityError):
        load_pack(path)
    load_pack(path, verify=False)          # opt-out stays available


def test_export_pack_sweeps_stray_tmp_litter(tmp_path):
    from repro.checkpoint.manager import export_pack
    stray_dir = tmp_path / ".tmp_pack_killed9"
    stray_dir.mkdir()
    (stray_dir / "x").write_text("partial")
    stray_file = tmp_path / "half.tmp"
    stray_file.write_text("partial")
    export_pack(str(tmp_path / "pack"), _rand_pack(DIMS, seed=8))
    assert not stray_dir.exists() and not stray_file.exists()


# ------------------------------------------------- the guarded plan

def test_guarded_plan_detects_hot_flip_before_results():
    plan = serving.build_plan(_rand_pack(DIMS, seed=9), mode="oracle")
    guard = GuardedPlan(plan, model_id="m")
    x = np.zeros((1, DIMS[0]), np.float32)
    np.asarray(guard.run(x))               # clean launch verifies
    _corrupt_hot_layer(plan, li=1, field="packed")
    with pytest.raises(IntegrityError) as ei:
        guard.run(x)
    assert ei.value.kind == "hot" and ei.value.layer == 1
    assert guard.stats["detected"] == 1


def test_guarded_plan_screens_nonfinite_outputs():
    plan = serving.build_plan(_rand_pack(DIMS, seed=10), mode="oracle")
    guard = GuardedPlan(
        plan, policy=IntegrityPolicy(verify_launch=False), model_id="m")
    x = np.zeros((1, DIMS[0]), np.float32)
    np.asarray(guard.run(x))
    bias = np.asarray(plan.layers[-1]["bias"]).copy()
    bias[0] = np.nan
    plan.layers[-1]["bias"] = jnp.asarray(bias)
    from repro.kernels import ops as kops
    kops.forget_pack_operands(plan.layers)
    with pytest.raises(IntegrityError) as ei:
        guard.run(x)
    assert ei.value.kind == "output"


def test_canary_probe_catches_silent_drift():
    plan = serving.build_plan(_rand_pack(DIMS, seed=11), mode="oracle")
    guard = GuardedPlan(
        plan, policy=IntegrityPolicy(verify_launch=False, canary=True),
        model_id="m")
    guard.check_canary()                   # arms the golden pair
    guard.check_canary()                   # stable: passes
    _corrupt_hot_layer(plan, li=0, field="alpha1")
    with pytest.raises(IntegrityError) as ei:
        guard.check_canary()
    assert ei.value.kind == "canary"


# ------------------------------------------------- frontend recovery

def _frontend(pack, **kw):
    fe = serving.ServingFrontend(cache=serving.PackCache())
    fe.register_pack("m", pack,
                     plan_kwargs={"mode": "oracle", "act_dtype": "int8"},
                     max_delay=1e-4, **kw)
    return fe


def test_e2e_flips_detected_recovered_bit_identical():
    """The acceptance criterion: under per-launch bit flips (cold tier
    intact) every corrupted launch is detected, recovered by cold-tier
    re-decode, and the served outputs are bit-identical on the int8
    grid to a no-fault run."""
    pack = _rand_pack(DIMS, seed=12)
    injector = None

    def wrap(plan):
        nonlocal injector
        injector = serving.FaultInjector(
            plan, rate=0.0, seed=11, flip_rate=0.06,
            flip_targets=("packed", "epilogue"))
        return injector

    rng = np.random.default_rng(0)
    xs = [rng.normal(size=(1, DIMS[0])).astype(np.float32)
          for _ in range(80)]
    ref = serving.build_plan(
        pc.decode_pack(pc.compress_pack(pack)),
        mode="oracle", act_dtype="int8")
    baseline = [np.asarray(ref.run(x)) for x in xs]

    fe = _frontend(pack, wrap=wrap, integrity=True)
    with fe:
        ys = [np.asarray(fe.submit("m", x).result(timeout=60).y)
              for x in xs]
        integ = fe.stats["integrity"]
        assert injector.flipped > 0
        assert integ["detected"] == injector.flipped
        assert integ["recovered"] == integ["detected"]
        assert not fe.stats["quarantined"]
    for y, b in zip(ys, baseline):
        np.testing.assert_array_equal(y, b)


def test_scrub_once_detects_and_recovers_hot_corruption():
    pack = _rand_pack(DIMS, seed=13)
    fe = _frontend(pack, integrity=True)
    x = np.zeros((1, DIMS[0]), np.float32)
    with fe:
        y0 = np.asarray(fe.submit("m", x).result(timeout=60).y)
        _corrupt_hot_layer(fe.registry.cache.plan("m"))
        report = fe.scrub_once()
        assert report["detected"] == 1 and report["recovered"] == 1
        assert not report["quarantined"]
        y1 = np.asarray(fe.submit("m", x).result(timeout=60).y)
    np.testing.assert_array_equal(y0, y1)


def test_cold_corruption_quarantines_with_corrupted_reason():
    pack = _rand_pack(DIMS, seed=14)
    fe = _frontend(pack, integrity=True)
    x = np.zeros((1, DIMS[0]), np.float32)
    with fe:
        fe.submit("m", x).result(timeout=60)
        _flip_payload_bit(fe.registry.cache.cold("m"))
        report = fe.scrub_once()
        assert report["quarantined"] == ["m"]
        with pytest.raises(serving.Rejected) as ei:
            fe.submit("m", x).result(timeout=60)
    assert ei.value.reason == "corrupted"


def test_hot_and_cold_both_corrupted_quarantines_not_loops():
    """Recovery must refuse to 'recover' from a poisoned cold tier: the
    re-decoded plan would fail verification again — quarantine instead
    of evict/re-decode forever."""
    pack = _rand_pack(DIMS, seed=15)
    fe = _frontend(pack, integrity=True)
    x = np.zeros((1, DIMS[0]), np.float32)
    with fe:
        fe.submit("m", x).result(timeout=60)
        _flip_payload_bit(fe.registry.cache.cold("m"))
        _corrupt_hot_layer(fe.registry.cache.plan("m"))
        # the triggering request gets the typed root cause...
        with pytest.raises(IntegrityError):
            fe.submit("m", x).result(timeout=60)
        assert fe.stats["quarantined"] == ["m"]
        assert fe.stats["integrity"]["recovery_failed"] == 1
        # ...and every later submit the typed "corrupted" rejection
        with pytest.raises(serving.Rejected) as ei:
            fe.submit("m", x).result(timeout=60)
        assert ei.value.reason == "corrupted"


def test_unregister_unwraps_guard_and_injector_chain():
    pack = _rand_pack(DIMS, seed=16)
    fe = _frontend(
        pack, integrity=True,
        wrap=lambda p: serving.FaultInjector(p, rate=0.0))
    chain = unwrap_chain(dict(fe.registry.items())["m"].plan)
    assert [type(p).__name__ for p in chain] == \
        ["GuardedPlan", "FaultInjector", "CachedPlan"]
    fe.registry.unregister("m")
    with pytest.raises(KeyError):
        fe.registry.cache.cold("m")
