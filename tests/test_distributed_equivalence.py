"""Multi-device (8 fake CPUs) numerics equivalence for the perf-path
shardings: sequence-parallel attention, EP MoE in-model, full train step on
a mesh == single device."""
from conftest import run_with_devices


def test_seq_parallel_attention_matches_single_device():
    run_with_devices("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.core import qat
from repro.nn import transformer as T
from repro.nn.module import QuantCtx

cfg = get_config("smollm-360m").smoke()   # 4 heads % model-axis 4 == 0?  -> force reshard
cfg = dataclasses.replace(cfg, n_heads=3, n_kv=3, head_dim=16, d_model=48)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = QuantCtx(quant=False, compute_dtype=jnp.float32)
key = jax.random.PRNGKey(0)
p = T.lm_init(key, cfg)
q = qat.build_qstate(p)
toks = jax.random.randint(key, (4, 16), 0, cfg.vocab)

ref, _, _ = T.lm_apply(p, q, toks, ctx, cfg, attn_reshard=False)
def f(p, toks):
    out, _, _ = T.lm_apply(p, q, toks, ctx, cfg, mesh=mesh, attn_reshard=True)
    return out
with mesh:
    out = jax.jit(f)(p, toks)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3, rtol=1e-3)
print("seq-parallel attention == single-device OK")
""", n_devices=8)


def test_sharded_train_step_matches_single_device():
    run_with_devices("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch import steps as S
from repro.launch.mesh import make_mesh
from repro.optim import adam, ec4t

cfg = get_config("smollm-360m").smoke()
mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
from repro.nn.transformer import lm_init
params = lm_init(key, cfg)
state = ec4t.init_train_state(params)
toks = jax.random.randint(key, (8, 16), 0, cfg.vocab)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

# single-device reference
loss_fn1 = S._loss_fn(cfg, mesh=None, use_ep=False, remat="none")
step1 = ec4t.make_train_step(loss_fn1, adam.AdamConfig(lr=1e-3), lam=cfg.lam)
s1, m1 = jax.jit(step1)(state, batch)

# sharded
loss_fn2 = S._loss_fn(cfg, mesh=mesh, use_ep=True, remat="full")
step2 = ec4t.make_train_step(loss_fn2, adam.AdamConfig(lr=1e-3), lam=cfg.lam)
rules = S.make_rules(cfg, mesh)
p_specs = rules.param_specs(state["params"])
state_sh = {
    "params": jax.device_put(state["params"], rules.named(mesh, p_specs)),
    "opt": state["opt"], "qstate": state["qstate"],
}
with mesh:
    s2, m2 = jax.jit(step2)(state_sh, batch)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
for l1, l2 in zip(jax.tree_util.tree_leaves(s1["params"]),
                  jax.tree_util.tree_leaves(s2["params"])):
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=5e-3, rtol=5e-3)
print("sharded train step == single-device OK, loss", float(m2["loss"]))
""", n_devices=8)


def test_mini_dryrun_all_families_compile():
    """CI-speed dry-run: one small cell per family on a (2,4) mesh."""
    run_with_devices("""
import jax
from repro.configs import get_config
from repro.launch import steps as S, specs
from repro.launch.mesh import make_mesh

for shape in specs.SHAPES.values():
    pass
specs.SHAPES["train_4k"] = dict(specs.SHAPES["train_4k"], seq=64, batch=8)
specs.SHAPES["prefill_32k"] = dict(specs.SHAPES["prefill_32k"], seq=64, batch=8)
specs.SHAPES["decode_32k"] = dict(specs.SHAPES["decode_32k"], seq=64, batch=8)
mesh = make_mesh((2, 4), ("data", "model"))
for arch in ("smollm-360m", "deepseek-v3-671b", "grok-1-314b",
             "mamba2-1.3b", "hymba-1.5b", "whisper-base"):
    cfg = get_config(arch).smoke()
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        bundle = S.build_step(cfg, mesh, shape)
        with mesh:
            compiled = jax.jit(
                bundle.fn, in_shardings=bundle.in_shardings,
                out_shardings=bundle.out_shardings,
                donate_argnums=bundle.donate).lower(*bundle.args).compile()
        assert compiled.cost_analysis() is not None
    print(arch, "OK")
""", n_devices=8, timeout=1200)
