"""Fused serving megakernel vs the chained per-layer oracle.

Every paper stack (MLP-GSC, MLP-HR, LeNet-300-100 — the latter has
odd/unpadded dims: 784 in, 300/100/10 out), batch=1 and odd batches, plus
the VMEM-budget fallback and a trained freeze->serve end-to-end check.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_mlps import MLPS
from repro.core import bitplanes as bp
from repro.kernels import ops, ref
from repro.kernels.fantastic4_fused_mlp import (fused_mlp_fits,
                                                fused_mlp_vmem_bytes,
                                                stream_mlp_fits,
                                                stream_mlp_vmem_bytes)
from repro.models import mlp as M

# (K, N) chains: the three paper stacks + a deliberately odd/unpadded one.
STACKS = {name: (cfg.d_in,) + tuple(cfg.features) for name, cfg in MLPS.items()}
STACKS["odd"] = (33, 130, 72, 7)


def _rand_pack(dims, seed=0, scale=None):
    """Synthetic frozen pack with BN-realistic magnitudes (activations stay
    O(1), as freeze_mlp's folded constants make them)."""
    rng = np.random.default_rng(seed)
    layers = []
    for i, (k, n) in enumerate(zip(dims[:-1], dims[1:])):
        codes = rng.integers(0, 16, size=(k + (k % 2), n)).astype(np.uint8)
        if k % 2:
            codes[-1] = 0
        s = scale if scale is not None else 1.0 / np.sqrt(k)
        layers.append({
            "packed": bp.pack_codes_rows(jnp.asarray(codes)),
            "omega": jnp.asarray(rng.normal(size=4) * s, jnp.float32),
            "alpha1": jnp.asarray(rng.normal(size=n) * 0.5, jnp.float32),
            "bias": jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32),
            "alpha2": jnp.asarray(np.float32(rng.uniform(0.5, 1.5))),
            "shape": (k, n),
            "activation": "relu" if i < len(dims) - 2 else None,
        })
    return {"layers": layers, "act_bits": None}


def _oracle(pack, x):
    for l in pack["layers"]:
        if l["shape"][0] % 2:
            # odd K: the pack carries one zero code row — mirror it on x
            x = jnp.pad(x, ((0, 0), (0, 1)))
        x = ref.fantastic4_matmul_ref(
            x, l["packed"], l["omega"], bias=l["bias"], alpha1=l["alpha1"],
            alpha2=l["alpha2"], activation=l["activation"],
            out_dtype=jnp.float32)
    return x


@pytest.mark.parametrize("stack", sorted(STACKS))
@pytest.mark.parametrize("batch", [1, 5, 64])
def test_fused_matches_per_layer_oracle(stack, batch):
    dims = STACKS[stack]
    # deterministic seed (hash() varies per interpreter run); rtol covers
    # the occasional pack whose activations drift past O(1), where f32
    # accumulation-order noise exceeds any fixed absolute gate.
    pack = _rand_pack(dims, seed=sorted(STACKS).index(stack) * 100 + batch)
    rng = np.random.default_rng(batch)
    x = jnp.asarray(rng.normal(size=(batch, dims[0])), jnp.float32)
    y_fused = M.mlp_serve(pack, x, use_kernel=True, fused=True,
                          interpret=True)
    y_ref = _oracle(pack, x)
    assert y_fused.shape == (batch, dims[-1])
    np.testing.assert_allclose(y_fused, y_ref, atol=1e-3, rtol=1e-5)


def test_fused_matches_per_layer_kernel_path():
    dims = STACKS["mlp-hr"]
    pack = _rand_pack(dims, seed=7)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, dims[0])),
                    jnp.float32)
    y_fused = M.mlp_serve(pack, x, fused=True, interpret=True)
    y_chain = M.mlp_serve(pack, x, fused=False, interpret=True,
                          block_m=None)
    np.testing.assert_allclose(y_fused, y_chain, atol=1e-3, rtol=1e-4)


def test_odd_k_serves_on_every_path():
    """Odd-K packs work on fused, per-layer-kernel AND oracle mlp_serve
    paths (each mirrors the pack's zero code row with a zero x column)."""
    pack = _rand_pack(STACKS["odd"], seed=11)
    x = jnp.asarray(np.random.default_rng(6).normal(size=(3, 33)),
                    jnp.float32)
    y_ref = _oracle(pack, x)
    for kwargs in ({"fused": True}, {"fused": False},
                   {"use_kernel": False}):
        y = M.mlp_serve(pack, x, interpret=True, **kwargs)
        np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-4,
                                   err_msg=str(kwargs))


def test_vmem_fallback_triggers_and_matches():
    """A 1-byte budget forces the per-layer fallback; result is unchanged."""
    dims = STACKS["odd"]
    pack = _rand_pack(dims, seed=3)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(4, dims[0])),
                    jnp.float32)
    shapes = tuple(l["shape"] for l in pack["layers"])
    assert fused_mlp_fits(shapes)
    assert not fused_mlp_fits(shapes, budget_bytes=1)
    y_fb = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                    vmem_budget_bytes=1)
    y_ref = _oracle(pack, x)
    np.testing.assert_allclose(y_fb, y_ref, atol=1e-3, rtol=1e-4)


def test_vmem_estimate_scales_with_stack():
    small = fused_mlp_vmem_bytes(((128, 128),))
    big = fused_mlp_vmem_bytes(((512, 512), (512, 512), (512, 256)))
    assert 0 < small < big
    # all paper stacks fit the default budget at 4 bits/weight
    for dims in STACKS.values():
        shapes = tuple(zip(dims[:-1], dims[1:]))
        assert fused_mlp_fits(shapes), dims


def test_stream_vmem_estimate_scales_with_batch_not_depth():
    """The streaming schedule's defining trade: its working set grows with
    the resident batch but NOT with layer count (one layer per grid
    step), so deep stacks that bust the batch-tiled budget still fit."""
    shapes3 = ((512, 512),) * 3
    shapes9 = ((512, 512),) * 9
    # streamed per-step set: invariant in L ...
    assert stream_mlp_vmem_bytes(shapes3, rows=64) == \
        stream_mlp_vmem_bytes(shapes9, rows=64)
    # ... but grows with the resident batch
    assert stream_mlp_vmem_bytes(shapes3, rows=64) < \
        stream_mlp_vmem_bytes(shapes3, rows=512)
    # batch-tiled grows with L instead
    assert fused_mlp_vmem_bytes(shapes3) < fused_mlp_vmem_bytes(shapes9)
    # a budget between the two admits stream but not batch-tiled
    mid = (stream_mlp_vmem_bytes(shapes9, rows=64)
           + fused_mlp_vmem_bytes(shapes9, block_m=64)) // 2
    assert stream_mlp_fits(shapes9, rows=64, budget_bytes=mid)
    assert not fused_mlp_fits(shapes9, block_m=64, budget_bytes=mid)
    assert not stream_mlp_fits(shapes9, rows=64, budget_bytes=1)
    assert not stream_mlp_fits((), rows=64)
    # the act scratch is charged at the kernel's real whole-tile padding:
    # 264 rows with 256-row tiles allocate a 512-row scratch, not 264
    assert stream_mlp_vmem_bytes(shapes3, rows=264, block_m=256) == \
        stream_mlp_vmem_bytes(shapes3, rows=512, block_m=256)
    assert stream_mlp_vmem_bytes(shapes3, rows=264, block_m=8) < \
        stream_mlp_vmem_bytes(shapes3, rows=264, block_m=256)


def test_stream_schedule_decode_amortized_paths_match():
    """Streaming schedule vs oracle across tile shapes that exercise the
    decode-once/reuse machinery: multiple batch tiles, ragged final tile,
    single-layer stack, odd-K dims."""
    for dims, batch, bm in (
            (STACKS["odd"], 40, 16),       # ragged last tile (40 = 2.5*16)
            (STACKS["lenet-300-100"], 24, 8),
            ((33, 17), 9, 8),              # single layer, odd everything
    ):
        pack = _rand_pack(dims, seed=sum(dims))
        x = jnp.asarray(
            np.random.default_rng(batch).normal(size=(batch, dims[0])),
            jnp.float32)
        y = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                     schedule="stream", block_m=bm)
        np.testing.assert_allclose(y, _oracle(pack, x), atol=1e-3,
                                   rtol=1e-4, err_msg=str((dims, batch, bm)))


def test_gelu_activation_matches_on_every_schedule():
    """gelu epilogues (the transformer FFN's activation) vs the oracle on
    all four kernel schedules — the static-activation paths (batch_tiled,
    db) and the coded-activation paths (ws, stream) alike."""
    dims = (33, 48, 17)
    pack = _rand_pack(dims, seed=21)
    for l in pack["layers"][:-1]:
        l["activation"] = "gelu"
    x = jnp.asarray(np.random.default_rng(3).normal(size=(9, dims[0])),
                    jnp.float32)
    y_ref = _oracle(pack, x)
    for sched in ("batch_tiled", "db", "ws", "stream"):
        y = ops.fantastic4_mlp_fused(x, pack["layers"], interpret=True,
                                     schedule=sched)
        np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-4,
                                   err_msg=sched)


def test_frozen_pack_serves_fused():
    """freeze_mlp -> mlp_serve(fused) == oracle serve on a real pack."""
    import jax
    from repro.core import qat
    cfg = MLPS["lenet-300-100"]
    params, bn = M.mlp_init(jax.random.PRNGKey(0), cfg)
    qs = qat.build_qstate(params)
    pack = M.freeze_mlp(params, qs, bn, lam=0.02)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(9, cfg.d_in)),
                    jnp.float32)
    y_fused = M.mlp_serve(pack, x, use_kernel=True, fused=True,
                          interpret=True)
    y_oracle = M.mlp_serve(pack, x, use_kernel=False)
    assert float(jnp.max(jnp.abs(y_fused - y_oracle))) < 1e-3
