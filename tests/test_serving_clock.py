"""MicroBatcher clock contract: pump re-reads the clock (deadline
overshoot regression), compute accounting keeps the live and virtual
domains apart, and the intake/flush paths are thread-safe."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from test_serving_plans import _rand_pack

EVEN_DIMS = (16, 12, 4)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


class SlowPlan:
    """Plan wrapper whose bucket entries advance a fake clock by ``cost``
    — compute that visibly takes (virtual) time."""

    def __init__(self, plan, clk: FakeClock, cost: float):
        self._plan, self._clk, self._cost = plan, clk, cost

    def __getattr__(self, name):
        return getattr(self._plan, name)

    def entry(self, bucket):
        fn = self._plan.entry(bucket)

        def slow(xb):
            self._clk.t += self._cost
            return fn(xb)
        return slow


def _plan(**kw):
    return serving.build_plan(_rand_pack(EVEN_DIMS), mode="oracle", **kw)


# -------------------------------------------- deadline overshoot (pump)


def test_pump_rereads_clock_after_long_compute():
    """Regression: a deadline expiring *during* a bucket's compute must
    flush in the same pump.  Pre-fix, pump captured ``now`` once at loop
    entry, so the second request waited for the next driver cycle —
    overshooting max_delay by a whole launch."""
    clk = FakeClock()
    plan = SlowPlan(_plan(), clk, cost=1.0)
    b = serving.MicroBatcher(plan, max_delay=0.1, max_bucket=2, clock=clk)
    x2 = jnp.zeros((2, EVEN_DIMS[0]), jnp.float32)   # fills the tile alone
    x1 = jnp.zeros((1, EVEN_DIMS[0]), jnp.float32)   # can never fill it
    r1 = b.submit(x2, now=0.0)         # deadline 0.1
    r2 = b.submit(x1, now=0.3)         # deadline 0.4
    clk.t = 0.2                        # r1 due, r2 not yet (and not full)
    done = b.pump()                    # no explicit now: clock re-read
    # serving r1 advanced the clock to 1.2 > r2's deadline: one pump
    # must flush both
    assert {c.rid for c in done} == {r1, r2}
    assert b.stats["flushes"] == 2
    assert b.pending_rows == 0


def test_pump_explicit_now_is_evaluated_once():
    """The virtual-clock replay path decides what time it is: an explicit
    ``now`` must NOT be re-read mid-pump."""
    clk = FakeClock()
    plan = SlowPlan(_plan(), clk, cost=1.0)
    b = serving.MicroBatcher(plan, max_delay=0.1, max_bucket=2, clock=clk)
    r1 = b.submit(jnp.zeros((2, EVEN_DIMS[0]), jnp.float32), now=0.0)
    b.submit(jnp.zeros((1, EVEN_DIMS[0]), jnp.float32), now=0.3)
    done = b.pump(now=0.2)             # r1 due at 0.2; r2 stays queued
    assert [c.rid for c in done] == [r1]
    assert b.pending_rows == 1


# ------------------------------------------- compute accounting domains


def test_live_clock_compute_domains_agree():
    b = serving.MicroBatcher(_plan())  # default live clock
    b.submit(jnp.zeros((1, EVEN_DIMS[0]), jnp.float32))
    b.flush()
    assert b.stats["wall_compute_s"] > 0
    assert b.stats["compute_s"] == b.stats["wall_compute_s"]


def test_injected_clock_leaves_compute_to_the_driver():
    """With a virtual clock the batcher cannot know the virtual cost of a
    launch: run_one records only wall time; compute_s belongs to the
    driver via account_compute."""
    b = serving.MicroBatcher(_plan(), clock=FakeClock())
    b.submit(jnp.zeros((1, EVEN_DIMS[0]), jnp.float32))
    b.flush()
    assert b.stats["wall_compute_s"] > 0
    assert b.stats["compute_s"] == 0.0
    b.account_compute(0.25)
    assert b.stats["compute_s"] == 0.25


def test_clock_none_requires_explicit_now():
    b = serving.MicroBatcher(_plan(), clock=None)
    x = jnp.zeros((1, EVEN_DIMS[0]), jnp.float32)
    with pytest.raises(ValueError):
        b.submit(x)
    rid = b.submit(x, now=1.0)
    b.flush(now=2.0)
    assert b.result(rid) is not None


def test_replay_stats_do_not_mix_clocks():
    """Regression: replay(service_times=...) used to accumulate the live
    launch measurement into compute_s while the makespan was virtual —
    utilization computed from the stats was nonsense.  compute_s must now
    be exactly the virtual service-time accounting."""
    plan = _plan()
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.normal(size=(1, EVEN_DIMS[0])), jnp.float32)
          for _ in range(6)]
    arrivals = np.linspace(0.0, 1e-3, 6)
    table = {b: 1e-3 for b in plan.bucket_sizes}
    out = serving.replay(plan, xs, arrivals, service_times=table)
    st = out["stats"]
    assert st["compute_s"] == pytest.approx(1e-3 * st["flushes"])
    assert st["wall_compute_s"] > 0
    assert st["wall_compute_s"] != st["compute_s"]


# ------------------------------------------------------- thread safety


def test_concurrent_submit_and_pump_stress():
    """Many submitter threads race one pump thread (the frontend's shape):
    every request must be served exactly once with the right logits."""
    plan = _plan()
    oracle = serving.build_plan(_rand_pack(EVEN_DIMS), mode="oracle")
    b = serving.MicroBatcher(plan, max_delay=1e-4, max_bucket=16)
    n_threads, per_thread = 4, 25
    lock = threading.Lock()
    sent = {}
    rng = np.random.default_rng(7)
    payloads = [[rng.normal(size=(1, EVEN_DIMS[0])).astype(np.float32)
                 for _ in range(per_thread)] for _ in range(n_threads)]

    def submitter(tid):
        for x in payloads[tid]:
            rid = b.submit(x)
            with lock:
                sent[rid] = x
            time.sleep(0.0005)

    served = {}

    def pumper():
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            for c in b.pump():
                served[c.rid] = c
            if len(served) == n_threads * per_thread and not alive():
                return
            time.sleep(0.0002)

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(n_threads)]

    def alive():
        return any(t.is_alive() for t in threads)

    pump_thread = threading.Thread(target=pumper)
    pump_thread.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # drain whatever the pump thread didn't catch before its exit
    pump_thread.join()
    for c in b.flush():
        served[c.rid] = c

    assert len(served) == n_threads * per_thread
    assert b.stats["requests"] == n_threads * per_thread
    assert b.stats["flushed_rows"] == n_threads * per_thread
    for rid, x in sent.items():
        np.testing.assert_allclose(served[rid].y, oracle.run(x),
                                   atol=1e-4, rtol=1e-4)
