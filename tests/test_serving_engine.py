"""MicroBatcher: padding/coalescing parity (a request served from a padded
bucket must equal serving it alone — bit-identical on the int8 paths),
deadline-based partial flush, FIFO scatter, and the replay simulator."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from test_serving_plans import _rand_pack

DIMS = (33, 129, 71, 7)          # odd-K everywhere
EVEN_DIMS = (64, 96, 10)


def _plan(pack, **kw):
    return serving.build_plan(pack, mode="fused", interpret=True, **kw)


# ------------------------------------------------------- padding parity

@pytest.mark.parametrize("dims", [DIMS, EVEN_DIMS],
                         ids=["oddK", "evenK"])
@pytest.mark.parametrize("act_dtype", ["float32", "int8"])
def test_padded_bucket_parity_vs_alone(dims, act_dtype):
    """Satellite contract: logits for a request served in a padded /
    coalesced bucket are bit-identical (int8) / allclose (fp32) to serving
    the same request alone — including batch=1 and odd-K stacks."""
    pack = _rand_pack(dims, seed=sum(dims))
    calib_x = jnp.asarray(np.random.default_rng(0).normal(size=(16, dims[0])),
                          jnp.float32)
    kw = {}
    if act_dtype == "int8":
        kw = {"act_dtype": "int8",
              "calib": serving.calibrate_act_scales(pack, calib_x)}
    plan = _plan(pack, **kw)

    rng = np.random.default_rng(1)
    reqs = [jnp.asarray(rng.normal(size=(r, dims[0])), jnp.float32)
            for r in (1, 3, 1, 2)]           # 7 rows -> one 8-row bucket

    batcher = serving.MicroBatcher(plan)
    coalesced = batcher.serve(reqs)
    assert batcher.stats["flushes"] == 1
    assert batcher.stats["padded_rows"] == 1

    for req, got in zip(reqs, coalesced):
        alone = serving.MicroBatcher(plan).serve([req])[0]
        if act_dtype == "int8":
            np.testing.assert_array_equal(np.asarray(got), np.asarray(alone))
        else:
            np.testing.assert_allclose(got, alone, atol=1e-5, rtol=1e-5)
        # and the engine result matches the plan run directly (row slice
        # of a padded bucket == the request on its own bucket)
        np.testing.assert_allclose(got, plan.run(req), atol=1e-5, rtol=1e-5)


def test_single_row_bucket1_parity_int8():
    """batch=1: the latency (weight-stationary) bucket through the engine
    equals serving the row alone, bit for bit on int8."""
    pack = _rand_pack(DIMS, seed=2)
    x1 = jnp.asarray(np.random.default_rng(3).normal(size=(1, DIMS[0])),
                     jnp.float32)
    calib = serving.calibrate_act_scales(pack, x1)
    plan = _plan(pack, act_dtype="int8", calib=calib)
    assert plan.path_for(1) == "fused_ws"
    got = serving.MicroBatcher(plan).serve([x1])[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(plan.run(x1)))


# ------------------------------------------------------------- batching

def test_full_tile_flush_and_fifo_scatter():
    pack = _rand_pack(EVEN_DIMS)
    plan = _plan(pack, max_bucket=8)
    oracle = serving.build_plan(pack, mode="oracle")
    b = serving.MicroBatcher(plan, max_delay=1e9, clock=lambda: 0.0)
    rng = np.random.default_rng(5)
    xs = [jnp.asarray(rng.normal(size=(1, EVEN_DIMS[0])), jnp.float32)
          for _ in range(8)]
    rids = [b.submit(x) for x in xs]
    # 8 rows == max bucket: pump flushes exactly one full tile, no deadline
    done = b.pump(now=0.0)
    assert {c.rid for c in done} == set(rids)
    assert b.stats["flushes"] == 1
    assert b.stats["padded_rows"] == 0
    for x, rid in zip(xs, rids):
        np.testing.assert_allclose(b.result(rid).y, oracle.run(x),
                                   atol=1e-3, rtol=1e-4)
    with pytest.raises(KeyError):          # popped: loud, not None
        b.result(rids[0])


def test_deadline_partial_flush():
    plan = _plan(_rand_pack(EVEN_DIMS))
    b = serving.MicroBatcher(plan, max_delay=0.5)
    x = jnp.zeros((1, EVEN_DIMS[0]), jnp.float32)
    rid = b.submit(x, now=10.0)
    assert b.pump(now=10.1) == []          # not due, tile not full: holds
    assert b.pending_rows == 1
    done = b.pump(now=10.6)                # deadline hit: partial flush
    assert [c.rid for c in done] == [rid]
    assert done[0].bucket == 1


def test_multi_row_requests_stay_contiguous():
    pack = _rand_pack(EVEN_DIMS)
    plan = _plan(pack, max_bucket=4)
    oracle = serving.build_plan(pack, mode="oracle")
    b = serving.MicroBatcher(plan)
    rng = np.random.default_rng(6)
    big = jnp.asarray(rng.normal(size=(3, EVEN_DIMS[0])), jnp.float32)
    small = jnp.asarray(rng.normal(size=(2, EVEN_DIMS[0])), jnp.float32)
    r1, r2 = b.submit(big), b.submit(small)
    b.flush()
    # 3+2 rows > max_bucket 4: the second request must ride a second
    # launch, never be split across buckets
    assert b.stats["flushes"] == 2
    np.testing.assert_allclose(b.result(r1).y, oracle.run(big),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(b.result(r2).y, oracle.run(small),
                               atol=1e-3, rtol=1e-4)


def test_oversized_request_runs_alone_at_exact_rows():
    pack = _rand_pack(EVEN_DIMS)
    plan = _plan(pack, max_bucket=4)
    b = serving.MicroBatcher(plan, max_bucket=4)
    x = jnp.asarray(np.random.default_rng(7).normal(size=(9, EVEN_DIMS[0])),
                    jnp.float32)
    rid = b.submit(x)
    b.flush()
    c = b.result(rid)
    assert c.y.shape == (9, EVEN_DIMS[-1])
    np.testing.assert_allclose(
        c.y, serving.build_plan(pack, mode="oracle").run(x),
        atol=1e-3, rtol=1e-4)


def test_bad_request_shape_rejected():
    b = serving.MicroBatcher(_plan(_rand_pack(EVEN_DIMS)))
    with pytest.raises(ValueError):
        b.submit(jnp.zeros((2, 5), jnp.float32))


# --------------------------------------------------------------- replay

def test_replay_work_conserving_and_correct():
    pack = _rand_pack(EVEN_DIMS)
    plan = _plan(pack)
    oracle = serving.build_plan(pack, mode="oracle")
    rng = np.random.default_rng(8)
    xs = [jnp.asarray(rng.normal(size=(1, EVEN_DIMS[0])), jnp.float32)
          for _ in range(12)]
    arrivals = np.cumsum(rng.exponential(1e-4, size=12))
    out = serving.replay(plan, xs, arrivals, service_times={
        b: 1e-3 for b in plan.bucket_sizes})
    for x, y in zip(xs, out["results"]):
        np.testing.assert_allclose(y, oracle.run(x), atol=1e-3, rtol=1e-4)
    assert out["throughput_rps"] > 0
    assert out["stats"]["flushes"] <= 12   # backlog must coalesce
    # with a dense burst and 1ms service, later arrivals must have batched
    assert out["stats"]["flushes"] < 12


def test_replay_naive_equals_bucketed_results():
    pack = _rand_pack(DIMS, seed=4)
    plan = _plan(pack)
    rng = np.random.default_rng(9)
    xs = [jnp.asarray(rng.normal(size=(int(r), DIMS[0])), jnp.float32)
          for r in rng.choice([1, 2, 4], size=10)]
    arrivals = np.sort(rng.uniform(0, 1e-2, size=10))
    a = serving.replay(plan, xs, arrivals, max_bucket=1)
    b = serving.replay(plan, xs, arrivals)
    for ya, yb in zip(a["results"], b["results"]):
        np.testing.assert_allclose(ya, yb, atol=1e-5, rtol=1e-5)
