"""Checkpoint manager: atomic roundtrip, keep-k GC, shape guards, elastic
restore onto a different mesh, compressed 4-bit export sizes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.checkpoint.manager import CheckpointManager, export_quantized
from repro.core import qat


def _state():
    k = jax.random.PRNGKey(0)
    return {"params": {"lin": qat.make_quant_param(
                jax.random.normal(k, (16, 8))),
                       "norm": jnp.ones((8,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    mgr.save(7, state, extra={"note": "hi"})
    restored, meta = mgr.restore(state)
    assert meta["step"] == 7 and meta["note"] == "hi"
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((5,))})


def test_no_partial_dirs_after_failure(tmp_path):
    mgr = CheckpointManager(str(tmp_path))

    class Boom:
        """un-serialisable leaf forces a mid-save failure"""
    try:
        mgr.save(1, {"bad": Boom()})
    except Exception:
        pass
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert leftovers == []
    assert mgr.all_steps() == []


def test_elastic_restore_across_meshes(tmp_path):
    """Save from an 8-device (4,2) mesh; restore onto (2,4) — arrays land
    with the new sharding, values intact."""
    run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
w1 = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save(5, {{"w": w1}})
restored, meta = mgr.restore(
    {{"w": w}}, sharding_fn=lambda leaf: NamedSharding(mesh2, P("data", "model")))
assert restored["w"].sharding.mesh.shape == {{"data": 2, "model": 4}}
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("elastic OK")
""", n_devices=8)


def test_export_quantized_compresses(tmp_path):
    k = jax.random.PRNGKey(1)
    w = jax.random.normal(k, (256, 256)) * 0.05
    params = {"lin": qat.make_quant_param(w)}
    qs = qat.build_qstate(params)
    report = export_quantized(str(tmp_path / "exp"), params, qs, lam=0.05)
    assert report["compression_ratio"] > 7.0   # ~8x from 4bit + formats
    assert (tmp_path / "exp" / "export.npz").exists()
