"""Checkpoint manager: atomic roundtrip, keep-k GC, shape guards, elastic
restore onto a different mesh, compressed 4-bit export sizes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.checkpoint.manager import CheckpointManager, export_quantized
from repro.core import qat


def _state():
    k = jax.random.PRNGKey(0)
    return {"params": {"lin": qat.make_quant_param(
                jax.random.normal(k, (16, 8))),
                       "norm": jnp.ones((8,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    mgr.save(7, state, extra={"note": "hi"})
    restored, meta = mgr.restore(state)
    assert meta["step"] == 7 and meta["note"] == "hi"
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((5,))})


def test_no_partial_dirs_after_failure(tmp_path):
    mgr = CheckpointManager(str(tmp_path))

    class Boom:
        """un-serialisable leaf forces a mid-save failure"""
    try:
        mgr.save(1, {"bad": Boom()})
    except Exception:
        pass
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert leftovers == []
    assert mgr.all_steps() == []


def test_elastic_restore_across_meshes(tmp_path):
    """Save from an 8-device (4,2) mesh; restore onto (2,4) — arrays land
    with the new sharding, values intact."""
    run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
w1 = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save(5, {{"w": w1}})
restored, meta = mgr.restore(
    {{"w": w}}, sharding_fn=lambda leaf: NamedSharding(mesh2, P("data", "model")))
assert restored["w"].sharding.mesh.shape == {{"data": 2, "model": 4}}
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("elastic OK")
""", n_devices=8)


def test_export_quantized_compresses(tmp_path):
    k = jax.random.PRNGKey(1)
    w = jax.random.normal(k, (256, 256)) * 0.05
    params = {"lin": qat.make_quant_param(w)}
    qs = qat.build_qstate(params)
    report = export_quantized(str(tmp_path / "exp"), params, qs, lam=0.05)
    assert report["compression_ratio"] > 7.0   # ~8x from 4bit + formats
    assert (tmp_path / "exp" / "export.npz").exists()


def test_load_quantized_roundtrips_export(tmp_path):
    """export_quantized used to be write-only (dead artifact); its loader
    must recover exact codes + centroids and the unquantized leaves."""
    from repro.checkpoint.manager import load_quantized
    from repro.core import ecl

    k = jax.random.PRNGKey(2)
    w = jax.random.normal(k, (64, 32)) * 0.05
    params = {"lin": qat.make_quant_param(w), "norm": jnp.ones((32,))}
    qs = qat.build_qstate(params)
    export_quantized(str(tmp_path / "exp"), params, qs, lam=0.05)
    loaded = load_quantized(str(tmp_path / "exp"))
    codes_ref = np.asarray(ecl.assign(params["lin"]["w"],
                                      params["lin"]["omega"],
                                      qs["lin"]["probs"], 0.05))
    np.testing.assert_array_equal(loaded["lin"]["codes"], codes_ref)
    np.testing.assert_array_equal(loaded["lin"]["omega"],
                                  np.asarray(params["lin"]["omega"]))
    np.testing.assert_array_equal(loaded["norm"], np.ones((32,)))


def test_export_pack_cold_load_serve_bit_identical(tmp_path):
    """The satellite's acceptance path: freeze → export_pack (at-rest
    artifact) → load_pack → PackCache cold registration → serve must be
    bit-identical to serving the in-memory frozen pack."""
    from repro.checkpoint.manager import export_pack, load_pack
    from repro.serving import PackCache, build_plan
    from test_serving_plans import _rand_pack

    pack = _rand_pack((16, 12, 4), seed=11)
    path = str(tmp_path / "pack_art")
    report = export_pack(path, pack, meta={"model_id": "m"})
    assert report["compressed_bytes"] < report["fp32_bytes"]
    assert os.path.exists(os.path.join(path, "pack.npz"))

    cold = load_pack(path)
    assert cold.shapes == tuple(tuple(l["shape"])
                                for l in pack["layers"])
    cache = PackCache(plan_kwargs={"act_dtype": "int8"})
    proxy = cache.add("m", cold)
    rng = np.random.default_rng(3)
    x = rng.normal(size=(4, 16)).astype(np.float32)
    y_cold = np.asarray(proxy.run(x))
    y_mem = np.asarray(build_plan(pack, act_dtype="int8").run(x))
    np.testing.assert_array_equal(y_cold, y_mem)


def test_export_pack_atomic_overwrite(tmp_path):
    from repro.checkpoint.manager import export_pack, load_pack
    from test_serving_plans import _rand_pack

    path = str(tmp_path / "pack_art")
    export_pack(path, _rand_pack((16, 12, 4), seed=1))
    export_pack(path, _rand_pack((16, 8, 6), seed=2))   # overwrite in place
    assert load_pack(path).shapes[-1][-1] == 6
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert leftovers == []
