"""Bit-plane codec: pack/unpack roundtrips, decode == eq.(1), lead dims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bitplanes as bp


@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_rows_roundtrip(seed):
    rng = np.random.default_rng(seed)
    k = 2 * rng.integers(1, 16)
    n = rng.integers(1, 16)
    codes = jnp.asarray(rng.integers(0, 16, size=(k, n)), jnp.uint8)
    packed = bp.pack_codes_rows(codes)
    assert packed.shape == (k // 2, n)
    np.testing.assert_array_equal(bp.unpack_codes_rows(packed), codes)


def test_pack_rows_lead_dims():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 16, size=(3, 2, 8, 5)), jnp.uint8)
    packed = bp.pack_codes_rows(codes)
    assert packed.shape == (3, 2, 4, 5)
    np.testing.assert_array_equal(bp.unpack_codes_rows(packed), codes)


def test_pack_odd_raises():
    with pytest.raises(ValueError):
        bp.pack_codes_rows(jnp.zeros((3, 5), jnp.uint8))


def test_codebook_subset_sums():
    omega = jnp.asarray([0.5, -1.0, 2.0, 0.25])
    book = bp.codebook(omega)
    assert book.shape == (16,)
    assert book[0] == 0.0                       # code 0 == exact zero
    for c in range(16):
        expect = sum(float(omega[i]) for i in range(4) if (c >> i) & 1)
        np.testing.assert_allclose(book[c], expect, rtol=1e-6)


def test_decode_equals_codebook_gather():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(0, 16, size=(32, 8)), jnp.uint8)
    omega = jnp.asarray(rng.normal(size=4), jnp.float32)
    np.testing.assert_allclose(bp.decode(codes, omega),
                               bp.codebook(omega)[codes], rtol=1e-6)


def test_decode_batched_matches_unbatched():
    rng = np.random.default_rng(2)
    codes = jnp.asarray(rng.integers(0, 16, size=(5, 6, 4)), jnp.uint8)
    omega = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
    out = bp.decode(codes, omega)
    for i in range(5):
        np.testing.assert_allclose(out[i], bp.decode(codes[i], omega[i]))


def test_omega_grad_is_bitplane_sum():
    """d decode / d omega_i == sum of bit-plane B_i — paper eq. (2)."""
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(0, 16, size=(16, 16)), jnp.uint8)
    omega = jnp.asarray(rng.normal(size=4), jnp.float32)
    g = jax.grad(lambda om: jnp.sum(bp.decode(codes, om)))(omega)
    for i in range(4):
        bi = ((codes >> i) & 1).astype(jnp.float32).sum()
        np.testing.assert_allclose(g[i], bi, rtol=1e-5)


def test_init_omega_covers_int4_grid():
    w = jnp.asarray(np.random.default_rng(4).normal(size=(64, 64)), jnp.float32)
    omega = bp.init_omega_from_weights(w)
    book = np.sort(np.asarray(bp.codebook(omega)))
    # subset sums of {s,2s,4s,-8s} = int4 grid [-8s, 7s]
    s = float(jnp.max(jnp.abs(w))) / 8
    np.testing.assert_allclose(book, np.arange(-8, 8) * s, rtol=1e-5)
