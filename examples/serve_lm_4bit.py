"""Serve an LM with frozen 4-bit weights and batched greedy decoding.

    PYTHONPATH=src python examples/serve_lm_4bit.py [--arch mamba2-1.3b]

Initialises a (smoke-sized) assigned architecture, freezes every FC weight
to packed int4 codes + 4 centroids (weights live at 4 bits/weight from then
on — the paper's data-movement win), then runs prefill + decode over a
request batch.  Works for any of the 10 assigned archs; attention archs use
the KV cache, mamba2 the recurrent SSM state, hymba both.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.core import qat
from repro.models.lm import generate
from repro.nn import transformer as T
from repro.nn.module import QuantCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b", choices=list_configs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    if cfg.family == "audio":
        raise SystemExit("enc-dec serving: see launch/serve.py docstring")
    key = jax.random.PRNGKey(0)
    params = T.lm_init(key, cfg)
    qstate = qat.build_qstate(params)

    n_quant = sum(l.size for l in jax.tree_util.tree_leaves(params)
                  if l.dtype == jnp.float32) // 1
    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    packed_bytes = sum(l.size for p, l in
                       jax.tree_util.tree_flatten_with_path(frozen)[0]
                       if "packed" in str(p))
    print(f"{args.arch} (smoke): frozen FC weights -> {packed_bytes} bytes "
          f"of packed int4 codes")

    ctx = QuantCtx(quant=False, compute_dtype=jnp.float32)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len),
                                0, cfg.vocab)
    out = generate(frozen, 0, prompt, ctx, cfg, max_new=args.max_new)
    print(f"generated {out.shape[1]} tokens for {out.shape[0]} requests:")
    for i in range(args.batch):
        print(f"  req{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
