"""Serve an LM with frozen 4-bit weights and batched greedy decoding.

    PYTHONPATH=src python examples/serve_lm_4bit.py [--arch smollm-360m]

Initialises a (smoke-sized) assigned architecture, freezes every FC weight
to packed int4 codes + 4 centroids (weights live at 4 bits/weight from then
on — the paper's data-movement win), then runs prefill + decode over a
request batch.

Dense-attention archs serve through the engine by default: a
``serving.LMProgram`` (one megakernel-backed FFN plan set per block)
registered in a ``ServingFrontend`` — prefill and decode steps arrive as
wire rows and each lockstep decode flush hits the FFN kernels as an
``m = n_seqs`` weight-stationary bucket.  ``--no-engine`` (and any arch
outside the program's dense contract: mamba2 / hymba / global-attn) falls
back to the direct ``models.lm.generate`` loop over the frozen tree.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs
from repro.core import qat
from repro.models.lm import generate
from repro.nn import transformer as T
from repro.nn.module import QuantCtx


def serve_engine(frozen, cfg, prompt, max_new):
    """Prefill + decode as wire rows through the serving frontend."""
    from repro import serving

    b, s = prompt.shape
    prog = serving.LMProgram(frozen, cfg, max_prompt=s, max_new=max_new,
                             max_bucket=1 << (max(s, b, 8) - 1).bit_length())
    toks = []
    frontend = serving.ServingFrontend()
    with frontend:
        frontend.register(cfg.name, prog, max_delay=1e-3)
        futs = [frontend.submit(cfg.name,
                                prog.encode_prefill(i + 1, prompt[i])[None])
                for i in range(b)]
        toks.append([int(f.result(60.0).y[0, 0]) for f in futs])
        for _ in range(max_new - 1):
            futs = [frontend.submit(cfg.name,
                                    prog.encode_decode(i + 1)[None])
                    for i in range(b)]
            toks.append([int(f.result(60.0).y[0, 0]) for f in futs])
    print(f"engine: {frontend.stats['launches']} launches, schedules "
          f"{prog.describe()['ffn_schedules']}")
    return np.asarray(toks, np.int64).T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list_configs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--engine", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve through serving.LMProgram + ServingFrontend "
                         "(dense archs); --no-engine uses the direct "
                         "models.lm.generate loop")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    if cfg.family == "audio":
        raise SystemExit("enc-dec serving: see launch/serve.py docstring")
    key = jax.random.PRNGKey(0)
    params = T.lm_init(key, cfg)
    qstate = qat.build_qstate(params)

    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    packed_bytes = sum(l.size for p, l in
                       jax.tree_util.tree_flatten_with_path(frozen)[0]
                       if "packed" in str(p))
    print(f"{args.arch} (smoke): frozen FC weights -> {packed_bytes} bytes "
          f"of packed int4 codes")

    prompt = np.asarray(jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab))
    out = None
    if args.engine:
        try:
            out = serve_engine(frozen, cfg, prompt, args.max_new)
        except ValueError as e:
            print(f"engine path unavailable ({e}); using the direct loop")
    if out is None:
        ctx = QuantCtx(quant=False, compute_dtype=jnp.float32)
        out = generate(frozen, 0, jnp.asarray(prompt), ctx, cfg,
                       max_new=args.max_new)
    print(f"generated {out.shape[1]} tokens for {out.shape[0]} requests:")
    for i in range(args.batch):
        print(f"  req{i}: {np.asarray(out)[i].tolist()}")


if __name__ == "__main__":
    main()
