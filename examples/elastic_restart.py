"""Fault tolerance demo: train, get preempted, resume — elastically.

    PYTHONPATH=src python examples/elastic_restart.py

Phase 1 trains a smoke LM for 40 steps with checkpoints every 10.
Phase 2 simulates a preemption (SIGTERM mid-loop): the loop checkpoints
at the step boundary and exits cleanly.  Phase 3 constructs a *fresh*
process state and resumes from the latest checkpoint; the step-seeded
data pipeline skips ahead exactly, so the loss curve continues as if
nothing happened.  (On a real pod, phase 3 may run on a different mesh —
restore reshapes arrays onto whatever devices exist; see
tests/test_checkpoint.py::test_elastic_restore_across_meshes.)
"""
import os
import signal
import tempfile
import threading

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data import pipeline, synthetic
from repro.launch import steps as steps_mod
from repro.nn.transformer import lm_init
from repro.optim import adam, ec4t
from repro.runtime.fault import FaultTolerantLoop

cfg = get_config("smollm-360m").smoke()
ckpt_dir = tempfile.mkdtemp(prefix="elastic_")
key = jax.random.PRNGKey(0)
data_cfg = synthetic.LMDataCfg(vocab=cfg.vocab, seq_len=32, global_batch=8)


def batch_fn(step):
    b = synthetic.lm_batch(data_cfg, step)
    return {"tokens": b["tokens"], "labels": b["labels"]}


def make_loop():
    loss_fn = steps_mod._loss_fn(cfg, mesh=None, use_ep=False, remat="none")
    step_fn = jax.jit(ec4t.make_train_step(
        loss_fn, adam.AdamConfig(lr=1e-3), lam=cfg.lam))
    mgr = CheckpointManager(ckpt_dir, keep=3)
    losses = []
    loop = FaultTolerantLoop(
        step_fn, mgr, ckpt_every=10, metrics_every=5,
        on_metrics=lambda s, m: losses.append((s, float(m["loss"]))))
    return loop, losses


print("phase 1: train 25 steps")
loop, losses = make_loop()
state = ec4t.init_train_state(lm_init(key, cfg))
feed = pipeline.ShardedFeed(batch_fn, start_step=0)
state, step, reason = loop.run(state, feed, total_steps=25)
feed.close()
print(f"  -> {reason} at step {step}; metrics {losses[-2:]}")

print("phase 2: resume and get preempted mid-run")
loop2, losses2 = make_loop()
state2, start = loop2.resume_or(ec4t.init_train_state(lm_init(key, cfg)))
print(f"  resumed at step {start}")
threading.Timer(1.0, lambda: os.kill(os.getpid(), signal.SIGTERM)).start()
feed = pipeline.ShardedFeed(batch_fn, start_step=start)
state2, step2, reason2 = loop2.run(state2, feed, start_step=start,
                                   total_steps=10_000)
feed.close()
print(f"  -> {reason2} at step {step2} (checkpointed)")

print("phase 3: fresh process state resumes exactly")
loop3, losses3 = make_loop()
state3, start3 = loop3.resume_or(ec4t.init_train_state(lm_init(key, cfg)))
assert start3 == step2, (start3, step2)
feed = pipeline.ShardedFeed(batch_fn, start_step=start3)
state3, step3, reason3 = loop3.run(state3, feed, start_step=start3,
                                   total_steps=start3 + 15)
feed.close()
print(f"  resumed from {start3}, finished {reason3} at {step3}; "
      f"metrics {losses3[-2:]}")
print("elastic restart OK")
