"""Quickstart: the FantastIC4 pipeline end to end in ~80 lines.

    PYTHONPATH=src python examples/quickstart.py

1. ECL-quantize weight matrices to 16 subset-sum centroids (4 bit-planes
   × 4 basis values ω — paper eq. 1),
2. pick the cheapest lossless format (CSR / bitmask / dense4),
3. freeze them into a serving pack and resolve a ``serving.ExecutionPlan``
   (mode, autotuned blocks, VMEM fit — decided once, not per call),
4. serve a batch through the plan and ragged requests through the
   micro-batcher (queue → bucket → plan), checking both against the
   pure-jnp oracle plan.
"""
import numpy as np
import jax.numpy as jnp

from repro import serving
from repro.core import bitplanes, ecl, formats

rng = np.random.default_rng(0)

# --- "trained" weights: heavy-tailed (laplacian), like real post-training
# weight distributions, so low-entropy coding has zeros to find
DIMS = (256, 128, 10)                      # a 2-layer MLP stack
layers = []
for i, (k, n) in enumerate(zip(DIMS[:-1], DIMS[1:])):
    w = jnp.asarray(rng.laplace(size=(k, n)) * 0.03, jnp.float32)
    omega = bitplanes.init_omega_from_weights(w)   # 4 basis centroids
    codes, probs = ecl.ecl_fit(w, omega, lam=0.5, iters=12)
    sparsity = float(ecl.sparsity(codes))
    entropy = float(ecl.entropy_bits(ecl.histogram(codes)))
    print(f"layer {i}: sparsity {sparsity:.1%}, entropy {entropy:.2f} "
          f"bits/weight (vs 4.0 uncoded)")

    # --- multiple lossless formats; the cheapest wins (contribution 4)
    best = formats.select_format(np.asarray(codes))
    cr = formats.compression_ratio(np.asarray(codes))
    print(f"  selected {best}: {cr:.1f}x smaller than fp32")

    layers.append({
        "packed": bitplanes.pack_codes_rows(codes),
        "omega": omega.astype(jnp.float32),
        "alpha1": jnp.ones((n,), jnp.float32),
        "bias": jnp.zeros((n,), jnp.float32),
        "alpha2": jnp.asarray(np.float32(1.0)),
        "shape": (k, n),
        "activation": "relu" if i < len(DIMS) - 2 else None,
    })
pack = {"layers": layers, "act_bits": None}

# --- ONE execution plan per pack: resolves kernel schedule per batch
# bucket (weight-stationary ≤8 rows, batch-tiled megakernel above),
# autotuned block sizes and the VMEM-fit fallback up front.
plan = serving.build_plan(pack, mode="auto")
oracle = serving.build_plan(pack, mode="oracle")
d = plan.describe()
print(f"plan: {d['resolved_mode']} (buckets {d['bucket_sizes']}, "
      f"block_m {d['block_m']}), batch 1 -> {plan.mode_label(1)}")

x = jnp.asarray(rng.normal(size=(8, DIMS[0])), jnp.float32)
y = plan.run(x)
np.testing.assert_allclose(y, oracle.run(x), atol=1e-4)
print("Pallas serving plan matches oracle ✓  (output", y.shape, ")")

# --- ragged traffic through the micro-batcher: requests of 1-4 rows
# coalesce into one power-of-two bucket launch, results scatter back.
batcher = serving.MicroBatcher(plan)
reqs = [jnp.asarray(rng.normal(size=(r, DIMS[0])), jnp.float32)
        for r in (1, 4, 2, 1)]
outs = batcher.serve(reqs)
for req, out in zip(reqs, outs):
    np.testing.assert_allclose(out, oracle.run(req), atol=1e-4)
st = batcher.stats
print(f"micro-batcher served {st['requests']} ragged requests "
      f"({st['rows']} rows) in {st['flushes']} launch(es), bucket hist "
      f"{st['bucket_hist']} ✓")
