"""Quickstart: the FantastIC4 pipeline on one weight matrix in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. ECL-quantize a weight matrix to 16 subset-sum centroids (4 bit-planes
   × 4 basis values ω — paper eq. 1),
2. pick the cheapest lossless format (CSR / bitmask / dense4),
3. run the ACM matmul through the Pallas kernel (interpret mode on CPU)
   and check it against the fp32 reference.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes, ecl, formats
from repro.kernels import ops

rng = np.random.default_rng(0)

# --- a "trained" weight matrix: heavy-tailed (laplacian), like real
# post-training weight distributions, so low-entropy coding has zeros to find
w = jnp.asarray(rng.laplace(size=(256, 128)) * 0.03, jnp.float32)
omega = bitplanes.init_omega_from_weights(w)          # 4 basis centroids
print("basis centroids ω:", np.asarray(omega))

# --- entropy-constrained assignment (λ controls the size↔accuracy trade)
codes, probs = ecl.ecl_fit(w, omega, lam=0.5, iters=12)
sparsity = float(ecl.sparsity(codes))
entropy = float(ecl.entropy_bits(ecl.histogram(codes)))
print(f"sparsity {sparsity:.1%}, entropy {entropy:.2f} bits/weight "
      f"(vs 4.0 uncoded)")

# --- multiple lossless formats; the cheapest wins (paper contribution 4)
for fmt in formats.FORMATS:
    ct = formats.encode(np.asarray(codes), fmt)
    assert np.array_equal(formats.decode(ct), np.asarray(codes))
    print(f"  {fmt:8s}: {ct.size_bytes:6d} bytes")
best = formats.select_format(np.asarray(codes))
cr = formats.compression_ratio(np.asarray(codes))
print(f"selected {best}: {cr:.1f}x smaller than fp32")

# --- ACM execution: packed 4-bit codes -> Pallas kernel (VMEM decode + MXU)
x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
packed = bitplanes.pack_codes_rows(codes)
y = ops.fantastic4_matmul(x, packed, omega, activation="relu",
                          use_kernel=True, interpret=True)
y_ref = jnp.maximum(x @ bitplanes.decode(codes, omega), 0.0)
np.testing.assert_allclose(y, y_ref, atol=1e-4)
print("Pallas ACM kernel matches reference ✓  (output", y.shape, ")")
