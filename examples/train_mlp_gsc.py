"""End-to-end driver: train the paper's MLP-GSC with EC4T, freeze, serve.

    PYTHONPATH=src python examples/train_mlp_gsc.py [--steps 400]

This is the paper's own experiment shape (§VI-A Google Speech Commands):
a 512-512-256-256-128-128-12 MLP with BatchNorm, trained with the
entropy-constrained 4-bit method, then folded into the §V serving pipeline
(α₁⊙(x·Ŵ)+b → ReLU → α₂) with per-layer format selection.  Reports the
Table-II row for this run: accuracy, sparsity, compression ratio, and
checks serving == training-eval numerics.
"""
import argparse
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, ".")
from benchmarks.common import train_mlp  # noqa: E402

from repro.configs.paper_mlps import MLP_GSC  # noqa: E402
from repro.core import qat  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.models import mlp as M  # noqa: E402
from repro.nn.module import QuantCtx  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lam", type=float, default=0.3)
    args = ap.parse_args()

    print(f"training MLP-GSC ({'-'.join(map(str, MLP_GSC.features))}) "
          f"with EC4T, λ={args.lam} ...")
    params, qs, bn, metrics = train_mlp(MLP_GSC, lam=args.lam,
                                        steps=args.steps)
    print(f"accuracy {metrics['acc']:.1%}  sparsity {metrics['sparsity']:.1%}"
          f"  entropy {metrics['entropy_bits']:.2f} bits/weight")

    pack = M.freeze_mlp(params, qs, bn, lam=args.lam)
    summ = M.pack_compression_summary(pack)
    print(f"frozen: {summ['compression_ratio']:.1f}x compression, "
          f"formats per layer: {summ['formats']}")

    # serving == eval-mode training forward
    data_cfg = synthetic.ClsDataCfg(d_in=MLP_GSC.d_in, n_classes=12,
                                    batch=256, margin=3.0, seed=0)
    b = synthetic.cls_batch(data_cfg, 99_999)
    x = jnp.asarray(b["x"])
    ctx = QuantCtx(quant=True, lam=args.lam, compute_dtype=jnp.float32)
    y_eval, _ = M.mlp_apply(params, qs, bn, x, ctx, train=False)
    y_serve = M.mlp_serve(pack, x, use_kernel=False)
    np.testing.assert_allclose(y_serve, y_eval, atol=1e-2, rtol=1e-2)
    acc = float(M.accuracy(y_serve, jnp.asarray(b["labels"])))
    print(f"serving path verified ✓  held-out accuracy {acc:.1%}")


if __name__ == "__main__":
    main()
