"""Mamba2 — state-space duality (SSD), arXiv:2405.21060.

Sequence mode (train / prefill) uses the chunked SSD algorithm: the sequence
is cut into chunks of length Q; each chunk's *intra*-chunk contribution is a
small quadratic ("attention-like") einsum under a decay mask, chunk boundary
states are combined with a **parallel associative scan** (log-depth on TPU),
and the *inter*-chunk contribution is one more einsum.  Cost is
O(S·Q·(H·P + G·N)) — linear in S — which is what qualifies mamba2/hymba for
the ``long_500k`` cell.

Decode mode carries a recurrent state (B, H, P, N) plus a (width-1)-deep
convolution tail; one token costs O(H·P·N) regardless of context length.
``tests/test_ssm.py`` asserts sequence == step-by-step decode.

Quantization (DESIGN.md §5): in/out projections are EC4T-quantized (the bulk
of parameters); A_log, dt_bias, D, conv and norm parameters stay fp32 — they
are tiny and sensitivity-critical, the paper's mixed-precision rule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .layers import linear, linear_init, subtree
from .module import QuantCtx


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_model: int
    d_inner: int          # = expand * d_model
    n_heads: int          # d_inner // headdim
    d_state: int
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256

    @property
    def headdim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def ssm_init(key, cfg: SSMCfg, quantize: bool) -> dict:
    c = cfg
    k1, k2, k3 = jax.random.split(key, 3)
    d_in_proj = 2 * c.d_inner + 2 * c.n_groups * c.d_state + c.n_heads
    return {
        "in_proj": linear_init(k1, c.d_model, d_in_proj, quantize),
        "out_proj": linear_init(k2, c.d_inner, c.d_model, quantize),
        "conv_w": jax.random.normal(k3, (c.conv_width, c.conv_dim),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((c.conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, c.n_heads)),   # A = -exp(A_log)
        "dt_bias": jnp.zeros((c.n_heads,), jnp.float32),
        "D": jnp.ones((c.n_heads,), jnp.float32),
        "norm_scale": jnp.ones((c.d_inner,), jnp.float32),
    }


def init_ssm_state(batch: int, cfg: SSMCfg, dtype=jnp.float32) -> dict:
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), dtype),
    }


def _gated_rms_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                    eps: float = 1e-6) -> jax.Array:
    """Mamba2's RMSNorm(y * silu(z)) gate."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def _segsum_exp(a: jax.Array) -> jax.Array:
    """a: (..., Q) log-decays -> (..., Q, Q) lower-triangular exp(Σ_{k<i<=q} a_i).

    The mask is applied *inside* the exp (as -1e30) rather than on its
    output: ``where(mask, exp(diff), 0)`` leaks inf·0 = NaN through the
    upper triangle in reverse mode (diff > 0 there overflows exp)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]     # (..., q, k): Σ_{k+1..q}
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.exp(jnp.where(mask, diff, -1e30))


def ssd_chunked(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                chunk: int, init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (b, s, h, p) pre-scaled inputs (already ×dt); a: (b, s, h) log-decay
    (= dt·A, ≤ 0); B, C: (b, s, g, n) with h % g == 0.
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        a = jnp.pad(a, [(0, 0), (0, pad), (0, 0)])        # a=0 ⇒ decay 1
        B = jnp.pad(B, [(0, 0), (0, pad), (0, 0), (0, 0)])
        C = jnp.pad(C, [(0, 0), (0, pad), (0, 0), (0, 0)])
    nc = x.shape[1] // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    # broadcast groups to heads for the einsums
    Bh = jnp.repeat(Bc, rep, axis=3)                       # (b,nc,Q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # ---- intra-chunk (block-diagonal) term
    L = _segsum_exp(ac.transpose(0, 1, 3, 2))              # (b,nc,h,Q,Q)
    y_diag = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp",
                        Ch.astype(jnp.float32), Bh.astype(jnp.float32),
                        L, xc.astype(jnp.float32))

    # ---- chunk-final states
    a_cum = jnp.cumsum(ac, axis=2)                         # (b,nc,Q,h)
    a_tot = a_cum[:, :, -1]                                # (b,nc,h)
    decay_to_end = jnp.exp(a_tot[:, :, None] - a_cum)      # (b,nc,Q,h)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn",
                        Bh.astype(jnp.float32), decay_to_end,
                        xc.astype(jnp.float32))            # (b,nc,h,p,n)

    # ---- inter-chunk recurrence: s_c = exp(a_tot_c)·s_{c-1} + states_c
    decay_chunk = jnp.exp(a_tot).transpose(0, 2, 1)        # (b,h,nc)
    states_t = states.transpose(0, 2, 1, 3, 4)             # (b,h,nc,p,n)
    if init_state is not None:
        # prepend the carried-in state as a virtual chunk with decay 1
        states_t = jnp.concatenate(
            [init_state.astype(jnp.float32)[:, :, None], states_t], axis=2)
        decay_chunk = jnp.concatenate(
            [jnp.ones((b, h, 1), jnp.float32), decay_chunk], axis=2)

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, sr + sl * dr[..., None, None]

    dscan, sscan = jax.lax.associative_scan(
        combine, (decay_chunk, states_t), axis=2)
    final_state = sscan[:, :, -1]                          # (b,h,p,n)
    # state entering chunk c = scanned state of chunk c-1
    if init_state is not None:
        prev = sscan[:, :, :-1]
    else:
        prev = jnp.concatenate(
            [jnp.zeros_like(sscan[:, :, :1]), sscan[:, :, :-1]], axis=2)
    prev = prev.transpose(0, 2, 1, 3, 4)                   # (b,nc,h,p,n)

    # ---- inter-chunk output term
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Ch.astype(jnp.float32), prev, jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(b, nc * chunk, h, p)[:, :s]
    return y, final_state


def ssm_apply(p: dict, q_state: Any, u: jax.Array, ctx: QuantCtx,
              cfg: SSMCfg, *, state: Optional[dict] = None):
    """Sequence-mode mamba2 block: u (b, s, d_model) -> (y, new_state).

    When ``state`` is given its ssm/conv tails seed the computation
    (prefill-continuation / decode parity tests)."""
    c = cfg
    b, s, _ = u.shape
    zxbcdt = linear(p["in_proj"], subtree(q_state, "in_proj"), u, ctx)
    z = zxbcdt[..., :c.d_inner]
    xBC = zxbcdt[..., c.d_inner:c.d_inner + c.conv_dim]
    dt_raw = zxbcdt[..., -c.n_heads:]

    # causal depthwise conv (width W): pad left with conv tail (or zeros)
    w = c.conv_width
    tail = (state["conv"].astype(xBC.dtype) if state is not None
            else jnp.zeros((b, w - 1, c.conv_dim), xBC.dtype))
    xBC_pad = jnp.concatenate([tail, xBC], axis=1)
    new_conv_tail = xBC_pad[:, -(w - 1):]
    conv = sum(xBC_pad[:, i:i + s] * p["conv_w"][i].astype(xBC.dtype)
               for i in range(w))
    xBC = jax.nn.silu(conv.astype(jnp.float32) + p["conv_b"]).astype(ctx.dtype)

    x = xBC[..., :c.d_inner].reshape(b, s, c.n_heads, c.headdim)
    B = xBC[..., c.d_inner:c.d_inner + c.n_groups * c.d_state]
    C = xBC[..., c.d_inner + c.n_groups * c.d_state:]
    B = B.reshape(b, s, c.n_groups, c.d_state)
    C = C.reshape(b, s, c.n_groups, c.d_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (b,s,h)
    A = -jnp.exp(p["A_log"])                                          # (h,)
    a = dt * A                                                        # log-decay
    x_dt = x.astype(jnp.float32) * dt[..., None]

    init_ssm = state["ssm"] if state is not None else None
    y, fin = ssd_chunked(x_dt, a, B, C, c.chunk, init_state=init_ssm)
    y = y + x.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, s, c.d_inner).astype(ctx.dtype)

    y = _gated_rms_norm(y, z, p["norm_scale"])
    out = linear(p["out_proj"], subtree(q_state, "out_proj"), y, ctx)
    new_state = {"ssm": fin, "conv": new_conv_tail.astype(jnp.float32)}
    return out, new_state


def ssm_step(p: dict, q_state: Any, u: jax.Array, ctx: QuantCtx,
             cfg: SSMCfg, state: dict):
    """Decode-mode: u (b, 1, d_model), O(H·P·N) per token."""
    c = cfg
    b = u.shape[0]
    zxbcdt = linear(p["in_proj"], subtree(q_state, "in_proj"), u, ctx)
    z = zxbcdt[:, 0, :c.d_inner]
    xBC_new = zxbcdt[:, 0, c.d_inner:c.d_inner + c.conv_dim]
    dt_raw = zxbcdt[:, 0, -c.n_heads:]

    conv_in = jnp.concatenate(
        [state["conv"].astype(xBC_new.dtype), xBC_new[:, None]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32), p["conv_w"])
    xBC = jax.nn.silu(conv + p["conv_b"]).astype(ctx.dtype)
    new_conv_tail = conv_in[:, 1:].astype(jnp.float32)

    x = xBC[:, :c.d_inner].reshape(b, c.n_heads, c.headdim)
    B = xBC[:, c.d_inner:c.d_inner + c.n_groups * c.d_state]
    C = xBC[:, c.d_inner + c.n_groups * c.d_state:]
    rep = c.n_heads // c.n_groups
    Bh = jnp.repeat(B.reshape(b, c.n_groups, c.d_state), rep, axis=1)
    Ch = jnp.repeat(C.reshape(b, c.n_groups, c.d_state), rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (b,h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                              # (b,h)

    s_new = (state["ssm"].astype(jnp.float32) * dA[..., None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32),
                          Bh.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", s_new, Ch.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, c.d_inner).astype(ctx.dtype)

    y = _gated_rms_norm(y, z, p["norm_scale"])
    out = linear(p["out_proj"], subtree(q_state, "out_proj"), y[:, None], ctx)
    return out, {"ssm": s_new, "conv": new_conv_tail}
