"""Attention: GQA / MHA / sliding-window / MLA, with chunked online-softmax.

One implementation serves all assigned LM archs:

* **GQA** (qwen2-vl, smollm, danube, glm4, grok) — ``n_kv <= n_heads`` KV
  heads, queries grouped.  MHA (codeqwen, whisper) is the ``n_kv == n_heads``
  special case.
* **SWA** (danube, hymba) — sliding-window mask of width ``window``; caps the
  KV cache at ``window`` for decode, which is what makes ``long_500k``
  sub-quadratic for these archs.
* **MLA** (deepseek-v3) — low-rank latent compression of Q and KV.  The
  cache stores only the 512-wide latent + 64-wide rope key.  Prefill/train
  decompress the latent **per KV chunk inside the softmax scan** (never
  materialising the (B,S,128,192) full K); decode uses the *absorbed* form
  (W_uk folded into the query, attention directly against the latent).

All softmax paths run through :func:`chunked_attention`, a flash-attention
style online-softmax over KV chunks expressed with ``jax.lax.scan``:

* memory is O(Sq · chunk) instead of O(Sq · Skv) — required for
  ``prefill_32k``/``decode_32k``;
* KV heads are consumed via grouped einsums (no ``repeat`` to Q heads);
* an optional ``kv_chunk_fn`` maps raw scan inputs to the chunk's (K, V) —
  identity for GQA, latent-decompression for MLA;
* it is the exact softmax (running max + normaliser), asserted against the
  dense reference in tests.

Sharding notes (runtime/sharding.py): Q/K/V/O kernels shard over the 'model'
mesh axis on the head dimension when divisible, else stay replicated; the KV
cache shards on batch over the data axes.  This file is sharding-agnostic —
it computes on global logical shapes and lets GSPMD partition.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rotary, linear, linear_init, subtree
from .module import QuantCtx, materialize

NEG_INF = -1e30  # large-but-finite: keeps fully-masked rows NaN-free


# ------------------------------------------------------------ mask helpers

def _mask_bias(q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
               window: Optional[int], kv_len: Optional[jax.Array]) -> jax.Array:
    """Additive bias (B, Sq, Skv) from position vectors.

    q_pos: (B, Sq) int32 absolute positions of the queries.
    kv_pos: (B, Skv) int32 absolute positions of the keys (-1 = padding).
    kv_len: optional (B,) number of valid cache entries (decode).
    """
    q = q_pos[:, :, None]          # (B, Sq, 1)
    k = kv_pos[:, None, :]         # (B, 1, Skv)
    ok = k >= 0
    if causal:
        ok &= k <= q
    if window is not None:
        ok &= q - k < window
    if kv_len is not None:
        ok &= k < kv_len[:, None, None]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ------------------------------------------------ chunked online softmax

def chunked_attention(q: jax.Array, kv_parts: Any, *,
                      q_pos: jax.Array, kv_pos: jax.Array,
                      causal: bool = True, window: Optional[int] = None,
                      kv_len: Optional[jax.Array] = None,
                      chunk: int = 1024, scale: float,
                      n_kv: int, dv: int,
                      kv_chunk_fn: Optional[Callable] = None) -> jax.Array:
    """Exact softmax attention, online over KV chunks.

    q: (B, Sq, H, D).  ``kv_parts`` is a pytree whose leaves have the KV
    sequence on axis 1; ``kv_chunk_fn(parts_chunk)`` maps a chunk of it to
    ``(k, v)`` of shapes (B, c, n_kv, D) / (B, c, n_kv, dv).  When None,
    ``kv_parts`` must already be that (k, v) tuple.
    Returns (B, Sq, H, dv) in f32.
    """
    b, sq, h, d = q.shape
    rep = h // n_kv
    # keep q/k/v in their storage dtype; the score einsums accumulate in
    # f32 via preferred_element_type (MXU bf16xbf16+f32).  Materialising
    # f32 *copies* of every KV chunk doubled the serving memory-roofline
    # term (EXPERIMENTS.md §Perf iteration 1).
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(b, sq, n_kv, rep, d)

    skv = jax.tree_util.tree_leaves(kv_parts)[0].shape[1]
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        def padk(a):
            w = [(0, 0)] * a.ndim
            w[1] = (0, pad)
            return jnp.pad(a, w)
        kv_parts = jax.tree_util.tree_map(padk, kv_parts)
        # padded keys land at position -1 so the mask rejects them
        kv_pos = jnp.pad(kv_pos, [(0, 0), (0, pad)], constant_values=-1)
    n_chunks = (skv + pad) // chunk

    def to_scan(a):  # (B, n*c, ...) -> (n, B, c, ...)
        return a.reshape(a.shape[0], n_chunks, chunk, *a.shape[2:]).swapaxes(0, 1)

    scan_parts = jax.tree_util.tree_map(to_scan, kv_parts)
    scan_pos = to_scan(kv_pos[:, :, None])[..., 0]           # (n, B, c)

    ident = kv_chunk_fn is None

    def body(carry, inp):
        m, l, acc = carry          # (B,G,rep,Sq) ×2, (B,G,rep,Sq,dv)
        parts_c, pos_c = inp
        kc, vc = parts_c if ident else kv_chunk_fn(parts_c)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qf, kc,
                       preferred_element_type=jnp.float32)  # (B,G,rep,Sq,c)
        s = s + _mask_bias(q_pos, pos_c, causal=causal, window=window,
                           kv_len=kv_len)[:, None, None]
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, n_kv, rep, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, n_kv, rep, sq), jnp.float32),
            jnp.zeros((b, n_kv, rep, sq, dv), jnp.float32))
    # checkpoint the chunk body: the bwd pass re-forms each chunk's scores
    # instead of stacking (n_chunks, B, G, rep, Sq, c) f32 probability
    # tensors in HBM — on memory-bound cells the recompute is ~free
    # (§Perf iteration 5)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                                  init, (scan_parts, scan_pos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]             # (B,G,rep,Sq,dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)


def dense_attention_ref(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                        kv_len=None, scale=None):
    """O(Sq·Skv)-memory oracle for tests."""
    d = q.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    rep = q.shape[2] // k.shape[2]
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kf)
    s = s + _mask_bias(q_pos, kv_pos, causal=causal, window=window,
                       kv_len=kv_len)[:, None]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


def softmax_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                      kv_len=None, chunk=1024, scale=None):
    """Standard (k, v) entry point into :func:`chunked_attention`."""
    scale = (q.shape[-1] ** -0.5) if scale is None else scale
    return chunked_attention(
        q, (k, v), q_pos=q_pos, kv_pos=kv_pos, causal=causal,
        window=window, kv_len=kv_len, chunk=chunk, scale=scale,
        n_kv=k.shape[2], dv=v.shape[-1])


# ---------------------------------------------------------------- KV cache

def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    """Ring-buffer KV cache.  ``pos`` holds each slot's absolute position
    (-1 = empty); masking is purely position-based, so a window-capped
    buffer (SWA decode: size == window) wraps for free — this is what keeps
    ``long_500k`` decode at O(window) memory for danube/hymba."""
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


def _cache_update(cache: dict, k_new: jax.Array, v_new: jax.Array,
                  positions: jax.Array) -> dict:
    """Write Sq new KV entries at slot len % size (functional).

    Multi-entry writes (prefill) must not wrap: callers size prefill caches
    at full sequence length; only single-token decode wraps."""
    size = cache["k"].shape[1]
    idx = cache["len"] % size
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, idx, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, idx, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"],
                                       positions[0].astype(jnp.int32), (idx,))
    return {"k": k, "v": v, "pos": pos, "len": cache["len"] + k_new.shape[1]}


# -------------------------------------------------------------------- GQA

def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             quantize: bool, qkv_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": linear_init(kq, d_model, n_heads * head_dim, quantize, bias=qkv_bias),
        "k": linear_init(kk, d_model, n_kv * head_dim, quantize, bias=qkv_bias),
        "v": linear_init(kv, d_model, n_kv * head_dim, quantize, bias=qkv_bias),
        "o": linear_init(ko, n_heads * head_dim, d_model, quantize),
    }


def gqa_apply(p: dict, q_state: Any, x: jax.Array, ctx: QuantCtx, *,
              n_heads: int, n_kv: int, head_dim: int,
              cos_sin: Optional[tuple] = None,
              positions: Optional[jax.Array] = None,
              causal: bool = True, window: Optional[int] = None,
              cache: Optional[dict] = None,
              kv_override: Optional[tuple] = None,
              chunk: int = 1024):
    """Self-attention (or cross-attention when ``kv_override`` is given).

    Returns (y, new_cache).  ``positions``: (B, Sq) absolute positions of x.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    q = linear(p["q"], subtree(q_state, "q"), x, ctx).reshape(b, s, n_heads, head_dim)
    if kv_override is None:
        k = linear(p["k"], subtree(q_state, "k"), x, ctx).reshape(b, s, n_kv, head_dim)
        v = linear(p["v"], subtree(q_state, "v"), x, ctx).reshape(b, s, n_kv, head_dim)
        if cos_sin is not None:
            cos, sin = cos_sin
            q = apply_rotary(q, cos, sin)
            k = apply_rotary(k, cos, sin)
    else:
        k, v = kv_override                      # cross-attn: precomputed KV

    new_cache = None
    if cache is not None and kv_override is None:
        new_cache = _cache_update(cache, k, v, positions)
        k, v = new_cache["k"], new_cache["v"]
        kv_pos = jnp.broadcast_to(new_cache["pos"], (b, k.shape[1]))
    else:
        kv_pos = jnp.broadcast_to(
            jnp.arange(k.shape[1], dtype=jnp.int32), (b, k.shape[1]))

    out = softmax_attention(
        q, k, v, positions, kv_pos, causal=causal and kv_override is None,
        window=window, chunk=chunk)
    out = out.reshape(b, s, n_heads * head_dim).astype(ctx.dtype)
    y = linear(p["o"], subtree(q_state, "o"), out, ctx)
    return y, new_cache


# -------------------------------------------------------------------- MLA

@dataclasses.dataclass(frozen=True)
class MLACfg:
    """DeepSeek-V3 multi-head latent attention dims (arXiv:2412.19437)."""
    d_model: int = 7168
    n_heads: int = 128
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def mla_init(key, cfg: MLACfg, quantize: bool) -> dict:
    """Low-rank Q and KV projections.  The *latent* c_kv (kv_lora_rank) plus
    the shared rope key (qk_rope_dim) are what decode caches — the paper's
    'compress the cache' idea; the cache stays 16-bit (activations are
    quantization-sensitive, FantastIC4 fig. 2)."""
    ks = jax.random.split(key, 5)
    c = cfg
    return {
        "q_down": linear_init(ks[0], c.d_model, c.q_lora_rank, quantize),
        "q_up": linear_init(ks[1], c.q_lora_rank, c.n_heads * c.qk_dim, quantize),
        "kv_down": linear_init(ks[2], c.d_model,
                               c.kv_lora_rank + c.qk_rope_dim, quantize),
        "kv_up": linear_init(ks[3], c.kv_lora_rank,
                             c.n_heads * (c.qk_nope_dim + c.v_head_dim), quantize),
        "o": linear_init(ks[4], c.n_heads * c.v_head_dim, c.d_model, quantize),
    }


def init_mla_cache(batch: int, max_len: int, cfg: MLACfg,
                   dtype=jnp.bfloat16) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


def mla_apply(p: dict, q_state: Any, x: jax.Array, ctx: QuantCtx,
              cfg: MLACfg, *, cos_sin: tuple,
              positions: Optional[jax.Array] = None,
              cache: Optional[dict] = None, chunk: int = 1024,
              force_absorbed: Optional[bool] = None):
    """MLA block.  Path selection:

    * Sq > 1 (train / prefill): *naive* form with per-chunk latent
      decompression inside the softmax scan — cheaper when Sq is large and
      never materialises the (B, Skv, H, qk_dim) K tensor.
    * Sq == 1 (decode): *absorbed* form — W_uk folded into the query and
      W_uv applied after attending directly over the latent; per-step cost
      O(Skv · H · (r + rope)) instead of O(Skv · H · r · decompress).
    """
    b, s, _ = x.shape
    c = cfg
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    q = linear(p["q_up"], subtree(q_state, "q_up"),
               linear(p["q_down"], subtree(q_state, "q_down"), x, ctx), ctx)
    q = q.reshape(b, s, c.n_heads, c.qk_dim)
    q_nope, q_rope = q[..., :c.qk_nope_dim], q[..., c.qk_nope_dim:]

    kv = linear(p["kv_down"], subtree(q_state, "kv_down"), x, ctx)
    ckv, k_rope = kv[..., :c.kv_lora_rank], kv[..., c.kv_lora_rank:]

    cos, sin = cos_sin
    q_rope = apply_rotary(q_rope, cos, sin)
    k_rope = apply_rotary(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        idx = cache["len"] % cache["ckv"].shape[1]
        ckv_all = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, idx, 0))
        kr_all = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, idx, 0))
        pos_all = jax.lax.dynamic_update_slice(
            cache["pos"], positions[0].astype(jnp.int32), (idx,))
        new_cache = {"ckv": ckv_all, "krope": kr_all, "pos": pos_all,
                     "len": cache["len"] + s}
        ckv, k_rope = ckv_all, kr_all
        kv_pos = jnp.broadcast_to(pos_all, (b, ckv.shape[1]))
    else:
        kv_pos = jnp.broadcast_to(
            jnp.arange(ckv.shape[1], dtype=jnp.int32), (b, ckv.shape[1]))

    skv = ckv.shape[1]
    scale = c.qk_dim ** -0.5

    # materialise the (possibly fake-quantized) up-projection once
    w_up = materialize(p["kv_up"]["kernel"], subtree(subtree(q_state, "kv_up"),
                                                     "kernel"), ctx)
    w_up = w_up.reshape(c.kv_lora_rank, c.n_heads, c.qk_nope_dim + c.v_head_dim)
    w_uk = w_up[..., :c.qk_nope_dim]          # (r, H, nope)
    w_uv = w_up[..., c.qk_nope_dim:]          # (r, H, v)

    absorbed = (s == 1) if force_absorbed is None else force_absorbed
    if absorbed:
        # fold W_uk into the query; attend over the latent (n_kv = 1)
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        q_full = jnp.concatenate([q_abs, q_rope.astype(jnp.float32)], axis=-1)
        k_lat = jnp.concatenate([ckv, k_rope], axis=-1)[:, :, None, :]
        out_lat = chunked_attention(
            q_full, (k_lat, ckv[:, :, None, :]), q_pos=positions,
            kv_pos=kv_pos, causal=True, chunk=chunk,
            scale=scale, n_kv=1, dv=c.kv_lora_rank)
        out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_uv.astype(jnp.float32))
    else:
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

        def decompress(parts_c):
            ckv_c, kr_c = parts_c             # (B,c,r), (B,c,rope)
            kvu = jnp.einsum("bkr,rhd->bkhd", ckv_c.astype(jnp.float32),
                             w_up.astype(jnp.float32))
            k_c = jnp.concatenate(
                [kvu[..., :c.qk_nope_dim],
                 jnp.broadcast_to(kr_c[:, :, None, :].astype(jnp.float32),
                                  (*kr_c.shape[:2], c.n_heads, c.qk_rope_dim))],
                axis=-1)
            return k_c, kvu[..., c.qk_nope_dim:]

        out = chunked_attention(
            q_full, (ckv, k_rope), q_pos=positions, kv_pos=kv_pos,
            causal=True, chunk=chunk, scale=scale,
            n_kv=c.n_heads, dv=c.v_head_dim, kv_chunk_fn=decompress)

    out = out.reshape(b, s, c.n_heads * c.v_head_dim).astype(ctx.dtype)
    y = linear(p["o"], subtree(q_state, "o"), out, ctx)
    return y, new_cache
