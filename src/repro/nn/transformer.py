"""Decoder-only transformer assembly for all assigned LM-family archs.

One ``lm_init``/``lm_apply`` pair covers dense / vlm / moe / ssm / hybrid by
branching on ``ArchConfig.family`` at *trace* time.  Layers are **stacked**
((L, ...) leaves) and executed with ``jax.lax.scan`` so that the HLO holds a
single layer body — this keeps compile time flat in depth (61-layer deepseek
lowers in the same time as 2-layer smoke) and is what makes the 80-cell
dry-run tractable.  Heterogeneous depth (deepseek: 3 dense + 58 MoE layers)
becomes two consecutive scans over two stacks.

Per-layer quantization state (probs) and KV/SSM caches are stacked the same
way and travel through the scan as xs/ys.  Per-layer scalars that vary
across layers (hymba's SWA-vs-global window) are scan inputs too, so the
body stays layer-uniform.

Activation checkpointing: ``remat`` wraps the scan body with
``jax.checkpoint`` — "full" recomputes the whole block on the backward pass
(min memory), "dots" saves matmul outputs (the XLA-recommended middle
ground), "none" saves everything.  A hillclimb lever in §Perf.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (embedding_init, gelu_mlp, gelu_mlp_init, layer_norm,
                     layer_norm_init, linear_init, mrope_cos_sin,
                     rms_norm, rms_norm_init, rope_cos_sin, subtree,
                     swiglu, swiglu_init)
from .module import QuantCtx

HUGE_WINDOW = 1 << 30     # "global attention" encoded as a very wide window


def _norm_init(cfg: ArchConfig, d: int) -> dict:
    return layer_norm_init(d) if cfg.norm == "layer" else rms_norm_init(d)


def _norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    return layer_norm(p, x) if cfg.norm == "layer" else rms_norm(p, x)


def _mlp_init(key, cfg: ArchConfig, d_ff: int) -> dict:
    if cfg.act == "gelu":
        return gelu_mlp_init(key, cfg.d_model, d_ff, cfg.quantize)
    return swiglu_init(key, cfg.d_model, d_ff, cfg.quantize)


def _mlp(cfg: ArchConfig, p: dict, q: Any, x: jax.Array, ctx: QuantCtx):
    if cfg.act == "gelu":
        return gelu_mlp(p, q, x, ctx)
    return swiglu(p, q, x, ctx)


def _ssm_cfg(cfg: ArchConfig) -> ssm_lib.SSMCfg:
    return ssm_lib.SSMCfg(d_model=cfg.d_model, d_inner=cfg.d_inner,
                          n_heads=cfg.ssm_heads, d_state=cfg.ssm_state,
                          n_groups=cfg.ssm_groups, chunk=cfg.ssm_chunk)


def _mla_cfg(cfg: ArchConfig) -> attn.MLACfg:
    m = cfg.mla
    return attn.MLACfg(d_model=cfg.d_model, n_heads=cfg.n_heads,
                       q_lora_rank=m.q_lora_rank, kv_lora_rank=m.kv_lora_rank,
                       qk_nope_dim=m.qk_nope_dim, qk_rope_dim=m.qk_rope_dim,
                       v_head_dim=m.v_head_dim)


# ------------------------------------------------------------- layer init

def _layer_init(key, cfg: ArchConfig, kind: str) -> dict:
    """kind: dense | moe | ssm | hybrid (resolved from family per depth)."""
    ks = jax.random.split(key, 8)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p: dict = {"ln1": _norm_init(cfg, d)}

    if kind != "ssm":
        if cfg.mla is not None:
            p["attn"] = attn.mla_init(ks[0], _mla_cfg(cfg), cfg.quantize)
        else:
            p["attn"] = attn.gqa_init(ks[0], d, cfg.n_heads, cfg.n_kv, hd,
                                      cfg.quantize, qkv_bias=cfg.qkv_bias)

    if kind == "ssm" or kind == "hybrid":
        p["ssm"] = ssm_lib.ssm_init(ks[1], _ssm_cfg(cfg), cfg.quantize)
    if kind == "hybrid":
        p["attn_norm"] = rms_norm_init(d)
        p["ssm_norm"] = rms_norm_init(d)

    if kind == "dense":
        p["ln2"] = _norm_init(cfg, d)
        p["mlp"] = _mlp_init(ks[2], cfg, cfg.dense_ff or cfg.d_ff)
    elif kind == "moe":
        p["ln2"] = _norm_init(cfg, d)
        p["moe"] = moe_lib.moe_init(ks[2], d, cfg.d_ff, cfg.n_experts,
                                    cfg.quantize,
                                    n_shared=cfg.n_shared_experts)
    elif kind == "hybrid":
        p["ln2"] = _norm_init(cfg, d)
        p["mlp"] = _mlp_init(ks[2], cfg, cfg.d_ff)
    return p


def _stack(trees: list) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _layer_kinds(cfg: ArchConfig) -> list:
    if cfg.family == "moe":
        return (["dense"] * cfg.n_dense_layers
                + ["moe"] * (cfg.n_layers - cfg.n_dense_layers))
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "hybrid":
        return ["hybrid"] * cfg.n_layers
    return ["dense"] * cfg.n_layers   # dense | vlm


def lm_init(key, cfg: ArchConfig) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    kinds = _layer_kinds(cfg)
    stacks: dict = {}
    for kind in ("dense", "moe", "ssm", "hybrid"):
        idx = [i for i, k in enumerate(kinds) if k == kind]
        if idx:
            stacks[kind] = _stack([_layer_init(keys[i], cfg, kind)
                                   for i in idx])
    p = {
        "embed": embedding_init(keys[-1], cfg.padded_vocab, cfg.d_model),
        "final_norm": _norm_init(cfg, cfg.d_model),
        "stacks": stacks,
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(keys[-2], cfg.d_model, cfg.padded_vocab,
                                   quantize=False)
    return p


# ------------------------------------------------------------------ cache

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, cap_window: bool = False) -> dict:
    """Stacked per-layer decode state.  With ``cap_window`` (decode-only
    usage) SWA archs get a window-sized ring buffer — O(window) memory at
    any context length; prefill callers keep the full length so multi-token
    writes never wrap.  hymba's few global-attention layers force full
    length (its long-context memory win comes from the SSM branch + SWA on
    the other 29 layers)."""
    kinds = _layer_kinds(cfg)
    caches: dict = {}

    def attn_cache():
        if cfg.mla is not None:
            return attn.init_mla_cache(batch, max_len, _mla_cfg(cfg), dtype)
        kv_len = max_len
        if cap_window and cfg.window and not cfg.global_attn_layers:
            kv_len = min(max_len, cfg.window)
        return attn.init_kv_cache(batch, kv_len, cfg.n_kv,
                                  cfg.resolved_head_dim, dtype)

    for kind in ("dense", "moe", "ssm", "hybrid"):
        n = sum(1 for k in kinds if k == kind)
        if not n:
            continue
        per: dict = {}
        if kind != "ssm":
            per["attn"] = attn_cache()
        if kind in ("ssm", "hybrid"):
            per["ssm"] = ssm_lib.init_ssm_state(batch, _ssm_cfg(cfg))
        caches[kind] = _stack([per] * n)
    return caches


# ---------------------------------------------------------------- forward

def _windows_for(cfg: ArchConfig, idx: list) -> Optional[jax.Array]:
    """Per-layer window sizes (hymba) or None for a uniform setting."""
    if not cfg.global_attn_layers:
        return None
    ws = [HUGE_WINDOW if i in cfg.global_attn_layers else cfg.window
          for i in idx]
    return jnp.asarray(ws, jnp.int32)


def _attn_batch_reshard(cfg: ArchConfig, mesh, seq: int) -> bool:
    """True when attention should run batch-sharded over the *model* axis.

    Archs whose head counts don't divide the model axis (smollm 15H/5kv,
    hymba 25H/5kv, qwen2-vl 12H/2kv, glm4 2kv...) fall back to replicated
    attention weights; without this reshard every model-column then runs
    the *same* attention compute — a tp× FLOP and intermediate-traffic
    inflation (20.8× HLO/MODEL on smollm, §Perf iteration 2).  Resharding
    the activations so batch spans (data × model) for the attention block
    costs two cheap batch all-to-alls per layer and removes the redundancy.
    """
    if mesh is None or "model" not in mesh.axis_names:
        return False
    tp = mesh.shape["model"]
    if tp == 1 or cfg.mla is not None:
        return False
    heads_sharded = cfg.n_heads % tp == 0 and cfg.n_kv % tp == 0
    return (not heads_sharded) and seq % tp == 0


def _block(cfg: ArchConfig, kind: str, lp: dict, lq: Any, x: jax.Array,
           ctx: QuantCtx, *, cos_sin, positions, lcache, window,
           mesh, use_ep: bool, attn_reshard: bool = False):
    """One transformer block; returns (x, new_lcache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, lp["ln1"], x)
    new_cache: dict = {}

    def reshard(arr, full: bool):
        """Sequence-parallel attention re-sharding: inside the attention
        block, (B, S, ...) tensors shard S over 'model' (queries split;
        GSPMD all-gathers the much smaller K/V).  Going *into* the block
        this is a free partition refinement; going out it is one gather of
        the block output.  (Batch-dim resharding triggered GSPMD's
        'involuntary full rematerialization' — §Perf iteration 3.)"""
        if not attn_reshard or arr is None or arr.ndim < 2:
            return arr
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        spec = P(axes, "model" if full else None,
                 *([None] * (arr.ndim - 2)))
        return jax.lax.with_sharding_constraint(
            arr, jax.sharding.NamedSharding(mesh, spec))

    if kind == "ssm":
        st = lcache["ssm"] if lcache is not None else None
        if x.shape[1] == 1 and st is not None:
            y, new_st = ssm_lib.ssm_step(lp["ssm"], subtree(lq, "ssm"), h,
                                         ctx, _ssm_cfg(cfg), st)
        else:
            y, new_st = ssm_lib.ssm_apply(lp["ssm"], subtree(lq, "ssm"), h,
                                          ctx, _ssm_cfg(cfg), state=st)
        new_cache["ssm"] = new_st
        x = x + y
        return x, new_cache, aux

    # --- attention branch (dense / moe / hybrid)
    acache = lcache["attn"] if lcache is not None else None
    if cfg.mla is not None:
        ay, new_ac = attn.mla_apply(lp["attn"], subtree(lq, "attn"), h, ctx,
                                    _mla_cfg(cfg), cos_sin=cos_sin,
                                    positions=positions, cache=acache,
                                    chunk=cfg.attn_chunk)
    else:
        h_a = reshard(h, full=True)
        cs_a = (jax.tree_util.tree_map(lambda a: reshard(a, True), cos_sin)
                if attn_reshard and cos_sin is not None
                and cos_sin[0].ndim >= 2 else cos_sin)
        ay, new_ac = attn.gqa_apply(lp["attn"], subtree(lq, "attn"), h_a, ctx,
                                    n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                                    head_dim=cfg.resolved_head_dim,
                                    cos_sin=cs_a,
                                    positions=reshard(positions, True),
                                    causal=True, window=window,
                                    cache=acache, chunk=cfg.attn_chunk)
        ay = reshard(ay, full=False)
    if new_ac is not None:
        new_cache["attn"] = new_ac

    if kind == "hybrid":
        st = lcache["ssm"] if lcache is not None else None
        if x.shape[1] == 1 and st is not None:
            sy, new_st = ssm_lib.ssm_step(lp["ssm"], subtree(lq, "ssm"), h,
                                          ctx, _ssm_cfg(cfg), st)
        else:
            sy, new_st = ssm_lib.ssm_apply(lp["ssm"], subtree(lq, "ssm"), h,
                                           ctx, _ssm_cfg(cfg), state=st)
        new_cache["ssm"] = new_st
        # hymba: mean of per-branch normalised outputs
        y = 0.5 * (rms_norm(lp["attn_norm"], ay) + rms_norm(lp["ssm_norm"], sy))
    else:
        y = ay
    x = x + y

    # --- FFN branch
    if kind == "moe":
        h2 = _norm(cfg, lp["ln2"], x)
        y2, aux = moe_lib.moe_ffn(lp["moe"], subtree(lq, "moe"), h2, ctx,
                                  mesh=mesh, top_k=cfg.top_k,
                                  gate=cfg.moe_gate,
                                  capacity_factor=cfg.capacity_factor,
                                  routed_scaling=cfg.routed_scaling,
                                  use_ep=use_ep)
        x = x + y2
    elif "mlp" in lp:
        h2 = _norm(cfg, lp["ln2"], x)
        x = x + _mlp(cfg, lp["mlp"], subtree(lq, "mlp"), h2, ctx)
    return x, new_cache, aux


def _run_stack(cfg: ArchConfig, kind: str, stack_p, stack_q, x, ctx, *,
               cos_sin, positions, stack_cache, windows, mesh, use_ep,
               remat: str, attn_reshard: bool = False):
    """scan one homogeneous layer stack."""
    n_layers = jax.tree_util.tree_leaves(stack_p)[0].shape[0]
    if not isinstance(stack_q, dict):
        # no quantization state (frozen serving): scan needs a leading axis
        stack_q = jnp.zeros((n_layers,), jnp.uint8)

    def body(carry, xs):
        x, aux_sum = carry
        lp, lq, lcache, window = xs
        x, new_cache, aux = _block(cfg, kind, lp, lq, x, ctx,
                                   cos_sin=cos_sin, positions=positions,
                                   lcache=lcache, window=window,
                                   mesh=mesh, use_ep=use_ep,
                                   attn_reshard=attn_reshard)
        return (x, aux_sum + aux), new_cache

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if windows is None:
        w_static = None if cfg.window is None else cfg.window
        windows = jnp.full((n_layers,),
                           w_static if w_static is not None else HUGE_WINDOW,
                           jnp.int32)
        if cfg.window is None:
            windows = None            # uniform no-window: keep mask simpler

    xs = (stack_p, stack_q, stack_cache,
          windows if windows is not None
          else jnp.zeros((n_layers,), jnp.int32))
    if windows is None:
        # replace the window input with None semantics inside body via closure
        def body_nw(carry, xs):
            lp, lq, lcache, _ = xs
            return body(carry, (lp, lq, lcache, None))
        (x, aux), new_caches = jax.lax.scan(body_nw, (x, jnp.zeros((), jnp.float32)), xs)
    else:
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, new_caches


def lm_apply(params: dict, qstate: Any, tokens: Optional[jax.Array],
             ctx: QuantCtx, cfg: ArchConfig, *,
             embeds: Optional[jax.Array] = None,
             positions: Optional[jax.Array] = None,
             cache: Optional[dict] = None,
             mesh: Optional[jax.sharding.Mesh] = None,
             use_ep: bool = True, remat: str = "none",
             attn_reshard: Optional[bool] = None):
    """Forward pass.  Returns (logits, new_cache, aux_loss).

    ``tokens``: (B, S) int32, or ``embeds``: (B, S, d) for the stubbed
    vlm/audio frontends.  ``positions``: (B, S) absolute positions (decode
    passes the cache offset); defaults to arange.
    """
    if embeds is None:
        x = params["embed"]["table"].astype(ctx.dtype)[tokens]
        b, s = tokens.shape
    else:
        x = embeds.astype(ctx.dtype)
        b, s = embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        rotary_dim = cfg.mla.qk_rope_dim
    else:
        rotary_dim = int(hd * cfg.rotary_frac)
    if cfg.mrope_sections is not None:
        pos3 = jnp.stack([positions] * 3)
        cos_sin = mrope_cos_sin(pos3, rotary_dim, cfg.rope_theta,
                                cfg.mrope_sections, dtype=jnp.float32)
    elif cfg.family != "ssm":
        cos_sin = rope_cos_sin(positions, rotary_dim, cfg.rope_theta,
                               dtype=jnp.float32)
    else:
        cos_sin = None

    if attn_reshard is None:
        attn_reshard = cache is None and _attn_batch_reshard(cfg, mesh, s)
    kinds = _layer_kinds(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict = {}
    for kind in ("dense", "moe", "ssm", "hybrid"):
        if kind not in params["stacks"]:
            continue
        idx = [i for i, k in enumerate(kinds) if k == kind]
        stack_q = subtree(subtree(qstate, "stacks"), kind)
        stack_c = cache.get(kind) if cache is not None else None
        windows = _windows_for(cfg, idx)
        x, aux, nc = _run_stack(
            cfg, kind, params["stacks"][kind], stack_q, x, ctx,
            cos_sin=cos_sin, positions=positions, stack_cache=stack_c,
            windows=windows, mesh=mesh, use_ep=use_ep, remat=remat,
            attn_reshard=attn_reshard)
        aux_total = aux_total + aux
        if stack_c is not None:
            new_caches[kind] = nc

    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"]["table"].astype(
            jnp.float32).T
    else:
        w = params["lm_head"]["kernel"]
        logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    # mask padded vocab rows
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits, (new_caches if cache is not None else None), aux_total
