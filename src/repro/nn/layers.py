"""Shared layers: norms, linear (quantization-aware), embeddings, rotary
embeddings (RoPE / partial-rotary / M-RoPE), and MLP blocks."""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from .module import QuantCtx, materialize, maybe_quant_param


# ------------------------------------------------------------------ norms

def rms_norm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def layer_norm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ----------------------------------------------------------------- linear

def linear_init(key, d_in: int, d_out: int, quantize: bool,
                bias: bool = False, dtype=jnp.float32) -> dict:
    scale = 1.0 / (d_in ** 0.5)
    w = jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)
    p = {"kernel": maybe_quant_param(w, quantize)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, q: Any, x: jax.Array, ctx: QuantCtx) -> jax.Array:
    qk = q["kernel"] if isinstance(q, dict) else 0
    w = materialize(p["kernel"], qk, ctx)
    y = x @ w
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


# -------------------------------------------------------------- embedding

def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: dict, ids: jax.Array, ctx: QuantCtx) -> jax.Array:
    return p["table"].astype(ctx.dtype)[ids]


def unembed(p: dict, x: jax.Array, ctx: QuantCtx) -> jax.Array:
    """Tied read-out: logits = x @ table.T (f32 accumulation)."""
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T


# ----------------------------------------------------------------- rotary

def rope_cos_sin(positions: jax.Array, rotary_dim: int, theta: float,
                 dtype=jnp.float32):
    """positions (..., S) -> cos/sin (..., S, rotary_dim//2)."""
    half = rotary_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def mrope_cos_sin(positions: jax.Array, rotary_dim: int, theta: float,
                  sections: Sequence[int], dtype=jnp.float32):
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) (t, h, w) streams;
    sections: per-stream number of rotary *pairs* (sums to rotary_dim//2).
    Each rotary pair takes its angle from the stream its index falls in."""
    half = rotary_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (3, B, S, half)
    stream = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)            # (half,)
    onehot = jax.nn.one_hot(stream, 3, dtype=jnp.float32).T  # (3, half)
    sel = jnp.einsum("tbsh,th->bsh", ang, onehot)            # (B, S, half)
    return jnp.cos(sel).astype(dtype), jnp.sin(sel).astype(dtype)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, Dh); cos/sin: (B, S, half) with half <= Dh//2.

    Rotates the first 2*half dims (GLM-style partial rotary supported),
    pairing dim i with dim i+half (NeoX/llama convention)."""
    half = cos.shape[-1]
    x_rot, x_pass = x[..., :2 * half], x[..., 2 * half:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1) if x_pass.shape[-1] else out


def sinusoidal_positions(n: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (n, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(n)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# -------------------------------------------------------------------- MLP

def swiglu_init(key, d: int, d_ff: int, quantize: bool) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": linear_init(k1, d, d_ff, quantize),
            "up": linear_init(k2, d, d_ff, quantize),
            "down": linear_init(k3, d_ff, d, quantize)}


def swiglu(p: dict, q: Any, x: jax.Array, ctx: QuantCtx) -> jax.Array:
    g = linear(p["gate"], q["gate"] if isinstance(q, dict) else 0, x, ctx)
    u = linear(p["up"], q["up"] if isinstance(q, dict) else 0, x, ctx)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return linear(p["down"], q["down"] if isinstance(q, dict) else 0, h, ctx)


def gelu_mlp_init(key, d: int, d_ff: int, quantize: bool,
                  bias: bool = True) -> dict:
    k1, k2 = jax.random.split(key)
    return {"fc1": linear_init(k1, d, d_ff, quantize, bias=bias),
            "fc2": linear_init(k2, d_ff, d, quantize, bias=bias)}


def gelu_mlp(p: dict, q: Any, x: jax.Array, ctx: QuantCtx) -> jax.Array:
    h = linear(p["fc1"], q["fc1"] if isinstance(q, dict) else 0, x, ctx)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return linear(p["fc2"], q["fc2"] if isinstance(q, dict) else 0, h, ctx)


def subtree(q: Any, key: str) -> Any:
    """Navigate the qstate mirror tree (0 where absent)."""
    return q[key] if isinstance(q, dict) and key in q else 0
