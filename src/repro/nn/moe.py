"""Mixture-of-Experts: sort-based capacity routing with expert parallelism.

Two execution paths over identical routing math (tests assert equality):

* :func:`moe_apply` — single logical device / pure GSPMD.  Sort-based
  dispatch (argsort by expert id + scatter into an (E, C, d) buffer), no
  (N, E, C) one-hot tensor is ever materialised.
* :func:`moe_apply_ep` — production path: ``jax.shard_map`` over the full
  mesh.  Tokens are sharded over *all* mesh axes (the model axis included —
  a free re-partition of the replicated activations), each device routes its
  local tokens, and two ``all_to_all`` collectives over the 'model' axis move
  token slots to/from the expert-owning shards.  Expert weights live sharded
  over 'model' (E % tp == 0: deepseek 256e) and are replicated over the data
  axes (their gradient psum is inserted by shard_map's transpose).

Routing variants:

* ``gate="softmax"``  — grok-1 style: softmax over the top-k logits.
* ``gate="sigmoid"``  — deepseek-v3 style: sigmoid scores, selection by
  score + a bias-correction term (aux-loss-free balancing, the bias is a
  slow-updated buffer), weights = selected scores / their sum, scaled by
  ``routed_scaling``.

A Switch-style load-balance auxiliary loss is returned alongside (coefficient
applied by the caller); deepseek runs with coefficient ~0 and relies on the
bias correction.  The router itself stays fp32 and un-quantized (paper's
mixed-precision contribution: sensitive small parameters keep full
precision); expert FFN weights are EC4T-quantized.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from .layers import linear, linear_init, subtree
from .module import QuantCtx, materialize


# ------------------------------------------------------------------- init

def moe_init(key, d: int, d_ff: int, n_experts: int, quantize: bool,
             n_shared: int = 0, shared_ff: Optional[int] = None) -> dict:
    """Stacked expert SwiGLU weights (E, ...) + fp32 router (+ shared expert)."""
    kr, ke, ks = jax.random.split(key, 3)
    scale = d ** -0.5

    def expert_bank(k, d_in, d_out):
        w = jax.random.uniform(k, (n_experts, d_in, d_out), jnp.float32,
                               -scale, scale)
        if quantize:
            from ..core import qat
            return qat.make_quant_param(w)
        return w

    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": {
            "w": jax.random.normal(kr, (d, n_experts), jnp.float32) * 0.02,
            "bias_correction": jnp.zeros((n_experts,), jnp.float32),
        },
        "experts": {
            "gate": expert_bank(k1, d, d_ff),
            "up": expert_bank(k2, d, d_ff),
            "down": expert_bank(k3, d_ff, d),
        },
    }
    if n_shared:
        from .layers import swiglu_init
        p["shared"] = swiglu_init(ks, d, (shared_ff or d_ff) * n_shared,
                                  quantize)
    return p


# ---------------------------------------------------------------- routing

def route(logits: jax.Array, bias_correction: jax.Array, *, top_k: int,
          gate: str, routed_scaling: float = 1.0):
    """(N, E) logits -> (ids (N,k) int32, weights (N,k) f32, aux_loss)."""
    n, e = logits.shape
    if gate == "softmax":
        sel_score = logits
        _, ids = jax.lax.top_k(sel_score, top_k)
        w = jax.nn.softmax(jnp.take_along_axis(logits, ids, axis=1), axis=-1)
        probs = jax.nn.softmax(logits, axis=-1)
    elif gate == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        _, ids = jax.lax.top_k(scores + bias_correction[None, :], top_k)
        sel = jnp.take_along_axis(scores, ids, axis=1)
        w = routed_scaling * sel / jnp.maximum(sel.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        raise ValueError(gate)
    # Switch-style load-balance aux loss: E * Σ_e f_e · p_e
    onehot_frac = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(
        1.0 / (n * top_k))
    aux = e * jnp.sum(onehot_frac * probs.mean(0))
    return ids.astype(jnp.int32), w.astype(jnp.float32), aux


def _dispatch_indices(flat_ids: jax.Array, n_experts: int, capacity: int):
    """Sort-based slot assignment.  flat_ids: (N*k,) expert of each
    assignment.  Returns (slot (N*k,), keep (N*k,)): slot = e*C + pos within
    expert for kept assignments (earlier tokens win — the paper-standard
    'drop by position' policy), garbage otherwise."""
    order = jnp.argsort(flat_ids, stable=True)            # (A,)
    sorted_ids = flat_ids[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_ids].add(1)
    starts = jnp.cumsum(counts) - counts                  # (E,)
    pos_in_e = jnp.arange(flat_ids.size, dtype=jnp.int32) - starts[sorted_ids]
    keep_sorted = pos_in_e < capacity
    slot_sorted = sorted_ids * capacity + jnp.minimum(pos_in_e, capacity - 1)
    # scatter back to assignment order
    inv = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.size, dtype=order.dtype))
    return slot_sorted[inv], keep_sorted[inv]


def _expert_ffn(experts: dict, q_state: Any, xs: jax.Array,
                ctx: QuantCtx) -> jax.Array:
    """xs: (E, C, d) -> (E, C, d) via per-expert SwiGLU (batched einsum)."""
    def mat(name):
        return materialize(experts[name], subtree(q_state, name), ctx)
    g = jnp.einsum("ecd,edf->ecf", xs, mat("gate"))
    u = jnp.einsum("ecd,edf->ecf", xs, mat("up"))
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xs.dtype)
    return jnp.einsum("ecf,efd->ecd", h, mat("down"))


def _capacity(n_assign: int, n_experts: int, factor: float) -> int:
    c = int(-(-n_assign * factor // n_experts))           # ceil
    return max(8, -(-c // 8) * 8)                         # pad to 8


# --------------------------------------------------- single-device / GSPMD

def moe_apply(p: dict, q_state: Any, x: jax.Array, ctx: QuantCtx, *,
              top_k: int, gate: str = "softmax", capacity_factor: float = 1.25,
              routed_scaling: float = 1.0,
              mesh: Optional[jax.sharding.Mesh] = None):
    """MoE forward on (..., d) tokens; returns (y, aux_loss).

    With a mesh, the (E, C, d) dispatch buffer is sharding-constrained:
    capacity over the data axes, FFN width implicitly over 'model' via the
    per-expert-TP weight sharding.  Without the constraint GSPMD replicates
    the scattered buffer and every device runs every token (observed 30×
    FLOP inflation on grok — EXPERIMENTS.md §Perf)."""
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    n = xt.shape[0]
    e = p["router"]["w"].shape[1]

    dp_axes = tuple(a for a in ("pod", "data")
                    if mesh is not None and a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]

    def constrain(arr, spec):
        if mesh is None or mesh.devices.size == 1:
            return arr
        return jax.lax.with_sharding_constraint(
            arr, jax.sharding.NamedSharding(mesh, spec))

    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    ids, w, aux = route(logits, jax.lax.stop_gradient(
        p["router"]["bias_correction"]), top_k=top_k, gate=gate,
        routed_scaling=routed_scaling)

    cap = _capacity(n * top_k, e, capacity_factor)
    if dp > 1:
        cap = -(-cap // dp) * dp          # capacity divisible by dp shards
    flat_ids = ids.reshape(-1)
    slot, keep = _dispatch_indices(flat_ids, e, cap)

    token_of = jnp.repeat(jnp.arange(n, dtype=jnp.int32), top_k)
    buf = jnp.zeros((e * cap, d), ctx.dtype)
    buf = buf.at[jnp.where(keep, slot, e * cap)].set(
        xt[token_of].astype(ctx.dtype), mode="drop")
    buf = constrain(buf.reshape(e, cap, d),
                    P(None, dp_axes if dp_axes else None, None))

    out_buf = _expert_ffn(p["experts"], subtree(q_state, "experts"),
                          buf, ctx)
    out_buf = constrain(out_buf, P(None, dp_axes if dp_axes else None, None))
    out_buf = out_buf.reshape(e * cap, d)

    gathered = out_buf[slot] * (w.reshape(-1, 1) * keep[:, None]).astype(ctx.dtype)
    y = jnp.zeros((n, d), ctx.dtype).at[token_of].add(gathered)
    y = constrain(y, P(dp_axes if dp_axes else None, None))

    if "shared" in p:
        from .layers import swiglu
        y = y + swiglu(p["shared"], subtree(q_state, "shared"), xt, ctx)
    return y.reshape(shape), aux


# --------------------------------------------------------- shard_map EP

def moe_apply_ep(p: dict, q_state: Any, x: jax.Array, ctx: QuantCtx, *,
                 mesh: jax.sharding.Mesh, top_k: int, gate: str = "softmax",
                 capacity_factor: float = 1.25, routed_scaling: float = 1.0,
                 expert_axis: str = "model"):
    """Expert-parallel MoE over ``mesh``: tokens sharded over every mesh
    axis, experts over ``expert_axis``; two all_to_alls per block.

    Equivalent to :func:`moe_apply` up to capacity-drop boundary effects
    (local capacity is enforced per shard — the deliberate production
    trade-off: no global sort, no global collectives outside the two a2a).
    """
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    all_axes = tuple(mesh.axis_names)
    data_axes = tuple(a for a in all_axes if a != expert_axis)
    ep = mesh.shape[expert_axis]
    e = p["router"]["w"].shape[1]
    assert e % ep == 0, (e, ep)

    # decode-sized batches may not divide over every mesh axis: pad token
    # rows to the device count (zero rows route like any token, their
    # outputs are sliced away; capacity is computed from the padded count,
    # so drops are unaffected to first order)
    n_tok = xt.shape[0]
    n_dev = int(mesh.devices.size)
    pad = (-n_tok) % n_dev
    if pad:
        xt = jnp.concatenate([xt, jnp.zeros((pad, d), xt.dtype)], axis=0)

    def local_moe(xt_l, router_w, bias_corr, gate_w, up_w, down_w):
        n_l = xt_l.shape[0]
        logits = xt_l.astype(jnp.float32) @ router_w
        ids, w, aux = route(logits, jax.lax.stop_gradient(bias_corr),
                            top_k=top_k, gate=gate,
                            routed_scaling=routed_scaling)
        cap = _capacity(n_l * top_k, e, capacity_factor)
        flat_ids = ids.reshape(-1)
        slot, keep = _dispatch_indices(flat_ids, e, cap)
        token_of = jnp.repeat(jnp.arange(n_l, dtype=jnp.int32), top_k)

        buf = jnp.zeros((e * cap, d), ctx.dtype)
        buf = buf.at[jnp.where(keep, slot, e * cap)].set(
            xt_l[token_of].astype(ctx.dtype), mode="drop")
        buf = buf.reshape(e, cap, d)

        # (E, C, d) -> (E_loc, ep*C, d): slots travel to their expert's shard
        buf = jax.lax.all_to_all(buf, expert_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
        out = _expert_ffn({"gate": gate_w, "up": up_w, "down": down_w},
                          0, buf, ctx)
        out = jax.lax.all_to_all(out, expert_axis, split_axis=1,
                                 concat_axis=0, tiled=True).reshape(e * cap, d)

        gathered = out[slot] * (w.reshape(-1, 1) * keep[:, None]).astype(ctx.dtype)
        y = jnp.zeros((n_l, d), ctx.dtype).at[token_of].add(gathered)
        return y, jax.lax.pmean(aux, all_axes)

    # expert weights enter shard_map already materialised (fake-quant runs
    # once, outside, under GSPMD; only the a2a pattern needs manual control)
    eq = subtree(q_state, "experts")
    mats = [materialize(p["experts"][k], subtree(eq, k), ctx)
            for k in ("gate", "up", "down")]

    tok_spec = P(all_axes, None)
    y, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(tok_spec, P(None, None), P(None),
                  P(expert_axis, None, None), P(expert_axis, None, None),
                  P(expert_axis, None, None)),
        out_specs=(tok_spec, P()),
    )(xt, p["router"]["w"], p["router"]["bias_correction"], *mats)
    if pad:
        y = y[:n_tok]
        xt = xt[:n_tok]

    if "shared" in p:
        from .layers import swiglu
        y = y + swiglu(p["shared"], subtree(q_state, "shared"), xt, ctx)
    return y.reshape(shape), aux


# --------------------------------------------- shard_map expert-TP (E < tp)

def moe_apply_tp(p: dict, q_state: Any, x: jax.Array, ctx: QuantCtx, *,
                 mesh: jax.sharding.Mesh, top_k: int, gate: str = "softmax",
                 capacity_factor: float = 1.25, routed_scaling: float = 1.0,
                 expert_axis: str = "model"):
    """Per-expert tensor parallelism for few-expert archs (grok: 8e on a
    16-wide model axis).  Tokens shard over the data axes; every model
    column holds a 1/tp slice of every expert's FFN width.  Dispatch is
    purely *local* (sort + scatter within the shard — no cross-device
    scatter), expert FFNs contract their ff slice, and a single psum over
    'model' reduces the row-sharded down-projection.

    Replaces the GSPMD fallback whose cross-shard scatter lowered to
    per-layer all-reduces of the whole (E·C, d) buffer — 1.5e13 collective
    B/device on grok train (§Perf grok iteration 1)."""
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    all_axes = tuple(mesh.axis_names)
    data_axes = tuple(a for a in all_axes if a != expert_axis)
    e = p["router"]["w"].shape[1]

    eq = subtree(q_state, "experts")
    mats = [materialize(p["experts"][k], subtree(eq, k), ctx)
            for k in ("gate", "up", "down")]

    def local_moe(xt_l, router_w, bias_corr, gate_w, up_w, down_w):
        n_l = xt_l.shape[0]
        logits = xt_l.astype(jnp.float32) @ router_w
        ids, w, aux = route(logits, jax.lax.stop_gradient(bias_corr),
                            top_k=top_k, gate=gate,
                            routed_scaling=routed_scaling)
        cap = _capacity(n_l * top_k, e, capacity_factor)
        flat_ids = ids.reshape(-1)
        slot, keep = _dispatch_indices(flat_ids, e, cap)
        token_of = jnp.repeat(jnp.arange(n_l, dtype=jnp.int32), top_k)

        buf = jnp.zeros((e * cap, d), ctx.dtype)
        buf = buf.at[jnp.where(keep, slot, e * cap)].set(
            xt_l[token_of].astype(ctx.dtype), mode="drop").reshape(e, cap, d)

        g = jnp.einsum("ecd,edf->ecf", buf, gate_w)      # ff/tp slice
        u = jnp.einsum("ecd,edf->ecf", buf, up_w)
        h = (jax.nn.silu(g.astype(jnp.float32))
             * u.astype(jnp.float32)).astype(buf.dtype)
        out = jnp.einsum("ecf,efd->ecd", h, down_w)      # partial sums
        out = jax.lax.psum(out, expert_axis)             # the one collective
        out = out.reshape(e * cap, d)

        gathered = out[slot] * (w.reshape(-1, 1)
                                * keep[:, None]).astype(ctx.dtype)
        y = jnp.zeros((n_l, d), ctx.dtype).at[token_of].add(gathered)
        # aux is already invariant along 'model' (same tokens per column);
        # only the data axes need the mean
        return y, jax.lax.pmean(aux, data_axes)

    tok_spec = P(data_axes, None)
    y, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(tok_spec, P(None, None), P(None),
                  P(None, None, expert_axis), P(None, None, expert_axis),
                  P(None, expert_axis, None)),
        out_specs=(tok_spec, P()),
    )(xt, p["router"]["w"], p["router"]["bias_correction"], *mats)

    if "shared" in p:
        from .layers import swiglu
        y = y + swiglu(p["shared"], subtree(q_state, "shared"), xt, ctx)
    return y.reshape(shape), aux


def moe_ffn(p, q_state, x, ctx, *, mesh: Optional[jax.sharding.Mesh],
            top_k: int, gate: str = "softmax", capacity_factor: float = 1.25,
            routed_scaling: float = 1.0, use_ep: bool = True):
    """Dispatcher: shard_map EP when experts divide the model axis
    (deepseek 256e), shard_map expert-TP when the FFN width divides instead
    (grok 8e × ff 32768), pure-GSPMD sort dispatch otherwise."""
    e = p["router"]["w"].shape[1]
    gate_bank = p["experts"]["gate"]
    if isinstance(gate_bank, dict):      # quant {"w",...} / frozen {"packed",...}
        gate_bank = gate_bank.get("w", gate_bank.get("packed"))
    ff = gate_bank.shape[-1]
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    multi = mesh is not None and mesh.devices.size > 1
    if use_ep and multi and e % tp == 0:
        return moe_apply_ep(p, q_state, x, ctx, mesh=mesh, top_k=top_k,
                            gate=gate, capacity_factor=capacity_factor,
                            routed_scaling=routed_scaling)
    if use_ep and multi and ff % tp == 0:
        return moe_apply_tp(p, q_state, x, ctx, mesh=mesh, top_k=top_k,
                            gate=gate, capacity_factor=capacity_factor,
                            routed_scaling=routed_scaling)
    return moe_apply(p, q_state, x, ctx, top_k=top_k, gate=gate,
                     capacity_factor=capacity_factor,
                     routed_scaling=routed_scaling, mesh=mesh)
