"""Minimal functional module system.

Parameters are nested dicts of arrays; a *quantized* tensor is the dict
``{"w", "omega"}`` (see ``core.qat``). Every ``*_init(key, ...)`` returns a
param tree; every ``*_apply(p, q, x, ...)`` consumes the param tree ``p`` and
the mirrored quantization-state tree ``q`` (probs at quant leaves, 0
elsewhere). ``QuantCtx`` carries the QAT mode so one model definition serves
fp32 baseline, EC4T training, and frozen serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core import qat


@dataclasses.dataclass(frozen=True)
class QuantCtx:
    quant: bool = False          # EC4T fake-quant active?
    lam: float = 0.0             # entropy-penalty strength λ
    compute_dtype: Any = jnp.bfloat16
    deterministic: bool = True

    @property
    def dtype(self):
        return self.compute_dtype


FP32_CTX = QuantCtx(quant=False, compute_dtype=jnp.float32)


def materialize(node: Any, q: Any, ctx: QuantCtx) -> jax.Array:
    """Resolve a (possibly quantized/frozen) weight leaf to compute dtype.

    Frozen leaves ({"packed", "omega"}) decode 4-bit codes on the fly —
    serving reads 4 bits/weight from HBM and reconstructs W = Σ ω_i B_i in
    registers/VMEM; on TPU this is the Pallas kernel, under plain XLA it is
    the same dataflow expressed with jnp ops."""
    if qat.is_quant_leaf(node):
        if ctx.quant:
            return qat.apply_quant(node, q, ctx.lam, ctx.dtype)
        return node["w"].astype(ctx.dtype)
    if qat.is_frozen_leaf(node):
        return qat.decode_frozen(node, ctx.dtype)
    return node.astype(ctx.dtype)


def maybe_quant_param(w: jax.Array, quantize: bool) -> Any:
    return qat.make_quant_param(w) if quantize else w


def param_count(tree: Any) -> int:
    """Trainable parameter count (masters counted once, probs excluded)."""
    n = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        n += leaf.size
    return n


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))
