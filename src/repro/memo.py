"""Identity-keyed memoization for frozen serving objects.

Serving-path caches (folded int8 operands, stacked weight-stationary
operands, execution plans) key on *object identity*: a frozen pack's
arrays are never mutated in place, so ``id(pack)`` plus an ``is`` check is
a correct and allocation-free cache key.  The subtle invariants live here
once instead of at every cache site:

* values hold **strong references** to the keyed objects, so their ids
  cannot be recycled by the allocator while the entry lives;
* a hit re-verifies every keyed object with ``is`` (two live objects can
  never share an id, but a dead key's id can be reused — the strong refs
  prevent that for *our* entries; the check keeps the contract explicit);
* insertion-order eviction past ``max_entries`` bounds memory —
  **pinned** entries (``put(..., pin=True)``) are exempt: they neither
  count toward the bound nor get auto-evicted, because their lifetime is
  owned by an external manager (the serving pack cache) which removes
  them explicitly via :meth:`drop`.  Without the pin, the memo's
  insertion-order eviction was disconnected from the frontend lifetime:
  an evicted plan would be silently re-resolved (and re-jitted) as a
  *duplicate* on the next ``get_plan`` while a frontend still held the
  original — double device memory and a cold compile on the request path.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

MISS = object()        # sentinel: distinguishes "no entry" from value None


class IdentityMemo:
    def __init__(self, max_entries: int = 32):
        self.max_entries = max_entries
        self._entries: dict = {}
        self._pinned: set = set()

    @staticmethod
    def _key(objs: Sequence[Optional[object]], extra: Tuple) -> Tuple:
        return (tuple(None if o is None else id(o) for o in objs)
                + tuple(extra))

    def get(self, objs: Sequence[Optional[object]], extra: Tuple = ()):
        """Return the cached value, or :data:`MISS`."""
        hit = self._entries.get(self._key(objs, extra))
        if hit is None:
            return MISS
        held, value = hit
        if all(h is o for h, o in zip(held, objs)):
            return value
        return MISS

    def put(self, objs: Sequence[Optional[object]], extra: Tuple,
            value, *, pin: bool = False) -> None:
        """Insert an entry.  ``pin=True`` exempts it from auto-eviction
        (and from the ``max_entries`` count) until :meth:`drop` removes
        it — for entries whose lifetime an external cache manages."""
        key = self._key(objs, extra)
        if key not in self._entries and \
                len(self._entries) - len(self._pinned) >= self.max_entries:
            for k in self._entries:
                if k not in self._pinned:
                    del self._entries[k]
                    break
        if pin:
            self._pinned.add(key)
        self._entries[key] = (tuple(objs), value)

    def drop(self, obj: object) -> int:
        """Remove (and unpin) every entry keyed on ``obj``'s identity;
        returns how many were dropped.  The release half of the pinned
        contract: an entry owned by an external manager is removed here,
        never by auto-eviction."""
        dropped = 0
        for key in list(self._entries):
            held, _ = self._entries[key]
            if any(h is obj for h in held):
                del self._entries[key]
                self._pinned.discard(key)
                dropped += 1
        return dropped
