"""Identity-keyed memoization for frozen serving objects.

Serving-path caches (folded int8 operands, stacked weight-stationary
operands, execution plans) key on *object identity*: a frozen pack's
arrays are never mutated in place, so ``id(pack)`` plus an ``is`` check is
a correct and allocation-free cache key.  The subtle invariants live here
once instead of at every cache site:

* values hold **strong references** to the keyed objects, so their ids
  cannot be recycled by the allocator while the entry lives;
* a hit re-verifies every keyed object with ``is`` (two live objects can
  never share an id, but a dead key's id can be reused — the strong refs
  prevent that for *our* entries; the check keeps the contract explicit);
* insertion-order eviction past ``max_entries`` bounds memory.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

MISS = object()        # sentinel: distinguishes "no entry" from value None


class IdentityMemo:
    def __init__(self, max_entries: int = 32):
        self.max_entries = max_entries
        self._entries: dict = {}

    @staticmethod
    def _key(objs: Sequence[Optional[object]], extra: Tuple) -> Tuple:
        return (tuple(None if o is None else id(o) for o in objs)
                + tuple(extra))

    def get(self, objs: Sequence[Optional[object]], extra: Tuple = ()):
        """Return the cached value, or :data:`MISS`."""
        hit = self._entries.get(self._key(objs, extra))
        if hit is None:
            return MISS
        held, value = hit
        if all(h is o for h, o in zip(held, objs)):
            return value
        return MISS

    def put(self, objs: Sequence[Optional[object]], extra: Tuple,
            value) -> None:
        if len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
        self._entries[self._key(objs, extra)] = (tuple(objs), value)
