"""Whisper-base backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the assignment the conv frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings (B, 1500, d_model); everything after that is
real: sinusoidal-position encoder (bidirectional MHA), learned-position
decoder (causal self-attention with KV cache + cross-attention), LayerNorm,
GELU MLPs, tied output projection.

Cross-attention KV is computed once from the encoder output
(:func:`precompute_cross`) and handed to every decode step — the standard
enc-dec serving split.  FC projections are EC4T-quantized like every other
arch (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..nn import attention as attn
from ..nn.layers import (embedding_init, gelu_mlp, gelu_mlp_init, layer_norm,
                         layer_norm_init, linear, sinusoidal_positions,
                         subtree)
from ..nn.module import QuantCtx

MAX_TGT = 448      # whisper's decoder context


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _enc_layer_init(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": layer_norm_init(d),
        "attn": attn.gqa_init(k1, d, cfg.n_heads, cfg.n_kv, hd, cfg.quantize),
        "ln2": layer_norm_init(d),
        "mlp": gelu_mlp_init(k2, d, cfg.d_ff, cfg.quantize),
    }


def _dec_layer_init(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": layer_norm_init(d),
        "attn": attn.gqa_init(k1, d, cfg.n_heads, cfg.n_kv, hd, cfg.quantize),
        "ln_cross": layer_norm_init(d),
        "cross": attn.gqa_init(k2, d, cfg.n_heads, cfg.n_kv, hd, cfg.quantize),
        "ln2": layer_norm_init(d),
        "mlp": gelu_mlp_init(k3, d, cfg.d_ff, cfg.quantize),
    }


def whisper_init(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    enc = _stack([_enc_layer_init(k, cfg)
                  for k in jax.random.split(ks[0], cfg.n_enc_layers)])
    dec = _stack([_dec_layer_init(k, cfg)
                  for k in jax.random.split(ks[1], cfg.n_layers)])
    return {
        "enc_layers": enc,
        "enc_ln": layer_norm_init(cfg.d_model),
        "dec_layers": dec,
        "dec_ln": layer_norm_init(cfg.d_model),
        "embed": embedding_init(ks[2], cfg.padded_vocab, cfg.d_model),
        "dec_pos": jax.random.normal(ks[3], (MAX_TGT, cfg.d_model),
                                     jnp.float32) * 0.02,
    }


# ---------------------------------------------------------------- encoder

def whisper_encode(params, qstate, frames: jax.Array, ctx: QuantCtx,
                   cfg: ArchConfig) -> jax.Array:
    """frames: (B, T, d) stubbed conv-frontend output -> encoder states."""
    b, t, _ = frames.shape
    x = frames.astype(ctx.dtype) + sinusoidal_positions(
        t, cfg.d_model, ctx.dtype)[None]
    eq = subtree(qstate, "enc_layers")
    if not isinstance(eq, dict):
        eq = jnp.zeros((cfg.n_enc_layers,), jnp.uint8)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(x, xs):
        lp, lq = xs
        h = layer_norm(lp["ln1"], x)
        y, _ = attn.gqa_apply(lp["attn"], subtree(lq, "attn"), h, ctx,
                              n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                              head_dim=cfg.resolved_head_dim,
                              positions=pos, causal=False,
                              chunk=cfg.attn_chunk)
        x = x + y
        h = layer_norm(lp["ln2"], x)
        return x + gelu_mlp(lp["mlp"], subtree(lq, "mlp"), h, ctx), None

    x, _ = jax.lax.scan(body, x, (params["enc_layers"], eq))
    return layer_norm(params["enc_ln"], x)


def precompute_cross(params, qstate, enc_out: jax.Array, ctx: QuantCtx,
                     cfg: ArchConfig):
    """Per-decoder-layer cross K/V from encoder states: (L, B, T, n_kv, hd)."""
    b, t, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    dq = subtree(qstate, "dec_layers")
    if not isinstance(dq, dict):
        dq = jnp.zeros((cfg.n_layers,), jnp.uint8)

    def body(_, xs):
        lp, lq = xs
        lqc = subtree(lq, "cross")
        k = linear(lp["cross"]["k"], subtree(lqc, "k"), enc_out, ctx)
        v = linear(lp["cross"]["v"], subtree(lqc, "v"), enc_out, ctx)
        return None, (k.reshape(b, t, cfg.n_kv, hd),
                      v.reshape(b, t, cfg.n_kv, hd))

    _, (ks, vs) = jax.lax.scan(body, None, (params["dec_layers"], dq))
    return ks, vs


# ---------------------------------------------------------------- decoder

def init_dec_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    per = {"attn": attn.init_kv_cache(batch, max_len, cfg.n_kv,
                                      cfg.resolved_head_dim, dtype)}
    return _stack([per] * cfg.n_layers)


def whisper_decode(params, qstate, tokens: jax.Array, cross_kv,
                   ctx: QuantCtx, cfg: ArchConfig, *,
                   positions: Optional[jax.Array] = None,
                   cache: Optional[dict] = None):
    """Decoder forward.  Returns (logits, new_cache)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"]["table"].astype(ctx.dtype)[tokens]
    x = x + params["dec_pos"].astype(ctx.dtype)[positions]
    dq = subtree(qstate, "dec_layers")
    if not isinstance(dq, dict):    # frozen serving: scan needs a lead axis
        dq = jnp.zeros((cfg.n_layers,), jnp.uint8)
    cross_k, cross_v = cross_kv

    def body(x, xs):
        lp, lq, lcache, ck, cv = xs
        h = layer_norm(lp["ln1"], x)
        y, new_c = attn.gqa_apply(
            lp["attn"], subtree(lq, "attn"), h, ctx, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.resolved_head_dim,
            positions=positions, causal=True,
            cache=lcache["attn"] if lcache is not None else None,
            chunk=cfg.attn_chunk)
        x = x + y
        h = layer_norm(lp["ln_cross"], x)
        # cross-attention: queries from the decoder, precomputed enc KV
        y, _ = attn.gqa_apply(lp["cross"], subtree(lq, "cross"), h, ctx,
                              n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                              head_dim=cfg.resolved_head_dim,
                              positions=positions, kv_override=(ck, cv),
                              chunk=cfg.attn_chunk)
        x = x + y
        h = layer_norm(lp["ln2"], x)
        x = x + gelu_mlp(lp["mlp"], subtree(lq, "mlp"), h, ctx)
        return x, ({"attn": new_c} if new_c is not None else None)

    xs = (params["dec_layers"], dq, cache, cross_k, cross_v)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = layer_norm(params["dec_ln"], x)
    logits = x.astype(jnp.float32) @ params["embed"]["table"].astype(
        jnp.float32).T
    if cfg.padded_vocab != cfg.vocab:
        logits = jnp.where(jnp.arange(cfg.padded_vocab) >= cfg.vocab,
                           -1e30, logits)
    return logits, new_cache


def whisper_forward_loss(params, qstate, batch: dict, ctx: QuantCtx,
                         cfg: ArchConfig, **_):
    """Train forward: encode stubbed frames, teacher-force the decoder."""
    from .lm import lm_loss
    enc = whisper_encode(params, qstate, batch["embeds"], ctx, cfg)
    cross = precompute_cross(params, qstate, enc, ctx, cfg)
    logits, _ = whisper_decode(params, qstate, batch["tokens"], cross,
                               ctx, cfg)
    loss = lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))
    return loss, {"ce": loss, "aux": jnp.zeros(()), "loss": loss}
