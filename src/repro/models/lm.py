"""Causal-LM task heads: loss, next-token prediction, sampling.

The transformer body lives in ``nn/transformer.py``; this module owns the
task-level math shared by train/prefill/decode step functions
(``launch/steps.py``): masked cross-entropy over the padded vocab and greedy
sampling for the serving loop.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..nn import transformer as T
from ..nn.module import QuantCtx


def lm_loss(logits: jax.Array, labels: jax.Array, vocab: int,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy.  logits: (B, S, Vp); labels: (B, S) with
    ids < vocab; padded-vocab columns were already masked to -1e30."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_forward_loss(params, qstate, batch: dict, ctx: QuantCtx,
                    cfg: ArchConfig, *, mesh=None, use_ep=True,
                    remat: str = "none"):
    """Full train forward: returns (loss, metrics)."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")      # stubbed vlm/audio frontends
    logits, _, aux = T.lm_apply(params, qstate, tokens, ctx, cfg,
                                embeds=embeds, mesh=mesh, use_ep=use_ep,
                                remat=remat)
    ce = lm_loss(logits, batch["labels"], cfg.vocab, batch.get("mask"))
    loss = ce + cfg.aux_loss_coef * aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


def greedy_step(params, qstate, tokens, ctx, cfg, *, positions, cache,
                mesh=None):
    """One serving step: feed tokens, return (next_token, new_cache)."""
    logits, cache, _ = T.lm_apply(params, qstate, tokens, ctx, cfg,
                                  positions=positions, cache=cache,
                                  mesh=mesh)
    nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], axis=-1).astype(jnp.int32)
    return nxt, cache


def generate(params, qstate, prompt: jax.Array, ctx: QuantCtx,
             cfg: ArchConfig, *, max_new: int, mesh=None) -> jax.Array:
    """Greedy generation: prefill the prompt then decode max_new tokens.

    Python-loop driver for examples/tests (the jitted serving path is
    launch/serve.py)."""
    b, s = prompt.shape
    cache = T.init_cache(cfg, b, s + max_new, dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    nxt, cache = greedy_step(params, qstate, prompt, ctx, cfg,
                             positions=pos, cache=cache, mesh=mesh)
    outs = [nxt]
    for t in range(max_new - 1):
        p_t = jnp.full((b, 1), s + t, jnp.int32)
        nxt, cache = greedy_step(params, qstate, nxt, ctx, cfg,
                                 positions=p_t, cache=cache, mesh=mesh)
        outs.append(nxt)
    return jnp.concatenate(outs, axis=1)
