"""The paper's hardware-conform MLPs (§VI-A): MLP-GSC, MLP-HR, LeNet-300-100.

This is the direct reproduction path.  Three phases:

* **train** — EC4T fake-quant linears + BatchNorm (batch statistics, EMA
  running stats) + ReLU; exactly the models of Table II.
* **freeze** — ECL-assign final codes; fold BatchNorm and quantization
  scales into the §V epilogue constants:

      y = α₂ · relu( α₁ ⊙ (x·Ŵ) + b' )
      α₁ = γ/σ   (per-feature; absorbs de-quantization + batch-norm scale)
      b' = β − γμ/σ + α₁·bias
      α₂ = activation re-quantization scale for the next layer

  and encode each layer's codes in its *cheapest* format (CSR / bitmask /
  dense4 — contribution 4, Table II's CR column).
* **serve** — run the packed codes through the Pallas kernels (VMEM
  bit-plane decode + MXU matmul + fused epilogue) or the pure-jnp oracle;
  optional int8 activation mode mirrors the paper's 8-bit activation FPGA
  configuration.

  The default kernel path (``mlp_serve(..., fused=True)``) is the
  *megakernel*: the entire stack executes inside one ``pallas_call`` with
  activations resident in VMEM between layers (kernel values cannot spill
  to HBM) — the software analogue of the paper's pipelined float unit,
  where only the input batch tile and the final logits touch HBM:

      HBM:   x tile ─▶ │ L₁ ─▶ L₂ ─▶ … ─▶ L_n │ ─▶ logits tile
      VMEM:            │  all packed weights,  │
                       │  act scratch (bm, W)  │

  Per-layer inside the bar: decode ``W = Σ ωᵢBᵢ`` from the 4-bit codes,
  MXU matmul, ×α₁ +b ReLU ×α₂ — writing into the activation scratch that
  the next layer reads.  When the stack's working set exceeds the VMEM
  budget (``kernels.fantastic4_fused_mlp.fused_mlp_fits``) the call falls
  back to the chained per-layer kernel, which round-trips activations
  through HBM but handles arbitrarily large layers.  Block sizes come from
  the shape-aware autotuner (``kernels.autotune``) unless pinned.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.paper_mlps import MLPConfig
from ..core import acm, bitplanes, ecl, formats, qat
from ..runtime import integrity
from .. import serving
from ..nn.module import QuantCtx


# ------------------------------------------------------------------- init

def mlp_init(key, cfg: MLPConfig) -> tuple:
    """Returns (params, bn_state).  Every FC layer is EC4T-quantized
    (the paper quantizes input and output layers too — Table II note)."""
    params = {"layers": []}
    bn_state = {"layers": []}
    d_in = cfg.d_in
    keys = jax.random.split(key, len(cfg.features))
    for i, d_out in enumerate(cfg.features):
        scale = (2.0 / d_in) ** 0.5
        w = jax.random.normal(keys[i], (d_in, d_out), jnp.float32) * scale
        layer = {"kernel": qat.make_quant_param(w),
                 "bias": jnp.zeros((d_out,), jnp.float32)}
        st = {}
        if cfg.batch_norm:
            layer["bn_gamma"] = jnp.ones((d_out,), jnp.float32)
            layer["bn_beta"] = jnp.zeros((d_out,), jnp.float32)
            st = {"mean": jnp.zeros((d_out,), jnp.float32),
                  "var": jnp.ones((d_out,), jnp.float32)}
        params["layers"].append(layer)
        bn_state["layers"].append(st)
        d_in = d_out
    return params, bn_state


# ---------------------------------------------------------------- forward

def mlp_apply(params: dict, qstate: Any, bn_state: dict, x: jax.Array,
              ctx: QuantCtx, *, train: bool = False,
              bn_momentum: float = 0.9):
    """Training/eval forward.  Returns (logits, new_bn_state)."""
    new_bn = {"layers": []}
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        lq = qstate["layers"][i] if isinstance(qstate, dict) else 0
        node = layer["kernel"]
        if ctx.quant:
            w = qat.apply_quant(node, lq["kernel"], ctx.lam, jnp.float32)
        else:
            w = node["w"].astype(jnp.float32)
        x = x.astype(jnp.float32) @ w + layer["bias"]
        st = {}
        if "bn_gamma" in layer:
            if train:
                mu = x.mean(0)
                var = x.var(0)
                st = {"mean": bn_momentum * bn_state["layers"][i]["mean"]
                              + (1 - bn_momentum) * mu,
                      "var": bn_momentum * bn_state["layers"][i]["var"]
                             + (1 - bn_momentum) * var}
            else:
                mu = bn_state["layers"][i]["mean"]
                var = bn_state["layers"][i]["var"]
                st = bn_state["layers"][i]
            x = (x - mu) * jax.lax.rsqrt(var + 1e-5) * layer["bn_gamma"] \
                + layer["bn_beta"]
        new_bn["layers"].append(st)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x, new_bn


# ----------------------------------------------------------------- freeze

def freeze_dense_layer(codes: jax.Array, omega: jax.Array, *,
                       alpha1: Optional[np.ndarray] = None,
                       bias: Optional[np.ndarray] = None,
                       alpha2: Optional[float] = None,
                       activation: Optional[str] = None) -> dict:
    """Pack one ECL-coded FC layer into the canonical serving layer dict.

    ``codes`` is the unpadded ``(K, M)`` uint8 code matrix; odd K grows a
    zero code row before bit-plane packing (decoded zero weights — the
    serving chains mirror the pad on x).  Epilogue constants default to
    the identity (α₁=1, b=0, α₂=1).  This is the single construction
    every freezer shares — the paper MLPs (:func:`freeze_mlp`) and the
    transformer block packs (``serving.lm.freeze_lm``) — so format
    selection, size accounting and the frozen-at-birth content CRC are
    identical across workloads.
    """
    k, m = codes.shape
    if k % 2:
        codes = jnp.concatenate(
            [codes, jnp.zeros((1, m), jnp.uint8)], axis=0)
    packed = bitplanes.pack_codes_rows(codes)
    alpha1 = np.ones((m,), np.float32) if alpha1 is None \
        else np.asarray(alpha1, np.float32)
    bias = np.zeros((m,), np.float32) if bias is None \
        else np.asarray(bias, np.float32)
    alpha2 = np.float32(1.0 if alpha2 is None else alpha2)
    codes_np = np.asarray(codes[:k])
    fmt = formats.select_format(codes_np)
    ct = formats.encode(codes_np, fmt)
    return {
        "packed": packed,
        "omega": omega.astype(jnp.float32),
        "alpha1": jnp.asarray(alpha1, jnp.float32),
        "bias": jnp.asarray(bias, jnp.float32),
        "alpha2": jnp.asarray(alpha2),
        "shape": (k, m),
        "activation": activation,
        "format": fmt,
        "size_bytes": ct.size_bytes,
        "dense_bytes": codes_np.size * 4,   # fp32 original, for CR
        # frozen-at-birth content digest: every downstream tier
        # (GuardedPlan, compress_pack, export_pack) verifies against
        # this same value
        "crc": integrity.layer_content_crc(
            codes_np, omega, alpha1, bias, alpha2),
    }


def freeze_mlp(params: dict, qstate: dict, bn_state: dict, lam: float,
               act_bits: Optional[int] = None) -> dict:
    """ECL-quantize every layer and fold BN into the §V epilogue constants.

    Returns a serving pack: per layer {packed codes, omega, alpha1, bias,
    alpha2, format, size_bytes}.  ``act_bits`` enables the paper's
    quantized-activation mode (8 in the FPGA config): alpha2 re-scales the
    ReLU output into the next layer's integer grid.
    """
    layers = []
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        node = layer["kernel"]
        probs = qstate["layers"][i]["kernel"]["probs"]
        codes = ecl.assign(node["w"], node["omega"], probs, lam)
        m = codes.shape[1]

        if "bn_gamma" in layer:
            st = bn_state["layers"][i]
            inv_sigma = 1.0 / np.sqrt(np.asarray(st["var"]) + 1e-5)
            alpha1 = np.asarray(layer["bn_gamma"]) * inv_sigma
            bias = (np.asarray(layer["bn_beta"])
                    + alpha1 * (np.asarray(layer["bias"]) - np.asarray(st["mean"])))
        else:
            alpha1 = np.ones((m,), np.float32)
            bias = np.asarray(layer["bias"])

        layers.append(freeze_dense_layer(
            codes, node["omega"], alpha1=alpha1, bias=bias,
            activation="relu" if i < n - 1 else None))
    return {"layers": layers, "act_bits": act_bits}


def _compat_plan(pack: dict, *, use_kernel: bool, fused: bool,
                 act_dtype: str, calib: Optional[dict],
                 interpret: Optional[bool], block_m: Optional[int],
                 double_buffer: bool):
    """Map the legacy keyword surface onto a (memoized) ExecutionPlan.

    The historical contracts are preserved exactly: ``fused=True`` is the
    batch-tiled megakernel at every batch size (``ws_bucket_rows=0`` — the
    weight-stationary latency schedule is a *plan-level* choice, selected
    by the serving engine's batch=1 bucket, not silently swapped under
    callers that pinned the fused path and rely on its bit-exactness
    contract vs the per-layer chain)."""
    mode = "oracle" if not use_kernel else ("fused" if fused
                                            else "per_layer")
    return serving.get_plan(pack, mode=mode, act_dtype=act_dtype,
                            calib=calib, double_buffer=double_buffer,
                            interpret=interpret, block_m=block_m,
                            ws_bucket_rows=0)


def mlp_serve(pack: dict, x: jax.Array, *, use_kernel: bool = True,
              fused: bool = True, interpret: Optional[bool] = None,
              block_m: Optional[int] = None,
              double_buffer: bool = False) -> jax.Array:
    """End-to-end inference on the frozen pack.

    Thin compatibility wrapper over ``serving.ExecutionPlan`` (which is
    where mode/block/VMEM-fit resolution now lives): ``use_kernel=True,
    fused=True`` (default) resolves to the megakernel plan (falling back
    to the per-layer kernel when the stack exceeds the VMEM budget);
    ``fused=False`` to the per-layer chain; ``use_kernel=False`` to the
    pure-jnp oracle.  ``block_m=None`` defers to the autotuner;
    ``double_buffer`` selects the pipelined two-row-group variant.  New
    code should build a plan directly (``serving.build_plan``) and reuse
    it.
    """
    plan = _compat_plan(pack, use_kernel=use_kernel, fused=fused,
                        act_dtype="float32", calib=None,
                        interpret=interpret, block_m=block_m,
                        double_buffer=double_buffer)
    return plan.run(x)


def pack_compression_summary(pack: dict) -> dict:
    comp = sum(l["size_bytes"] for l in pack["layers"])
    orig = sum(l["dense_bytes"] for l in pack["layers"])
    return {
        "compressed_bytes": comp,
        "fp32_bytes": orig,
        "compression_ratio": orig / comp,
        "formats": [l["format"] for l in pack["layers"]],
    }


# --------------------------------------------------------------- training

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(lp, labels[:, None], axis=-1).mean()


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return (logits.argmax(-1) == labels).mean()


# ------------------------------------------- int8 activation mode (§V/§VI)

def calibrate_act_scales(pack: dict, x_calib: jax.Array) -> dict:
    """Per-layer activation scales from a calibration batch — the paper's
    8-bit-activation FPGA configuration.  Delegates to the serving
    engine's calibration (``serving.calibrate_act_scales``), which plans
    run once at build time."""
    return serving.calibrate_act_scales(pack, x_calib)


def mlp_serve_int8(pack: dict, calib: dict, x: jax.Array, *,
                   use_kernel: bool = True,
                   fused: bool = True,
                   interpret: Optional[bool] = None,
                   block_m: Optional[int] = None,
                   double_buffer: bool = False) -> jax.Array:
    """Serving with int8 inter-layer activations (paper §VI-C: 8-bit
    activations, 16-bit basis weights, fp scaling).

    Layer i emits round(y/s_i) clipped to int8; layer i+1 folds s_i into
    its alpha1 — the FantastIC4 ACM datapath never sees floats between
    layers except through the two alpha multipliers, exactly the §V
    pipeline.  The final layer returns float logits.

    Compatibility wrapper over ``serving.ExecutionPlan`` with
    ``act_dtype="int8"``.  ``use_kernel=True, fused=True`` (default) runs
    the whole int8 datapath inside the megakernel — activations are
    re-quantized to int8 in VMEM and never touch HBM between layers, the
    full §V/§VI-C engine — falling back to the per-layer chain past the
    VMEM budget.  The fused and chained paths share the scale-folding
    arithmetic term for term and agree bit-for-bit whenever the per-layer
    kernel takes K in one block (always the case in interpret/CPU mode; a
    TPU block_k split of a wide layer can flip a quantization boundary by
    one ulp — see ``ops.fantastic4_mlp_fused``).
    """
    plan = _compat_plan(pack, use_kernel=use_kernel, fused=fused,
                        act_dtype="int8", calib=calib,
                        interpret=interpret, block_m=block_m,
                        double_buffer=double_buffer)
    return plan.run(x)
