"""Column-sharded serving execution over a ``('data', 'model')`` mesh.

The serving stack so far scales *across* models (one stream per device,
``frontend.ServingFrontend(streams=N)``); this module scales a *single*
pack across devices — the Megatron column split applied to the frozen
FantastIC4 serving pack.  Each layer's packed (⌈K/2⌉, N) bit-plane
tensor splits over its output features on the ``'model'`` axis (the same
``//packed`` column rule the training-side tree uses — see
``runtime.sharding.serving_pack_specs``), so every shard decodes and
multiplies only its N/tp column slice: 4-bit weight bytes, decode work
and the (K, N/tp) matmul all shrink by the model-axis width.  The
epilogue vectors (alpha1 / bias) follow their layer's split; ω and
alpha2 — the paper's full-precision shared parameters — replicate.

Between layers the next matmul needs the *full* activation row, so each
layer ends in one tiled ``all_gather`` of the column blocks over
``'model'`` (N/tp columns moved per device per layer — the only
communication; there is no psum on this path, which is what keeps it
**bit-exact**, see below).  Batch rows shard over ``'data'`` when the
row count divides the axis and replicate otherwise.

Bit-exactness
-------------

Column-splitting never changes a single output column's arithmetic: the
contraction (K) dimension is not partitioned, every column is computed
in full on exactly one shard with the same accumulation order as the
unsharded per-layer chain kernel, and the tiled all-gather merely
re-concatenates the blocks in mesh order.  A row split would end in a
psum — a *re-association* of the fp32 accumulation — and break the int8
grid's bitwise parity contract; the column split preserves it, and the
int8 inter-layer requantization (clip∘round on elementwise-identical
inputs) then reproduces ``kernels.ops.fantastic4_mlp_chain_int8``
bit-for-bit (``tests/test_serving_sharded.py`` pins this on a forced
multi-device host).

Widths that do not divide the model axis **replicate** (the rules'
divisibility guard): that layer computes fully on every shard and skips
the gather — correct everywhere, scale-out where the pack allows it.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..kernels import ops as kops
from ..runtime.sharding import Rules, serving_pack_specs


class ShardedStack:
    """One frozen pack bound to one mesh: operands placed once at build
    (``device_put`` under the serving-pack partition specs), one jitted
    shard_map program per batch shape.  Callable like a plan entry:
    ``stack(x) -> logits``.  Built by ``ExecutionPlan(mode="sharded")`` —
    use the plan, not this class, from serving code."""

    def __init__(self, pack: dict, mesh: Mesh, *,
                 act_dtype: str = "float32",
                 act_scales: Optional[List[float]] = None,
                 interpret: Optional[bool] = None,
                 use_kernel: bool = True):
        if "model" not in mesh.axis_names or "data" not in mesh.axis_names:
            raise ValueError(
                f"sharded serving needs a ('data', 'model') mesh; got axes "
                f"{tuple(mesh.axis_names)} (build one with "
                "launch.mesh.fit_mesh)")
        if act_dtype == "int8" and act_scales is None:
            raise ValueError("act_dtype='int8' requires act_scales")
        self.mesh = mesh
        self.layers = pack["layers"]
        self.act_dtype = act_dtype
        self.act_scales = list(act_scales) if act_scales else None
        self.interpret = interpret
        self.use_kernel = use_kernel
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.dp = int(axis_sizes.get("data", 1))
        self.tp = int(axis_sizes.get("model", 1))
        rules = Rules(tuple(mesh.axis_names), axis_sizes, cfg=None)
        self.specs = serving_pack_specs(self.layers, rules)
        self.col_sharded: Tuple[bool, ...] = tuple(
            len(s["packed"]) == 2 and s["packed"][1] is not None
            for s in self.specs)
        # operands placed once, under the rules' specs — every later call
        # reuses the resident shards (the plan/operand-cache posture).
        self._operand_specs = tuple(
            (s["packed"], s["omega"], s["alpha1"], s["bias"], s["alpha2"])
            for s in self.specs)
        self._operands = tuple(
            tuple(jax.device_put(
                jnp.asarray(arr, dtype=None), NamedSharding(mesh, spec))
                for arr, spec in zip(
                    (layer["packed"], layer["omega"], layer["alpha1"],
                     layer["bias"],
                     jnp.asarray(1.0 if layer.get("alpha2") is None
                                 else layer["alpha2"], jnp.float32)),
                    self._operand_specs[i]))
            for i, layer in enumerate(self.layers))
        self._fns: Dict[Tuple[int, int], Callable] = {}

    # ----------------------------------------------------------- body

    def _stack_body(self, x: jax.Array, operands) -> jax.Array:
        """Per-shard stack: the per-layer chain with column-local matmuls
        and a tiled gather after each split layer.  Mirrors
        ``fantastic4_mlp_chain`` / ``fantastic4_mlp_chain_int8``
        expression-for-expression — the bitwise parity ground truth."""
        int8 = self.act_dtype == "int8"
        n = len(self.layers)
        xq = x.astype(jnp.float32)
        in_scale = 1.0
        for i, (layer, ops_i) in enumerate(zip(self.layers, operands)):
            packed, omega, alpha1, bias, alpha2 = ops_i
            if layer["shape"][0] % 2:
                # odd K: the pack carries one zero code row — mirror on x
                xq = jnp.pad(xq, ((0, 0), (0, 1)))
            if int8:
                alpha1 = alpha1 * in_scale     # de-quantize inputs
                alpha2 = None
            y = kops.fantastic4_matmul(
                xq, packed, omega, bias=bias, alpha1=alpha1,
                alpha2=alpha2, activation=layer.get("activation"),
                use_kernel=self.use_kernel, interpret=self.interpret)
            if self.col_sharded[i]:
                y = jax.lax.all_gather(y, "model", axis=1, tiled=True)
            if int8 and i < n - 1:
                s = self.act_scales[i]
                y = jnp.clip(jnp.round(y / s), -127, 127)
                y = y.astype(jnp.int8).astype(jnp.float32)
                in_scale = s
            xq = y
        return xq

    # ----------------------------------------------------------- call

    def _fn_for(self, m: int, d: int) -> Callable:
        fn = self._fns.get((m, d))
        if fn is None:
            # batch rows shard over 'data' when they divide the axis; an
            # indivisible batch replicates (every device computes every
            # row — correct, not scaled) instead of failing.
            xspec = P("data", None) if m % self.dp == 0 else P(None, None)
            mapped = shard_map(
                self._stack_body, mesh=self.mesh,
                in_specs=(xspec, self._operand_specs),
                out_specs=xspec)
            fn = jax.jit(mapped)
            self._fns[(m, d)] = fn
        return fn

    def __call__(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x, jnp.float32)
        return self._fn_for(*x.shape)(x, self._operands)

    # ----------------------------------------------------------- report

    def describe(self) -> dict:
        return {
            "mesh": dict(zip(self.mesh.axis_names,
                             (int(s) for s in self.mesh.devices.shape))),
            "n_devices": int(self.mesh.devices.size),
            "col_sharded_layers": [i for i, c in
                                   enumerate(self.col_sharded) if c],
            "replicated_layers": [i for i, c in
                                  enumerate(self.col_sharded) if not c],
        }
