"""Unified serving engine: servable programs + tile-bucketed micro-batching.

    queue ──▶ bucket ──▶ program ──▶ kernel

The engine serves anything implementing the :class:`~.plans.\
ServableProgram` protocol (``d_in``/``d_out``/``bucket_sizes`` +
``bucket_for``/``entry``/``run``/``describe``): the frozen-MLP
:class:`~.plans.ExecutionPlan`, the lazy :class:`~.pack_cache.CachedPlan`
cache handle, guard/fault proxies, and the transformer
:class:`~.lm.LMProgram` (4-bit prefill/decode) all ride the same
batcher → frontend → cache machinery.

* :mod:`plans` — :class:`ExecutionPlan`: mode (fused fp32 / fused int8 /
  per-layer / oracle), autotuned blocks, VMEM-fit fallback and int8
  calibration resolved ONCE per frozen pack, exposing jitted entry points
  per power-of-two batch bucket — each bucket bound to its measured-best
  kernel schedule (batch-tiled / double-buffered / weight-stationary /
  decode-amortized streaming) by the schedule-aware autotuner.
* :mod:`batcher` — :class:`MicroBatcher`: FIFO request queue coalesced
  into those buckets (full-tile flush, deadline-based partial flush),
  results scattered back per request; :func:`replay` drives a ragged
  arrival trace through it work-conservingly on a virtual clock.
* :mod:`frontend` — :class:`ServingFrontend`: the live driver — a
  :class:`ModelRegistry` of packs behind one real-clock dispatch thread
  (sleep until ``min(next_deadline)``, oldest-deadline-first launches
  with a full-tile fast path), futures / asyncio on the submit side.
  ``streams=N`` replicates the execution stream: N workers (one per
  device when the host has them), join-shortest-estimated-work
  assignment off the admission controller's service-time EWMA, and a
  per-stream quarantine rung in the degradation ladder.
* :mod:`sharded` — :class:`ShardedStack`: the column-split multi-device
  program for ONE pack over a ``('data','model')`` mesh (Megatron
  column split of the packed bit-planes, tiled all-gather per layer,
  bit-exact vs the per-layer chain); served through
  ``ExecutionPlan(mode="sharded", mesh=...)``.

* :mod:`slo` — the robustness policy layer: :class:`SLOTier` latency
  classes (tiered ``max_delay``/deadline budgets + bounded dispatch
  priority), the typed :class:`Rejected` outcome, and the
  :class:`AdmissionController` cost model (measured per-bucket service
  times) that sheds load the engine provably cannot serve within its
  tier's deadline.  Fault injection for the frontend's degradation
  ladder (retry → chain fallback → quarantine, :class:`RetryPolicy`)
  lives in ``runtime.fault`` (:class:`FaultInjector`) and is re-exported
  here.

* :mod:`pack_cache` — the two-tier model store for many-model fleets:
  cold packs stay in their entropy-coded :class:`ColdPack` form
  (``core.formats`` codecs), are decoded + calibrated + plan-resolved
  lazily on first traffic, and resolved plans live in an LRU hot tier
  (``max_hot`` / ``hot_bytes`` budgets) with eviction back to compressed
  form — bit-identical across an evict/reload cycle.

Every serving entry point (``models.mlp.mlp_serve*``, ``launch.serve``,
the benchmarks, the examples) flows through this package instead of
threading mode keywords down to the kernels.
"""
from ..runtime.fault import FaultInjector, InjectedFault      # noqa: F401
from ..runtime.integrity import (GuardedPlan, IntegrityError,  # noqa: F401
                                 IntegrityPolicy, unwrap_chain)
from .plans import (ACT_DTYPES, MODES, ExecutionPlan,        # noqa: F401
                    ServableProgram, adopt_plan, build_plan,
                    calibrate_act_scales, forget_plan, get_plan)
from .slo import (TIERS, AdmissionController, Rejected,       # noqa: F401
                  SLOTier, resolve_tier)
from .batcher import Completion, MicroBatcher, Taken, replay  # noqa: F401
from .pack_cache import (CachedPlan, ColdPack, PackCache,     # noqa: F401
                         compress_pack, decode_pack,
                         plan_resident_bytes, verify_cold_pack)
from .sharded import ShardedStack                             # noqa: F401
from .frontend import (ModelRegistry, RetryPolicy, Served,    # noqa: F401
                       ServingFrontend)
# .lm imports models.mlp (freeze helper), which imports this package —
# keep it last so the partially-initialized module is already complete
from .lm import LMProgram, build_lm_program, freeze_lm        # noqa: F401
