"""Two-tier packed-weight model store: compressed cold tier, LRU hot tier.

The paper's premise is that a 4bit-compact MLP is tiny *at rest* and only
expanded at execution time — but until this module the serving registry
kept every model's resolved :class:`~.plans.ExecutionPlan` (decoded
operands, calibration, jitted entries) resident forever, so a fleet of
compact models cost as much as a fleet of dense ones.  The cache restores
the paper's storage story at fleet scale:

* **cold tier** — every registered model lives in its entropy-coded
  :class:`~repro.core.formats.CompressedTensor` form (``dense4`` /
  ``bitmask`` / ``csr`` / ``huffman``, chosen per layer by
  ``select_format_ext``) plus the fp32 §V epilogue constants.  This is
  the at-rest format: a few % of the decoded plan's footprint for the
  paper stacks.
* **hot tier** — an LRU of resolved plans under a configurable budget
  (``max_hot`` entries and/or ``hot_bytes`` decoded bytes).  A model is
  decoded, calibrated, and plan-resolved **lazily on first traffic**;
  eviction releases the plan, its pinned ``plans._PLAN_MEMO`` entry and
  the kernel-level operand memos (``ops.forget_pack_operands``) — the
  model silently falls back to its compressed form and the next request
  re-resolves it.

**Bit-identity across evict/reload** holds by construction: the codecs
are lossless, plan resolution is deterministic for a given backend, and
the int8 activation scales measured at the *first* resolve are captured
as the model's calibration — a re-resolve reuses them instead of
re-measuring, so an evict→reload cycle returns the exact same bytes
(``tests/test_pack_cache.py`` pins this on the int8 grid).

Count-budget eviction runs **before** the new resolve, so the hot tier's
high-water mark never exceeds ``max_hot`` plans; the byte budget is
enforced after (the new plan's size is unknowable until decode) and
always spares the entry being returned.

:class:`CachedPlan` is the registry-facing face: a lazy proxy that
exposes the static plan surface (``d_in``/``d_out``/``bucket_sizes``)
without decoding, and resolves through the cache on first use of an
execution attribute (``bucket_for``/``entry``/``run``).  A
``MicroBatcher`` built on one never notices eviction: an in-flight
launch holds a strong reference to the real plan, and the next launch
transparently re-resolves.  (Plan-local degradation state —
``demote_bucket`` poisonings — does not survive eviction; a re-resolve
rebinds every bucket fresh.)
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core import bitplanes, formats
from ..runtime import integrity
from ..runtime.integrity import IntegrityError
from .plans import (DEFAULT_MAX_BUCKET, ExecutionPlan, _pow2_buckets,
                    adopt_plan, build_plan, forget_plan)

__all__ = [
    "ColdLayer", "ColdPack", "CachedPlan", "PackCache",
    "compress_pack", "decode_pack", "plan_resident_bytes",
    "cold_pack_to_payload", "cold_pack_from_payload",
    "verify_cold_pack",
]


def _nbytes(a) -> int:
    a = np.asarray(a)
    return int(a.size) * a.dtype.itemsize


# --------------------------------------------------------------- cold form

@dataclasses.dataclass(frozen=True)
class ColdLayer:
    """One layer at rest: entropy-coded 4-bit codes + fp32 epilogue."""
    codes: formats.CompressedTensor     # (k, n) uint8 codes, compressed
    omega: np.ndarray                   # (4,) centroid basis
    alpha1: np.ndarray                  # (n,) §V scale
    bias: np.ndarray                    # (n,) folded bias
    alpha2: np.ndarray                  # scalar §V rescale
    shape: Tuple[int, int]              # (k, n) true shape (pre-padding)
    activation: Optional[str]           # "relu" | None
    # integrity digests (None on packs built before checksumming existed):
    # content_crc is the representation-independent layer_content_crc;
    # payload_crc covers the raw CompressedTensor payload so the cold
    # tier can be scrubbed without a decode.
    content_crc: Optional[int] = None
    payload_crc: Optional[int] = None

    @property
    def size_bytes(self) -> int:
        """At-rest footprint: compressed codes + epilogue constants."""
        return (self.codes.size_bytes + _nbytes(self.omega)
                + _nbytes(self.alpha1) + _nbytes(self.bias)
                + _nbytes(self.alpha2))

    @property
    def fp32_bytes(self) -> int:
        """The dense fp32 weight this layer replaces (paper CR basis)."""
        k, n = self.shape
        return (4 * k * n + _nbytes(self.omega) + _nbytes(self.alpha1)
                + _nbytes(self.bias) + _nbytes(self.alpha2))


@dataclasses.dataclass(frozen=True)
class ColdPack:
    """A frozen pack in its at-rest form — what the cold tier stores and
    what :func:`repro.checkpoint.manager.export_pack` serializes."""
    layers: Tuple[ColdLayer, ...]
    act_bits: Optional[int] = None

    @property
    def shapes(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(l.shape for l in self.layers)

    @property
    def d_in(self) -> int:
        return self.layers[0].shape[0]

    @property
    def d_out(self) -> int:
        return self.layers[-1].shape[1]

    @property
    def size_bytes(self) -> int:
        return sum(l.size_bytes for l in self.layers)

    @property
    def fp32_bytes(self) -> int:
        return sum(l.fp32_bytes for l in self.layers)

    @property
    def compression_ratio(self) -> float:
        return self.fp32_bytes / max(self.size_bytes, 1)


def compress_pack(pack: dict) -> ColdPack:
    """Frozen serving pack (``models.mlp.freeze_mlp``) → at-rest form.

    Codes are recovered from the kernel's row-pair nibble layout, the
    odd-``k`` zero padding row is stripped (``shape`` keeps the true
    ``k``), and each layer picks its best format over the extended set
    (including huffman).  Lossless: :func:`decode_pack` rebuilds a pack
    whose plan output is bit-identical to the original's."""
    layers = []
    for i, layer in enumerate(pack["layers"]):
        k, n = (int(d) for d in layer["shape"])
        codes = np.asarray(bitplanes.unpack_codes_rows(layer["packed"]),
                           np.uint8)[:k]
        omega = np.asarray(layer["omega"], np.float32)
        alpha1 = np.asarray(layer["alpha1"], np.float32)
        bias = np.asarray(layer["bias"], np.float32)
        alpha2 = np.asarray(layer["alpha2"], np.float32)
        crc = integrity.layer_content_crc(codes, omega, alpha1, bias,
                                          alpha2)
        stamped = layer.get("crc")
        if stamped is not None and int(stamped) != crc:
            raise IntegrityError(
                f"pack layer {i} content disagrees with its stamped "
                f"checksum (expected {int(stamped):#010x}, got "
                f"{crc:#010x})", kind="content", layer=i)
        ct = formats.encode(codes, formats.select_format_ext(codes))
        layers.append(ColdLayer(
            codes=ct,
            omega=omega,
            alpha1=alpha1,
            bias=bias,
            alpha2=alpha2,
            shape=(k, n),
            activation=layer.get("activation"),
            content_crc=crc,
            payload_crc=integrity.payload_crc(ct)))
    return ColdPack(layers=tuple(layers), act_bits=pack.get("act_bits"))


def verify_cold_pack(cold: ColdPack) -> None:
    """Payload-level scrub of the cold tier: re-checksum every layer's
    raw ``CompressedTensor`` payload against ``payload_crc``.  Cheap (no
    decode) — the full content check happens on every
    :func:`decode_pack`.  Layers without digests (pre-checksum packs)
    are skipped."""
    for i, cl in enumerate(cold.layers):
        if cl.payload_crc is None:
            continue
        got = integrity.payload_crc(cl.codes)
        if got != cl.payload_crc:
            raise IntegrityError(
                f"cold payload checksum mismatch at layer {i} "
                f"(expected {cl.payload_crc:#010x}, got {got:#010x})",
                kind="cold", layer=i)


def decode_pack(cold: ColdPack) -> dict:
    """At-rest form → frozen serving pack (``freeze_mlp`` layout: kernel
    row-pair packing, odd-``k`` zero pad, compression metadata kept so
    ``models.mlp.pack_compression_summary`` still reads it)."""
    layers = []
    for i, cl in enumerate(cold.layers):
        k, n = cl.shape
        if cl.payload_crc is not None:
            got = integrity.payload_crc(cl.codes)
            if got != cl.payload_crc:
                raise IntegrityError(
                    f"cold payload checksum mismatch at layer {i} "
                    f"(expected {cl.payload_crc:#010x}, got {got:#010x})",
                    kind="cold", layer=i)
        try:
            codes = formats.decode(cl.codes).astype(np.uint8).reshape(k, n)
        except IntegrityError:
            raise
        except Exception as exc:
            raise IntegrityError(
                f"cold payload at layer {i} failed to decode: {exc}",
                kind="cold", layer=i) from exc
        content_crc = integrity.layer_content_crc(
            codes, cl.omega, cl.alpha1, cl.bias, cl.alpha2)
        if cl.content_crc is not None and content_crc != cl.content_crc:
            raise IntegrityError(
                f"decoded content checksum mismatch at layer {i} "
                f"(expected {cl.content_crc:#010x}, got "
                f"{content_crc:#010x})", kind="cold", layer=i)
        full = codes
        if k % 2:
            full = np.concatenate([codes, np.zeros((1, n), np.uint8)],
                                  axis=0)
        layers.append({
            "packed": bitplanes.pack_codes_rows(jnp.asarray(full)),
            "omega": jnp.asarray(cl.omega, jnp.float32),
            "alpha1": jnp.asarray(cl.alpha1, jnp.float32),
            "bias": jnp.asarray(cl.bias, jnp.float32),
            "alpha2": jnp.asarray(cl.alpha2, jnp.float32),
            "shape": (k, n),
            "activation": cl.activation,
            "format": cl.codes.format,
            "size_bytes": cl.codes.size_bytes,
            "dense_bytes": k * n * 4,
            "crc": content_crc,
        })
    pack = {"layers": layers}
    if cold.act_bits is not None:
        pack["act_bits"] = cold.act_bits
    return pack


# ------------------------------------------------- npz payload (de)serial

_SEP = "//"


def cold_pack_to_payload(cold: ColdPack, prefix: str = "") -> Dict[str, np.ndarray]:
    """Flatten a :class:`ColdPack` into an ``np.savez``-able dict.  Keys
    are ``{prefix}layer{i}//field`` with the compressed payload nested a
    level deeper (``...//codes//{payload key}``)."""
    out: Dict[str, np.ndarray] = {
        prefix + "num_layers": np.int64(len(cold.layers)),
        prefix + "act_bits": np.int64(-1 if cold.act_bits is None
                                      else cold.act_bits),
        prefix + "crc_algo": np.array(integrity.CRC_ALGO),
    }
    for i, cl in enumerate(cold.layers):
        p = f"{prefix}layer{i}{_SEP}"
        out[p + "format"] = np.array(cl.codes.format)
        out[p + "shape"] = np.asarray(cl.shape, np.int64)
        out[p + "activation"] = np.array(cl.activation or "")
        out[p + "content_crc"] = np.int64(
            -1 if cl.content_crc is None else cl.content_crc)
        out[p + "payload_crc"] = np.int64(
            -1 if cl.payload_crc is None else cl.payload_crc)
        out[p + "omega"] = np.asarray(cl.omega, np.float32)
        out[p + "alpha1"] = np.asarray(cl.alpha1, np.float32)
        out[p + "bias"] = np.asarray(cl.bias, np.float32)
        out[p + "alpha2"] = np.asarray(cl.alpha2, np.float32)
        for key, arr in cl.codes.payload.items():
            out[f"{p}codes{_SEP}{key}"] = np.asarray(arr)
    return out


def cold_pack_from_payload(payload: Dict[str, np.ndarray],
                           prefix: str = "") -> ColdPack:
    """Inverse of :func:`cold_pack_to_payload` (accepts a live dict or a
    loaded ``NpzFile``)."""
    n_layers = int(np.asarray(payload[prefix + "num_layers"]))
    act_bits = int(np.asarray(payload[prefix + "act_bits"]))
    algo_key = prefix + "crc_algo"
    if algo_key in payload:
        algo = str(np.asarray(payload[algo_key]))
        if algo != integrity.CRC_ALGO:
            raise IntegrityError(
                f"pack digests use checksum algorithm {algo!r} but this "
                f"host verifies with {integrity.CRC_ALGO!r}; refusing to "
                "mis-verify", kind="artifact")

    def _opt_crc(key: str) -> Optional[int]:
        if key not in payload:
            return None           # pre-checksum artifact
        v = int(np.asarray(payload[key]))
        return None if v < 0 else v

    layers = []
    for i in range(n_layers):
        p = f"{prefix}layer{i}{_SEP}"
        fmt = str(np.asarray(payload[p + "format"]))
        shape = tuple(int(d) for d in np.asarray(payload[p + "shape"]))
        act = str(np.asarray(payload[p + "activation"])) or None
        codes_prefix = f"{p}codes{_SEP}"
        ct_payload = {key[len(codes_prefix):]: np.asarray(payload[key])
                      for key in payload
                      if key.startswith(codes_prefix)}
        layers.append(ColdLayer(
            codes=formats.CompressedTensor(fmt, shape, ct_payload),
            omega=np.asarray(payload[p + "omega"], np.float32),
            alpha1=np.asarray(payload[p + "alpha1"], np.float32),
            bias=np.asarray(payload[p + "bias"], np.float32),
            alpha2=np.asarray(payload[p + "alpha2"], np.float32),
            shape=shape, activation=act,
            content_crc=_opt_crc(p + "content_crc"),
            payload_crc=_opt_crc(p + "payload_crc")))
    return ColdPack(layers=tuple(layers),
                    act_bits=None if act_bits < 0 else act_bits)


# ----------------------------------------------------------- hot-tier cost

def plan_resident_bytes(plan) -> int:
    """Decoded footprint of a resolved program's operands (the hot-tier
    accounting unit): per-layer packed codes + epilogue constants, plus
    the calibration vector.  Jitted executables and memoized kernel
    operands scale with this, so it is the byte knob ``hot_bytes``
    budgets against.  Works on any :class:`~.plans.ServableProgram`
    whose ``.layers`` are standard frozen layer dicts."""
    total = 0
    for layer in plan.layers:
        for key in ("packed", "omega", "alpha1", "bias", "alpha2"):
            total += _nbytes(layer[key])
    scales = getattr(plan, "act_scales", None)
    if scales is not None:
        total += 4 * len(scales)
    return total


# ----------------------------------------------------------------- proxy

class CachedPlan:
    """Lazy plan handle: static surface without decoding, execution
    surface resolved through the owning :class:`PackCache` per call.
    Safe to hold across evictions — every execution attribute re-resolves
    (LRU hit when hot, decode+rebuild when cold).

    Implements :class:`~.plans.ServableProgram`: the static protocol
    surface (``d_in``/``d_out``/``bucket_sizes``/``rows_per_request``)
    answers without a decode, so registering a cold model costs
    nothing."""

    rows_per_request: Optional[int] = None   # row-oriented, like the plans

    def __init__(self, cache: "PackCache", model_id: str, *,
                 d_in: int, d_out: int,
                 bucket_sizes: Tuple[int, ...]):
        self.cache = cache
        self.model_id = model_id
        self.d_in = d_in
        self.d_out = d_out
        # static estimate (pow2 up to the configured max_bucket): the
        # resolved plan's top bucket can be smaller (tuned block_m cap),
        # in which case bucket_for() returns None for the outsized
        # coalesce and run() serves it on the oversize binding — correct,
        # just not pre-compiled.
        self.bucket_sizes = bucket_sizes

    def resolve(self) -> ExecutionPlan:
        """The real plan — hot-tier hit or lazy decode+rebuild."""
        return self.cache.plan(self.model_id)

    @property
    def resident(self) -> bool:
        return self.cache.has_hot(self.model_id)

    # execution surface (everything MicroBatcher / the degradation ladder
    # touches) — each call goes through the cache so eviction is invisible
    def bucket_for(self, m: int) -> Optional[int]:
        return self.resolve().bucket_for(m)

    def entry(self, bucket: int):
        return self.resolve().entry(bucket)

    def run(self, x):
        return self.resolve().run(x)

    def warmup(self, buckets=None) -> None:
        self.resolve().warmup(buckets)

    def demote_bucket(self, rows: int, **kwargs):
        return self.resolve().demote_bucket(rows, **kwargs)

    @property
    def buckets(self):
        return self.resolve().buckets

    @property
    def act_scales(self):
        return self.resolve().act_scales

    @property
    def act_dtype(self):
        return self.resolve().act_dtype

    @property
    def pack(self) -> dict:
        return self.resolve().pack

    @property
    def layers(self):
        return self.resolve().layers

    def describe(self) -> dict:
        d = {"model_id": self.model_id, "cached": True,
             "resident": self.resident}
        if self.resident:
            d.update(self.resolve().describe())
        return d


# ----------------------------------------------------------------- cache

class PackCache:
    """The two-tier store (module docstring has the design contract).

    ``max_hot`` bounds resident plan *count* (evicted before a new
    resolve, so the high-water mark never exceeds it); ``hot_bytes``
    bounds resident decoded *bytes* (enforced post-resolve, sparing the
    entry being returned).  ``None`` disables a bound.  ``plan_kwargs``
    are defaults for every resolve (per-model kwargs at :meth:`add`
    override them).  Thread-safe; resolution runs under the lock, so two
    racing requests for the same cold model decode it once."""

    def __init__(self, max_hot: Optional[int] = None,
                 hot_bytes: Optional[int] = None, *,
                 plan_kwargs: Optional[dict] = None):
        if max_hot is not None and max_hot < 1:
            raise ValueError(f"max_hot must be >= 1, got {max_hot}")
        self.max_hot = max_hot
        self.hot_bytes = hot_bytes
        self.default_plan_kwargs = dict(plan_kwargs or {})
        self._lock = threading.RLock()
        self._cold: Dict[str, ColdPack] = {}
        self._plan_kwargs: Dict[str, dict] = {}
        self._calib: Dict[str, dict] = {}
        self._hot: "OrderedDict[str, ExecutionPlan]" = OrderedDict()
        self._bytes: Dict[str, int] = {}
        self.stats = {"resolves": 0, "hits": 0, "evictions": 0,
                      "updates": 0, "decode_s": 0.0,
                      "resident_bytes": 0, "resident_high_water": 0,
                      "cold_start_s": []}

    # ------------------------------------------------------------ intake

    def add(self, model_id: str, pack: Union[dict, ColdPack], *,
            plan_kwargs: Optional[dict] = None) -> CachedPlan:
        """Register a model by pack — a frozen serving pack (compressed
        here) or an already-cold :class:`ColdPack` (e.g. from
        ``checkpoint.manager.load_pack``).  Nothing is decoded until
        first traffic; the returned :class:`CachedPlan` is what goes into
        a ``ModelRegistry``."""
        cold = pack if isinstance(pack, ColdPack) else compress_pack(pack)
        kwargs = {**self.default_plan_kwargs, **(plan_kwargs or {})}
        # a caller-provided calib seeds the per-model calibration the
        # cache otherwise captures at first resolve (same storage, same
        # bit-identity guarantee)
        calib = kwargs.pop("calib", None)
        with self._lock:
            if model_id in self._cold:
                raise ValueError(f"model {model_id!r} already cached")
            self._cold[model_id] = cold
            self._plan_kwargs[model_id] = kwargs
            if calib is not None:
                self._calib[model_id] = calib
        max_bucket = kwargs.get("max_bucket", DEFAULT_MAX_BUCKET)
        return CachedPlan(self, model_id, d_in=cold.d_in,
                          d_out=cold.d_out,
                          bucket_sizes=_pow2_buckets(max(max_bucket, 1)))

    def update(self, model_id: str, pack: Union[dict, ColdPack]) -> None:
        """Hot-swap a model's weights (pack update): the cold form is
        replaced, the stale hot plan (if any) is evicted, and the stored
        calibration is dropped — the *next* request resolves the new
        weights.  Existing :class:`CachedPlan` handles (and the batchers
        holding them) keep working; queued requests are never dropped,
        they just execute on the new plan."""
        cold = pack if isinstance(pack, ColdPack) else compress_pack(pack)
        with self._lock:
            if model_id not in self._cold:
                raise KeyError(f"model {model_id!r} not cached")
            self._cold[model_id] = cold
            self._calib.pop(model_id, None)
            self._evict_locked(model_id)
            self.stats["updates"] += 1

    def remove(self, model_id: str) -> None:
        """Forget a model entirely (both tiers).  Idempotent."""
        with self._lock:
            self._evict_locked(model_id)
            self._cold.pop(model_id, None)
            self._plan_kwargs.pop(model_id, None)
            self._calib.pop(model_id, None)

    def cold(self, model_id: str) -> ColdPack:
        """The at-rest form of a cached model (the recovery source of
        truth the scrubber verifies against)."""
        with self._lock:
            try:
                return self._cold[model_id]
            except KeyError:
                raise KeyError(
                    f"model {model_id!r} not cached; have "
                    f"{sorted(self._cold)}") from None

    # ----------------------------------------------------------- serving

    def plan(self, model_id: str) -> ExecutionPlan:
        """The resolved plan: LRU hit, or lazy decode + calibrate +
        resolve (count budget enforced *before* the resolve)."""
        with self._lock:
            hit = self._hot.get(model_id)
            if hit is not None:
                self._hot.move_to_end(model_id)
                self.stats["hits"] += 1
                return hit
            try:
                cold = self._cold[model_id]
            except KeyError:
                raise KeyError(
                    f"model {model_id!r} not cached; have "
                    f"{sorted(self._cold)}") from None
            while self.max_hot is not None and len(self._hot) >= self.max_hot:
                self._evict_locked(next(iter(self._hot)))
            t0 = time.perf_counter()
            kwargs = self._plan_kwargs.get(model_id, {})
            plan = build_plan(decode_pack(cold),
                              calib=self._calib.get(model_id), **kwargs)
            dt = time.perf_counter() - t0
            # first int8 resolve measures the activation scales; keep them
            # so every re-resolve is calibration-free AND bit-identical
            if model_id not in self._calib and plan.act_scales is not None:
                self._calib[model_id] = {
                    "act_scales": [float(s) for s in plan.act_scales]}
            # pin into the compat-path plan memo so get_plan on this pack
            # never re-resolves a duplicate; unhashable kwargs (calib_x
            # arrays) can't be part of a memo key and are left out — the
            # adopted entry still answers the plain-kwargs lookup
            adopt_plan(plan.pack, plan,
                       **{k: v for k, v in kwargs.items()
                          if isinstance(v, (str, int, float, bool,
                                            tuple, type(None)))})
            self._hot[model_id] = plan
            nbytes = plan_resident_bytes(plan)
            self._bytes[model_id] = nbytes
            self.stats["resolves"] += 1
            self.stats["decode_s"] += dt
            self.stats["cold_start_s"].append(dt)
            self.stats["resident_bytes"] += nbytes
            self.stats["resident_high_water"] = max(
                self.stats["resident_high_water"],
                self.stats["resident_bytes"])
            while (self.hot_bytes is not None and len(self._hot) > 1
                   and self.stats["resident_bytes"] > self.hot_bytes):
                self._evict_locked(next(iter(self._hot)))
            return plan

    # ---------------------------------------------------------- eviction

    def _evict_locked(self, model_id: str) -> bool:
        plan = self._hot.pop(model_id, None)
        if plan is None:
            return False
        self.stats["resident_bytes"] -= self._bytes.pop(model_id, 0)
        self.stats["evictions"] += 1
        # release the plan memo entry (pinned at adopt) and the decoded
        # kernel operands — without this the "evicted" plan stays fully
        # resident through module-global memos for the process lifetime
        forget_plan(plan.pack)
        return True

    def evict(self, model_id: str) -> bool:
        """Push one model back to the cold tier (no-op if not hot)."""
        with self._lock:
            return self._evict_locked(model_id)

    def evict_all(self) -> int:
        with self._lock:
            return sum(self._evict_locked(m) for m in list(self._hot))

    # ------------------------------------------------------- introspection

    def has_hot(self, model_id: str) -> bool:
        with self._lock:
            return model_id in self._hot

    def __contains__(self, model_id: str) -> bool:
        with self._lock:
            return model_id in self._cold

    def __len__(self) -> int:
        with self._lock:
            return len(self._cold)

    def ids(self) -> List[str]:
        with self._lock:
            return list(self._cold)

    def hot_ids(self) -> List[str]:
        """LRU → MRU order."""
        with self._lock:
            return list(self._hot)

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self.stats["resident_bytes"]

    @property
    def cold_bytes(self) -> int:
        with self._lock:
            return sum(c.size_bytes for c in self._cold.values())

    def describe(self) -> dict:
        with self._lock:
            return {
                "models": len(self._cold),
                "hot": list(self._hot),
                "max_hot": self.max_hot,
                "hot_bytes_budget": self.hot_bytes,
                "resident_bytes": self.stats["resident_bytes"],
                "resident_high_water": self.stats["resident_high_water"],
                "cold_bytes": sum(c.size_bytes
                                  for c in self._cold.values()),
                "fp32_bytes": sum(c.fp32_bytes
                                  for c in self._cold.values()),
                "resolves": self.stats["resolves"],
                "hits": self.stats["hits"],
                "evictions": self.stats["evictions"],
                "updates": self.stats["updates"],
            }
