"""Async multi-model serving frontend: a real-clock driver over batchers.

The :class:`MicroBatcher` decides *what* to coalesce; until now the repo
only had virtual-clock drivers (``replay``, the benchmarks) around it.
This module is the missing runtime half — the thing that turns the replay
simulator into a runnable server, and the deployment shape FantastIC4
targets: **many small compact MLPs sharing one device** (the paper's §V
units are never idle only if *something* always has a full tile to
launch).

    submit(model_id, x) ──▶ per-model MicroBatcher ──▶ one dispatch
    (any thread / async)     (queue → bucket)          thread, single
                                                       execution stream

Driver loop
-----------

One daemon thread owns the (real, ``time.monotonic``) clock and the
execution stream:

1. **pick** the next launch among batchers whose trigger has fired — a
   *full tile* (pending rows ≥ the largest bucket) launches immediately,
   a *due deadline* (oldest request waited ``max_delay``) launches a
   partial bucket.  Among fired batchers the **oldest head deadline
   wins** (deadline = arrival + ``max_delay``, so this is global FIFO in
   arrival order across models).
2. if nothing fired, **sleep until ``min(next_deadline)``** across all
   registered models — or indefinitely when every queue is empty; any
   ``submit`` notifies the condition variable, so a full tile formed by a
   burst launches without waiting out the deadline.
3. launch via ``MicroBatcher.run_one()`` with the batcher's lock dropped
   around the device round-trip — submits keep landing while the kernel
   runs, and the next pick re-reads the clock, so deadlines that expired
   during compute are served next (the ``pump`` clock fix, satellite of
   the same PR, enforces the same rule inside single-batcher drivers).

Fairness
--------

Oldest-deadline-first *across* models is starvation-free by
construction: a backlogged model's full tiles run while nothing is due
(work conservation), but the moment a trickle model's request ages past
its ``max_delay`` its deadline is the oldest fired trigger and it
preempts further full tiles.  A model under sustained load therefore
bounds another model's extra wait by one bucket's compute, not by the
backlog depth (``tests/test_serving_frontend.py`` pins this).

Clock contract
--------------

The frontend is the *live* driver: batchers it registers run on its
``time.monotonic`` clock, latencies reported in :class:`Served` are wall
time (submit → results scattered), and ``stats["compute_s"]`` equals
``stats["wall_compute_s"]`` (same domain).  Virtual-time experiments
belong to ``serving.replay``, which owns its clock explicitly — the two
drivers never share a batcher.

Sync callers get a ``concurrent.futures.Future`` back from
:meth:`ServingFrontend.submit`; async callers ``await`` the same request
through :meth:`ServingFrontend.asubmit` (the future is wrapped into the
running asyncio loop — the driver thread doubles as the executor, no
event-loop-blocking calls anywhere on the await path).

SLO tiers and overload
----------------------

``register(..., tier=)`` attaches a latency class (``serving.slo``): the
tier's ``max_delay`` is the batching budget, its ``deadline`` gates
admission (the batcher's cost model sheds requests that provably cannot
make the SLO), and its ``weight`` enters the pick rule — fired batchers
are ordered by ``head_deadline - tier.weight``, so a latency-tier
request preempts throughput-tier full tiles by up to ``weight`` seconds
of queue age and no more (bounded priority ⇒ still starvation-free).
Rejected/shed submits resolve their future with a typed
:class:`~.slo.Rejected` — callers always learn promptly, with a reason.

Faults and graceful degradation
-------------------------------

A failed launch is no longer fatal for the stream.  The batcher requeues
the taken requests (host-side numpy — nothing is lost) and the driver
walks a degradation ladder per model, governed by :class:`RetryPolicy`:

1. **retry** — the launch is re-driven from the intact queue up to
   ``max_retries`` times (transient XLA/VMEM errors clear on retry, the
   ``runtime.fault`` posture applied to serving);
2. **chain fallback** — a fused ``(bucket, schedule)`` entry that keeps
   failing is *poisoned*: ``plan.demote_bucket`` rebinds that bucket to
   the per-layer chain path (bit-identical results, degraded speed) and
   the ladder restarts;
3. **quarantine** — a model whose failures survive retry *and* fallback
   is isolated: its outstanding futures get the root cause, its queue is
   dropped, new submits are rejected (``Rejected("quarantined")``) — and
   **every other model keeps serving**.  Previously one bad model killed
   the whole dispatch stream.

Every rung is counted in ``stats`` (``retries`` / ``fallbacks`` /
``quarantined`` / per-model mirrors) — degradation is measurable, never
silent.  Errors in the dispatch machinery itself (not a launch) still
fail everything loudly, exactly as before.

Replicated execution streams (scale-out)
----------------------------------------

``ServingFrontend(streams=N)`` splits the driver into one dispatch
thread plus N stream workers — one per device on a multi-device host
(``devices=`` pins the assignment; default round-robin over
``jax.devices()``), one per thread on the single-device interpret host
(where streams time-share the device through the GIL: the correctness
and dispatch machinery are identical, the speedup is not — see README).
The dispatch thread still owns *what* launches (same tier-weighted
oldest-deadline pick), but instead of executing inline it **takes** the
coalesced bucket (``MicroBatcher.take``) and assigns it to the stream
with the least estimated backlog — join-shortest-estimated-work over
the admission controller's per-bucket service-time EWMA
(``AdmissionController.launch_estimate``), so a slow stream accrues
backlog and stops winning assignments.  The worker **executes**
(``MicroBatcher.execute``) with the batcher's requeue-on-failure
contract intact, and resolves the futures; :class:`Served` carries the
``stream`` that ran it.

The degradation ladder gains a per-stream rung: launch failures count
against the stream that ran them as well as the model, and a stream
whose failures survive the retry budget is **quarantined by itself**
(its queued tickets reroute to healthy streams, the model's ladder
restarts) as long as another stream is active — one poisoned device
degrades the fleet by 1/N instead of killing it.  Failures that follow
the model across streams still walk the model ladder (retry → chain
fallback → model quarantine) exactly as before.  ``streams=1``
(default) is byte-for-byte the single-stream driver above.
"""
from __future__ import annotations

import asyncio
import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..runtime.integrity import (GuardedPlan, IntegrityError,
                                 IntegrityPolicy, unwrap_chain)
from .batcher import MicroBatcher, Taken
from .pack_cache import (CachedPlan, ColdPack, PackCache,
                         verify_cold_pack)
from .plans import ServableProgram, forget_plan
from .slo import (REJECT_CORRUPTED, REJECT_QUARANTINED,
                  REJECT_UNREGISTERED, Rejected, resolve_tier)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Degradation ladder knobs (see module docstring).

    ``max_retries``  — launch retries per rung before escalating.
    ``backoff_s``    — sleep ``backoff_s * attempt`` between retries
                       (transient-fault spacing; 0 keeps tests fast).
    ``fallback``     — poison-and-demote the failing fused bucket to the
                       per-layer chain before giving up on the model.
    ``quarantine``   — isolate the model after the ladder; ``False``
                       escalates to the pre-ladder contract instead
                       (stream-fatal, every future fails).
    ``recover``      — detected corruption (a typed ``IntegrityError``
                       from a :class:`~repro.runtime.integrity.\
GuardedPlan`) takes the recovery rung instead of the retry ladder:
                       evict the poisoned plan and re-decode from the
                       verified cold tier (bit-identical — captured
                       ``act_scales`` survive).  Only quarantines when
                       the cold copy itself fails verification."""
    max_retries: int = 2
    backoff_s: float = 0.0
    fallback: bool = True
    quarantine: bool = True
    recover: bool = True


@dataclasses.dataclass
class Served:
    """One completed request as the frontend hands it back."""
    model_id: str
    rid: int
    y: "np.ndarray"           # (rows, d_out), host-resident (see batcher)
    arrival: float            # frontend clock at submit
    finish: float             # frontend clock when results scattered
    latency: float            # finish - arrival (wall seconds)
    bucket: int               # rows of the bucket that served it
    batched_rows: int         # real rows sharing the launch
    stream: int = 0           # execution stream that ran the launch


class ModelRegistry:
    """Model id → (:class:`~.plans.ServableProgram`, :class:`MicroBatcher`).

    Any program satisfying the protocol registers — a frozen-pack
    :class:`~.plans.ExecutionPlan`, a transformer :class:`~.lm.LMProgram`,
    a :class:`~.pack_cache.CachedPlan` handle, or a guarded/fault-proxy
    wrapper around one of those; the registry and frontend feature-detect
    optional capabilities (``demote_bucket``, ``buckets``, ``pack``) and
    never type-switch on the concrete class.

    Every registered batcher shares the registry's clock, so one dispatch
    loop can compare deadlines across models directly.  Registration is
    thread-safe and allowed while a frontend is running (the driver picks
    the new queue up on its next cycle).  Registered batchers default to
    ``keep_results=False``: a frontend consumes completions from
    ``run_one``'s return value, so retaining them for ``result()`` would
    hold every output a long-running server ever produced — pass
    ``keep_results=True`` only for a batcher you drive yourself."""

    def __init__(self, *, clock: Callable[[], float] = time.monotonic,
                 cache: Optional[PackCache] = None):
        self.clock = clock
        self.cache = cache
        self._lock = threading.Lock()
        self._plans: Dict[str, ServableProgram] = {}
        self._batchers: Dict[str, MicroBatcher] = {}

    def register(self, model_id: str, plan: ServableProgram, *,
                 tier=None,
                 max_delay: Optional[float] = None,
                 max_bucket: Optional[int] = None,
                 max_queued_rows: Optional[int] = None,
                 service_times: Optional[Dict[int, float]] = None,
                 keep_results: bool = False,
                 integrity=None) -> MicroBatcher:
        """Register a model.  ``tier`` (an ``SLOTier`` or a name from
        ``serving.TIERS``) attaches a latency class: its ``max_delay``
        becomes the batching budget (an explicit ``max_delay`` still
        overrides) and its deadline gates admission through the
        batcher's cost model (seed it with measured per-bucket
        ``service_times``; live launches keep it current via EWMA).
        ``max_queued_rows`` bounds the queue — submits past it are
        rejected, typed, instead of growing memory.  ``integrity``
        (``True`` or an :class:`~repro.runtime.integrity.\
IntegrityPolicy`) wraps the plan in a ``GuardedPlan`` — per-launch
        operand checksums, NaN/Inf output screen, scrubbable surface."""
        if integrity:
            policy = integrity if isinstance(integrity, IntegrityPolicy) \
                else IntegrityPolicy()
            plan = GuardedPlan(plan, policy=policy, model_id=model_id)
        resolved = resolve_tier(tier) if tier is not None else None
        if max_delay is None and resolved is None:
            max_delay = 2e-3          # pre-tier default, kept stable
        with self._lock:
            if model_id in self._batchers:
                raise ValueError(f"model {model_id!r} already registered")
            batcher = MicroBatcher(plan, max_delay=max_delay,
                                   max_bucket=max_bucket, clock=self.clock,
                                   keep_results=keep_results,
                                   tier=resolved,
                                   max_queued_rows=max_queued_rows,
                                   service_times=service_times)
            self._plans[model_id] = plan
            self._batchers[model_id] = batcher
        return batcher

    def register_pack(self, model_id: str,
                      pack: "dict | ColdPack", *,
                      plan_kwargs: Optional[dict] = None,
                      wrap: Optional[Callable] = None,
                      **reg_kwargs) -> MicroBatcher:
        """Register a model by its *pack* (frozen serving pack or cold
        :class:`~.pack_cache.ColdPack`) through the registry's
        :class:`~.pack_cache.PackCache`: the model stays compressed until
        first traffic, and its resolved plan lives under the cache's LRU
        budget.  A registry built without a cache gets an unbounded one
        on first use.  ``plan_kwargs`` go to the plan resolve
        (``act_dtype=...``, ``max_bucket=...``); ``wrap`` (a callable)
        interposes a proxy between the cache handle and the batcher —
        e.g. a ``runtime.fault.FaultInjector``, which composes with
        ``integrity=`` as GuardedPlan(wrap(CachedPlan)) so injected
        corruption is caught by the guard; the remaining kwargs are
        :meth:`register`'s (tier, max_delay, integrity, ...)."""
        with self._lock:
            if self.cache is None:
                self.cache = PackCache()
        proxy = self.cache.add(model_id, pack, plan_kwargs=plan_kwargs)
        plan = proxy if wrap is None else wrap(proxy)
        try:
            return self.register(model_id, plan, **reg_kwargs)
        except BaseException:
            self.cache.remove(model_id)
            raise

    def unregister(self, model_id: str) -> List:
        """Remove a model (lifecycle bugfix: there was no way to retire
        one — its plan, decoded operands, and jitted entries leaked for
        the process lifetime).  Drops the queue and returns the dropped
        pending requests so the caller can resolve their futures with a
        typed cause (:meth:`ServingFrontend.unregister` does); releases
        every plan-side cache — the pack cache's tiers for cache-managed
        plans, the plan/operand memos for direct ones.  Raises
        ``KeyError`` for an unknown model."""
        with self._lock:
            if model_id not in self._batchers:
                raise KeyError(f"model {model_id!r} not registered; have "
                               f"{sorted(self._batchers)}")
            plan = self._plans.pop(model_id)
            batcher = self._batchers.pop(model_id)
        dropped = batcher.drop_all()
        # the registered plan may be wrapped (GuardedPlan / FaultInjector
        # proxies) — release the *innermost* plan's caches
        target = next((p for p in unwrap_chain(plan)
                       if isinstance(p, CachedPlan)), None)
        if target is not None:
            target.cache.remove(model_id)
        else:
            pack = getattr(plan, "pack", None)
            if isinstance(pack, dict):
                forget_plan(pack)
        return dropped

    def plan(self, model_id: str) -> ServableProgram:
        with self._lock:
            return self._plans[model_id]

    def batcher(self, model_id: str) -> MicroBatcher:
        try:
            return self._batchers[model_id]
        except KeyError:
            raise KeyError(f"model {model_id!r} not registered; have "
                           f"{sorted(self._batchers)}") from None

    def items(self) -> List[Tuple[str, MicroBatcher]]:
        with self._lock:
            return list(self._batchers.items())

    def ids(self) -> List[str]:
        with self._lock:
            return list(self._batchers)

    def __contains__(self, model_id: str) -> bool:
        with self._lock:
            return model_id in self._batchers

    def __len__(self) -> int:
        with self._lock:
            return len(self._batchers)

    def next_deadline(self) -> Optional[float]:
        """Earliest queued deadline across every model (None when idle)."""
        deadlines = [d for _, b in self.items()
                     if (d := b.next_deadline()) is not None]
        return min(deadlines) if deadlines else None


class ServingFrontend:
    """See module docstring.  Use as a context manager (starts/stops the
    dispatch thread) or call :meth:`start` / :meth:`close` explicitly."""

    def __init__(self, registry: Optional[ModelRegistry] = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 retry_policy: Optional[RetryPolicy] = RetryPolicy(),
                 cache: Optional[PackCache] = None,
                 streams: Optional[int] = None,
                 devices: Optional[Sequence] = None,
                 scrub_interval_s: Optional[float] = None,
                 stall_threshold_s: Optional[float] = None):
        self.registry = registry if registry is not None \
            else ModelRegistry(clock=clock, cache=cache)
        self.clock = self.registry.clock
        self.retry_policy = retry_policy
        # background scrubber cadence (None disables the thread;
        # scrub_once() is always callable) and the launch-watchdog
        # threshold (None disables check_stalls' flagging)
        self.scrub_interval_s = scrub_interval_s
        self.stall_threshold_s = stall_threshold_s
        self._scrub_stop = threading.Event()
        self._scrub_thread: Optional[threading.Thread] = None
        if streams is None:
            streams = len(devices) if devices else 1
        if streams < 1:
            raise ValueError(f"streams must be >= 1, got {streams}")
        if devices is not None and len(devices) != streams:
            raise ValueError(f"devices ({len(devices)}) must match "
                             f"streams ({streams})")
        self.streams = streams
        if devices is None:
            devices = [None] * streams
            if streams > 1:
                # one stream per device when the host has them; on a
                # single-device host streams stay thread-only (no
                # default_device overhead on every launch).
                import jax
                devs = jax.devices()
                if len(devs) > 1:
                    devices = [devs[i % len(devs)] for i in range(streams)]
        self._devices = list(devices)
        self._cond = threading.Condition()
        self._futures: Dict[Tuple[str, int],
                            concurrent.futures.Future] = {}
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._draining = True
        self._error: Optional[BaseException] = None
        self._quarantined: set = set()
        self._quarantine_reasons: Dict[str, str] = {}
        self._fail_streak: Dict[str, int] = {}
        # multi-stream state (all no-ops at streams=1): per-stream ticket
        # queues, estimated-backlog accounting for the JSW assignment,
        # failure streaks and the stream quarantine set.
        self._tickets: List[collections.deque] = \
            [collections.deque() for _ in range(streams)]
        self._stream_load = [0.0] * streams
        self._stream_streak = [0] * streams
        self._stream_quarantined: set = set()
        self._stream_inflight = 0
        self._workers_stop = False
        self.stats = {"launches": 0, "rejected": 0, "launch_failures": 0,
                      "retries": 0, "fallbacks": 0, "quarantined": [],
                      "by_model": {},
                      "integrity": {"detected": 0, "recovered": 0,
                                    "recovery_failed": 0,
                                    "recovery_s": []},
                      "scrub": {"cycles": 0, "checked": 0, "detected": 0,
                                "recovered": 0, "deferred": 0,
                                "errors": 0},
                      "streams": [{"launches": 0, "launch_failures": 0,
                                   "busy_s": 0.0, "quarantined": False,
                                   "last_launch_s": None,
                                   "inflight": False, "stalled": False}
                                  for _ in range(streams)]}

    def _model_stats(self, model_id: str) -> dict:
        # lazy: models may be registered through self.register OR straight
        # through the registry (documented as legal while running).
        return self.stats["by_model"].setdefault(
            model_id, {"requests": 0, "launches": 0, "rejected": 0,
                       "launch_failures": 0, "retries": 0, "fallbacks": 0,
                       "quarantined": False})

    # ---------------------------------------------------------- lifecycle

    def start(self) -> "ServingFrontend":
        with self._cond:
            if self._running:
                return self
            if self._thread is not None and self._thread.is_alive():
                raise RuntimeError("previous dispatch thread is still "
                                   "draining; close() it first")
            self._running = True
            self._thread = threading.Thread(
                target=self._loop, name="serving-frontend", daemon=True)
            self._thread.start()
            if self.scrub_interval_s is not None and \
                    self._scrub_thread is None:
                self._scrub_stop = threading.Event()
                self._scrub_thread = threading.Thread(
                    target=self._scrub_loop, name="serving-scrubber",
                    daemon=True)
                self._scrub_thread.start()
        return self

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = 30.0) -> None:
        """Stop the driver.  ``drain=True`` (default) serves everything
        still queued before the thread exits; ``drain=False`` cancels the
        outstanding futures instead.  Raises ``RuntimeError`` if the
        dispatch thread is still draining after ``timeout`` — the caller
        must retry (idempotent) rather than believe the stream stopped;
        futures are only cancelled once the thread is provably dead."""
        scrubber = self._scrub_thread
        if scrubber is not None:
            self._scrub_stop.set()
            scrubber.join(timeout)
            if not scrubber.is_alive():
                self._scrub_thread = None
        with self._cond:
            self._draining = drain
            if self._running:
                self._running = False
                self._cond.notify_all()
        thread = self._thread
        if thread is not None:
            thread.join(timeout)
            if thread.is_alive():
                raise RuntimeError(
                    f"dispatch thread still draining after {timeout} s; "
                    "retry close() (or close(drain=False))")
            self._thread = None
        if not drain:
            with self._cond:
                for fut in self._futures.values():
                    fut.cancel()
                self._futures.clear()

    def __enter__(self) -> "ServingFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # ------------------------------------------------------------- intake

    def register(self, model_id: str, plan: ServableProgram, *,
                 tier=None,
                 max_delay: Optional[float] = None,
                 max_bucket: Optional[int] = None,
                 max_queued_rows: Optional[int] = None,
                 service_times: Optional[Dict[int, float]] = None,
                 integrity=None) -> MicroBatcher:
        batcher = self.registry.register(model_id, plan, tier=tier,
                                         max_delay=max_delay,
                                         max_bucket=max_bucket,
                                         max_queued_rows=max_queued_rows,
                                         service_times=service_times,
                                         integrity=integrity)
        self._model_stats(model_id)
        with self._cond:
            # a fresh registration under a quarantined id is a new model
            # (the old one was unregistered): it serves, not auto-rejects
            self._quarantined.discard(model_id)
            self._quarantine_reasons.pop(model_id, None)
            self._cond.notify_all()
        return batcher

    def register_pack(self, model_id: str, pack, *,
                      plan_kwargs: Optional[dict] = None,
                      wrap: Optional[Callable] = None,
                      **reg_kwargs) -> MicroBatcher:
        """Compressed-tier registration (see
        :meth:`ModelRegistry.register_pack`): the model stays in its
        entropy-coded cold form until first traffic.  ``integrity=``
        wraps the cache handle in a GuardedPlan; together with the cold
        tier this enables the recovery rung — detected corruption
        re-decodes from the verified compressed copy instead of
        quarantining."""
        batcher = self.registry.register_pack(
            model_id, pack, plan_kwargs=plan_kwargs, wrap=wrap,
            **reg_kwargs)
        self._model_stats(model_id)
        with self._cond:
            self._quarantined.discard(model_id)
            self._quarantine_reasons.pop(model_id, None)
            self._cond.notify_all()
        return batcher

    def unregister(self, model_id: str, *,
                   cause: Optional[BaseException] = None) -> None:
        """Retire a model: its queue is dropped, every outstanding future
        resolves promptly with a typed cause (default
        ``Rejected("unregistered")``), and every plan-side cache —
        registry entry, pack-cache tiers, plan/operand memos — is
        released.  New submits raise ``KeyError`` (unknown model).
        Raises ``KeyError`` if the model was never registered."""
        if cause is None:
            cause = Rejected(REJECT_UNREGISTERED,
                             "model was unregistered while the request "
                             "was outstanding", model_id=model_id)
        self.registry.unregister(model_id)
        with self._cond:
            self._fail_streak.pop(model_id, None)
            for key in [k for k in self._futures if k[0] == model_id]:
                fut = self._futures.pop(key)
                if not fut.cancelled():
                    fut.set_exception(cause)
            self._cond.notify_all()

    def submit(self, model_id: str, x) -> concurrent.futures.Future:
        """Queue one request from any thread; resolves to a
        :class:`Served` when its bucket has run.

        Overload/fault outcomes resolve the returned future with a typed
        :class:`~.slo.Rejected` (reason ``queue_full`` / ``deadline`` /
        ``quarantined``) instead of raising here or hanging — callers
        that ``await``/``result()`` uniformly see every outcome.  Invalid
        requests (bad shape, unknown model) still raise synchronously:
        those are caller bugs, not load conditions."""
        fut: concurrent.futures.Future = concurrent.futures.Future()
        with self._cond:
            if self._error is not None:
                raise RuntimeError(
                    "frontend dispatch thread died") from self._error
            # quarantine check precedes the registry lookup: a
            # quarantined model is *unregistered* (lifecycle fix) yet
            # must keep rejecting with the typed reason, not "unknown
            # model"; doing the lookup under the lock also means a
            # racing unregister either sees this request in the queue
            # (and fails its future with the typed cause) or this
            # submit sees the model already gone (KeyError) — a future
            # can never be left dangling between the two.
            if model_id in self._quarantined:
                self.stats["rejected"] += 1
                self._model_stats(model_id)["rejected"] += 1
                reason = self._quarantine_reasons.get(
                    model_id, REJECT_QUARANTINED)
                detail = ("model weights failed integrity verification "
                          "and could not be recovered from the cold tier"
                          if reason == REJECT_CORRUPTED else
                          "model is quarantined after repeated launch "
                          "failures")
                fut.set_exception(Rejected(reason, detail,
                                           model_id=model_id))
                return fut
            batcher = self.registry.batcher(model_id)
            if not self._running:
                raise RuntimeError("frontend is not running (use "
                                   "`with frontend:` or call start())")
            try:
                rid = batcher.submit(x, now=self.clock())
            except Rejected as rej:
                rej.model_id = model_id
                self.stats["rejected"] += 1
                self._model_stats(model_id)["rejected"] += 1
                fut.set_exception(rej)
                return fut
            self._futures[(model_id, rid)] = fut
            self._model_stats(model_id)["requests"] += 1
            self._cond.notify_all()
        return fut

    async def asubmit(self, model_id: str, x) -> Served:
        """Asyncio face of :meth:`submit`: awaitable from any coroutine,
        driven by the same dispatch thread."""
        return await asyncio.wrap_future(self.submit(model_id, x))

    def serve(self, model_id: str, xs: Sequence,
              timeout: Optional[float] = None) -> List[Served]:
        """Synchronous convenience: submit every request, block until all
        are served, return in submission order.  If a later ``submit``
        raises (bad shape, dead frontend), the earlier futures are
        cancelled before the cause propagates — their queued requests
        would otherwise keep occupying the queue with nobody left to
        collect them."""
        futs: List[concurrent.futures.Future] = []
        try:
            for x in xs:
                futs.append(self.submit(model_id, x))
        except BaseException:
            for f in futs:
                f.cancel()
            raise
        return [f.result(timeout) for f in futs]

    # ----------------------------------------------------------- dispatch

    def _pick(self, now: float) -> Optional[Tuple[str, MicroBatcher]]:
        """The fired batcher with the oldest *tier-weighted* head
        deadline: full tiles fire immediately, partial buckets fire when
        due, and fired candidates are ordered by ``deadline -
        tier.weight`` — with the default (weight-0) tiers this is exactly
        global arrival FIFO (deadline = arrival + max_delay); a
        latency-class tier preempts other models' full tiles by up to its
        ``weight`` seconds of queue age, no more, so bulk tiers age past
        the credit and still win (starvation-free).  Quarantined models
        never launch."""
        best = None
        best_key = None
        for model_id, batcher in self.registry.items():
            if model_id in self._quarantined:
                continue
            deadline = batcher.next_deadline()
            if deadline is None:
                continue
            fired = (deadline <= now
                     or batcher.pending_rows >= batcher.max_bucket)
            if not fired:
                continue
            key = deadline - batcher.tier.weight
            if best_key is None or key < best_key:
                best, best_key = (model_id, batcher), key
        return best

    def _fatal(self, exc: BaseException) -> None:
        """Stream-fatal path (dispatch machinery error, or the ladder is
        disabled): fail everything outstanding loudly, refuse new work."""
        with self._cond:
            self._error = exc
            self._running = False
            self._draining = False      # nothing left worth draining
            self._workers_stop = True
            for fut in self._futures.values():
                if not fut.cancelled():
                    fut.set_exception(exc)
            self._futures.clear()
            self._cond.notify_all()

    def _quarantine(self, model_id: str, batcher: MicroBatcher,
                    exc: BaseException) -> None:
        """Isolate one model: root cause to its outstanding futures, its
        queue dropped, new submits rejected — other models keep serving.
        The model is fully *unregistered* (lifecycle fix: its plan,
        decoded operands and jitted entries used to stay resident for
        the process lifetime); the quarantine flag is marked first so a
        racing submit sees the typed rejection, never "unknown model"."""
        with self._cond:
            self._quarantined.add(model_id)
            if isinstance(exc, IntegrityError):
                self._quarantine_reasons[model_id] = REJECT_CORRUPTED
            self._model_stats(model_id)["quarantined"] = True
            if model_id not in self.stats["quarantined"]:
                self.stats["quarantined"].append(model_id)
        try:
            self.registry.unregister(model_id)
        except KeyError:
            batcher.drop_all()     # already retired elsewhere: just drain
        with self._cond:
            for key in [k for k in self._futures if k[0] == model_id]:
                fut = self._futures.pop(key)
                if not fut.cancelled():
                    fut.set_exception(exc)
            self._cond.notify_all()

    def _degrade(self, model_id: str, batcher: MicroBatcher,
                 exc: Exception) -> None:
        """One failed launch through the ladder: retry (queue is intact —
        the batcher requeued the taken requests) → poison-and-demote the
        failing fused bucket to the per-layer chain → quarantine the
        model.  Raises when the ladder is disabled (stream-fatal, the
        pre-ladder contract)."""
        policy = self.retry_policy
        with self._cond:
            self.stats["launch_failures"] += 1
            ms = self._model_stats(model_id)
            ms["launch_failures"] += 1
            streak = self._fail_streak.get(model_id, 0) + 1
            self._fail_streak[model_id] = streak
        if policy is None:
            raise exc
        if isinstance(exc, IntegrityError) and policy.recover:
            # recovery rung: corruption is not transient — retrying the
            # same poisoned operands cannot succeed, and demoting the
            # bucket would serve corrupt bytes through the chain path.
            # Evict the plan and re-decode from the verified cold tier
            # (bit-identical); quarantine only when the cold copy itself
            # fails.
            with self._cond:
                self.stats["integrity"]["detected"] += 1
            if self._recover(model_id, batcher, exc):
                with self._cond:
                    self._fail_streak[model_id] = 0
                return
            with self._cond:
                self.stats["integrity"]["recovery_failed"] += 1
            if policy.quarantine:
                self._quarantine(model_id, batcher, exc)
                return
            raise exc
        if streak <= policy.max_retries:
            with self._cond:
                self.stats["retries"] += 1
                ms["retries"] += 1
            if policy.backoff_s > 0:
                time.sleep(policy.backoff_s * streak)
            return
        if policy.fallback:
            bucket = batcher.last_failed_bucket
            plan = batcher.plan
            bp = getattr(plan, "buckets", {}).get(bucket)
            if bp is not None and bp.path.startswith("fused") and \
                    hasattr(plan, "demote_bucket"):
                plan.demote_bucket(bucket, reason=f"{type(exc).__name__} "
                                   f"x{streak}")
                with self._cond:
                    self.stats["fallbacks"] += 1
                    ms["fallbacks"] += 1
                    self._fail_streak[model_id] = 0   # fresh rung
                return
        if policy.quarantine:
            self._quarantine(model_id, batcher, exc)
            return
        raise exc

    # ------------------------------------------- integrity: recovery

    def _recover(self, model_id: str, batcher: MicroBatcher,
                 exc: BaseException) -> bool:
        """The recovery rung: evict the poisoned resolved plan and
        re-decode from the cold tier (``decode_pack`` verifies every
        payload and content checksum on the way up; the captured
        ``act_scales`` make the rebuild bit-identical).  The failed
        bucket's requests are already back in the queue (the batcher's
        requeue-on-failure contract), so the next pick re-serves them on
        the fresh operands.  Returns False — quarantine territory — when
        there is no cold tier to recover from (a directly-registered
        plan) or the cold copy fails verification too."""
        cached = next((p for p in unwrap_chain(batcher.plan)
                       if isinstance(p, CachedPlan)), None)
        if cached is None:
            return False
        t0 = time.perf_counter()
        try:
            cached.cache.evict(model_id)
            cached.cache.plan(model_id)     # verified cold-tier re-decode
            guard = next((p for p in unwrap_chain(batcher.plan)
                          if isinstance(p, GuardedPlan)), None)
            if guard is not None:
                guard.verify()              # fresh operands must check out
        except (IntegrityError, KeyError):
            return False
        dt = time.perf_counter() - t0
        with self._cond:
            it = self.stats["integrity"]
            it["recovered"] += 1
            it["recovery_s"].append(dt)
        return True

    # ------------------------------------------- integrity: scrubbing

    def scrub_once(self) -> dict:
        """One scrub pass over every registered model: verify the cold
        tier's payload checksums (cheap, no decode), re-verify resident
        guarded plans against their content checksums, and replay the
        canary probe where the policy arms one.  Detected corruption
        walks the same recover-or-quarantine path as a launch-time
        detection.  Non-resident cache-managed plans are NOT resolved —
        scrubbing never defeats the hot tier's laziness."""
        report = {"checked": 0, "detected": 0, "recovered": 0,
                  "quarantined": []}
        for model_id, batcher in self.registry.items():
            with self._cond:
                if model_id in self._quarantined:
                    continue
            chain = unwrap_chain(batcher.plan)
            guard = next((p for p in chain
                          if isinstance(p, GuardedPlan)), None)
            cached = next((p for p in chain
                           if isinstance(p, CachedPlan)), None)
            try:
                checked = False
                if cached is not None:
                    verify_cold_pack(cached.cache.cold(model_id))
                    checked = True
                if guard is not None and \
                        (cached is None or cached.resident):
                    guard.verify()
                    if guard.policy.canary:
                        guard.check_canary()
                    checked = True
                if checked:
                    report["checked"] += 1
            except KeyError:
                continue            # racing unregister: nothing to scrub
            except IntegrityError as exc:
                report["detected"] += 1
                with self._cond:
                    self.stats["integrity"]["detected"] += 1
                if exc.kind == "cold" or \
                        not self._recover(model_id, batcher, exc):
                    with self._cond:
                        self.stats["integrity"]["recovery_failed"] += 1
                    self._quarantine(model_id, batcher, exc)
                    report["quarantined"].append(model_id)
                else:
                    report["recovered"] += 1
        with self._cond:
            sc = self.stats["scrub"]
            sc["cycles"] += 1
            sc["checked"] += report["checked"]
            sc["detected"] += report["detected"]
            sc["recovered"] += report["recovered"]
        self.check_stalls()
        return report

    def _busy(self) -> bool:
        """Is the engine doing (or about to do) latency-sensitive work?"""
        with self._cond:
            if self._stream_inflight or \
                    any(ss.get("inflight")
                        for ss in self.stats["streams"]):
                return True
        return any(b.pending_rows for _, b in self.registry.items())

    #: consecutive busy cycles the scrubber will skip before scrubbing
    #: anyway — bounds starvation under sustained load to
    #: ``(SCRUB_MAX_DEFERS + 1) * scrub_interval_s``.
    SCRUB_MAX_DEFERS = 20

    def _scrub_loop(self) -> None:
        """Idle-aware cadence: wake every ``scrub_interval_s`` and scrub
        only when the engine is idle at that instant; a busy wake skips
        the whole cycle (bounded — after :data:`SCRUB_MAX_DEFERS`
        consecutive skips a saturated server gets scrubbed anyway).
        Deferring by whole intervals rather than polling in sub-interval
        slices keeps the thread's wakeup rate — and hence its GIL /
        scheduler interference with in-flight launches, which dwarfs the
        actual CRC work — independent of how busy the engine is.  A
        scrub failure is counted, never fatal: the scrubber is an
        auxiliary safety net and must not take the server down."""
        interval = max(float(self.scrub_interval_s), 1e-4)
        deferred = 0
        while not self._scrub_stop.wait(interval):
            if deferred < self.SCRUB_MAX_DEFERS and self._busy():
                deferred += 1
                with self._cond:
                    self.stats["scrub"]["deferred"] += 1
                continue
            deferred = 0
            try:
                self.scrub_once()
            except Exception:       # noqa: BLE001
                with self._cond:
                    self.stats["scrub"]["errors"] += 1

    # ------------------------------------------- launch watchdog

    def check_stalls(self, now: Optional[float] = None) -> List[int]:
        """Flag streams whose launch has been in flight longer than
        ``stall_threshold_s`` (a wedged device blocks its worker thread
        inside the launch — it cannot report on itself, so the scrubber
        / caller polls this).  Returns the stalled stream indices and
        mirrors them in ``stats["streams"][i]["stalled"]``; a stream
        that completes a launch clears its own flag."""
        if self.stall_threshold_s is None:
            return []
        if now is None:
            now = self.clock()
        stalled = []
        with self._cond:
            for i, ss in enumerate(self.stats["streams"]):
                last = ss.get("last_launch_s")
                if ss.get("inflight") and last is not None and \
                        now - last > self.stall_threshold_s:
                    ss["stalled"] = True
                    stalled.append(i)
                else:
                    ss["stalled"] = False
        return stalled

    def _loop(self) -> None:
        try:
            self._loop_inner()
        except BaseException as exc:           # noqa: BLE001
            # an error in the dispatch machinery itself (not a launch —
            # those walk the ladder in _degrade) is fatal for the stream:
            # a silent thread death would leave every future hanging
            # until its caller's timeout with no root cause.
            self._fatal(exc)

    def _loop_inner(self) -> None:
        if self.streams > 1:
            return self._loop_multi()
        while True:
            with self._cond:
                if not self._running:
                    if not self._draining:
                        return
                    pick = next(((m, b) for m, b in self.registry.items()
                                 if b.pending_rows
                                 and m not in self._quarantined), None)
                    if pick is None:
                        return
                else:
                    now = self.clock()
                    pick = self._pick(now)
                    if pick is None:
                        deadline = self.registry.next_deadline()
                        self._cond.wait(
                            None if deadline is None
                            else max(deadline - now, 0.0))
                        continue
            model_id, batcher = pick
            with self._cond:
                ss = self.stats["streams"][0]
                ss["last_launch_s"] = self.clock()   # watchdog heartbeat
                ss["inflight"] = True
            try:
                done, _bucket, _dt = batcher.run_one()
            except Exception as exc:           # noqa: BLE001
                self._degrade(model_id, batcher, exc)
                continue
            finally:
                with self._cond:
                    ss["inflight"] = False
            finish = self.clock()
            with self._cond:
                self._fail_streak.pop(model_id, None)
                self.stats["launches"] += 1
                self._model_stats(model_id)["launches"] += 1
                for c in done:
                    fut = self._futures.pop((model_id, c.rid), None)
                    if fut is not None and not fut.cancelled():
                        fut.set_result(Served(
                            model_id, c.rid, c.y, c.arrival, finish,
                            finish - c.arrival, c.bucket, c.batched_rows))

    # ------------------------------------------- multi-stream dispatch

    def _active_streams(self) -> List[int]:
        return [i for i in range(self.streams)
                if i not in self._stream_quarantined]

    def _assign_stream(self) -> int:
        """Join-shortest-estimated-work: the active stream with the least
        estimated backlog (queued ticket costs + in-flight remainder).
        Caller holds the lock."""
        active = self._active_streams()
        return min(active, key=lambda i: (self._stream_load[i], i))

    def _quarantine_stream(self, idx: int, exc: BaseException) -> None:
        """Isolate one execution stream: its queued tickets reroute to
        healthy streams (nothing is lost — requests go back to their
        batcher queues and re-fire), its worker exits, and dispatch
        never assigns to it again.  Only reachable while another stream
        is active — the last stream walks the model ladder instead."""
        requeued = []
        with self._cond:
            if idx in self._stream_quarantined:
                return
            self._stream_quarantined.add(idx)
            self.stats["streams"][idx]["quarantined"] = True
            self.stats["streams"][idx]["error"] = repr(exc)
            self._stream_load[idx] = 0.0
            while self._tickets[idx]:
                requeued.append(self._tickets[idx].popleft())
            self._cond.notify_all()
        for _model_id, batcher, taken, _est in requeued:
            batcher.requeue(taken)

    def _degrade_stream(self, idx: int, model_id: str,
                        batcher: MicroBatcher, exc: Exception) -> None:
        """The multi-stream failure ladder: the model's retry rung first
        (the requeued requests re-dispatch — often to a different
        stream, which is what separates a poisoned device from a
        poisoned model), then stream quarantine while other streams are
        healthy, then the model's own fallback/quarantine rungs."""
        policy = self.retry_policy
        with self._cond:
            self._stream_streak[idx] += 1
            self.stats["streams"][idx]["launch_failures"] += 1
            stream_streak = self._stream_streak[idx]
            others_active = len(self._active_streams()) > 1
        if policy is not None and policy.quarantine and \
                not isinstance(exc, IntegrityError) and \
                stream_streak > policy.max_retries and others_active:
            # (corrupted weights follow the *model* across streams —
            # an IntegrityError never indicts the stream that ran it,
            # it goes straight to the model's recovery rung)
            self._quarantine_stream(idx, exc)
            with self._cond:
                # fresh ladder for the model on the surviving streams:
                # its failures so far are attributed to the bad stream.
                self._fail_streak.pop(model_id, None)
            return
        self._degrade(model_id, batcher, exc)

    def _worker(self, idx: int) -> None:
        try:
            self._worker_inner(idx)
        except BaseException as exc:          # noqa: BLE001
            self._fatal(exc)

    def _worker_inner(self, idx: int) -> None:
        while True:
            with self._cond:
                while True:
                    if idx in self._stream_quarantined:
                        return
                    if self._tickets[idx] and not (
                            self._workers_stop and not self._draining):
                        model_id, batcher, taken, est = \
                            self._tickets[idx].popleft()
                        self._stream_inflight += 1
                        break
                    if self._workers_stop:
                        return
                    self._cond.wait()
            t0 = time.perf_counter()
            with self._cond:
                ss = self.stats["streams"][idx]
                ss["last_launch_s"] = self.clock()   # watchdog heartbeat
                ss["inflight"] = True
            try:
                done, _bucket, _dt = batcher.execute(
                    taken, device=self._devices[idx])
            except Exception as exc:          # noqa: BLE001
                with self._cond:
                    ss["inflight"] = False
                    self._stream_load[idx] = max(
                        0.0, self._stream_load[idx] - est)
                    self._stream_inflight -= 1
                    self._cond.notify_all()
                self._degrade_stream(idx, model_id, batcher, exc)
                continue
            finish = self.clock()
            dt = time.perf_counter() - t0
            with self._cond:
                ss["inflight"] = False
                self._stream_load[idx] = max(
                    0.0, self._stream_load[idx] - est)
                self._stream_inflight -= 1
                self._stream_streak[idx] = 0
                self._fail_streak.pop(model_id, None)
                self.stats["launches"] += 1
                self._model_stats(model_id)["launches"] += 1
                ss = self.stats["streams"][idx]
                ss["launches"] += 1
                ss["busy_s"] += dt
                for c in done:
                    fut = self._futures.pop((model_id, c.rid), None)
                    if fut is not None and not fut.cancelled():
                        fut.set_result(Served(
                            model_id, c.rid, c.y, c.arrival, finish,
                            finish - c.arrival, c.bucket, c.batched_rows,
                            stream=idx))
                self._cond.notify_all()

    def _loop_multi(self) -> None:
        with self._cond:
            self._workers_stop = False
        workers = [threading.Thread(target=self._worker, args=(i,),
                                    name=f"serving-stream-{i}",
                                    daemon=True)
                   for i in range(self.streams)]
        for w in workers:
            w.start()
        try:
            while True:
                with self._cond:
                    if not self._running:
                        if not self._draining:
                            break
                        pick = next(
                            ((m, b) for m, b in self.registry.items()
                             if b.pending_rows
                             and m not in self._quarantined), None)
                        if pick is None:
                            if self._stream_inflight or any(
                                    self._tickets[i]
                                    for i in self._active_streams()):
                                # a failing launch may requeue during the
                                # drain — re-check for pending rows after
                                # every completion instead of blocking on
                                # an empty-queue forever wait.
                                self._cond.wait(0.05)
                                continue
                            break
                    else:
                        now = self.clock()
                        pick = self._pick(now)
                        if pick is None:
                            deadline = self.registry.next_deadline()
                            self._cond.wait(
                                None if deadline is None
                                else max(deadline - now, 0.0))
                            continue
                model_id, batcher = pick
                taken = batcher.take()
                if taken is None:
                    continue
                est = batcher.admission.launch_estimate(taken.rows)
                if est is None:
                    est = 1e-3      # unmeasured: any small constant ranks
                with self._cond:
                    idx = self._assign_stream()
                    self._tickets[idx].append(
                        (model_id, batcher, taken, est))
                    self._stream_load[idx] += est
                    self._cond.notify_all()
        finally:
            with self._cond:
                self._workers_stop = True
                self._cond.notify_all()
            for w in workers:
                w.join()
