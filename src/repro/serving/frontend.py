"""Async multi-model serving frontend: a real-clock driver over batchers.

The :class:`MicroBatcher` decides *what* to coalesce; until now the repo
only had virtual-clock drivers (``replay``, the benchmarks) around it.
This module is the missing runtime half — the thing that turns the replay
simulator into a runnable server, and the deployment shape FantastIC4
targets: **many small compact MLPs sharing one device** (the paper's §V
units are never idle only if *something* always has a full tile to
launch).

    submit(model_id, x) ──▶ per-model MicroBatcher ──▶ one dispatch
    (any thread / async)     (queue → bucket)          thread, single
                                                       execution stream

Driver loop
-----------

One daemon thread owns the (real, ``time.monotonic``) clock and the
execution stream:

1. **pick** the next launch among batchers whose trigger has fired — a
   *full tile* (pending rows ≥ the largest bucket) launches immediately,
   a *due deadline* (oldest request waited ``max_delay``) launches a
   partial bucket.  Among fired batchers the **oldest head deadline
   wins** (deadline = arrival + ``max_delay``, so this is global FIFO in
   arrival order across models).
2. if nothing fired, **sleep until ``min(next_deadline)``** across all
   registered models — or indefinitely when every queue is empty; any
   ``submit`` notifies the condition variable, so a full tile formed by a
   burst launches without waiting out the deadline.
3. launch via ``MicroBatcher.run_one()`` with the batcher's lock dropped
   around the device round-trip — submits keep landing while the kernel
   runs, and the next pick re-reads the clock, so deadlines that expired
   during compute are served next (the ``pump`` clock fix, satellite of
   the same PR, enforces the same rule inside single-batcher drivers).

Fairness
--------

Oldest-deadline-first *across* models is starvation-free by
construction: a backlogged model's full tiles run while nothing is due
(work conservation), but the moment a trickle model's request ages past
its ``max_delay`` its deadline is the oldest fired trigger and it
preempts further full tiles.  A model under sustained load therefore
bounds another model's extra wait by one bucket's compute, not by the
backlog depth (``tests/test_serving_frontend.py`` pins this).

Clock contract
--------------

The frontend is the *live* driver: batchers it registers run on its
``time.monotonic`` clock, latencies reported in :class:`Served` are wall
time (submit → results scattered), and ``stats["compute_s"]`` equals
``stats["wall_compute_s"]`` (same domain).  Virtual-time experiments
belong to ``serving.replay``, which owns its clock explicitly — the two
drivers never share a batcher.

Sync callers get a ``concurrent.futures.Future`` back from
:meth:`ServingFrontend.submit`; async callers ``await`` the same request
through :meth:`ServingFrontend.asubmit` (the future is wrapped into the
running asyncio loop — the driver thread doubles as the executor, no
event-loop-blocking calls anywhere on the await path).
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .batcher import MicroBatcher
from .plans import ExecutionPlan


@dataclasses.dataclass
class Served:
    """One completed request as the frontend hands it back."""
    model_id: str
    rid: int
    y: "np.ndarray"           # (rows, d_out), host-resident (see batcher)
    arrival: float            # frontend clock at submit
    finish: float             # frontend clock when results scattered
    latency: float            # finish - arrival (wall seconds)
    bucket: int               # rows of the bucket that served it
    batched_rows: int         # real rows sharing the launch


class ModelRegistry:
    """Model id → (:class:`ExecutionPlan`, :class:`MicroBatcher`).

    Every registered batcher shares the registry's clock, so one dispatch
    loop can compare deadlines across models directly.  Registration is
    thread-safe and allowed while a frontend is running (the driver picks
    the new queue up on its next cycle).  Registered batchers default to
    ``keep_results=False``: a frontend consumes completions from
    ``run_one``'s return value, so retaining them for ``result()`` would
    hold every output a long-running server ever produced — pass
    ``keep_results=True`` only for a batcher you drive yourself."""

    def __init__(self, *, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self._lock = threading.Lock()
        self._plans: Dict[str, ExecutionPlan] = {}
        self._batchers: Dict[str, MicroBatcher] = {}

    def register(self, model_id: str, plan: ExecutionPlan, *,
                 max_delay: float = 2e-3,
                 max_bucket: Optional[int] = None,
                 keep_results: bool = False) -> MicroBatcher:
        with self._lock:
            if model_id in self._batchers:
                raise ValueError(f"model {model_id!r} already registered")
            batcher = MicroBatcher(plan, max_delay=max_delay,
                                   max_bucket=max_bucket, clock=self.clock,
                                   keep_results=keep_results)
            self._plans[model_id] = plan
            self._batchers[model_id] = batcher
        return batcher

    def plan(self, model_id: str) -> ExecutionPlan:
        return self._plans[model_id]

    def batcher(self, model_id: str) -> MicroBatcher:
        try:
            return self._batchers[model_id]
        except KeyError:
            raise KeyError(f"model {model_id!r} not registered; have "
                           f"{sorted(self._batchers)}") from None

    def items(self) -> List[Tuple[str, MicroBatcher]]:
        with self._lock:
            return list(self._batchers.items())

    def ids(self) -> List[str]:
        with self._lock:
            return list(self._batchers)

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._batchers

    def __len__(self) -> int:
        return len(self._batchers)

    def next_deadline(self) -> Optional[float]:
        """Earliest queued deadline across every model (None when idle)."""
        deadlines = [d for _, b in self.items()
                     if (d := b.next_deadline()) is not None]
        return min(deadlines) if deadlines else None


class ServingFrontend:
    """See module docstring.  Use as a context manager (starts/stops the
    dispatch thread) or call :meth:`start` / :meth:`close` explicitly."""

    def __init__(self, registry: Optional[ModelRegistry] = None, *,
                 clock: Callable[[], float] = time.monotonic):
        self.registry = registry if registry is not None \
            else ModelRegistry(clock=clock)
        self.clock = self.registry.clock
        self._cond = threading.Condition()
        self._futures: Dict[Tuple[str, int],
                            concurrent.futures.Future] = {}
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._draining = True
        self._error: Optional[BaseException] = None
        self.stats = {"launches": 0, "by_model": {}}

    def _model_stats(self, model_id: str) -> dict:
        # lazy: models may be registered through self.register OR straight
        # through the registry (documented as legal while running).
        return self.stats["by_model"].setdefault(
            model_id, {"requests": 0, "launches": 0})

    # ---------------------------------------------------------- lifecycle

    def start(self) -> "ServingFrontend":
        with self._cond:
            if self._running:
                return self
            if self._thread is not None and self._thread.is_alive():
                raise RuntimeError("previous dispatch thread is still "
                                   "draining; close() it first")
            self._running = True
            self._thread = threading.Thread(
                target=self._loop, name="serving-frontend", daemon=True)
            self._thread.start()
        return self

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = 30.0) -> None:
        """Stop the driver.  ``drain=True`` (default) serves everything
        still queued before the thread exits; ``drain=False`` cancels the
        outstanding futures instead.  Raises ``RuntimeError`` if the
        dispatch thread is still draining after ``timeout`` — the caller
        must retry (idempotent) rather than believe the stream stopped;
        futures are only cancelled once the thread is provably dead."""
        with self._cond:
            self._draining = drain
            if self._running:
                self._running = False
                self._cond.notify_all()
        thread = self._thread
        if thread is not None:
            thread.join(timeout)
            if thread.is_alive():
                raise RuntimeError(
                    f"dispatch thread still draining after {timeout} s; "
                    "retry close() (or close(drain=False))")
            self._thread = None
        if not drain:
            with self._cond:
                for fut in self._futures.values():
                    fut.cancel()
                self._futures.clear()

    def __enter__(self) -> "ServingFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # ------------------------------------------------------------- intake

    def register(self, model_id: str, plan: ExecutionPlan, *,
                 max_delay: float = 2e-3,
                 max_bucket: Optional[int] = None) -> MicroBatcher:
        batcher = self.registry.register(model_id, plan,
                                         max_delay=max_delay,
                                         max_bucket=max_bucket)
        self._model_stats(model_id)
        with self._cond:
            self._cond.notify_all()
        return batcher

    def submit(self, model_id: str, x) -> concurrent.futures.Future:
        """Queue one request from any thread; resolves to a
        :class:`Served` when its bucket has run."""
        batcher = self.registry.batcher(model_id)
        fut: concurrent.futures.Future = concurrent.futures.Future()
        with self._cond:
            if self._error is not None:
                raise RuntimeError(
                    "frontend dispatch thread died") from self._error
            if not self._running:
                raise RuntimeError("frontend is not running (use "
                                   "`with frontend:` or call start())")
            rid = batcher.submit(x, now=self.clock())
            self._futures[(model_id, rid)] = fut
            self._model_stats(model_id)["requests"] += 1
            self._cond.notify_all()
        return fut

    async def asubmit(self, model_id: str, x) -> Served:
        """Asyncio face of :meth:`submit`: awaitable from any coroutine,
        driven by the same dispatch thread."""
        return await asyncio.wrap_future(self.submit(model_id, x))

    def serve(self, model_id: str, xs: Sequence,
              timeout: Optional[float] = None) -> List[Served]:
        """Synchronous convenience: submit every request, block until all
        are served, return in submission order."""
        futs = [self.submit(model_id, x) for x in xs]
        return [f.result(timeout) for f in futs]

    # ----------------------------------------------------------- dispatch

    def _pick(self, now: float) -> Optional[Tuple[str, MicroBatcher]]:
        """The fired batcher with the oldest head deadline: full tiles
        fire immediately, partial buckets fire when due — one total order
        (deadline = arrival + max_delay ⇒ global arrival FIFO)."""
        best = None
        best_deadline = None
        for model_id, batcher in self.registry.items():
            deadline = batcher.next_deadline()
            if deadline is None:
                continue
            fired = (deadline <= now
                     or batcher.pending_rows >= batcher.max_bucket)
            if not fired:
                continue
            if best_deadline is None or deadline < best_deadline:
                best, best_deadline = (model_id, batcher), deadline
        return best

    def _loop(self) -> None:
        while True:
            with self._cond:
                if not self._running:
                    if not self._draining:
                        return
                    pick = next(((m, b) for m, b in self.registry.items()
                                 if b.pending_rows), None)
                    if pick is None:
                        return
                else:
                    now = self.clock()
                    pick = self._pick(now)
                    if pick is None:
                        deadline = self.registry.next_deadline()
                        self._cond.wait(
                            None if deadline is None
                            else max(deadline - now, 0.0))
                        continue
            model_id, batcher = pick
            try:
                done, _bucket, _dt = batcher.run_one()
            except BaseException as exc:       # noqa: BLE001
                # a failed launch (XLA/VMEM/kernel error) is fatal for the
                # stream: a silent thread death would leave every future
                # hanging until its caller's timeout with no root cause.
                # Fail everything outstanding loudly and refuse new work.
                with self._cond:
                    self._error = exc
                    self._running = False
                    for fut in self._futures.values():
                        if not fut.cancelled():
                            fut.set_exception(exc)
                    self._futures.clear()
                return
            finish = self.clock()
            with self._cond:
                self.stats["launches"] += 1
                self._model_stats(model_id)["launches"] += 1
                for c in done:
                    fut = self._futures.pop((model_id, c.rid), None)
                    if fut is not None and not fut.cancelled():
                        fut.set_result(Served(
                            model_id, c.rid, c.y, c.arrival, finish,
                            finish - c.arrival, c.bucket, c.batched_rows))
