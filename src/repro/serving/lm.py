"""LM block programs — serve a 4-bit frozen transformer through the engine.

The serving stack (micro-batcher, frontend, pack cache, integrity guard)
speaks :class:`~repro.serving.plans.ServableProgram`.  This module provides
the second implementation of that protocol after :class:`ExecutionPlan`:
:class:`LMProgram`, a two-phase causal-LM program over a 4-bit frozen
transformer.

Freezing (:func:`freeze_lm`) reuses the EC4T path end to end: every FC-family
projection — attention q/k/v/o *and* the FFN matrices — becomes a packed
``{"packed", "omega"}`` leaf (4 bits/weight in HBM); embeddings, norms,
biases and the lm head stay fp32 per the paper's mixed-precision rule.

The program then resolves **megakernel-backed plans per block** for the FFN,
built from the *same packed codes* the frozen tree holds, so the engine path
and the direct ``generate`` loop multiply bitwise-identical weights:

* ``act == "gelu"``  — one 2-layer fused chain plan per block
  (fc1 + gelu + fc2, biases folded into the §V epilogue).
* ``act == "swiglu"``— three single-layer plans per block (gate / up /
  down).  The GLU halves cannot share a chain plan: each quantized leaf
  carries its *own* 4-centroid ω basis, and a pack layer has exactly one.
  The ``silu(g) * u`` combine runs between plans, exactly mirroring
  :func:`repro.nn.layers.swiglu` in fp32.

Attention stays a dense-math jax path over the frozen leaves (``materialize``
decodes packed q/k/v/o on the fly), jitted once and vmapped over sequences so
every per-request KV cache stays independent.

Two phases, one wire format.  A request row is

    [seq_id, n_tokens, tok_0 .. tok_{n-1}, 0-padding]      (d_in floats)

``n_tokens >= 1`` prefills a new sequence and emits its first token;
``n_tokens == 0`` advances an existing sequence one decode step.  The output
row is ``[token_id]`` (d_out == 1).  seq_id 0 marks bucket padding (output
0.0); unknown/invalid rows answer -1.0 rather than failing the batch.

This shape is what binds the phases to the kernel schedules the paper cares
about: a decode batch reaches the FFN as ``m = n_seqs`` rows (the
weight-stationary sweet spot), while a prefill reaches it as ``m = s`` token
rows (batch-tiled territory).  The plans' measured mode selection does the
rest per bucket.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core import bitplanes, qat
from ..nn import attention as attn
from ..nn.layers import layer_norm, rms_norm, rope_cos_sin
from ..nn.module import FP32_CTX
from . import plans

__all__ = ["freeze_lm", "build_lm_program", "LMProgram"]


def _check_lm_supported(cfg: ArchConfig) -> None:
    """The LM program covers the dense-attention archs; the exotic block
    flavours keep their existing launch paths until they grow programs."""
    if cfg.family != "dense":
        raise ValueError(
            f"LMProgram serves dense-family archs only, got {cfg.family!r} "
            f"({cfg.name})")
    if cfg.mla is not None or cfg.encdec or cfg.global_attn_layers:
        raise ValueError(
            f"LMProgram does not support mla/encdec/mixed-attn archs "
            f"({cfg.name})")
    if cfg.act not in ("swiglu", "gelu"):
        raise ValueError(f"unsupported FFN act {cfg.act!r}")
    if not cfg.quantize:
        raise ValueError(
            "LMProgram serves 4-bit frozen trees; arch has quantize=False")


def freeze_lm(params: Any, qstate: Any, cfg: ArchConfig,
              lam: Optional[float] = None) -> Any:
    """Freeze a trained transformer for serving: every quantized leaf (attn
    q/k/v/o and FFN matrices) becomes a packed 4-bit ``{"packed","omega"}``
    dict; embeddings/norms/biases stay fp32.  Thin, checked wrapper over
    :func:`repro.core.qat.freeze_tree`."""
    _check_lm_supported(cfg)
    return qat.freeze_tree(params, qstate, cfg.lam if lam is None else lam)


def _frozen_codes(leaf: dict) -> Tuple[np.ndarray, np.ndarray]:
    """(K, M) uint8 codes + (4,) omega from a frozen kernel leaf."""
    if not qat.is_frozen_leaf(leaf):
        raise ValueError(
            "expected a frozen {'packed','omega'} leaf — freeze the tree "
            "with freeze_lm() before building an LMProgram")
    codes = np.asarray(bitplanes.unpack_codes_rows(leaf["packed"]))
    return codes, np.asarray(leaf["omega"], np.float32)


def _np_or_none(x) -> Optional[np.ndarray]:
    return None if x is None else np.asarray(x, np.float32)


class LMProgram:
    """ServableProgram serving greedy prefill/decode of a frozen 4-bit LM.

    Stateful: sequences live in the program between requests (seq_id ->
    per-block KV caches + last token).  ``rows_per_request = 1`` — each wire
    row is one whole request, so the micro-batcher's scatter loop maps row i
    of a bucket back to request i with no partial-request splits.
    """

    rows_per_request: int = 1

    def __init__(self, frozen: Any, cfg: ArchConfig, *,
                 max_prompt: int = 64, max_new: int = 64,
                 mode: str = "auto", interpret: Optional[bool] = None,
                 max_bucket: int = 64, block_m: Optional[int] = None):
        _check_lm_supported(cfg)
        if max_prompt < 1 or max_new < 1:
            raise ValueError("max_prompt and max_new must be >= 1")
        if max_prompt > max_bucket:
            raise ValueError(
                f"max_prompt ({max_prompt}) must fit the FFN bucket ceiling "
                f"({max_bucket}): a prefill reaches the FFN as one "
                "s-token row batch")
        self.cfg = cfg
        self.frozen = frozen
        self.max_prompt = int(max_prompt)
        self.max_new = int(max_new)
        self.cache_len = self.max_prompt + self.max_new
        if cfg.window is not None and self.cache_len < cfg.window:
            raise ValueError(
                f"KV cache ({self.cache_len}) shorter than the attention "
                f"window ({cfg.window})")

        # --- ServableProgram surface
        self.d_in = 2 + self.max_prompt
        self.d_out = 1
        sizes, b = [], 1
        while b <= max_bucket:
            sizes.append(b)
            b *= 2
        self.bucket_sizes: Tuple[int, ...] = tuple(sizes)

        # --- per-block frozen params (slice the L-stacked leaves)
        stacks = frozen["stacks"]
        if set(stacks.keys()) != {"dense"}:
            raise ValueError(
                f"expected a pure dense stack, got {sorted(stacks)}")
        self._blocks: List[dict] = [
            jax.tree_util.tree_map(lambda a, _l=l: a[_l], stacks["dense"])
            for l in range(cfg.n_layers)
        ]
        self._table = jnp.asarray(frozen["embed"]["table"], jnp.float32)

        # --- FFN plans per block, built from the frozen leaves' own codes
        self._plan_kw = dict(mode=mode, act_dtype="float32",
                             interpret=interpret, max_bucket=max_bucket,
                             block_m=block_m)
        self._packs: List[dict] = []
        self._plans: List[Dict[str, plans.ExecutionPlan]] = []
        self.layers: List[dict] = []
        for l, blk in enumerate(self._blocks):
            self._plans.append(self._build_block_plans(l, blk["mlp"]))

        # --- per-sequence decode state
        self._states: Dict[int, dict] = {}
        self._next_sid = 1

        # --- jitted, seq-vmapped attention step (params traced: all blocks
        # share the compilation; one compile per (n_seqs, seq_len) shape)
        rotary_dim = int(cfg.resolved_head_dim * cfg.rotary_frac)

        def attn_one(p, h, pos, cache):
            cos_sin = rope_cos_sin(pos, rotary_dim, cfg.rope_theta,
                                   dtype=jnp.float32)
            return attn.gqa_apply(
                p, 0, h, FP32_CTX, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                head_dim=cfg.resolved_head_dim, cos_sin=cos_sin,
                positions=pos, causal=True, window=cfg.window,
                cache=cache, chunk=cfg.attn_chunk)

        self._attn_step = jax.jit(jax.vmap(attn_one,
                                           in_axes=(None, 0, 0, 0)))

    # ------------------------------------------------------------- plans

    def _make_plan(self, label: str, layers: List[dict]
                   ) -> plans.ExecutionPlan:
        pack = {"layers": layers, "name": label}
        self._packs.append(pack)
        self.layers.extend(layers)
        return plans.build_plan(pack, **self._plan_kw)

    def _build_block_plans(self, l: int, mlp: dict
                           ) -> Dict[str, plans.ExecutionPlan]:
        # call-time import: models.mlp itself imports the serving package
        # (either module may be imported first)
        from ..models.mlp import freeze_dense_layer
        if self.cfg.act == "gelu":
            c1, o1 = _frozen_codes(mlp["fc1"]["kernel"])
            c2, o2 = _frozen_codes(mlp["fc2"]["kernel"])
            chain = [
                freeze_dense_layer(c1, o1, activation="gelu",
                                   bias=_np_or_none(mlp["fc1"].get("bias"))),
                freeze_dense_layer(c2, o2, activation=None,
                                   bias=_np_or_none(mlp["fc2"].get("bias"))),
            ]
            return {"chain": self._make_plan(f"blk{l}.mlp", chain)}
        out = {}
        for name in ("gate", "up", "down"):
            codes, omega = _frozen_codes(mlp[name]["kernel"])
            layer = freeze_dense_layer(
                codes, omega, activation=None,
                bias=_np_or_none(mlp[name].get("bias")))
            out[name] = self._make_plan(f"blk{l}.{name}", [layer])
        return out

    def _ffn(self, l: int, h: jax.Array) -> jax.Array:
        pl = self._plans[l]
        if "chain" in pl:
            return pl["chain"].run(h)
        g = pl["gate"].run(h)
        u = pl["up"].run(h)
        inner = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * u
        return pl["down"].run(inner)

    # ------------------------------------------------------------ forward

    def _norm(self, p: dict, x: jax.Array) -> jax.Array:
        return layer_norm(p, x) if self.cfg.norm == "layer" \
            else rms_norm(p, x)

    def _fresh_cache(self) -> dict:
        cfg = self.cfg
        return attn.init_kv_cache(1, self.cache_len, cfg.n_kv,
                                  cfg.resolved_head_dim, jnp.float32)

    def _run(self, tokens: np.ndarray, positions: np.ndarray,
             caches: List[Any]) -> Tuple[np.ndarray, List[Any]]:
        """One forward over ``n`` independent sequences.

        tokens/positions: (n, S) int32; ``caches[l]`` is the block-l KV
        cache with a leading lane axis (each lane a batch-1 cache tree).
        Returns (next_token (n,), new caches).  Matches ``T.lm_apply``'s
        dense block math; the FFN runs through the per-block plans.
        """
        cfg = self.cfg
        n, s = tokens.shape
        tok = jnp.asarray(tokens, jnp.int32)
        pos = jnp.asarray(positions, jnp.int32)
        x = self._table[tok]                                   # (n, S, d)
        new_caches: List[Any] = []
        for l, blk in enumerate(self._blocks):
            h = self._norm(blk["ln1"], x)
            ay, nc = self._attn_step(blk["attn"], h[:, None],
                                     pos[:, None], caches[l])
            x = x + ay[:, 0]
            new_caches.append(nc)
            h2 = self._norm(blk["ln2"], x)
            f = self._ffn(l, h2.reshape(n * s, cfg.d_model))
            x = x + f.reshape(n, s, cfg.d_model).astype(jnp.float32)
        x = self._norm(self.frozen["final_norm"], x)
        last = x[:, -1].astype(jnp.float32)                    # (n, d)
        if cfg.tie_embeddings:
            logits = last @ self._table.T
        else:
            w = self.frozen["lm_head"]["kernel"]
            logits = last @ jnp.asarray(w, jnp.float32)
        nxt = jnp.argmax(logits[:, :cfg.vocab], axis=-1)
        return np.asarray(nxt, np.int64), new_caches

    # ----------------------------------------------------- sequence state

    def _alloc_sid(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        return sid

    def _prefill_seq(self, sid: int, toks: np.ndarray) -> int:
        if sid in self._states:
            raise ValueError(f"seq {sid} already live")
        toks = np.asarray(toks, np.int32).reshape(-1)
        s = toks.shape[0]
        if not 1 <= s <= self.max_prompt:
            raise ValueError(
                f"prompt length {s} outside [1, {self.max_prompt}]")
        stacked = [jax.tree_util.tree_map(lambda a: a[None],
                                          self._fresh_cache())
                   for _ in self._blocks]
        pos = np.arange(s, dtype=np.int32)[None]
        nxt, new_stacked = self._run(toks[None], pos, stacked)
        self._states[sid] = {
            "caches": [jax.tree_util.tree_map(lambda a: a[0], ns)
                       for ns in new_stacked],
            "pos": s,
            "last": int(nxt[0]),
        }
        return int(nxt[0])

    def _decode_batch(self, sids: Sequence[int]) -> List[int]:
        sts = [self._states[s] for s in sids]
        if self.cfg.window is None:
            for sid, st in zip(sids, sts):
                # a wrapped write would overwrite still-visible history
                if st["pos"] >= self.cache_len:
                    raise RuntimeError(
                        f"seq {sid} exhausted its KV cache "
                        f"({self.cache_len} slots); release it")
        n = len(sts)
        n_pad = 1
        while n_pad < n:
            n_pad *= 2
        padded = sts + [sts[0]] * (n_pad - n)   # lanes >= n are discarded
        tokens = np.asarray([[st["last"]] for st in padded], np.int32)
        pos = np.asarray([[st["pos"]] for st in padded], np.int32)
        caches = [
            jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[st["caches"][l] for st in padded])
            for l in range(len(self._blocks))
        ]
        nxt, new_caches = self._run(tokens, pos, caches)
        for i, sid in enumerate(sids):
            st = self._states[sid]
            st["caches"] = [jax.tree_util.tree_map(lambda a, _i=i: a[_i], nc)
                           for nc in new_caches]
            st["pos"] += 1
            st["last"] = int(nxt[i])
        return [self._states[sid]["last"] for sid in sids]

    # ------------------------------------------------------- public API

    def prefill(self, tokens, sid: Optional[int] = None
                ) -> Tuple[int, int]:
        """Start a sequence: ingest the prompt, return (sid, first token)."""
        if sid is None:
            sid = self._alloc_sid()
        first = self._prefill_seq(int(sid), np.asarray(tokens))
        return int(sid), first

    def decode_step(self, sid: int) -> int:
        """Advance one sequence one token (greedy)."""
        if sid not in self._states:
            raise KeyError(f"unknown seq {sid}")
        return self._decode_batch([int(sid)])[0]

    def release(self, sid: int) -> None:
        self._states.pop(int(sid), None)

    @property
    def live_sequences(self) -> int:
        return len(self._states)

    def generate(self, prompts, max_new: int) -> np.ndarray:
        """Direct greedy loop: prefill each row of ``prompts`` (B, S), then
        ``max_new - 1`` batched decode steps.  This drives the exact same
        ``_run`` internals the engine path uses, so engine decode output is
        bit-identical to this loop by construction."""
        prompts = np.asarray(prompts)
        if prompts.ndim != 2:
            raise ValueError("prompts must be (B, S)")
        sids, firsts = [], []
        for b in range(prompts.shape[0]):
            sid, first = self.prefill(prompts[b])
            sids.append(sid)
            firsts.append(first)
        outs = [firsts]
        for _ in range(max_new - 1):
            outs.append(self._decode_batch(sids))
        for sid in sids:
            self.release(sid)
        return np.asarray(outs, np.int64).T         # (B, max_new)

    # ----------------------------------------------- wire-format helpers

    def encode_prefill(self, sid: int, tokens) -> np.ndarray:
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if not 1 <= toks.shape[0] <= self.max_prompt:
            raise ValueError(
                f"prompt length {toks.shape[0]} outside "
                f"[1, {self.max_prompt}]")
        row = np.zeros((self.d_in,), np.float32)
        row[0] = float(sid)
        row[1] = float(toks.shape[0])
        row[2:2 + toks.shape[0]] = toks.astype(np.float32)
        return row

    def encode_decode(self, sid: int) -> np.ndarray:
        row = np.zeros((self.d_in,), np.float32)
        row[0] = float(sid)
        return row

    # -------------------------------------------- ServableProgram entries

    def bucket_for(self, m: int) -> Optional[int]:
        for b in self.bucket_sizes:
            if m <= b:
                return b
        return None

    def entry(self, bucket: int):
        if bucket not in self.bucket_sizes:
            raise ValueError(f"no bucket {bucket}; have {self.bucket_sizes}")

        def run_bucket(xb):
            X = np.asarray(xb, np.float32)
            assert X.shape == (bucket, self.d_in), \
                f"entry({bucket}) got {X.shape}"
            out = np.zeros((bucket, self.d_out), np.float32)
            dec_idx: List[int] = []
            dec_sids: List[int] = []
            for i in range(bucket):
                sid = int(round(float(X[i, 0])))
                if sid <= 0:                       # bucket padding
                    continue
                n_tok = int(round(float(X[i, 1])))
                if n_tok > 0:                      # prefill row
                    toks = np.asarray(
                        np.round(X[i, 2:2 + n_tok]), np.int32)
                    try:
                        out[i, 0] = float(self._prefill_seq(sid, toks))
                    except ValueError:
                        out[i, 0] = -1.0           # don't fail the bucket
                elif sid in self._states:          # decode row
                    dec_idx.append(i)
                    dec_sids.append(sid)
                else:
                    out[i, 0] = -1.0               # unknown sequence
            if dec_sids:
                for i, tok in zip(dec_idx, self._decode_batch(dec_sids)):
                    out[i, 0] = float(tok)
            return jnp.asarray(out)

        return run_bucket

    def run(self, x) -> jax.Array:
        X = np.asarray(x, np.float32)
        m = X.shape[0]
        bucket = self.bucket_for(m)
        if bucket is None:
            raise ValueError(
                f"{m} rows exceeds the largest bucket "
                f"({self.bucket_sizes[-1]})")
        if m < bucket:                 # zero rows are inert padding rows
            X = np.concatenate(
                [X, np.zeros((bucket - m, self.d_in), np.float32)])
        return self.entry(bucket)(X)[:m]

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> "LMProgram":
        """FFN-plan warmup: compile each block plan's entries so the first
        served request doesn't eat the jit cost."""
        for pl in self._plans:
            for p in pl.values():
                p.warmup(buckets)
        return self

    def forget(self) -> None:
        """Drop plan-memo + kernel-operand cache entries for every block
        pack (mirror of ``plans.forget_plan`` for a retiring program)."""
        for pack in self._packs:
            plans.forget_plan(pack)

    def describe(self) -> dict:
        rep = self._plans[0]["chain" if self.cfg.act == "gelu" else "down"]
        decode_b = self.bucket_sizes[0]
        prefill_b = self.bucket_for(self.max_prompt) or self.bucket_sizes[-1]
        return {
            "program": "lm",
            "arch": self.cfg.name,
            "blocks": len(self._blocks),
            "ffn": ("fused gelu chain (1 plan/block)"
                    if self.cfg.act == "gelu"
                    else "swiglu split (gate/up/down plans/block)"),
            "wire": ("row = [seq_id, n_tokens, tok...]; n_tokens>0 "
                     "prefill, 0 decode; out = [token_id]"),
            "rows_per_request": self.rows_per_request,
            "d_in": self.d_in,
            "d_out": self.d_out,
            "bucket_sizes": list(self.bucket_sizes),
            "kv_cache": {"slots": self.cache_len,
                         "window": self.cfg.window},
            "live_sequences": self.live_sequences,
            "ffn_schedules": {
                "decode(m=n_seqs)": rep.schedule_for(decode_b),
                f"prefill(m<={self.max_prompt})":
                    rep.schedule_for(prefill_b),
            },
            "block0_plans": {k: p.describe()["resolved_mode"]
                             for k, p in self._plans[0].items()},
        }


def build_lm_program(params: Any, qstate: Any, cfg: ArchConfig,
                     lam: Optional[float] = None, **kwargs) -> LMProgram:
    """Freeze + wrap in one call (the common launch path)."""
    return LMProgram(freeze_lm(params, qstate, cfg, lam), cfg, **kwargs)
