"""SLO tiers, typed rejection, and cost-model admission control.

The frontend (PR 5) is starvation-free but treats every model the same:
one global ``max_delay``, unbounded queues, accept-everything intake.
That is the opposite of the always-on edge-multi-tenant deployment
FantastIC4 §V targets — a box serving many compact MLPs has *classes* of
traffic (interactive keyword spotting next to bulk scoring), and under
overload it must degrade **measurably, never silently**.  This module is
the policy half of that robustness layer:

* :class:`SLOTier` — a latency class: the batching budget (``max_delay``,
  how long a partial bucket may wait for coalescing), the end-to-end
  deadline budget (``deadline``, the SLO a request must complete within
  counted from arrival), and a bounded dispatch-priority ``weight`` the
  frontend's tier-weighted oldest-deadline pick uses (see
  ``frontend._pick``: a latency-tier deadline preempts throughput-tier
  full tiles, but only by ``weight`` seconds — a throughput request older
  than that still wins, so no tier can starve another).
* :class:`Rejected` — the typed outcome of admission control.  A shed or
  rejected request resolves its future **with this exception**, carrying
  the machine-readable reason — never a hang, never a silent drop.
* :class:`AdmissionController` — the cost model.  The FPGA latency-model
  idiom (SNIPPETS.md §2) applied to serving: predict whether an offered
  request fits *before* accepting it, from the plan's measured per-bucket
  service times (a seeded table from the autotune/benchmark sweep, kept
  current by a running EWMA of live launches).  A request whose predicted
  completion provably exceeds its tier's deadline is shed at submit time,
  while the queue slot it would have wasted serves traffic that can still
  make its SLO.

The mechanics (bounded queues, requeue-on-failure, retry/fallback/
quarantine) live in ``batcher``/``frontend``; everything here is pure
policy and host-side arithmetic — no JAX, no clocks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

#: reasons a request can be rejected with (machine-readable contract)
REJECT_QUEUE_FULL = "queue_full"        # bounded queue at capacity
REJECT_DEADLINE = "deadline"            # cost model: SLO provably missed
REJECT_QUARANTINED = "quarantined"      # model isolated after faults
REJECT_UNREGISTERED = "unregistered"    # model removed while request queued
REJECT_CORRUPTED = "corrupted"          # weights failed integrity checks
                                        # and cold-tier recovery


class Rejected(RuntimeError):
    """A request the serving stack refused to take (or had to drop).

    Admission control *resolves the future* with this exception — the
    caller always learns promptly, with a typed reason, instead of
    hanging until a timeout.  ``reason`` is one of ``REJECT_QUEUE_FULL``
    / ``REJECT_DEADLINE`` / ``REJECT_QUARANTINED`` /
    ``REJECT_UNREGISTERED``; ``est_wait`` carries the cost model's
    predicted wait for deadline sheds."""

    def __init__(self, reason: str, detail: str = "", *,
                 model_id: Optional[str] = None,
                 est_wait: Optional[float] = None):
        self.reason = reason
        self.model_id = model_id
        self.est_wait = est_wait
        msg = f"request rejected ({reason})"
        if model_id:
            msg += f" for model {model_id!r}"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


@dataclasses.dataclass(frozen=True)
class SLOTier:
    """One latency class.  All budgets in seconds.

    ``max_delay``  — coalescing budget: how long the oldest queued request
                     may wait before a partial bucket flushes.
    ``deadline``   — end-to-end SLO counted from arrival; the admission
                     controller sheds a request whose predicted completion
                     exceeds it, and benchmarks report the fraction served
                     within it.
    ``weight``     — dispatch-priority credit: the frontend compares fired
                     batchers by ``head_deadline - weight``, so this tier
                     preempts others' full tiles by up to ``weight``
                     seconds of queue age — bounded, hence starvation-free.
    """
    name: str
    max_delay: float
    deadline: float
    weight: float = 0.0

    def scaled(self, unit: float) -> "SLOTier":
        """This tier with every budget multiplied by ``unit`` — the
        trace benchmarks derive host-independent tiers from the measured
        top-bucket service time instead of wall-clock constants."""
        return dataclasses.replace(
            self, max_delay=self.max_delay * unit,
            deadline=self.deadline * unit, weight=self.weight * unit)


#: the built-in latency classes.  ``standard`` reproduces the pre-tier
#: default (max_delay 2 ms, no priority credit) so registration without a
#: tier behaves exactly as before; ``latency`` trades batching efficiency
#: for response time and carries a 20 ms preemption credit; ``throughput``
#: batches aggressively and yields priority.
TIERS: Dict[str, SLOTier] = {
    "latency": SLOTier("latency", max_delay=5e-4, deadline=2.5e-2,
                       weight=2e-2),
    "standard": SLOTier("standard", max_delay=2e-3, deadline=1e-1),
    "throughput": SLOTier("throughput", max_delay=8e-3, deadline=4e-1),
}


def resolve_tier(tier) -> SLOTier:
    """``None`` → standard, a name → the built-in, an SLOTier → itself
    (build custom tiers with ``dataclasses.replace`` / ``SLOTier(...)``)."""
    if tier is None:
        return TIERS["standard"]
    if isinstance(tier, SLOTier):
        return tier
    try:
        return TIERS[tier]
    except KeyError:
        raise ValueError(f"unknown SLO tier {tier!r}; have "
                         f"{sorted(TIERS)} (or pass an SLOTier)") from None


class AdmissionController:
    """Per-batcher service cost model: measured per-bucket launch times.

    ``seed`` it with a measured table (the benchmark/autotune sweep's
    per-bucket service times) and/or let :meth:`observe` maintain a
    running EWMA from live launches.  :meth:`wait_estimate` predicts how
    long a newly arriving request would wait until *its* bucket's launch
    completes, assuming the queue ahead of it drains in full-tile
    launches — the work-conserving lower bound, so a rejection is
    conservative: if even the lower bound busts the deadline, the SLO is
    provably unattainable.  With no measurement yet for a needed bucket
    the controller abstains (returns ``None`` → admit): it only sheds
    what it can *prove* it cannot serve.
    """

    def __init__(self, bucket_for: Callable[[int], Optional[int]],
                 max_bucket: int, *,
                 service_times: Optional[Dict[int, float]] = None,
                 alpha: float = 0.25):
        self._bucket_for = bucket_for
        self._max_bucket = max_bucket
        self._alpha = alpha
        self._svc: Dict[int, float] = dict(service_times or {})

    def seed(self, service_times: Dict[int, float]) -> None:
        self._svc.update(
            {int(b): float(t) for b, t in service_times.items()})

    def observe(self, bucket: int, dt: float) -> None:
        """Fold one live launch measurement into the EWMA."""
        old = self._svc.get(bucket)
        self._svc[bucket] = dt if old is None else \
            (1.0 - self._alpha) * old + self._alpha * dt

    def estimate(self, bucket: int) -> Optional[float]:
        return self._svc.get(bucket)

    def launch_estimate(self, rows: int) -> Optional[float]:
        """Predicted service seconds for one launch of ``rows`` rows —
        the multi-stream frontend's join-shortest-estimated-work input.
        Unlike :meth:`admit` this never gates anything, so it may be
        loose: with no measurement for the exact bucket it scales the
        nearest measured bucket linearly by row count (the launch cost
        of these kernels is close to linear in the row tile), and only
        abstains (``None``) when nothing was ever measured."""
        bucket = self._bucket_for(rows) or rows
        est = self._svc.get(bucket)
        if est is not None:
            return est
        if not self._svc:
            return None
        nearest = min(self._svc, key=lambda b: abs(b - bucket))
        return self._svc[nearest] * (bucket / max(nearest, 1))

    def service_times(self) -> Dict[int, float]:
        return dict(self._svc)

    def wait_estimate(self, queued_rows: int,
                      new_rows: int) -> Optional[float]:
        """Predicted seconds until a ``new_rows``-row request admitted
        behind ``queued_rows`` queued rows completes (lower bound)."""
        total = queued_rows + new_rows
        full, rem = divmod(total, self._max_bucket)
        t = 0.0
        if full:
            top = self._bucket_for(self._max_bucket) or self._max_bucket
            svc = self._svc.get(top)
            if svc is None:
                return None
            t += full * svc
        if rem:
            b = self._bucket_for(rem)
            svc = self._svc.get(b) if b is not None else None
            if svc is None:
                return None
            t += svc
        return t

    def admit(self, queued_rows: int, new_rows: int,
              tier: SLOTier) -> None:
        """Raise :class:`Rejected` when the cost model proves the request
        cannot complete within ``tier.deadline``; otherwise return."""
        est = self.wait_estimate(queued_rows, new_rows)
        if est is not None and est > tier.deadline:
            raise Rejected(
                REJECT_DEADLINE,
                f"predicted wait {est * 1e3:.2f} ms exceeds tier "
                f"{tier.name!r} deadline {tier.deadline * 1e3:.2f} ms "
                f"({queued_rows} rows queued)",
                est_wait=est)
