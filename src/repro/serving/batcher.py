"""Request queue + tile-bucketed micro-batcher over a ServableProgram.

The batcher depends only on the :class:`~repro.serving.plans.ServableProgram`
surface — ``d_in``, ``bucket_sizes``, ``bucket_for``, ``entry``, ``run``,
plus the optional ``rows_per_request`` contract — so an
:class:`~repro.serving.plans.ExecutionPlan`, an LM prefill/decode program
(``serving.lm``) or any proxy around either slots in unchanged.

FantastIC4's throughput story (§V: 2.45 TOPS on the GSC MLPs) assumes the
execution units always see full row tiles; a serving frontend that launches
the megakernel once per arriving request feeds it mostly padding.  The
:class:`MicroBatcher` closes that gap — continuous batching at MLP scale:

    requests ──▶ FIFO queue ──▶ coalesce into the plan's power-of-two
    (ragged)                    row buckets (pad the remainder) ──▶ one
                                bucket entry launch ──▶ scatter rows back
                                per request

Three flush triggers:

* **full tile** — the queue holds enough rows for the largest bucket:
  flush immediately (the megakernel sees a full ``block_m`` tile).
* **deadline** — the oldest queued request has waited ``max_delay``:
  flush a partial bucket rather than hold latency hostage to arrival rate.
* **explicit** — ``flush()`` / ``run_one(force=True)`` drains regardless
  (used by work-conserving drivers that flush whenever the engine is
  idle, and at shutdown).

Requests keep their rows contiguous (a multi-row request is never split
across buckets) and results are scattered back by request id.  Because
every row's output depends only on its own input row, a request served
from a padded/coalesced bucket is bit-identical to the same request served
alone through the same bucket entry — the padding-parity contract
``tests/test_serving_engine.py`` enforces.

Clock contract
--------------

The batcher is clock-agnostic: every method takes an explicit ``now`` (or
falls back to ``self.clock``), so tests and the ragged-arrival benchmark
can drive it on a virtual clock while the kernel launches run for real.
Two clock domains therefore exist and the stats keep them apart:

* ``stats["wall_compute_s"]`` — always the **live** ``perf_counter``
  measurement of the blocking device round-trips, whatever clock drives
  the trigger logic.  This is the number a host-load investigation wants.
* ``stats["compute_s"]`` — compute time in the **batcher's clock
  domain**.  With the default live clock the two are the same
  measurement.  When the caller injects a virtual clock (``clock=`` a
  fake, or ``clock=None`` for drivers like :func:`replay` that pass an
  explicit ``now`` everywhere), the batcher cannot know the virtual cost
  of a launch — the driver does — so ``run_one`` leaves ``compute_s``
  alone and the driver accounts its virtual service time via
  :meth:`MicroBatcher.account_compute`.  Mixing the two domains (the
  pre-fix behavior: live seconds accumulated under a virtual makespan)
  made ``compute_s / makespan`` utilization nonsense.

``pump(now=None)`` re-reads the clock on **every** loop iteration: a
deadline that expires while a long bucket blocks on compute is flushed by
the same pump instead of overshooting ``max_delay`` until the next driver
cycle.  An explicit ``now`` is evaluated exactly once (the virtual-clock
replay path decides time itself).

All mutating entry points are serialized by an internal lock, so a
threaded driver (``serving.frontend``) may ``submit`` from many threads
while one dispatch thread pumps; the lock is *released* around the
blocking device round-trip so intake never stalls behind compute.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .slo import (REJECT_QUEUE_FULL, AdmissionController, Rejected,
                  SLOTier, resolve_tier)


@dataclasses.dataclass
class _Pending:
    rid: int
    x: np.ndarray             # (rows, d_in) — host-resident until launch
    rows: int
    arrival: float
    deadline: float


@dataclasses.dataclass
class Completion:
    """One served request: scattered logits + queueing metadata."""
    rid: int
    y: np.ndarray             # (rows, d_out)
    arrival: float
    bucket: int               # rows of the bucket that served it
    batched_rows: int         # real rows sharing the launch


@dataclasses.dataclass
class Taken:
    """One coalesced bucket popped from the queue but not yet launched —
    the handoff unit between a dispatcher that *decides* (which stream
    runs this bucket) and the stream worker that *executes* it.  The
    requests stay host-side numpy until :meth:`MicroBatcher.execute`
    consumes them, so a failed launch can requeue them intact."""
    requests: List[_Pending]
    rows: int


class MicroBatcher:
    """See module docstring.  ``max_bucket`` caps coalescing below the
    plan's largest bucket (``max_bucket=1`` degenerates to naive
    per-request serving — the benchmark baseline).  ``clock=None`` marks
    a fully virtual batcher: every call must pass an explicit ``now`` and
    the driver owns compute accounting (see the clock contract above).

    ``keep_results=False`` is for drivers that consume completions from
    ``run_one``/``pump`` return values (the serving frontend resolves
    futures from them): nothing is retained for :meth:`result`, otherwise
    a long-running server would hold every output it ever produced.

    Overload posture (``serving.slo``): an explicit ``tier`` attaches a
    latency class — ``max_delay`` defaults to the tier's coalescing
    budget and every submit runs the :class:`AdmissionController` cost
    model against the tier's end-to-end deadline (sheds raise
    :class:`Rejected` with reason ``deadline``).  ``max_queued_rows``
    bounds the queue independently of tiers: a submit that would push the
    queued rows past the bound raises :class:`Rejected` with reason
    ``queue_full`` instead of growing memory without limit.  Both
    rejections leave the queue untouched and are counted in ``stats``
    (``rejected_full`` / ``shed_deadline`` / ``rejected_rows``).  Without
    ``tier``/``max_queued_rows`` intake behaves exactly as before
    (admit everything)."""

    def __init__(self, plan, *, max_delay: Optional[float] = None,
                 max_bucket: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = time.monotonic,
                 keep_results: bool = True,
                 tier: Optional[SLOTier] = None,
                 max_queued_rows: Optional[int] = None,
                 service_times: Optional[Dict[int, float]] = None):
        self.plan = plan
        # programs with per-row request state (e.g. one row per decode
        # sequence) fix the row count a request must carry; None = any.
        self.rows_per_request: Optional[int] = getattr(
            plan, "rows_per_request", None)
        self.tier = resolve_tier(tier)
        self.max_delay = self.tier.max_delay if max_delay is None \
            else max_delay
        top = max(plan.bucket_sizes)
        self.max_bucket = min(max_bucket or top, top)
        self.max_queued_rows = max_queued_rows
        self.clock = clock
        # live-domain compute accounting only when trigger time and
        # perf_counter advance together; any injected clock is virtual.
        self._live_clock = clock is time.monotonic
        self._lock = threading.RLock()
        self.keep_results = keep_results
        self._queue: Deque[_Pending] = collections.deque()
        self._queued_rows = 0
        self._inflight: set = set()          # submitted, result not stored
        self._results: Dict[int, Completion] = {}
        self._next_rid = 0
        self._last_failed_bucket: Optional[int] = None
        # the cost model is always maintained (EWMA of live launches, a
        # seeded table from the caller's measured sweep); it *gates*
        # intake only when a tier was explicitly attached — legacy
        # batchers keep the admit-everything contract.
        self.admission = AdmissionController(
            plan.bucket_for, self.max_bucket, service_times=service_times)
        self._admission_gates = tier is not None
        self.stats = {"requests": 0, "rows": 0, "flushes": 0,
                      "flushed_rows": 0, "padded_rows": 0,
                      "bucket_hist": {}, "compute_s": 0.0,
                      "wall_compute_s": 0.0, "rejected_full": 0,
                      "shed_deadline": 0, "rejected_rows": 0,
                      "launch_failures": 0}

    def _now(self, now: Optional[float]) -> float:
        if now is not None:
            return now
        if self.clock is None:
            raise ValueError(
                "virtual batcher (clock=None): pass an explicit now=")
        return self.clock()

    # ------------------------------------------------------------- intake

    def submit(self, x, now: Optional[float] = None) -> int:
        """Queue one request (``(rows, d_in)`` or a single ``(d_in,)``
        row); returns its request id.  Thread-safe.  Raises
        :class:`Rejected` (typed, reason-carrying) when the bounded queue
        is full or the tier's cost model proves the SLO unattainable —
        the queue is left untouched either way."""
        now = self._now(now)
        x = np.asarray(x, np.float32)         # host-side: no XLA dispatch
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] != self.plan.d_in:
            raise ValueError(f"request must be (rows, {self.plan.d_in}), "
                             f"got {x.shape}")
        if self.rows_per_request and x.shape[0] != self.rows_per_request:
            # programs that carry per-row request state pin the row count;
            # admitting a mismatched request would mis-scatter every later
            # request sharing its bucket — fail loudly at intake instead.
            raise ValueError(
                f"program requires exactly {self.rows_per_request} row(s) "
                f"per request (rows_per_request contract), got "
                f"{x.shape[0]}")
        with self._lock:
            rows = x.shape[0]
            if self.max_queued_rows is not None and \
                    self._queued_rows + rows > self.max_queued_rows:
                self.stats["rejected_full"] += 1
                self.stats["rejected_rows"] += rows
                raise Rejected(
                    REJECT_QUEUE_FULL,
                    f"{self._queued_rows} rows queued + {rows} new > "
                    f"bound {self.max_queued_rows}")
            if self._admission_gates:
                try:
                    self.admission.admit(self._queued_rows, rows, self.tier)
                except Rejected:
                    self.stats["shed_deadline"] += 1
                    self.stats["rejected_rows"] += rows
                    raise
            rid = self._next_rid
            self._next_rid += 1
            self._queue.append(_Pending(rid, x, x.shape[0], now,
                                        now + self.max_delay))
            self._queued_rows += x.shape[0]
            self._inflight.add(rid)
            self.stats["requests"] += 1
            self.stats["rows"] += x.shape[0]
        return rid

    @property
    def pending_rows(self) -> int:
        return self._queued_rows

    def next_deadline(self) -> Optional[float]:
        with self._lock:
            return self._queue[0].deadline if self._queue else None

    def oldest_arrival(self) -> Optional[float]:
        with self._lock:
            return self._queue[0].arrival if self._queue else None

    @property
    def last_failed_bucket(self) -> Optional[int]:
        """Bucket rows of the most recent failed launch (degradation
        ladder input: which ``(bucket, schedule)`` entry to poison)."""
        return self._last_failed_bucket

    def drop_all(self) -> List[_Pending]:
        """Empty the queue without serving it (quarantine path): returns
        the dropped requests so the driver can resolve their futures with
        the root cause instead of leaving them hanging."""
        with self._lock:
            dropped = list(self._queue)
            self._queue.clear()
            self._queued_rows = 0
            for p in dropped:
                self._inflight.discard(p.rid)
            return dropped

    # -------------------------------------------------------------- flush

    def _take(self) -> List[_Pending]:
        """Pop whole requests FIFO up to ``max_bucket`` rows (always at
        least one request — an oversized request runs alone at exact
        size rather than being split).  Caller holds the lock."""
        taken: List[_Pending] = []
        rows = 0
        while self._queue:
            nxt = self._queue[0]
            if taken and rows + nxt.rows > self.max_bucket:
                break
            taken.append(self._queue.popleft())
            rows += nxt.rows
            if rows >= self.max_bucket:
                break
        self._queued_rows -= rows
        return taken

    def account_compute(self, dt: float) -> None:
        """Record ``dt`` seconds of compute in the batcher's clock domain.
        Virtual-clock drivers (e.g. :func:`replay` with a service-time
        table) call this with their virtual cost; the live wall time of
        the launch is already in ``stats["wall_compute_s"]``."""
        with self._lock:
            self.stats["compute_s"] += dt

    def take(self, now: Optional[float] = None) -> Optional[Taken]:
        """Pop one coalesced bucket off the queue without launching it
        (``None`` when the queue is empty).  The multi-stream frontend
        separates the two halves of :meth:`run_one`: the dispatch thread
        *takes* (so it can cost the bucket and pick the least-loaded
        stream) and the chosen stream worker *executes*.  A taken bucket
        the caller abandons can be returned via :meth:`requeue`."""
        self._now(now)
        with self._lock:
            taken = self._take()
        if not taken:
            return None
        return Taken(taken, sum(p.rows for p in taken))

    def requeue(self, taken: Taken) -> None:
        """Put a taken-but-never-launched bucket back at the queue head
        (original order, original deadlines) — the dispatcher's undo."""
        with self._lock:
            for p in reversed(taken.requests):
                self._queue.appendleft(p)
            self._queued_rows += taken.rows

    def run_one(self, now: Optional[float] = None
                ) -> Tuple[List[Completion], int, float]:
        """Serve one bucket now (no trigger checks — the caller decided).
        Returns ``(completions, bucket_rows, wall_seconds)``; wall time
        covers the blocking device round-trip for the whole bucket.  The
        lock is dropped around the round-trip so submits stay live.
        """
        t = self.take(now)
        if t is None:
            return [], 0, 0.0
        return self.execute(t)

    def execute(self, t: Taken, *, device=None
                ) -> Tuple[List[Completion], int, float]:
        """Launch one taken bucket (the execution half of
        :meth:`run_one`).  ``device`` routes the launch to a specific
        device — ``jax.default_device`` scoped around the round-trip, so
        per-device streams on a multi-device host each keep their own
        executable and the compute really lands on their device; on the
        single-device interpret host it is a no-op and streams degrade
        to threads sharing the device.  A failed launch requeues the
        taken requests at the queue head, exactly as before the split."""
        taken, rows = t.requests, t.rows
        bucket = self.plan.bucket_for(rows)
        padded = (bucket or rows) - rows
        # coalesce/pad/scatter run host-side in numpy: every distinct
        # (request count, row split) combo would otherwise compile its own
        # tiny concat/pad/slice XLA programs, and under ragged live
        # traffic those combos never stop being new — the bucket entry is
        # the only device program a launch should ever wait on.
        xb = np.concatenate([p.x for p in taken], axis=0) \
            if len(taken) > 1 else taken[0].x
        t0 = time.perf_counter()
        try:
            ctx = jax.default_device(device) if device is not None \
                else contextlib.nullcontext()
            with ctx:
                if bucket is None:
                    y = self.plan.run(jnp.asarray(xb))  # oversized: exact
                    bucket = rows
                else:
                    if padded:
                        xb = np.pad(xb, ((0, padded), (0, 0)))
                    y = self.plan.entry(bucket)(jnp.asarray(xb))
                y = np.asarray(jax.block_until_ready(y))
        except BaseException:
            # a failed launch loses NOTHING: requests are host-side numpy
            # until the kernel consumes them, so put the taken batch back
            # at the head of the queue (original order, original
            # deadlines) and let the driver decide — retry the intact
            # queue, fall back, or quarantine (serving.frontend's
            # degradation ladder).
            with self._lock:
                for p in reversed(taken):
                    self._queue.appendleft(p)
                self._queued_rows += rows
                self.stats["launch_failures"] += 1
                self._last_failed_bucket = bucket if bucket else rows
            raise
        dt = time.perf_counter() - t0
        self.admission.observe(bucket, dt)   # running EWMA cost model

        if y.ndim != 2 or y.shape[0] < rows:
            # a program that returns fewer rows than it was handed would
            # silently mis-scatter the tail requests of the bucket; make
            # the contract violation loud and attributable instead.
            raise RuntimeError(
                f"program returned {getattr(y, 'shape', None)} for a "
                f"{rows}-row bucket (need >= {rows} rows): refusing to "
                "scatter misaligned results")
        out: List[Completion] = []
        off = 0
        with self._lock:
            for p in taken:
                c = Completion(p.rid, y[off:off + p.rows], p.arrival, bucket,
                               rows)
                if self.keep_results:
                    self._results[p.rid] = c
                self._inflight.discard(p.rid)
                out.append(c)
                off += p.rows
            st = self.stats
            st["flushes"] += 1
            st["flushed_rows"] += rows
            st["padded_rows"] += padded
            st["bucket_hist"][bucket] = st["bucket_hist"].get(bucket, 0) + 1
            st["wall_compute_s"] += dt
            if self._live_clock:
                st["compute_s"] += dt
        return out, bucket, dt

    def pump(self, now: Optional[float] = None,
             force: bool = False) -> List[Completion]:
        """Flush every bucket whose trigger has fired (full tile or
        expired deadline; everything when ``force``).

        Without an explicit ``now`` the clock is re-read on every
        iteration: a deadline expiring *during* a bucket's blocking
        compute triggers in the same pump instead of waiting (and
        overshooting ``max_delay``) for the next driver cycle.  An
        explicit ``now`` is honored as-is — virtual-clock drivers decide
        what time it is."""
        reread = now is None
        cur = self._now(now)
        done: List[Completion] = []
        while True:
            with self._lock:
                if not self._queue:
                    break
                full = self._queued_rows >= self.max_bucket
                due = self._queue[0].deadline <= cur
                if not (full or due or force):
                    break
            done.extend(self.run_one(cur)[0])
            if reread:
                cur = self.clock()
        return done

    def flush(self, now: Optional[float] = None) -> List[Completion]:
        return self.pump(now, force=True)

    # ------------------------------------------------------------ results

    def result(self, rid: int) -> Optional[Completion]:
        """Pop a completed request's result.  Returns ``None`` while the
        request is still queued or in flight; raises ``KeyError`` for a
        rid that was never issued or whose result was already consumed —
        previously both cases returned ``None`` indistinguishably from
        "still queued", hiding double-pop bugs in drivers."""
        with self._lock:
            if rid in self._results:
                return self._results.pop(rid)
            if rid in self._inflight:
                return None
            if not (0 <= rid < self._next_rid):
                raise KeyError(f"unknown request id {rid}")
            raise KeyError(f"request {rid}: result already consumed")

    def serve(self, xs: Sequence) -> List[np.ndarray]:
        """Synchronous convenience: submit every request, drain the queue,
        return logits in submission order."""
        rids = [self.submit(x) for x in xs]
        self.flush()
        return [self.result(r).y for r in rids]


def replay(plan, xs: Sequence, arrivals: Sequence[float], *,
           max_delay: float = 2e-3, max_bucket: Optional[int] = None,
           service_times: Optional[Dict[int, float]] = None,
           n_streams: int = 1) -> dict:
    """Replay a ragged arrival trace through the engine, work-conserving:
    an execution stream starts a bucket as soon as it is free and work is
    queued, absorbing every request that arrived by then — continuous
    batching under backlog, immediate dispatch when idle.

    ``arrivals`` are virtual timestamps (e.g. a Poisson process);
    launches run for real on device.  When ``service_times`` maps bucket
    rows → seconds (a pre-calibrated table), the virtual clock advances by
    the table instead of the noisy live measurement — the live run still
    produces (and scatters) every result.  The batcher runs fully
    virtual (``clock=None``): ``stats["compute_s"]`` carries the
    virtual-makespan accounting and ``stats["wall_compute_s"]`` the live
    launches, never mixed.  Returns per-request latencies and throughput
    over the virtual makespan.

    ``n_streams`` replays the same trace against N replicated execution
    streams sharing the one queue (the scale-out frontend's shape): each
    bucket launches on the earliest-free stream.  ``n_streams=1`` is
    bit-for-bit the old single-server simulation, and because streams
    replicate the same plan the scattered results are identical at any
    N — only the virtual timeline changes.  Per-stream launch counts are
    returned as ``stream_launches``.
    """
    if n_streams < 1:
        raise ValueError(f"n_streams must be >= 1, got {n_streams}")
    order = np.argsort(np.asarray(arrivals), kind="stable")
    batcher = MicroBatcher(plan, max_delay=max_delay, max_bucket=max_bucket,
                           clock=None)
    todo = collections.deque(
        (float(arrivals[i]), int(i)) for i in order)
    completions: Dict[int, Completion] = {}
    finish: Dict[int, float] = {}
    rid_to_req: Dict[int, int] = {}
    free = [0.0] * n_streams            # per-stream earliest-free time
    launches = [0] * n_streams
    while todo or batcher.pending_rows:
        if not batcher.pending_rows:
            t_arr, i = todo.popleft()
            rid_to_req[batcher.submit(xs[i], now=t_arr)] = i
        stream = min(range(n_streams), key=free.__getitem__)
        start = max(free[stream], batcher.oldest_arrival())
        # continuous batching: absorb everything that arrived by the time
        # this bucket actually launches.
        while todo and todo[0][0] <= start and \
                batcher.pending_rows < batcher.max_bucket:
            t_arr, i = todo.popleft()
            rid_to_req[batcher.submit(xs[i], now=t_arr)] = i
        done, bucket, dt = batcher.run_one(now=start)
        if service_times is not None:
            dt = service_times.get(bucket, dt)
        batcher.account_compute(dt)
        free[stream] = start + dt
        launches[stream] += 1
        for c in done:
            completions[rid_to_req[c.rid]] = c
            finish[rid_to_req[c.rid]] = free[stream]
    n = len(xs)
    lat = np.asarray([finish[i] - float(arrivals[i]) for i in range(n)])
    makespan = max(max(finish.values()), max(float(a) for a in arrivals))
    return {
        "results": [completions[i].y for i in range(n)],
        "latency_mean_ms": float(lat.mean() * 1e3),
        "latency_p95_ms": float(np.percentile(lat, 95) * 1e3),
        "latency_max_ms": float(lat.max() * 1e3),
        "makespan_s": float(makespan),
        "throughput_rps": n / max(makespan, 1e-12),
        "n_streams": n_streams,
        "stream_launches": launches,
        "stats": batcher.stats,
    }
