"""Execution plans: serve-time dispatch resolved once, at freeze time.

Before this module, every serving entry point re-decided its execution
strategy per call by threading mode keywords (``fused=``, ``int8=``,
``double_buffer=``, ``block_m=``, ``interpret=``) down through
``models/mlp.py`` into ``kernels/ops.py`` — and the launcher, two
benchmarks and the examples each re-implemented the same resolution
slightly differently.  An :class:`ExecutionPlan` captures the whole
decision once per frozen pack:

* **mode** — ``fused`` (megakernel) / ``per_layer`` (chained kernel) /
  ``oracle`` (pure jnp) / ``sharded`` (the column-split multi-device
  program over a ``('data','model')`` mesh — pass ``mesh=``, see
  ``serving.sharded``), with ``auto`` resolving to the fastest
  single-device mode that fits; the VMEM-budget check runs at build
  time, so a stack that cannot fuse is *reported* as ``per_layer``
  instead of silently falling back inside the kernel wrapper on every
  call.
* **activation dtype** — fp32 or the paper's §VI-C int8 inter-layer
  activations; int8 calibration runs once at plan build (a provided calib
  dict, a calibration batch, or a deterministic synthetic batch), never
  per request.
* **block sizes** — the autotuner is consulted once (timed sweep on TPU,
  heuristic in interpret mode) and the tuned ``block_m`` is pinned into
  every entry point.
* **batch buckets** — powers of two up to the tuned ``block_m``.  Each
  bucket resolves to a concrete kernel schedule via **autotuner v2**
  (``kernels.autotune.get_schedule_config``): on a real backend a timed
  sweep over every *eligible* ``(schedule, block_m)`` candidate —
  batch-tiled, double-buffered, weight-stationary, decode-amortized
  streaming — binds the bucket to its *measured* winner; in interpret
  mode a dataflow prior answers (ws for the ≤``WS_BUCKET_ROWS`` latency
  buckets, db where requested and engageable, batch-tiled otherwise,
  stream when the whole stack busts the batch-tiled VMEM budget), since
  timing the interpreter is meaningless.  The measured ws↔batch-tiled
  crossover row count is persisted with the cache and replaces the
  ``WS_BUCKET_ROWS`` constant as the prior once it exists
  (``ws_bucket_rows=0`` opts the ws schedule out entirely; an explicit
  positive value caps its eligibility).  ``entry(b)`` returns a
  shape-stable callable per bucket, so serving a stream of ragged batch
  sizes compiles ``len(buckets)`` programs instead of one per distinct
  size.

The micro-batcher (``serving.batcher``) sits on top: it coalesces queued
requests into these buckets so the execution units always see full row
tiles — the runtime half of the paper's throughput story.
"""
from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..kernels.fantastic4_fused_mlp import (VMEM_BUDGET_BYTES,
                                            fused_mlp_fits,
                                            stream_mlp_fits, ws_mlp_fits)
from ..kernels import autotune
from ..memo import MISS, IdentityMemo

MODES = ("auto", "fused", "per_layer", "oracle", "sharded")
ACT_DTYPES = ("float32", "int8")
# weight-stationary latency prior: one f32 sublane tile — the dataflow-
# motivated *pre-measurement* answer only.  On a real backend the
# per-bucket timed sweep decides, and the measured ws↔batch-tiled
# crossover persisted in the autotune cache replaces this constant as the
# prior from then on (on the CPU-interpret host the per-layer grid steps
# make ws ~2-3x slower at batch 1, which is exactly why the gate must be
# measured, not assumed).  ``ws_bucket_rows=0`` opts the ws schedule out;
# an explicit positive value caps its eligibility.
WS_BUCKET_ROWS = 8
DEFAULT_MAX_BUCKET = 256
_CALIB_BATCH = 64

# bucket path <-> kernel schedule naming (paths predate autotuner v2 and
# are kept stable for describe()/bench labels).
PATH_BY_SCHEDULE = {"ws": "fused_ws", "batch_tiled": "fused",
                    "db": "fused_db", "stream": "fused_stream"}
SCHEDULE_BY_PATH = {v: k for k, v in PATH_BY_SCHEDULE.items()}


@runtime_checkable
class ServableProgram(Protocol):
    """The contract every serving layer programs against.

    A servable program maps ``(rows, d_in)`` float32 batches to
    ``(rows, d_out)`` outputs through a fixed set of row *buckets*, each
    backed by a shape-stable compiled entry point.  The micro-batcher,
    frontend/registry, pack cache, integrity guard and fault injector all
    depend on exactly this surface — :class:`ExecutionPlan` (a frozen MLP
    pack), ``serving.lm.LMProgram`` (a 4-bit transformer's prefill/decode
    engine), and the ``CachedPlan``/``GuardedPlan``/``FaultInjector``
    proxies are interchangeable implementations.

    Required:

    * ``d_in`` / ``d_out`` — the wire width of one request row.  For
      tensor programs these are the feature dims; programs with their own
      request encoding (e.g. the LM program's token rows) document the
      row layout in ``describe()``.
    * ``bucket_sizes`` — ascending row buckets the program compiles for.
    * ``bucket_for(m)`` — smallest bucket holding ``m`` rows (None when
      ``m`` overflows the largest bucket).
    * ``entry(bucket)`` — shape-stable callable for exactly ``bucket``
      rows.
    * ``run(x)`` — pad-to-bucket convenience wrapper around ``entry``.
    * ``describe()`` — a JSON-able report of what will execute.

    Optional, feature-detected via ``getattr``/``hasattr`` (never
    ``isinstance`` on a concrete class — the acceptance contract of the
    serving hot path):

    * ``rows_per_request`` — fixed row count each request must carry
      (programs with per-row request state, e.g. one row per decode
      sequence); absent/None means any row count.
    * ``warmup(buckets=None)`` — precompile entry points.
    * ``demote_bucket(rows, reason=...)`` — degradation rebind.
    * ``buckets`` / ``schedule_for`` / ``mode_label`` — schedule
      reporting surfaces used by benches and the frontend's degradation
      ladder.
    * ``layers`` — the 4-bit pack layer dicts backing the program (CRC
      verification, bit-flip injection, operand-cache release).
    * ``pack`` / ``act_dtype`` / ``act_scales`` — pack-cache plumbing.
    """

    d_in: int
    d_out: int
    bucket_sizes: Tuple[int, ...]

    def bucket_for(self, m: int) -> Optional[int]: ...

    def entry(self, bucket: int) -> Callable: ...

    def run(self, x): ...

    def describe(self) -> dict: ...


def calibrate_act_scales(pack: dict, x_calib: jax.Array) -> dict:
    """Per-layer activation scales from a calibration batch — the paper's
    8-bit-activation FPGA configuration.  alpha2 of layer i becomes the
    re-quantization scale mapping the ReLU output onto the next layer's
    int8 grid; the next layer's alpha1 absorbs the de-quantization."""
    scales = []
    x = x_calib.astype(jnp.float32)
    for layer in pack["layers"]:
        if layer["shape"][0] % 2:
            # odd K: the pack carries one zero code row — mirror it on x
            x = jnp.pad(x, ((0, 0), (0, 1)))
        y = kops.fantastic4_matmul(
            x, layer["packed"], layer["omega"], bias=layer["bias"],
            alpha1=layer["alpha1"], alpha2=None,
            activation=layer["activation"], use_kernel=False)
        s = jnp.maximum(jnp.max(jnp.abs(y)), 1e-6) / 127.0
        scales.append(float(s))
        x = y
    return {"act_scales": scales}


def _default_calib_x(d_in: int, seed: int = 0) -> jax.Array:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(_CALIB_BATCH, d_in)), jnp.float32)


def _pow2_buckets(max_rows: int) -> Tuple[int, ...]:
    out, b = [], 1
    while b <= max_rows:
        out.append(b)
        b *= 2
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """One resolved (bucket rows → kernel schedule) binding."""
    rows: int
    path: str        # "fused[_ws|_db|_stream]" | "per_layer" | "oracle"
    block_m: Optional[int] = None      # per-bucket tuned tile (fused paths)
    source: str = "mode"     # "sweep" | "heuristic" | "migrated" | "mode"


class ExecutionPlan:
    """Frozen-pack serving plan: mode, blocks, calibration and per-bucket
    entry points resolved once.  Build with :func:`build_plan` (or the
    memoizing :func:`get_plan`).  The reference :class:`ServableProgram`
    implementation — a pure tensor program with no per-request state, so
    ``rows_per_request`` stays None (any row count)."""

    rows_per_request: Optional[int] = None

    def __init__(self, pack: dict, *,
                 mode: str = "auto",
                 act_dtype: str = "float32",
                 double_buffer: bool = False,
                 ws_bucket_rows: Optional[int] = None,
                 calib: Optional[dict] = None,
                 calib_x: Optional[jax.Array] = None,
                 interpret: Optional[bool] = None,
                 block_m: Optional[int] = None,
                 max_bucket: int = DEFAULT_MAX_BUCKET,
                 vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                 mesh=None):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if mode == "sharded" and mesh is None:
            raise ValueError("mode='sharded' requires mesh= (build one "
                             "with launch.mesh.fit_mesh)")
        self.mesh = mesh
        if act_dtype not in ACT_DTYPES:
            raise ValueError(
                f"act_dtype must be one of {ACT_DTYPES}, got {act_dtype!r}")
        self.pack = pack
        self.layers = pack["layers"]
        self.shapes = tuple(tuple(l["shape"]) for l in self.layers)
        self.d_in = self.shapes[0][0]
        self.d_out = self.shapes[-1][1]
        self.requested_mode = mode
        self.act_dtype = act_dtype
        self.requested_double_buffer = double_buffer
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self.vmem_budget_bytes = vmem_budget_bytes
        self.notes: List[str] = []
        self._stack_extra = "stack" + "x".join(str(n) for _, n in
                                               self.shapes)
        self._backend_key = "interpret" if self.interpret else \
            jax.default_backend()

        # ws gating: an explicit value is both the eligibility ceiling and
        # the prior (0 = opt out entirely); None leaves eligibility to the
        # VMEM fit and takes the prior from the measured crossover when one
        # exists for this backend, else the WS_BUCKET_ROWS constant.
        self.ws_bucket_rows = ws_bucket_rows
        if ws_bucket_rows is not None:
            self.ws_eligible_rows: Optional[int] = ws_bucket_rows
            self.ws_prior_rows = ws_bucket_rows
            self.ws_prior_source = "explicit"
        elif mode in ("auto", "fused"):
            self.ws_eligible_rows = None
            measured = autotune.get_ws_crossover(
                self.d_in, self.d_out, backend=self._backend_key,
                act_dtype=act_dtype, stack=self._stack_extra)
            if measured is not None:
                self.ws_prior_rows = measured
                self.ws_prior_source = "measured"
            else:
                self.ws_prior_rows = WS_BUCKET_ROWS
                self.ws_prior_source = "constant"
        else:
            self.ws_eligible_rows = 0
            self.ws_prior_rows = 0
            self.ws_prior_source = "mode"

        # ---- int8 calibration: once, at build time
        self.act_scales: Optional[List[float]] = None
        if act_dtype == "int8":
            if calib is not None:
                self.act_scales = list(calib["act_scales"])
            else:
                if calib_x is None:
                    calib_x = _default_calib_x(self.d_in)
                    self.notes.append(
                        "int8 calibration ran on a synthetic batch "
                        f"({_CALIB_BATCH}x{self.d_in}); pass calib=/calib_x= "
                        "for task-realistic scales")
                self.act_scales = list(
                    calibrate_act_scales(pack, calib_x)["act_scales"])

        # ---- sharded: the column-split multi-device program
        # (serving.sharded), built once here — operands device_put under
        # the partition rules, one jitted program per batch shape.
        self._sharded = None
        if mode == "sharded":
            from .sharded import ShardedStack
            self._sharded = ShardedStack(
                pack, mesh, act_dtype=act_dtype,
                act_scales=self.act_scales, interpret=self.interpret)

        # ---- mode resolution: the VMEM-fit decision happens HERE, not
        # per call inside the kernel wrapper, so callers can report the
        # path that will actually execute before running anything.  A
        # stack too big for the batch-tiled (whole-stack-resident)
        # megakernel can still fuse through the layer-streamed schedules
        # (stream/ws hold one layer per grid step).
        self._stack_fits = fused_mlp_fits(
            self.shapes, block_m=block_m or 256,
            budget_bytes=vmem_budget_bytes, act_dtype=act_dtype)
        self._stack_fits_db = fused_mlp_fits(
            self.shapes, block_m=block_m or 256,
            budget_bytes=vmem_budget_bytes, act_dtype=act_dtype,
            double_buffer=True)
        # gate at the minimal (8-row) tile: "some stream configuration
        # serves max_bucket rows" — per-bucket binding then picks (and
        # fit-guards) the actual tile.
        stream_ok = stream_mlp_fits(
            self.shapes, rows=max_bucket, block_m=8,
            budget_bytes=vmem_budget_bytes, act_dtype=act_dtype)
        if mode == "auto":
            mode = "fused" if (self._stack_fits or stream_ok) \
                else "per_layer"
        if mode == "fused" and not self._stack_fits:
            if stream_ok:
                self.notes.append(
                    "stack exceeds the whole-stack (batch-tiled) "
                    f"megakernel VMEM budget ({vmem_budget_bytes} B): "
                    "only the layer-streamed schedules (stream/ws) are "
                    "eligible")
            else:
                self.notes.append(
                    "stack exceeds the fused-megakernel VMEM budget "
                    f"({vmem_budget_bytes} B): resolved to per_layer")
                mode = "per_layer"
        self.resolved_mode = mode

        # ---- blocks: the plan-wide block_m (largest bucket / overflow
        # batches).  On a real backend the consultation must carry a
        # measure closure: answering from the heuristic would persist a
        # non-sweep entry under the real backend's cache key and
        # permanently mask the timed sweep (the autotuner's own contract).
        self.block_m = block_m
        self.block_source = "explicit" if block_m is not None else None
        if mode == "fused" and block_m is None:
            if self._stack_fits:
                def _measure(cfg: autotune.BlockConfig) -> float:
                    xm = jnp.zeros((max_bucket, self.d_in), jnp.float32)
                    return kops._timeit(lambda: kops.fantastic4_mlp_fused(
                        xm, self.layers, use_kernel=True,
                        interpret=self.interpret, block_m=cfg.block_m,
                        act_dtype=act_dtype, act_scales=self.act_scales,
                        vmem_budget_bytes=vmem_budget_bytes))

                cfg = autotune.get_block_config(
                    max_bucket, self.d_in, self.d_out,
                    dtype="float32", fused=True,
                    backend="interpret" if self.interpret else None,
                    act_dtype=act_dtype,
                    extra=self._stack_extra,
                    measure=None if self.interpret else _measure)
                self.block_m = cfg.block_m
                self.block_source = cfg.source
            else:
                # batch-tiled ineligible: nothing to sweep at the stack
                # level; per-bucket stream tiles are tuned below.
                self.block_m = autotune.heuristic_blocks(
                    max_bucket, self.d_in, self.d_out, fused=True,
                    backend=self._backend_key).block_m
                self.block_source = "heuristic"

        # ---- buckets: powers of two up to min(block_m, max_bucket),
        # each bound to its own (schedule, block_m) by autotuner v2.
        top = max_bucket
        if mode == "fused" and self.block_m:
            top = min(top, max(self.block_m, 1))
        self.bucket_sizes = _pow2_buckets(max(top, 1))
        self.buckets: Dict[int, BucketPlan] = {}
        self.ws_crossover_rows: Optional[int] = None
        if mode in ("per_layer", "oracle", "sharded"):
            for b in self.bucket_sizes:
                self.buckets[b] = BucketPlan(b, mode)
            self.default_path = mode
        else:
            for b in self.bucket_sizes:
                self.buckets[b] = self._bind_bucket(b, max_bucket)
            # overflow batches (past the largest bucket) run at exact size:
            # batch-tiled (double-buffered when requested and it fits) or
            # the per-layer chain when the whole stack can't reside.
            if self._stack_fits_db and double_buffer:
                self.default_path = "fused_db"
            elif self._stack_fits:
                self.default_path = "fused"
            else:
                self.default_path = "per_layer"
            ws_won = [b for b, p in self.buckets.items()
                      if p.path == "fused_ws"]
            self.ws_crossover_rows = max(ws_won) if ws_won else 0
            fused_srcs = [p.source for p in self.buckets.values()
                          if p.path.startswith("fused")]
            if (not self.interpret and fused_srcs
                    and self.ws_eligible_rows is None
                    and all(s == "sweep" for s in fused_srcs)):
                # every bucket measured with ws fully eligible: persist
                # the ws<->batch-tiled crossover so future plans (and
                # hosts sharing the cache) start from the measurement,
                # not the constant.  An opt-out/capped plan must NOT
                # record — its "crossover" reflects the caller's
                # restriction, not a measurement.
                autotune.record_ws_crossover(
                    self.ws_crossover_rows, self.d_in, self.d_out,
                    backend=self._backend_key, act_dtype=act_dtype,
                    stack=self._stack_extra)

        if double_buffer:
            if mode != "fused":
                self.notes.append(
                    "double_buffer requested but resolved mode is "
                    f"{mode}: ignored")
            elif not any(p.path == "fused_db" for p in self.buckets.values()):
                if max(self.bucket_sizes) < 16:
                    self.notes.append(
                        "double_buffer requested but no bucket has a "
                        ">=16-row tile: single-buffered schedule everywhere")
                else:
                    self.notes.append(
                        "double_buffer requested but the per-bucket "
                        "schedule sweep bound other schedules everywhere")
        if (mode == "fused" and self.ws_eligible_rows != 0
                and not any(p.path == "fused_ws"
                            for p in self.buckets.values())):
            if not ws_mlp_fits(self.shapes, rows=1,
                               budget_bytes=vmem_budget_bytes,
                               act_dtype=act_dtype):
                self.notes.append(
                    "weight-stationary latency path unavailable (per-layer "
                    "working set exceeds the VMEM budget)")
            elif self.ws_prior_source == "measured":
                self.notes.append(
                    "weight-stationary schedule measured out (crossover "
                    f"{self.ws_prior_rows} rows): other schedules won "
                    "every bucket")

        self._entries: Dict[int, Callable] = {}
        self._oversize_memo: Dict[int, BucketPlan] = {}

    # ------------------------------------------------------------ resolve

    def _eligible_schedules(self, rows: int) -> tuple:
        """Schedules whose VMEM working set fits this bucket, with the ws
        opt-out/ceiling applied — the candidate set the sweep may bind."""
        el = []
        if self._stack_fits:
            el.append("batch_tiled")
            if rows >= 16 and self._stack_fits_db:
                el.append("db")
        if stream_mlp_fits(self.shapes, rows=rows, block_m=8,
                           budget_bytes=self.vmem_budget_bytes,
                           act_dtype=self.act_dtype):
            el.append("stream")
        cap = self.ws_eligible_rows
        if cap != 0 and (cap is None or rows <= cap) and \
                ws_mlp_fits(self.shapes, rows=rows,
                            budget_bytes=self.vmem_budget_bytes,
                            act_dtype=self.act_dtype):
            el.append("ws")
        return tuple(el)

    def _prior_schedule(self, rows: int, eligible: tuple) -> str:
        """Pre-measurement answer: the dataflow-motivated prior (measured
        crossover when the cache has one — see ws_prior_source)."""
        if "ws" in eligible and rows <= self.ws_prior_rows:
            return "ws"
        if "db" in eligible and self.requested_double_buffer:
            return "db"
        if "batch_tiled" in eligible:
            return "batch_tiled"
        return eligible[0]

    def _schedule_fits(self, schedule: str, rows: int, bm: int) -> bool:
        """Does this exact (schedule, block_m) candidate fit VMEM?  The
        sweep must never time a candidate that would silently take the
        per-layer chain fallback inside the kernel wrapper — a chain time
        winning under a fused label is exactly the mislabel the schedule
        bindings exist to prevent."""
        if schedule == "batch_tiled":
            return self._stack_fits
        if schedule == "db":
            return self._stack_fits_db
        if schedule == "ws":
            return ws_mlp_fits(self.shapes, rows=rows,
                               budget_bytes=self.vmem_budget_bytes,
                               act_dtype=self.act_dtype)
        return stream_mlp_fits(self.shapes, rows=rows, block_m=bm,
                               budget_bytes=self.vmem_budget_bytes,
                               act_dtype=self.act_dtype)

    def _schedule_measure(self, rows: int) -> Callable[[str, int], float]:
        xm = jnp.zeros((rows, self.d_in), jnp.float32)

        def measure(schedule: str, bm: int) -> float:
            if not self._schedule_fits(schedule, rows, bm):
                return float("inf")
            return kops._timeit(lambda: kops.fantastic4_mlp_fused(
                xm, self.layers, use_kernel=True, interpret=self.interpret,
                block_m=bm, act_dtype=self.act_dtype,
                act_scales=self.act_scales, schedule=schedule,
                vmem_budget_bytes=self.vmem_budget_bytes))
        return measure

    def _bind_bucket(self, rows: int, max_bucket: int) -> BucketPlan:
        eligible = self._eligible_schedules(rows)
        if not eligible:
            return BucketPlan(rows, "per_layer", source="mode")
        cfg = autotune.get_schedule_config(
            rows, self.d_in, self.d_out,
            schedules=eligible,
            prior=self._prior_schedule(rows, eligible),
            dtype="float32", backend=self._backend_key,
            act_dtype=self.act_dtype, stack=self._stack_extra,
            measure=None if self.interpret else
            self._schedule_measure(rows),
            legacy_m=max_bucket, block_m_hint=self.block_m)
        bm = cfg.block_m
        if cfg.schedule == "stream" and cfg.source != "sweep" and bm:
            # prior/migrated tile was chosen without a fit check: halve
            # until the streaming working set fits, so the binding can
            # never silently execute the chain fallback under its label.
            while bm > 8 and not self._schedule_fits("stream", rows, bm):
                bm //= 2
        return BucketPlan(rows, PATH_BY_SCHEDULE[cfg.schedule],
                          block_m=bm, source=cfg.source)

    def bucket_for(self, m: int) -> Optional[int]:
        """Smallest bucket holding ``m`` rows; None when ``m`` overflows
        the largest bucket (run at exact size via the oversize binding)."""
        for b in self.bucket_sizes:
            if m <= b:
                return b
        return None

    def oversize_binding(self, m: int) -> BucketPlan:
        """Resolved ``(path, block_m)`` for a batch past the largest
        bucket (run at exact size — the fused kernels grid over row
        tiles).  The largest bucket's tuned winner is the closest
        measurement the sweep ever produced for this size class, so
        oversize batches inherit it — fit-guarded at the *actual* row
        count, since the streamed working sets grow with rows.  Routing
        them down a plan-level ``default_path``/``block_m`` instead (the
        pre-fix behavior) executed a schedule no sweep ever bound for
        that size while ``path_for``/``schedule_for``/bench labels
        claimed otherwise."""
        cached = self._oversize_memo.get(m)
        if cached is not None:
            return cached
        bp = self._resolve_oversize(m)
        self._oversize_memo[m] = bp
        return bp

    def _resolve_oversize(self, m: int) -> BucketPlan:
        if self.resolved_mode in ("per_layer", "oracle", "sharded"):
            return BucketPlan(m, self.resolved_mode, source="mode")
        top = self.buckets[max(self.bucket_sizes)]
        if top.path.startswith("fused"):
            sched = SCHEDULE_BY_PATH[top.path]
            bm = top.block_m or self.block_m or 8
            if sched == "stream":
                # the streamed working set scales with block_m: shrink the
                # inherited tile until it fits at m rows before giving up.
                while bm > 8 and not self._schedule_fits(sched, m, bm):
                    bm //= 2
            if self._schedule_fits(sched, m, bm):
                return BucketPlan(m, top.path, block_m=bm,
                                  source=top.source)
        # top bucket's winner does not scale to m rows: the whole-stack
        # schedules (rows-independent fit), then a fit-guarded stream
        # tile, then the per-layer chain — mirroring plan resolution.
        if self.default_path in ("fused", "fused_db") and self._stack_fits:
            return BucketPlan(m, self.default_path, block_m=self.block_m,
                              source="mode")
        bm = self.block_m or 8
        while bm > 8 and not self._schedule_fits("stream", m, bm):
            bm //= 2
        if self._schedule_fits("stream", m, bm):
            return BucketPlan(m, "fused_stream", block_m=bm, source="mode")
        return BucketPlan(m, "per_layer", source="mode")

    def demote_bucket(self, rows: int, *, reason: str = "fault") -> BucketPlan:
        """Graceful-degradation rebind: point one bucket at the per-layer
        chain path.  The serving frontend calls this when a fused
        ``(bucket, schedule)`` entry keeps failing after retries — the
        chain kernels share no schedule (and much less VMEM pressure)
        with the poisoned megakernel entry, so the model keeps serving,
        degraded but correct (chain and megakernel are bit-identical on
        the int8 grid and allclose in fp32 — the parity contract).  The
        jitted entry is dropped so the next launch compiles the fallback;
        the rebind is recorded in ``notes`` and the bucket's ``source``.
        """
        if rows not in self.buckets:
            raise KeyError(f"no bucket of {rows} rows; have "
                           f"{self.bucket_sizes}")
        bp = BucketPlan(rows, "per_layer", source=f"degraded:{reason}")
        self.buckets[rows] = bp
        self._entries.pop(rows, None)
        self.notes.append(
            f"bucket {rows} demoted to per_layer ({reason})")
        return bp

    # ------------------------------------------------------------ execute

    def _execute(self, x: jax.Array, path: str,
                 block_m: Optional[int] = None) -> jax.Array:
        if path == "sharded":
            return self._sharded(x)
        if path == "oracle":
            if self.act_dtype == "int8":
                return kops.fantastic4_mlp_chain_int8(
                    x, self.layers, self.act_scales, use_kernel=False)
            return kops.fantastic4_mlp_chain(x, self.layers,
                                             use_kernel=False)
        if path == "per_layer":
            if self.act_dtype == "int8":
                return kops.fantastic4_mlp_chain_int8(
                    x, self.layers, self.act_scales, use_kernel=True,
                    interpret=self.interpret)
            return kops.fantastic4_mlp_chain(x, self.layers, use_kernel=True,
                                             interpret=self.interpret)
        return kops.fantastic4_mlp_fused(
            x, self.layers, use_kernel=True, interpret=self.interpret,
            block_m=block_m or self.block_m, act_dtype=self.act_dtype,
            act_scales=self.act_scales,
            schedule=SCHEDULE_BY_PATH[path],
            vmem_budget_bytes=self.vmem_budget_bytes)

    def entry(self, bucket: int) -> Callable[[jax.Array], jax.Array]:
        """Shape-stable entry point for one bucket: a callable expecting a
        ``(bucket, d_in)`` input.  Cached per bucket — the underlying
        pallas wrappers are jitted on static shapes, so each bucket
        compiles once and every later call reuses the executable."""
        fn = self._entries.get(bucket)
        if fn is None:
            if bucket not in self.buckets:
                raise KeyError(f"no bucket of {bucket} rows; have "
                               f"{self.bucket_sizes}")
            bp = self.buckets[bucket]

            def fn(xb, _path=bp.path, _bm=bp.block_m, _bucket=bucket):
                assert xb.shape[0] == _bucket, (xb.shape, _bucket)
                return self._execute(xb, _path, block_m=_bm)
            self._entries[bucket] = fn
        return fn

    def run(self, x: jax.Array) -> jax.Array:
        """Serve one batch: pad rows up to the resolved bucket, execute its
        entry, slice the real rows back out.  Batches past the largest
        bucket run at exact size (the megakernel grids over row tiles)."""
        x = x.astype(jnp.float32)
        m = x.shape[0]
        b = self.bucket_for(m)
        if b is None:
            obp = self.oversize_binding(m)
            return self._execute(x, obp.path, block_m=obp.block_m)
        if m < b:
            x = jnp.pad(x, ((0, b - m), (0, 0)))
        return self.entry(b)(x)[:m]

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.run(x)

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> None:
        """Compile (and autotune, on TPU) every bucket entry up front so
        the first real request doesn't pay for it."""
        for b in buckets if buckets is not None else self.bucket_sizes:
            x = jnp.zeros((b, self.d_in), jnp.float32)
            jax.block_until_ready(self.entry(b)(x))

    # ------------------------------------------------------------- report

    def path_for(self, m: int) -> str:
        b = self.bucket_for(m)
        return self.oversize_binding(m).path if b is None \
            else self.buckets[b].path

    def schedule_for(self, m: int) -> str:
        """The kernel schedule that actually executes for ``m`` rows:
        ``"ws" | "batch_tiled" | "db" | "stream"`` on the fused paths,
        else the path name itself (``"per_layer"`` / ``"oracle"``) — the
        label every benchmark row carries."""
        path = self.path_for(m)
        return SCHEDULE_BY_PATH.get(path, path)

    def describe(self) -> dict:
        return {
            "requested_mode": self.requested_mode,
            "resolved_mode": self.resolved_mode,
            "act_dtype": self.act_dtype,
            "block_m": self.block_m,
            "block_source": self.block_source,
            "bucket_sizes": list(self.bucket_sizes),
            "bucket_paths": {b: p.path for b, p in self.buckets.items()},
            "bucket_schedules": {
                b: SCHEDULE_BY_PATH.get(p.path, p.path)
                for b, p in self.buckets.items()},
            "bucket_block_m": {b: p.block_m
                               for b, p in self.buckets.items()},
            "bucket_sources": {b: p.source
                               for b, p in self.buckets.items()},
            "ws_crossover_rows": self.ws_crossover_rows,
            "ws_prior_rows": self.ws_prior_rows,
            "ws_prior_source": self.ws_prior_source,
            "default_path": self.default_path,
            "interpret": self.interpret,
            "sharding": (None if self._sharded is None
                         else self._sharded.describe()),
            # per-layer content digests when the pack was stamped at
            # freeze/decode time (None entries on legacy packs) — lets
            # operators fingerprint exactly which weights are serving
            "layer_crcs": [layer.get("crc")
                           for layer in self.layers],
            "notes": list(self.notes),
        }

    def mode_label(self, m: Optional[int] = None) -> str:
        """Human-readable label of what will actually execute (for ``m``
        rows when given, otherwise the plan as a whole)."""
        names = {"fused": "fused megakernel",
                 "fused_db": "fused megakernel (double-buffered)",
                 "fused_ws": "fused megakernel (weight-stationary)",
                 "fused_stream": "fused megakernel (streaming)",
                 "sharded": "column-sharded multi-device stack",
                 "per_layer": "per-layer kernel",
                 "oracle": "jnp oracle"}
        if m is not None:
            label = names[self.path_for(m)]
        else:
            paths = {p.path for p in self.buckets.values()}
            label = " / ".join(names[p] for p in
                               ("fused_ws", "fused", "fused_db",
                                "fused_stream", "sharded", "per_layer",
                                "oracle")
                               if p in paths)
        if self.act_dtype == "int8":
            label += " [int8 activations]"
        return label


def build_plan(pack: dict, **kwargs) -> ExecutionPlan:
    """Resolve an :class:`ExecutionPlan` for a frozen pack (see the class
    for the knobs).  One call per pack per configuration — use
    :func:`get_plan` from per-request code paths."""
    return ExecutionPlan(pack, **kwargs)


# plan memoization per (pack identity, configuration): request-path callers
# (models.mlp compat wrappers, the launcher) must not re-resolve fits /
# autotune / calibration per call.  Identity keying is safe because frozen
# packs are never mutated in place (see repro.memo).
#
# Lifetime contract (the serving stack is keyed off the pack cache, this
# memo is the *compat-wrapper* path): a plan the ``serving.pack_cache``
# resolves is ADOPTED here pinned (``adopt_plan``), so a compat caller
# hitting ``get_plan`` on the same pack+configuration gets the cache's
# plan instead of silently re-resolving a duplicate (double device
# memory, a cold re-jit on the request path — the pre-fix bug when the
# memo's 32-entry insertion-order eviction dropped an entry a frontend
# still served from).  Eviction/unregistration calls ``forget_plan``,
# which releases the memo entries AND the kernel-level operand caches —
# the memo can never outlive a cache-managed plan.
_PLAN_MEMO = IdentityMemo()


def get_plan(pack: dict, *, calib: Optional[dict] = None,
             **kwargs) -> ExecutionPlan:
    extra = tuple(sorted(kwargs.items()))
    hit = _PLAN_MEMO.get((pack, calib), extra)
    if hit is not MISS:
        return hit
    plan = ExecutionPlan(pack, calib=calib, **kwargs)
    _PLAN_MEMO.put((pack, calib), extra, plan)
    return plan


def adopt_plan(pack: dict, plan: ExecutionPlan, *,
               calib: Optional[dict] = None, **kwargs) -> None:
    """Register an externally-managed (pack-cache) plan under the same
    key ``get_plan(pack, calib=calib, **kwargs)`` would compute, pinned:
    the memo's insertion-order eviction never drops it, so the compat
    path can never resolve a duplicate beside it.  Release is explicit,
    via :func:`forget_plan`."""
    _PLAN_MEMO.put((pack, calib), tuple(sorted(kwargs.items())), plan,
                   pin=True)


def forget_plan(pack: dict) -> None:
    """Release every plan-side cache entry keyed on ``pack``: the plan
    memo entries (pinned or not) and the kernel-level folded-int8 /
    weight-stationary operand memos keyed on the pack's layer list.
    Called by the pack cache on eviction and by
    ``ModelRegistry.unregister`` — without it a retired model's decoded
    operands and jitted entries survive for the process lifetime even
    though no frontend can reach them."""
    _PLAN_MEMO.drop(pack)
    layers = pack.get("layers") if isinstance(pack, dict) else None
    if layers is not None:
        kops.forget_pack_operands(layers)
