"""Execution plans: serve-time dispatch resolved once, at freeze time.

Before this module, every serving entry point re-decided its execution
strategy per call by threading mode keywords (``fused=``, ``int8=``,
``double_buffer=``, ``block_m=``, ``interpret=``) down through
``models/mlp.py`` into ``kernels/ops.py`` — and the launcher, two
benchmarks and the examples each re-implemented the same resolution
slightly differently.  An :class:`ExecutionPlan` captures the whole
decision once per frozen pack:

* **mode** — ``fused`` (megakernel) / ``per_layer`` (chained kernel) /
  ``oracle`` (pure jnp), with ``auto`` resolving to the fastest mode that
  fits; the VMEM-budget check runs at build time, so a stack that cannot
  fuse is *reported* as ``per_layer`` instead of silently falling back
  inside the kernel wrapper on every call.
* **activation dtype** — fp32 or the paper's §VI-C int8 inter-layer
  activations; int8 calibration runs once at plan build (a provided calib
  dict, a calibration batch, or a deterministic synthetic batch), never
  per request.
* **block sizes** — the autotuner is consulted once (timed sweep on TPU,
  heuristic in interpret mode) and the tuned ``block_m`` is pinned into
  every entry point.
* **batch buckets** — powers of two up to the tuned ``block_m``.  Each
  bucket resolves to a concrete kernel schedule: the weight-stationary
  megakernel for the latency bucket (≤ ``ws_bucket_rows`` rows), the
  double-buffered two-row-group variant where it can engage (≥16-row
  tiles, when requested), the plain megakernel otherwise.  ``entry(b)``
  returns a shape-stable callable per bucket, so serving a stream of
  ragged batch sizes compiles ``len(buckets)`` programs instead of one
  per distinct size.

The micro-batcher (``serving.batcher``) sits on top: it coalesces queued
requests into these buckets so the execution units always see full row
tiles — the runtime half of the paper's throughput story.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..kernels.fantastic4_fused_mlp import (VMEM_BUDGET_BYTES,
                                            fused_mlp_fits, ws_mlp_fits)
from ..kernels import autotune
from ..memo import MISS, IdentityMemo

MODES = ("auto", "fused", "per_layer", "oracle")
ACT_DTYPES = ("float32", "int8")
# latency bucket ceiling: one f32 sublane tile — the weight-stationary
# schedule's sweet spot (nothing to stream over the batch dim).  A
# dataflow-motivated constant, not a measured crossover: on the
# CPU-interpret host the per-layer grid steps make ws *slower* than the
# batch-tiled kernel (see ROADMAP); pass ws_bucket_rows=0 to opt out, or
# tune on real hardware.
WS_BUCKET_ROWS = 8
DEFAULT_MAX_BUCKET = 256
_CALIB_BATCH = 64


def calibrate_act_scales(pack: dict, x_calib: jax.Array) -> dict:
    """Per-layer activation scales from a calibration batch — the paper's
    8-bit-activation FPGA configuration.  alpha2 of layer i becomes the
    re-quantization scale mapping the ReLU output onto the next layer's
    int8 grid; the next layer's alpha1 absorbs the de-quantization."""
    scales = []
    x = x_calib.astype(jnp.float32)
    for layer in pack["layers"]:
        if layer["shape"][0] % 2:
            # odd K: the pack carries one zero code row — mirror it on x
            x = jnp.pad(x, ((0, 0), (0, 1)))
        y = kops.fantastic4_matmul(
            x, layer["packed"], layer["omega"], bias=layer["bias"],
            alpha1=layer["alpha1"], alpha2=None,
            activation=layer["activation"], use_kernel=False)
        s = jnp.maximum(jnp.max(jnp.abs(y)), 1e-6) / 127.0
        scales.append(float(s))
        x = y
    return {"act_scales": scales}


def _default_calib_x(d_in: int, seed: int = 0) -> jax.Array:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(_CALIB_BATCH, d_in)), jnp.float32)


def _pow2_buckets(max_rows: int) -> Tuple[int, ...]:
    out, b = [], 1
    while b <= max_rows:
        out.append(b)
        b *= 2
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """One resolved (bucket rows → kernel schedule) binding."""
    rows: int
    path: str        # "fused_ws" | "fused_db" | "fused" | "per_layer" | "oracle"


class ExecutionPlan:
    """Frozen-pack serving plan: mode, blocks, calibration and per-bucket
    entry points resolved once.  Build with :func:`build_plan` (or the
    memoizing :func:`get_plan`)."""

    def __init__(self, pack: dict, *,
                 mode: str = "auto",
                 act_dtype: str = "float32",
                 double_buffer: bool = False,
                 ws_bucket_rows: Optional[int] = None,
                 calib: Optional[dict] = None,
                 calib_x: Optional[jax.Array] = None,
                 interpret: Optional[bool] = None,
                 block_m: Optional[int] = None,
                 max_bucket: int = DEFAULT_MAX_BUCKET,
                 vmem_budget_bytes: int = VMEM_BUDGET_BYTES):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if act_dtype not in ACT_DTYPES:
            raise ValueError(
                f"act_dtype must be one of {ACT_DTYPES}, got {act_dtype!r}")
        self.pack = pack
        self.layers = pack["layers"]
        self.shapes = tuple(tuple(l["shape"]) for l in self.layers)
        self.d_in = self.shapes[0][0]
        self.d_out = self.shapes[-1][1]
        self.requested_mode = mode
        self.act_dtype = act_dtype
        self.requested_double_buffer = double_buffer
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self.vmem_budget_bytes = vmem_budget_bytes
        self.notes: List[str] = []
        if ws_bucket_rows is None:
            ws_bucket_rows = WS_BUCKET_ROWS if mode in ("auto", "fused") \
                else 0
        self.ws_bucket_rows = ws_bucket_rows

        # ---- int8 calibration: once, at build time
        self.act_scales: Optional[List[float]] = None
        if act_dtype == "int8":
            if calib is not None:
                self.act_scales = list(calib["act_scales"])
            else:
                if calib_x is None:
                    calib_x = _default_calib_x(self.d_in)
                    self.notes.append(
                        "int8 calibration ran on a synthetic batch "
                        f"({_CALIB_BATCH}x{self.d_in}); pass calib=/calib_x= "
                        "for task-realistic scales")
                self.act_scales = list(
                    calibrate_act_scales(pack, calib_x)["act_scales"])

        # ---- mode resolution: the VMEM-fit decision happens HERE, not
        # per call inside the kernel wrapper, so callers can report the
        # path that will actually execute before running anything.
        fits = fused_mlp_fits(self.shapes, block_m=block_m or 256,
                              budget_bytes=vmem_budget_bytes,
                              act_dtype=act_dtype,
                              double_buffer=double_buffer)
        if mode == "auto":
            mode = "fused" if fits else "per_layer"
        if mode == "fused" and not fits:
            self.notes.append(
                "stack exceeds the fused-megakernel VMEM budget "
                f"({vmem_budget_bytes} B): resolved to per_layer")
            mode = "per_layer"
        self.resolved_mode = mode

        # ---- blocks: one autotuner consultation, pinned for every entry.
        # On a real backend the consultation must carry a measure closure:
        # answering from the heuristic would persist a non-sweep entry
        # under the real backend's cache key and permanently mask the
        # timed sweep (the autotuner's own contract).
        self.block_m = block_m
        self.block_source = "explicit" if block_m is not None else None
        if mode == "fused" and block_m is None:
            def _measure(cfg: autotune.BlockConfig) -> float:
                xm = jnp.zeros((max_bucket, self.d_in), jnp.float32)
                return kops._timeit(lambda: kops.fantastic4_mlp_fused(
                    xm, self.layers, use_kernel=True,
                    interpret=self.interpret, block_m=cfg.block_m,
                    act_dtype=act_dtype, act_scales=self.act_scales,
                    vmem_budget_bytes=vmem_budget_bytes))

            cfg = autotune.get_block_config(
                max_bucket, self.d_in, self.d_out,
                dtype="float32", fused=True,
                backend="interpret" if self.interpret else None,
                act_dtype=act_dtype,
                extra="stack" + "x".join(str(n) for _, n in self.shapes),
                measure=None if self.interpret else _measure)
            self.block_m = cfg.block_m
            self.block_source = cfg.source

        # ---- buckets: powers of two up to min(block_m, max_bucket)
        top = max_bucket
        if mode == "fused" and self.block_m:
            top = min(top, max(self.block_m, 1))
        self.bucket_sizes = _pow2_buckets(max(top, 1))
        self.buckets: Dict[int, BucketPlan] = {
            b: BucketPlan(b, self._bucket_path(b)) for b in self.bucket_sizes}
        self.default_path = self._bucket_path(max(self.bucket_sizes) * 2)

        if double_buffer:
            if mode != "fused":
                self.notes.append(
                    "double_buffer requested but resolved mode is "
                    f"{mode}: ignored")
            elif not any(p.path == "fused_db" for p in self.buckets.values()):
                self.notes.append(
                    "double_buffer requested but no bucket has a >=16-row "
                    "tile: single-buffered schedule everywhere")
        if self.ws_bucket_rows and mode == "fused" and not any(
                p.path == "fused_ws" for p in self.buckets.values()):
            self.notes.append(
                "weight-stationary latency path unavailable (per-layer "
                "working set exceeds the VMEM budget)")

        self._entries: Dict[int, Callable] = {}

    # ------------------------------------------------------------ resolve

    def _bucket_path(self, rows: int) -> str:
        if self.resolved_mode in ("per_layer", "oracle"):
            return self.resolved_mode
        if (rows <= self.ws_bucket_rows
                and ws_mlp_fits(self.shapes, rows=rows,
                                budget_bytes=self.vmem_budget_bytes,
                                act_dtype=self.act_dtype)):
            return "fused_ws"
        if self.requested_double_buffer and rows >= 16:
            return "fused_db"
        return "fused"

    def bucket_for(self, m: int) -> Optional[int]:
        """Smallest bucket holding ``m`` rows; None when ``m`` overflows
        the largest bucket (run at exact size via the default path)."""
        for b in self.bucket_sizes:
            if m <= b:
                return b
        return None

    # ------------------------------------------------------------ execute

    def _execute(self, x: jax.Array, path: str) -> jax.Array:
        if path == "oracle":
            if self.act_dtype == "int8":
                return kops.fantastic4_mlp_chain_int8(
                    x, self.layers, self.act_scales, use_kernel=False)
            return kops.fantastic4_mlp_chain(x, self.layers,
                                             use_kernel=False)
        if path == "per_layer":
            if self.act_dtype == "int8":
                return kops.fantastic4_mlp_chain_int8(
                    x, self.layers, self.act_scales, use_kernel=True,
                    interpret=self.interpret)
            return kops.fantastic4_mlp_chain(x, self.layers, use_kernel=True,
                                             interpret=self.interpret)
        return kops.fantastic4_mlp_fused(
            x, self.layers, use_kernel=True, interpret=self.interpret,
            block_m=self.block_m, act_dtype=self.act_dtype,
            act_scales=self.act_scales,
            double_buffer=path == "fused_db",
            weight_stationary=path == "fused_ws",
            vmem_budget_bytes=self.vmem_budget_bytes)

    def entry(self, bucket: int) -> Callable[[jax.Array], jax.Array]:
        """Shape-stable entry point for one bucket: a callable expecting a
        ``(bucket, d_in)`` input.  Cached per bucket — the underlying
        pallas wrappers are jitted on static shapes, so each bucket
        compiles once and every later call reuses the executable."""
        fn = self._entries.get(bucket)
        if fn is None:
            if bucket not in self.buckets:
                raise KeyError(f"no bucket of {bucket} rows; have "
                               f"{self.bucket_sizes}")
            path = self.buckets[bucket].path

            def fn(xb, _path=path, _bucket=bucket):
                assert xb.shape[0] == _bucket, (xb.shape, _bucket)
                return self._execute(xb, _path)
            self._entries[bucket] = fn
        return fn

    def run(self, x: jax.Array) -> jax.Array:
        """Serve one batch: pad rows up to the resolved bucket, execute its
        entry, slice the real rows back out.  Batches past the largest
        bucket run at exact size (the megakernel grids over row tiles)."""
        x = x.astype(jnp.float32)
        m = x.shape[0]
        b = self.bucket_for(m)
        if b is None:
            return self._execute(x, self.default_path)
        if m < b:
            x = jnp.pad(x, ((0, b - m), (0, 0)))
        return self.entry(b)(x)[:m]

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.run(x)

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> None:
        """Compile (and autotune, on TPU) every bucket entry up front so
        the first real request doesn't pay for it."""
        for b in buckets if buckets is not None else self.bucket_sizes:
            x = jnp.zeros((b, self.d_in), jnp.float32)
            jax.block_until_ready(self.entry(b)(x))

    # ------------------------------------------------------------- report

    def path_for(self, m: int) -> str:
        b = self.bucket_for(m)
        return self.default_path if b is None else self.buckets[b].path

    def describe(self) -> dict:
        return {
            "requested_mode": self.requested_mode,
            "resolved_mode": self.resolved_mode,
            "act_dtype": self.act_dtype,
            "block_m": self.block_m,
            "block_source": self.block_source,
            "bucket_sizes": list(self.bucket_sizes),
            "bucket_paths": {b: p.path for b, p in self.buckets.items()},
            "default_path": self.default_path,
            "interpret": self.interpret,
            "notes": list(self.notes),
        }

    def mode_label(self, m: Optional[int] = None) -> str:
        """Human-readable label of what will actually execute (for ``m``
        rows when given, otherwise the plan as a whole)."""
        names = {"fused": "fused megakernel",
                 "fused_db": "fused megakernel (double-buffered)",
                 "fused_ws": "fused megakernel (weight-stationary)",
                 "per_layer": "per-layer kernel",
                 "oracle": "jnp oracle"}
        if m is not None:
            label = names[self.path_for(m)]
        else:
            paths = {p.path for p in self.buckets.values()}
            label = " / ".join(names[p] for p in
                               ("fused_ws", "fused", "fused_db",
                                "per_layer", "oracle") if p in paths)
        if self.act_dtype == "int8":
            label += " [int8 activations]"
        return label


def build_plan(pack: dict, **kwargs) -> ExecutionPlan:
    """Resolve an :class:`ExecutionPlan` for a frozen pack (see the class
    for the knobs).  One call per pack per configuration — use
    :func:`get_plan` from per-request code paths."""
    return ExecutionPlan(pack, **kwargs)


# plan memoization per (pack identity, configuration): request-path callers
# (models.mlp compat wrappers, the launcher) must not re-resolve fits /
# autotune / calibration per call.  Identity keying is safe because frozen
# packs are never mutated in place (see repro.memo).
_PLAN_MEMO = IdentityMemo()


def get_plan(pack: dict, *, calib: Optional[dict] = None,
             **kwargs) -> ExecutionPlan:
    extra = tuple(sorted(kwargs.items()))
    hit = _PLAN_MEMO.get((pack, calib), extra)
    if hit is not MISS:
        return hit
    plan = ExecutionPlan(pack, calib=calib, **kwargs)
    _PLAN_MEMO.put((pack, calib), extra, plan)
    return plan
