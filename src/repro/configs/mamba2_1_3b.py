"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD, unverified tier).

48L d_model=2048, attention-free, d_inner=4096 (expand 2), 64 heads of
headdim 64, ssm_state=128, vocab=50280.  O(1)-state decode => runs
long_500k.  The SSD recurrence itself has no weight matmul to quantize;
EC4T covers in/out projections (~90% of params) — DESIGN.md §5.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    tie_embeddings=True,
))
