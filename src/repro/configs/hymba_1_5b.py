"""hymba-1.5b [hybrid] — arXiv:2411.13676.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16;
parallel attention + mamba heads in every layer, outputs mean-combined
after per-branch normalisation.  SWA (1024) everywhere except 3 global
layers (first / middle / last).  Hybrid + SWA => runs long_500k.
(Meta tokens and cross-layer KV sharing from the paper are omitted —
orthogonal to FantastIC4's technique; noted in DESIGN.md.)
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, head_dim=64,
    d_ff=5504, vocab=32001,
    window=1024, global_attn_layers=(0, 15, 31),
    ssm_state=16, ssm_expand=2, ssm_headdim=64,
    rope_theta=10000.0,
))
