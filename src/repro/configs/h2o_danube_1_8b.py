"""h2o-danube-1.8b [dense] — arXiv:2401.16818 (llama+mistral mix, SWA).

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, sliding-window
attention (mistral-style, 4096).  SWA caps the KV cache => runs long_500k.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv=8, head_dim=80,
    d_ff=6912, vocab=32000,
    window=4096, rope_theta=10000.0,
))
