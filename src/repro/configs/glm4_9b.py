"""glm4-9b [dense] — hf:THUDM/glm-4-9b.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552, partial rotary
(50% of head dims), QKV bias.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv=2, head_dim=128,
    d_ff=13696, vocab=151552,
    rotary_frac=0.5, rope_theta=10000.0, qkv_bias=True,
))
