"""deepseek-v3-671b [moe] — arXiv:2412.19437.

61L d_model=7168 128H MLA (q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128), MoE: 1 shared + 256 routed experts top-8, expert d_ff=2048,
sigmoid gate with bias-corrected aux-loss-free routing, routed_scaling=2.5,
first 3 layers dense (d_ff 18432), vocab=129280.

MTP (multi-token prediction) head omitted — orthogonal to the paper's
technique (DESIGN.md §5).  MLA latent cache stays 16-bit (activations are
quantization-sensitive, FantastIC4 fig. 2).
"""
from .base import ArchConfig, MLADims, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv=128, head_dim=128,
    d_ff=2048, vocab=129280,
    mla=MLADims(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                qk_rope_dim=64, v_head_dim=128),
    n_experts=256, top_k=8, moe_gate="sigmoid", n_shared_experts=1,
    n_dense_layers=3, dense_ff=18432, routed_scaling=2.5,
    rope_theta=10000.0,
))
