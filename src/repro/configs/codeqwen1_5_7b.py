"""codeqwen1.5-7b [dense] — hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch).

32L d_model=4096 32H (MHA: kv=32) d_ff=13440 vocab=92416, QKV bias,
rope_theta=1e6 (64k context).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, head_dim=128,
    d_ff=13440, vocab=92416,
    rope_theta=1e6, qkv_bias=True,
))
