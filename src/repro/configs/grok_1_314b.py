"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified tier).

64L d_model=6144 48H (GQA kv=8) expert d_ff=32768 vocab=131072,
MoE 8 experts top-2 (softmax gate over the selected logits).
8 experts % 16-way model axis != 0 => per-expert tensor parallelism
(expert d_ff sharded), not expert parallelism — DESIGN.md §4.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, moe_gate="softmax",
    rope_theta=10000.0,
))
