"""The paper's own hardware-conform models (§VI-A): MLP-GSC, MLP-HR,
LeNet-300-100.  Feature widths are exactly the paper's; these run through
models/mlp.py (BatchNorm-folded alpha1, ReLU, alpha2 epilogue — the
FantastIC4 §V pipeline) and are the subjects of the Table II / Fig 9 /
Fig 11 benchmark analogues.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    name: str
    features: Tuple[int, ...]      # layer output widths
    d_in: int
    batch_norm: bool = True
    lam: float = 0.02

MLP_GSC = MLPConfig("mlp-gsc", (512, 512, 256, 256, 128, 128, 12), d_in=512)
MLP_HR = MLPConfig("mlp-hr", (512, 256, 128, 12), d_in=512)
LENET_300_100 = MLPConfig("lenet-300-100", (300, 100, 10), d_in=784,
                          batch_norm=False)

MLPS = {m.name: m for m in (MLP_GSC, MLP_HR, LENET_300_100)}
