"""qwen2-vl-2b [vlm] — arXiv:2409.12191 / hf:Qwen/Qwen2-VL-2B-Instruct.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, M-RoPE with
(t,h,w) sections (16,24,24) rotary pairs, QKV bias, tied embeddings.
Vision frontend is a STUB per the assignment: input_specs() feeds
precomputed patch embeddings; the transformer backbone is what runs.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, head_dim=128,
    d_ff=8960, vocab=151936,
    rope_theta=1e6, qkv_bias=True, tie_embeddings=True,
    mrope_sections=(16, 24, 24),
    notes="M-RoPE; dynamic-resolution vision stubbed (patch embeds provided)",
))
