"""ArchConfig — one dataclass describing every assigned architecture.

Each ``configs/<id>.py`` instantiates CONFIG with the exact numbers from the
assignment sheet (source cited in the module docstring).  ``smoke()``
produces a reduced same-family variant for CPU tests: fewer/narrower layers,
few experts, tiny vocab — same code paths, same block structure.

Quantization policy fields implement DESIGN.md §5: ``quantize`` turns EC4T
on for FC-family projection weights; embeddings / norms / biases / router /
SSM dynamics always stay high-precision (the paper's mixed-precision rule).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLADims:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None          # default d_model // n_heads
    # --- attention flavour
    window: Optional[int] = None            # SWA width (danube, hymba)
    global_attn_layers: Tuple[int, ...] = ()  # hymba: layers with full attn
    rotary_frac: float = 1.0                # glm4: 0.5 partial rotary
    rope_theta: float = 10000.0
    qkv_bias: bool = False                  # qwen-family, glm4
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl
    mla: Optional[MLADims] = None           # deepseek-v3
    # --- block flavour
    norm: str = "rms"                       # rms | layer
    act: str = "swiglu"                     # swiglu | gelu
    tie_embeddings: bool = False
    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    moe_gate: str = "softmax"               # softmax (grok) | sigmoid (dsv3)
    n_shared_experts: int = 0
    n_dense_layers: int = 0                 # deepseek: first 3 layers dense
    dense_ff: Optional[int] = None          # FFN width of those dense layers
    routed_scaling: float = 1.0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001
    # --- SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # --- enc-dec (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500                     # stubbed frame-embedding length
    # --- quantization (the paper's technique)
    quantize: bool = True
    lam: float = 0.02                       # entropy-penalty strength λ
    # --- bookkeeping
    vocab_pad_multiple: int = 256           # pad embedding rows for TP
    attn_chunk: int = 1024                  # online-softmax KV chunk
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def d_inner(self) -> int:               # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM, hybrid, or SWA-capped attention."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            dense_ff=128 if self.dense_ff else None,
            vocab=256,
            vocab_pad_multiple=32,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_dense_layers=min(self.n_dense_layers, 1),
            mla=MLADims(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                        qk_rope_dim=8, v_head_dim=16) if self.mla else None,
            mrope_sections=(2, 3, 3) if self.mrope_sections else None,
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=16,
            ssm_chunk=8,
            window=min(self.window, 16) if self.window else None,
            global_attn_layers=tuple(
                g for g in self.global_attn_layers if g < 2),
            enc_len=16 if self.encdec else self.enc_len,
            attn_chunk=16,
        )


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from . import ALL  # noqa: F401  — force-import the config modules
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from . import ALL  # noqa: F401
    return sorted(_REGISTRY)
