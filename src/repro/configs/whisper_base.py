"""whisper-base [audio] — arXiv:2212.04356 (unverified tier).

Enc-dec: 6+6L d_model=512 8H (MHA) d_ff=2048 vocab=51865, LayerNorm +
GELU, sinusoidal encoder positions, learned decoder positions.
Conv frontend is a STUB per the assignment: input_specs() provides
precomputed 1500-frame embeddings (B, 1500, 512).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, head_dim=64,
    d_ff=2048, vocab=51865,
    encdec=True, n_enc_layers=6, enc_len=1500,
    norm="layer", act="gelu",
))
