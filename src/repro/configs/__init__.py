"""Architecture configs: 10 assigned archs + the paper's own MLPs."""
from . import (qwen2_vl_2b, smollm_360m, h2o_danube_1_8b, glm4_9b,
               codeqwen1_5_7b, grok_1_314b, deepseek_v3_671b, hymba_1_5b,
               whisper_base, mamba2_1_3b, paper_mlps)
from .base import ArchConfig, get_config, list_configs

ALL = True
