# FantastIC4 Pallas TPU kernels: packed-int4 ACM matmul with fused epilogue
# (fantastic4_matmul.py), the whole-stack serving megakernel
# (fantastic4_fused_mlp.py), fused ECL assignment+dequant (ecl_quant.py),
# and the shape-aware block autotuner (autotune.py).
# ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles,
# including the literal bit-plane ACM form of eq. (1).
from jax.experimental.pallas import tpu as _pltpu

# Version-compat shim: JAX renamed ``pltpu.TPUCompilerParams`` to
# ``pltpu.CompilerParams``; the installed version may have either.  Every
# kernel module imports this symbol instead of touching pltpu directly.
# Defined before the ops import below so the kernel modules can pull it
# from the partially-initialised package.
COMPILER_PARAMS = (getattr(_pltpu, "CompilerParams", None)
                   or _pltpu.TPUCompilerParams)

from . import ops, ref  # noqa: F401,E402
