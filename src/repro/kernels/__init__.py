# FantastIC4 Pallas TPU kernels: packed-int4 ACM matmul with fused epilogue
# (fantastic4_matmul.py) and fused ECL assignment+dequant (ecl_quant.py).
# ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles,
# including the literal bit-plane ACM form of eq. (1).
from . import ops, ref  # noqa: F401
