"""Pallas TPU megakernel: a whole FantastIC4 MLP stack in one ``pallas_call``.

The paper's hardware win (§V) is a *pipelined* datapath: activations never
leave the chip between FC layers while the 4-bit weights stream in.  The
per-layer kernel already fuses the epilogue, but chaining L ``pallas_call``s
still round-trips every (M, N) activation through HBM L−1 times.  At 4
bits/weight the paper-shaped stacks fit in VMEM whole (MLP-GSC, the largest,
packs to ~0.4 MiB), so this kernel keeps the *activations* resident instead:

    HBM                      VMEM (one grid step, batch tile i)
    ────                     ──────────────────────────────────────────────
    x[i·bm:(i+1)·bm, :] ───▶ act₀ ─┐
    packed W₁ … W_L ───────▶ (all  │ decode Σωᵢ·Bᵢ → W_l, MXU matmul,
    ω, α₁, b, scale per l ──▶ L at │ epilogue ×α₁ +b ReLU ×scale — result
                              once)│ written to the act scratch, read
    out[i·bm:(i+1)·bm, :] ◀─ act_L ┘ back as the next layer's input

Only the first input tile and the last output tile touch HBM per grid step;
inter-layer activations exist solely as kernel values, which Pallas keeps
on-chip by construction (kernel intermediates cannot spill to HBM), with
the final activation parking in a ``(block_m, max_width)`` VMEM scratch
before the single HBM store.  ``fused_mlp_vmem_bytes`` budgets that
activation working set either way.  The grid is 1-D over batch tiles
(weights use constant index maps, so they are fetched once and revisited).

Two orthogonal variants on top of the PR-1 fp32 path:

* ``act_dtype="int8"`` — the paper's §VI-C FPGA configuration (8-bit
  inter-layer activations).  Each non-final layer's epilogue emits
  ``round(y / s_l)`` clipped to [−127, 127] and *cast to int8* before the
  value feeds the next layer's MXU op; the caller folds ``s_{l−1}`` into
  layer l's α₁ exactly as the per-layer ``mlp_serve_int8`` chain does, so
  the two paths agree on the quantized grid bit for bit.  The per-layer
  ``scale`` operand carries the quantization scale s_l instead of α₂
  (which the int8 serving path never uses; the final layer returns raw
  float logits).
* ``n_halves=2`` — double-buffered batch tile, emulating the paper's
  pipelined row processing: the (bm, ·) tile splits into two row groups
  that traverse the stack on a skewed schedule (group 1 runs layer l while
  group 0 runs layer l+1), so decode/MXU work on consecutive layers can
  overlap instead of serialising per layer.  Row groups are independent
  (each output row depends only on its input row), so results are
  unchanged.

Layer dims are zero-padded to ``DIM_ALIGN`` multiples: zero *codes* decode
to zero *weights* (code 0 has no set bit-planes), and padded epilogue
columns carry α₁ = b = 0, so padding is exactly absorbed — layer l+1's
padded K rows meet zero weights, and the final slice drops the rest.  In
int8 mode padded columns quantize to round(0/s) = 0, preserving the
invariant.

``fused_mlp_fits`` estimates the VMEM working set; callers fall back to the
per-layer kernel when a stack exceeds the budget (e.g. a >VMEM embedding
projection) — the software analogue of the paper's "fits the FPGA's on-chip
SRAM" precondition.

A third schedule serves the latency path (batch=1 bucket of the serving
engine): the **weight-stationary** variant
(``fantastic4_fused_mlp_ws_pallas``).  The batch-tiled megakernel above
keeps *all* layer weights VMEM-resident and streams batch tiles past them;
with a single-row batch there is nothing left to stream, so holding the
whole stack on-chip only inflates the working set.  The ws variant flips
the dataflow: the grid runs over *layers* (sequential ``"arbitrary"``
semantics), the tiny activation tile is the resident operand (a VMEM
scratch carried across grid steps), and each grid step fetches exactly one
layer's packed codes — every weight byte crosses HBM→VMEM once per
inference and is the stationary operand of its own step while the
activation hops through the scratch.  Layer operands are stacked into
uniform ``(L, D/2, D)`` / ``(L, 1, D)`` arrays (D = the stack's widest
padded dim) so one ``BlockSpec`` indexed by the layer id can address them;
zero-padded codes decode to zero weights and padded epilogue columns carry
α₁ = b = 0, so the uniform width is exactly absorbed (padded columns stay
0.0 through relu and int8 re-quantization alike).  Per-step VMEM is one
layer's codes + one decoded tile instead of the whole stack, so the ws
schedule also serves stacks whose *total* packed size busts the megakernel
budget, still in one launch.

The fourth schedule — the **decode-amortized streaming** variant
(``fantastic4_fused_mlp_stream_pallas``) — covers the mid-size batches
where neither of the above dominates.  The batch-tiled kernel re-runs
every layer's bit-plane decode (Σωᵢ·Bᵢ) once *per batch tile* (the weight
operands are revisited but the decoded tile is a kernel value, rebuilt
each grid step); the ws kernel decodes each layer once but cannot tile the
batch at all (the whole batch rides in its scratch and meets one layer per
step).  The streaming grid is ``(layers, batch tiles)`` ordered
layers-outer / batch-tiles-inner: at step (l, 0) layer l's codes are
decoded once into a persistent ``(D, D)`` VMEM scratch, and every
subsequent batch tile of that layer reuses the decoded tile — decode runs
**once per layer per inference batch**, L·T matmuls share L decodes.  The
activation ping-pongs through a whole-batch ``(M, D)`` VMEM scratch
(tile i's rows are read and rewritten in place — row ranges are disjoint
across tiles, so no tile ever reads another's output).  Per-step streamed
VMEM is one layer's codes + the decoded tile + one batch tile, so like the
ws schedule it serves stacks whose *total* packed size busts the
batch-tiled budget — but unlike ws it still tiles the batch, which is what
makes it the mid-size/large-batch rescue schedule.  Operands are the same
stacked uniform-width arrays as the ws kernel (``build_ws_operands``), so
the two schedules share their decode + epilogue arithmetic term for term
and the int8 grid is bit-identical across all four schedules.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import COMPILER_PARAMS, ref

# layer dims are padded to this multiple (f32 lane width) before entering
# the kernel; keeps every in-kernel slice tile-aligned.
DIM_ALIGN = 128
# conservative per-core budget: 16 MiB VMEM minus pipelining headroom.
VMEM_BUDGET_BYTES = 12 << 20


def _round_up(v: int, mult: int) -> int:
    return -(-max(v, 1) // mult) * mult


def padded_shapes(shapes: Sequence[Tuple[int, int]],
                  dim_align: int = DIM_ALIGN) -> Tuple[Tuple[int, int], ...]:
    return tuple((_round_up(k, dim_align), _round_up(n, dim_align))
                 for k, n in shapes)


def fused_mlp_vmem_bytes(shapes: Sequence[Tuple[int, int]],
                         block_m: int = 128,
                         dim_align: int = DIM_ALIGN,
                         act_dtype: str = "float32",
                         double_buffer: bool = False) -> int:
    """Working-set estimate for one grid step (bytes).

    packed codes for all layers + the largest decoded W tile + the x tile,
    activation scratch, output tile and epilogue vectors; ×2 on the
    HBM-fetched operands for the pipeline's double buffering.  int8 mode
    adds the quantized copy of the activation tile (1 byte/elem) that each
    epilogue materialises before the next layer's MXU op; the
    double-buffered schedule keeps up to two decoded W tiles live (layer l
    serves row group 1 one tick after group 0).
    """
    ps = padded_shapes(shapes, dim_align)
    packed = sum(kp // 2 * np_ for kp, np_ in ps)          # uint8
    epilogue = sum(2 * 4 * np_ + 4 * 4 + 4 for _, np_ in ps)
    decoded = max(4 * kp * np_ for kp, np_ in ps)
    if double_buffer:
        decoded *= 2
    max_w = max([ps[0][0]] + [np_ for _, np_ in ps])
    x_tile = 4 * block_m * ps[0][0]
    out_tile = 4 * block_m * ps[-1][1]
    act = 4 * block_m * max_w
    if act_dtype == "int8":
        act += block_m * max_w
    return 2 * (packed + epilogue + x_tile + out_tile) + decoded + act


def fused_mlp_fits(shapes: Sequence[Tuple[int, int]], *,
                   block_m: int = 128,
                   budget_bytes: int = VMEM_BUDGET_BYTES,
                   dim_align: int = DIM_ALIGN,
                   act_dtype: str = "float32",
                   double_buffer: bool = False) -> bool:
    """True when the whole stack's working set fits the VMEM budget."""
    if not shapes:
        return False
    return fused_mlp_vmem_bytes(shapes, block_m, dim_align,
                                act_dtype, double_buffer) <= budget_bytes


def _decode_tile(packed: jax.Array, omega_ref) -> jax.Array:
    """(kp//2, np) uint8 codes -> (kp, np) f32 W = Σ_i ω_i B_i."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    codes = jnp.stack([lo, hi], axis=1)
    codes = codes.reshape(packed.shape[0] * 2, packed.shape[1])
    w = jnp.zeros(codes.shape, jnp.float32)
    for i in range(4):
        bit = ((codes >> i) & 1).astype(jnp.float32)
        w = w + omega_ref[0, i] * bit
    return w


def _kernel(*refs, activations: Tuple[Optional[str], ...],
            act_dtype: str, n_halves: int):
    n_layers = len(activations)
    x_ref = refs[0]
    layer_refs = refs[1:1 + 5 * n_layers]
    o_ref = refs[1 + 5 * n_layers]
    act_ref = refs[2 + 5 * n_layers]          # (bm, max_width) VMEM scratch
    int8_acts = act_dtype == "int8"

    # Each layer's weight tile is decoded once and shared across row
    # groups: in the skewed schedule layer l serves group 0 at tick l and
    # group 1 at tick l+1, so the decoded tile stays live for exactly one
    # extra tick (≤2 decoded tiles concurrently) instead of being decoded
    # per group.  The python-level dict is static — the compiler sees one
    # _decode_tile per layer either way.
    decoded = {}

    def apply_layer(cur: jax.Array, l: int, last_use: bool) -> jax.Array:
        packed_ref, omega_ref, alpha1_ref, bias_ref, scale_ref = \
            layer_refs[5 * l:5 * l + 5]
        if l not in decoded:
            decoded[l] = _decode_tile(packed_ref[...], omega_ref)
        w = decoded[l]
        if last_use:
            del decoded[l]
        y = jnp.dot(cur, w, preferred_element_type=jnp.float32)
        y = y * alpha1_ref[...] + bias_ref[...]
        y = ref.apply_activation(y, activations[l])
        if int8_acts:
            if l < n_layers - 1:
                # §VI-C re-quantization: the activation leaves the layer as
                # a true int8 value (the float32 round-trip is exact on the
                # [-127, 127] grid, and mirrors the per-layer chain's math
                # term for term so both paths agree bitwise).
                q = jnp.clip(jnp.round(y / scale_ref[0, 0]), -127.0, 127.0)
                y = q.astype(jnp.int8).astype(jnp.float32)
        else:
            y = y * scale_ref[0, 0]           # fp32 epilogue: ×α₂
        return y

    x = x_ref[...].astype(jnp.float32)
    bm = x.shape[0]
    rows = bm // n_halves
    halves = [x[h * rows:(h + 1) * rows, :] for h in range(n_halves)]
    # Skewed schedule (trivial for n_halves=1): at tick t, row group h runs
    # layer t−h, so group 1 streams through layer l while group 0 is already
    # on layer l+1 — the paper's pipelined rows, §V.
    for t in range(n_layers + n_halves - 1):
        for h in range(n_halves):
            l = t - h
            if 0 <= l < n_layers:
                halves[h] = apply_layer(halves[h], l,
                                        last_use=h == n_halves - 1)
    # the last activation parks in the VMEM scratch before the single HBM
    # store; every earlier one only ever existed as on-chip kernel values
    # (Pallas intermediates cannot spill to HBM).
    width = halves[0].shape[1]
    for h in range(n_halves):
        act_ref[h * rows:(h + 1) * rows, :width] = halves[h]
    o_ref[...] = act_ref[:, :width].astype(o_ref.dtype)


def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@functools.partial(
    jax.jit,
    static_argnames=("shapes", "activations", "out_dtype", "block_m",
                     "interpret", "dim_align", "act_dtype", "double_buffer"))
def fantastic4_fused_mlp_pallas(
        x: jax.Array,
        packed: Tuple[jax.Array, ...],
        omega: Tuple[jax.Array, ...],
        alpha1: Tuple[jax.Array, ...],
        bias: Tuple[jax.Array, ...],
        scale: Tuple[jax.Array, ...],
        *, shapes: Tuple[Tuple[int, int], ...],
        activations: Tuple[Optional[str], ...],
        out_dtype=None, block_m: int = 128,
        interpret: bool = False,
        dim_align: int = DIM_ALIGN,
        act_dtype: str = "float32",
        double_buffer: bool = False) -> jax.Array:
    """x:(M, K₀) · per-layer packed codes -> (M, N_L) in one pallas_call.

    ``shapes[l] = (K_l, N_l)`` are the *unpadded* layer dims (``K_{l+1} ==
    N_l``); ``packed[l]`` is ``(ceil(K_l/2), N_l)`` uint8 row-pair codes.

    ``scale[l]`` is a scalar whose meaning depends on ``act_dtype``: the
    fp32 epilogue's α₂ multiplier, or the int8 mode's activation
    quantization scale s_l (the final layer's entry is ignored there — the
    logits stay float).  In int8 mode the caller must already have folded
    s_{l−1} into ``alpha1[l]``, exactly as the per-layer serving chain
    does.  ``double_buffer`` splits the batch tile into two row groups on
    the skewed schedule described in the module docstring (it needs two
    full sublane groups, so it engages only when the tile has ≥16 rows).
    """
    assert act_dtype in ("float32", "int8"), act_dtype
    n_layers = len(shapes)
    assert n_layers >= 1
    assert len(activations) == n_layers
    m, k0 = x.shape
    assert k0 == shapes[0][0], (x.shape, shapes)
    for l in range(1, n_layers):
        assert shapes[l][0] == shapes[l - 1][1], shapes
    out_dtype = out_dtype or x.dtype

    ps = padded_shapes(shapes, dim_align)
    bm = min(block_m, _round_up(m, 8))
    # two row groups need two whole f32 sublane tiles
    n_halves = 2 if double_buffer and bm % 16 == 0 else 1
    mp = _round_up(m, bm)
    xp = _pad2(x, mp, ps[0][0])

    operands = [xp]
    in_specs = [pl.BlockSpec((bm, ps[0][0]), lambda i: (i, 0))]
    for l, ((kp, np_), (k, n)) in enumerate(zip(ps, shapes)):
        operands += [
            _pad2(packed[l], kp // 2, np_),
            omega[l].reshape(1, 4).astype(jnp.float32),
            _pad2(alpha1[l].reshape(1, -1).astype(jnp.float32), 1, np_),
            _pad2(bias[l].reshape(1, -1).astype(jnp.float32), 1, np_),
            scale[l].reshape(1, 1).astype(jnp.float32),
        ]
        in_specs += [
            pl.BlockSpec((kp // 2, np_), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ]

    n_last_p = ps[-1][1]
    max_width = max([ps[0][0]] + [np_ for _, np_ in ps])
    out = pl.pallas_call(
        functools.partial(_kernel, activations=tuple(activations),
                          act_dtype=act_dtype, n_halves=n_halves),
        grid=(mp // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, n_last_p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n_last_p), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, max_width), jnp.float32)],
        compiler_params=COMPILER_PARAMS(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*operands)
    return out[:m, :shapes[-1][1]]


# ------------------------------------------------ weight-stationary variant

def ws_width(shapes: Sequence[Tuple[int, int]],
             dim_align: int = DIM_ALIGN) -> int:
    """Uniform stacked-operand width D: the stack's widest padded dim."""
    ps = padded_shapes(shapes, dim_align)
    return max([ps[0][0]] + [np_ for _, np_ in ps])


def ws_mlp_vmem_bytes(shapes: Sequence[Tuple[int, int]], rows: int = 8,
                      dim_align: int = DIM_ALIGN,
                      act_dtype: str = "float32") -> int:
    """Per-grid-step working set of the weight-stationary schedule (bytes).

    One layer's packed (D/2, D) block + its decoded (D, D) tile + the
    resident (rows, D) activation scratch and x/out tiles; ×2 on the
    streamed per-layer operands for pipelining double buffers.  Unlike
    ``fused_mlp_vmem_bytes`` this does not scale with L — the whole point
    of the schedule.
    """
    d = ws_width(shapes, dim_align)
    rp = _round_up(rows, 8)
    packed = d // 2 * d                              # uint8, one layer
    vectors = 2 * 4 * d + 4 * 4 + 4 * 4              # α₁/b + ω + meta
    decoded = 4 * d * d
    act = 4 * rp * d
    x_tile = 4 * rp * d
    out_tile = 4 * rp * d
    if act_dtype == "int8":
        act += rp * d
    return 2 * (packed + vectors) + decoded + act + x_tile + out_tile


def ws_mlp_fits(shapes: Sequence[Tuple[int, int]], *, rows: int = 8,
                budget_bytes: int = VMEM_BUDGET_BYTES,
                dim_align: int = DIM_ALIGN,
                act_dtype: str = "float32") -> bool:
    if not shapes:
        return False
    return ws_mlp_vmem_bytes(shapes, rows, dim_align,
                             act_dtype) <= budget_bytes


def build_ws_operands(packed: Sequence[jax.Array],
                      omega: Sequence[jax.Array],
                      alpha1: Sequence[jax.Array],
                      bias: Sequence[jax.Array],
                      scale: Sequence[jax.Array],
                      *, shapes: Sequence[Tuple[int, int]],
                      activations: Sequence[Optional[str]],
                      act_dtype: str = "float32",
                      dim_align: int = DIM_ALIGN) -> tuple:
    """Stack per-layer operands into the ws kernel's uniform-width arrays.

    Returns ``(packed (L, D/2, D) u8, omega (L, 1, 4), alpha1 (L, 1, D),
    bias (L, 1, D), meta (L, 1, 4))`` where ``meta[l] = [scale_l,
    activation_code, quant_flag, 0]`` (codes per ``ref.ACTIVATION_CODES``:
    0 none, 1 relu, 2 gelu) — the activation/re-quantization choices
    become data so one kernel body can serve every grid step (the layer id
    is a traced ``program_id``).  Do this once per frozen pack, not per
    call: the serving plan caches the result.
    """
    n_layers = len(shapes)
    d = ws_width(shapes, dim_align)
    pk, om, a1, bi, me = [], [], [], [], []
    for l in range(n_layers):
        pk.append(_pad2(packed[l], d // 2, d))
        om.append(omega[l].reshape(1, 4).astype(jnp.float32))
        a1.append(_pad2(alpha1[l].reshape(1, -1).astype(jnp.float32), 1, d))
        bi.append(_pad2(bias[l].reshape(1, -1).astype(jnp.float32), 1, d))
        act_f = float(ref.activation_code(activations[l]))
        quant_f = 1.0 if (act_dtype == "int8" and l < n_layers - 1) else 0.0
        me.append(jnp.asarray(
            [[float(jnp.asarray(scale[l]).reshape(())), act_f, quant_f,
              0.0]], jnp.float32))
    return (jnp.stack(pk), jnp.stack(om), jnp.stack(a1), jnp.stack(bi),
            jnp.stack(me))


def _ws_kernel(x_ref, packed_ref, omega_ref, alpha1_ref, bias_ref, meta_ref,
               o_ref, act_ref, *, act_dtype: str, n_layers: int):
    l = pl.program_id(0)

    @pl.when(l == 0)
    def _():
        act_ref[...] = x_ref[...].astype(jnp.float32)

    cur = act_ref[...]
    w = _decode_tile(packed_ref[0], omega_ref[0])
    y = jnp.dot(cur, w, preferred_element_type=jnp.float32)
    y = y * alpha1_ref[0] + bias_ref[0]
    # activation/quantization choices are per-layer *data* (meta operand):
    # the layer id is traced, so the branch cannot be a python conditional.
    y = ref.apply_activation_coded(y, meta_ref[0, 0, 1])
    s = meta_ref[0, 0, 0]
    if act_dtype == "int8":
        q = jnp.clip(jnp.round(y / s), -127.0, 127.0)
        yq = q.astype(jnp.int8).astype(jnp.float32)
        y = jnp.where(meta_ref[0, 0, 2] > 0, yq, y)
    else:
        y = y * s
    act_ref[...] = y

    @pl.when(l == n_layers - 1)
    def _():
        o_ref[...] = act_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("shapes", "activations", "out_dtype", "interpret",
                     "dim_align", "act_dtype"))
def fantastic4_fused_mlp_ws_pallas(
        x: jax.Array,
        packed_stack: jax.Array,
        omega_stack: jax.Array,
        alpha1_stack: jax.Array,
        bias_stack: jax.Array,
        meta_stack: jax.Array,
        *, shapes: Tuple[Tuple[int, int], ...],
        activations: Tuple[Optional[str], ...],
        out_dtype=None,
        interpret: bool = False,
        dim_align: int = DIM_ALIGN,
        act_dtype: str = "float32") -> jax.Array:
    """Weight-stationary whole-stack serving: grid over layers, activation
    resident in scratch, one layer's weights fetched per step.

    Operands come pre-stacked from ``build_ws_operands`` (uniform width D).
    The batch is not tiled — the whole (rounded) batch rides in the scratch
    — so this is the latency schedule for small row counts (the serving
    plan selects it for the batch≤8 bucket).  The grid must run in order
    (``"arbitrary"`` semantics): step l reads the activation step l−1
    wrote.
    """
    assert act_dtype in ("float32", "int8"), act_dtype
    n_layers = len(shapes)
    assert n_layers >= 1
    assert packed_stack.shape[0] == n_layers
    m, k0 = x.shape
    assert k0 == shapes[0][0], (x.shape, shapes)
    out_dtype = out_dtype or x.dtype
    d = ws_width(shapes, dim_align)
    mp = _round_up(m, 8)
    xp = _pad2(x, mp, d)

    out = pl.pallas_call(
        functools.partial(_ws_kernel, act_dtype=act_dtype,
                          n_layers=n_layers),
        grid=(n_layers,),
        in_specs=[
            pl.BlockSpec((mp, d), lambda l: (0, 0)),
            pl.BlockSpec((1, d // 2, d), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, 1, 4), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, 1, 4), lambda l: (l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((mp, d), lambda l: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((mp, d), jnp.float32)],
        compiler_params=COMPILER_PARAMS(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(xp, packed_stack, omega_stack, alpha1_stack, bias_stack, meta_stack)
    return out[:m, :shapes[-1][1]]


# ------------------------------------------- decode-amortized streaming variant

def stream_mlp_vmem_bytes(shapes: Sequence[Tuple[int, int]], rows: int,
                          block_m: int = 128,
                          dim_align: int = DIM_ALIGN,
                          act_dtype: str = "float32") -> int:
    """Per-grid-step working set of the streaming schedule (bytes).

    One layer's packed (D/2, D) block + the persistent decoded (D, D)
    scratch + the whole-batch (M, D) activation scratch + one (bm, D)
    x/out tile pair; ×2 on the streamed per-layer operands for pipelining
    double buffers.  Scales with the batch (the activation scratch holds
    every tile so the decode can be amortized across them) but not with L
    — the schedule's defining trade against the batch-tiled kernel.
    """
    d = ws_width(shapes, dim_align)
    rp = _round_up(rows, 8)
    bm = min(_round_up(block_m, 8), rp)
    mp = _round_up(rp, bm)       # the kernel pads the batch to whole tiles
    packed = d // 2 * d                              # uint8, one layer
    vectors = 2 * 4 * d + 4 * 4 + 4 * 4              # α₁/b + ω + meta
    decoded = 4 * d * d                              # persistent W scratch
    act = 4 * mp * d                                 # whole-batch scratch
    x_tile = 4 * bm * d
    out_tile = 4 * bm * d
    return 2 * (packed + vectors + x_tile + out_tile) + decoded + act


def stream_mlp_fits(shapes: Sequence[Tuple[int, int]], *, rows: int,
                    block_m: int = 128,
                    budget_bytes: int = VMEM_BUDGET_BYTES,
                    dim_align: int = DIM_ALIGN,
                    act_dtype: str = "float32") -> bool:
    if not shapes:
        return False
    return stream_mlp_vmem_bytes(shapes, rows, block_m, dim_align,
                                 act_dtype) <= budget_bytes


def _stream_kernel(x_ref, packed_ref, omega_ref, alpha1_ref, bias_ref,
                   meta_ref, o_ref, act_ref, w_ref, *, act_dtype: str,
                   n_layers: int, block_m: int):
    l = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(l == 0)
    def _():
        # first pass over the batch: park the input tiles in the resident
        # whole-batch scratch (later layers never touch x again).
        act_ref[pl.ds(i * block_m, block_m), :] = \
            x_ref[...].astype(jnp.float32)

    @pl.when(i == 0)
    def _():
        # THE amortization: layer l's bit-plane decode runs once per
        # inference batch, at its first batch tile, into a scratch that
        # persists across grid steps — every later tile of this layer
        # reuses it (the batch-tiled kernel redoes this per grid step).
        w_ref[...] = _decode_tile(packed_ref[0], omega_ref[0])

    cur = act_ref[pl.ds(i * block_m, block_m), :]
    y = jnp.dot(cur, w_ref[...], preferred_element_type=jnp.float32)
    y = y * alpha1_ref[0] + bias_ref[0]
    # per-layer activation/quantization choices are data (meta operand),
    # exactly as in the ws kernel — the layer id is traced.
    y = ref.apply_activation_coded(y, meta_ref[0, 0, 1])
    s = meta_ref[0, 0, 0]
    if act_dtype == "int8":
        q = jnp.clip(jnp.round(y / s), -127.0, 127.0)
        yq = q.astype(jnp.int8).astype(jnp.float32)
        y = jnp.where(meta_ref[0, 0, 2] > 0, yq, y)
    else:
        y = y * s
    # in-place ping-pong: tile i's rows are read and rewritten by the same
    # step; row ranges are disjoint across tiles, so no tile reads another
    # tile's freshly written rows.
    act_ref[pl.ds(i * block_m, block_m), :] = y

    @pl.when(l == n_layers - 1)
    def _():
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("shapes", "activations", "out_dtype", "block_m",
                     "interpret", "dim_align", "act_dtype"))
def fantastic4_fused_mlp_stream_pallas(
        x: jax.Array,
        packed_stack: jax.Array,
        omega_stack: jax.Array,
        alpha1_stack: jax.Array,
        bias_stack: jax.Array,
        meta_stack: jax.Array,
        *, shapes: Tuple[Tuple[int, int], ...],
        activations: Tuple[Optional[str], ...],
        out_dtype=None, block_m: int = 128,
        interpret: bool = False,
        dim_align: int = DIM_ALIGN,
        act_dtype: str = "float32") -> jax.Array:
    """Decode-amortized streaming whole-stack serving: grid over
    (layers, batch tiles) with layers outer, each layer decoded once per
    inference batch and reused across every batch tile.

    Operands come pre-stacked from ``build_ws_operands`` (uniform width D)
    — shared with the ws kernel, so decode + epilogue arithmetic is
    identical term for term and the int8 grid stays bit-exact across
    schedules.  The whole (rounded) batch is resident in a VMEM scratch;
    the grid must run in order (``"arbitrary"`` semantics both ways:
    layer l reads what layer l−1 wrote, tile i>0 reads the decode tile
    i=0 wrote).
    """
    assert act_dtype in ("float32", "int8"), act_dtype
    n_layers = len(shapes)
    assert n_layers >= 1
    assert packed_stack.shape[0] == n_layers
    m, k0 = x.shape
    assert k0 == shapes[0][0], (x.shape, shapes)
    out_dtype = out_dtype or x.dtype
    d = ws_width(shapes, dim_align)
    bm = min(block_m, _round_up(m, 8))
    mp = _round_up(m, bm)
    n_tiles = mp // bm
    xp = _pad2(x, mp, d)

    out = pl.pallas_call(
        functools.partial(_stream_kernel, act_dtype=act_dtype,
                          n_layers=n_layers, block_m=bm),
        grid=(n_layers, n_tiles),
        in_specs=[
            # x is only read on the first layer pass; pin the index to
            # tile 0 afterwards so later layers don't re-stream the batch.
            pl.BlockSpec((bm, d),
                         lambda l, i: (jnp.where(l == 0, i, 0), 0)),
            pl.BlockSpec((1, d // 2, d), lambda l, i: (l, 0, 0)),
            pl.BlockSpec((1, 1, 4), lambda l, i: (l, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda l, i: (l, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda l, i: (l, 0, 0)),
            pl.BlockSpec((1, 1, 4), lambda l, i: (l, 0, 0)),
        ],
        # only the last layer writes real output tiles; pinning earlier
        # layers to tile 0 keeps the copy-out traffic to one final pass
        # (tile 0's stale flushes are overwritten by its last-layer write).
        out_specs=pl.BlockSpec(
            (bm, d), lambda l, i: (jnp.where(l == n_layers - 1, i, 0), 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((mp, d), jnp.float32),
                        pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(xp, packed_stack, omega_stack, alpha1_stack, bias_stack, meta_stack)
    return out[:m, :shapes[-1][1]]
