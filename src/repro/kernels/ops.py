"""Public jit'd wrappers around the FantastIC4 Pallas kernels.

On a TPU backend the Pallas kernels run natively; on CPU (this container)
they execute in ``interpret=True`` mode so every test validates the actual
kernel body against the pure-jnp oracles in ``ref.py``. ``use_kernel=False``
selects the oracle path (used by the models' default serving path on CPU,
where interpret-mode would be needlessly slow for large layers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .ecl_quant import ecl_quant_pallas
from .fantastic4_matmul import fantastic4_matmul_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fantastic4_matmul(x: jax.Array, packed: jax.Array, omega: jax.Array,
                      bias: Optional[jax.Array] = None,
                      alpha1: Optional[jax.Array] = None,
                      alpha2: Optional[jax.Array] = None,
                      activation: Optional[str] = None,
                      out_dtype=None,
                      use_kernel: bool = True,
                      interpret: Optional[bool] = None,
                      block_m: int = 128, block_n: int = 256,
                      block_k: int = 512) -> jax.Array:
    """Quantized linear y = epilogue(x @ decode(packed, omega)).

    x: (M, K); packed: (K//2, N) uint8 (row-pair packed); omega: (4,).
    bias/alpha1: (N,) or None; alpha2: scalar or None.
    """
    n = packed.shape[1]
    if not use_kernel:
        return ref.fantastic4_matmul_ref(
            x, packed, omega, bias=bias, alpha1=alpha1, alpha2=alpha2,
            activation=activation, out_dtype=out_dtype)
    interpret = _default_interpret() if interpret is None else interpret
    alpha1 = jnp.ones((n,), jnp.float32) if alpha1 is None else alpha1
    bias = jnp.zeros((n,), jnp.float32) if bias is None else bias
    alpha2 = jnp.ones((), jnp.float32) if alpha2 is None else jnp.asarray(alpha2)
    return fantastic4_matmul_pallas(
        x, packed, omega, alpha1, bias, alpha2,
        activation=activation, out_dtype=out_dtype or x.dtype,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret)


def ecl_quant(w: jax.Array, omega: jax.Array, penalty: jax.Array,
              use_kernel: bool = True,
              interpret: Optional[bool] = None,
              block_r: int = 256, block_c: int = 512):
    """Fused ECL assign + dequant. Returns (codes uint8, w_hat f32)."""
    if not use_kernel:
        return ref.ecl_quant_ref(w, omega, penalty)
    interpret = _default_interpret() if interpret is None else interpret
    squeeze = w.ndim == 1
    w2 = w[None, :] if squeeze else w.reshape(w.shape[0], -1)
    codes, what = ecl_quant_pallas(w2, omega, penalty,
                                   block_r=block_r, block_c=block_c,
                                   interpret=interpret)
    if squeeze:
        return codes[0], what[0]
    return codes.reshape(w.shape), what.reshape(w.shape)
