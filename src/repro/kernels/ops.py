"""Public jit'd wrappers around the FantastIC4 Pallas kernels.

On a TPU backend the Pallas kernels run natively; on CPU (this container)
they execute in ``interpret=True`` mode so every test validates the actual
kernel body against the pure-jnp oracles in ``ref.py``. ``use_kernel=False``
selects the oracle path (used by the models' default serving path on CPU,
where interpret-mode would be needlessly slow for large layers).

Block sizes left as ``None`` are resolved by the shape-aware autotuner
(``autotune.py``): a timed candidate sweep on a real TPU backend, a pure
heuristic in interpret/CPU mode, both behind a persistent JSON cache — so
every entry point (models, launchers, benchmarks) runs the same tuned
configuration instead of the old hard-coded 128/256/512 defaults.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from ..memo import MISS, IdentityMemo
from . import autotune, ref
from .ecl_quant import ecl_quant_pallas
from .fantastic4_fused_mlp import (VMEM_BUDGET_BYTES, build_ws_operands,
                                   fantastic4_fused_mlp_pallas,
                                   fantastic4_fused_mlp_stream_pallas,
                                   fantastic4_fused_mlp_ws_pallas,
                                   fused_mlp_fits, stream_mlp_fits,
                                   ws_mlp_fits)
from .fantastic4_matmul import fantastic4_matmul_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _timeit(fn, repeats: int = 3) -> float:
    """Median wall-clock of ``fn()`` after one warm-up (compile) call."""
    try:
        jax.block_until_ready(fn())
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        return sorted(times)[len(times) // 2]
    except Exception:
        return float("inf")               # candidate failed to compile/run


def _resolve_blocks(m: int, k: int, n: int, *, dtype, interpret: bool,
                    block_m, block_n, block_k,
                    measure=None) -> autotune.BlockConfig:
    """Fill ``None`` blocks from the autotuner; explicit values win.

    Interpret-mode answers are keyed under backend "interpret" so they
    never shadow a real backend's timed sweep for the same shape.
    """
    if None not in (block_m, block_n, block_k):
        return autotune.BlockConfig(block_m, block_n, block_k,
                                    source="explicit")
    cfg = autotune.get_block_config(
        m, k, n, dtype=str(dtype), fused=False,
        backend="interpret" if interpret else None,
        measure=measure if not interpret else None)
    return autotune.BlockConfig(block_m or cfg.block_m,
                                block_n or cfg.block_n,
                                block_k or cfg.block_k, source=cfg.source)


def fantastic4_matmul(x: jax.Array, packed: jax.Array, omega: jax.Array,
                      bias: Optional[jax.Array] = None,
                      alpha1: Optional[jax.Array] = None,
                      alpha2: Optional[jax.Array] = None,
                      activation: Optional[str] = None,
                      out_dtype=None,
                      use_kernel: bool = True,
                      interpret: Optional[bool] = None,
                      block_m: Optional[int] = None,
                      block_n: Optional[int] = None,
                      block_k: Optional[int] = None) -> jax.Array:
    """Quantized linear y = epilogue(x @ decode(packed, omega)).

    x: (M, K); packed: (K//2, N) uint8 (row-pair packed); omega: (4,).
    bias/alpha1: (N,) or None; alpha2: scalar or None.
    block_*: None -> autotuned per shape (see module docstring).
    """
    n = packed.shape[1]
    if not use_kernel:
        return ref.fantastic4_matmul_ref(
            x, packed, omega, bias=bias, alpha1=alpha1, alpha2=alpha2,
            activation=activation, out_dtype=out_dtype)
    interpret = _default_interpret() if interpret is None else interpret
    alpha1 = jnp.ones((n,), jnp.float32) if alpha1 is None else alpha1
    bias = jnp.zeros((n,), jnp.float32) if bias is None else bias
    alpha2 = jnp.ones((), jnp.float32) if alpha2 is None else jnp.asarray(alpha2)

    def _measure(cfg: autotune.BlockConfig) -> float:
        return _timeit(lambda: fantastic4_matmul_pallas(
            x, packed, omega, alpha1, bias, alpha2,
            activation=activation, out_dtype=out_dtype or x.dtype,
            block_m=cfg.block_m, block_n=cfg.block_n, block_k=cfg.block_k,
            interpret=interpret))

    cfg = _resolve_blocks(x.shape[0], x.shape[1], n, dtype=x.dtype,
                          interpret=interpret, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          measure=_measure)
    return fantastic4_matmul_pallas(
        x, packed, omega, alpha1, bias, alpha2,
        activation=activation, out_dtype=out_dtype or x.dtype,
        block_m=cfg.block_m, block_n=cfg.block_n, block_k=cfg.block_k,
        interpret=interpret)


def fantastic4_mlp_chain(x: jax.Array, layers: Sequence[dict], *,
                         use_kernel: bool = True,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Chained per-layer serving over a frozen pack's layer list (kernel or
    oracle per ``use_kernel``) — the unfused path and the megakernel's
    over-budget fallback."""
    for layer in layers:
        if layer["shape"][0] % 2:
            # odd K: the pack carries one zero code row — mirror it on x
            x = jnp.pad(x, ((0, 0), (0, 1)))
        x = fantastic4_matmul(
            x, layer["packed"], layer["omega"], bias=layer["bias"],
            alpha1=layer["alpha1"], alpha2=layer["alpha2"],
            activation=layer.get("activation"), use_kernel=use_kernel,
            interpret=interpret)
    return x


def fantastic4_mlp_chain_int8(x: jax.Array, layers: Sequence[dict],
                              act_scales: Sequence[float], *,
                              use_kernel: bool = True,
                              interpret: Optional[bool] = None) -> jax.Array:
    """Per-layer int8-activation serving chain (paper §VI-C).

    Layer i emits ``round(y/s_i)`` clipped to int8; layer i+1 folds s_i
    into its alpha1.  This is both ``mlp_serve_int8``'s unfused path and
    the int8 megakernel's over-budget fallback — one implementation, so
    the fused kernel's bit-exactness contract has a single ground truth.
    """
    n = len(layers)
    xq = x.astype(jnp.float32)
    in_scale = 1.0
    for i, layer in enumerate(layers):
        if layer["shape"][0] % 2:
            # odd K: the pack carries one zero code row — mirror it on x
            xq = jnp.pad(xq, ((0, 0), (0, 1)))
        alpha1 = layer["alpha1"] * in_scale      # de-quantize inputs
        y = fantastic4_matmul(
            xq, layer["packed"], layer["omega"], bias=layer["bias"],
            alpha1=alpha1, alpha2=None, activation=layer.get("activation"),
            use_kernel=use_kernel, interpret=interpret)
        if i < n - 1:
            s = act_scales[i]
            xq = jnp.clip(jnp.round(y / s), -127, 127)
            xq = xq.astype(jnp.int8).astype(jnp.float32)
            in_scale = s
        else:
            xq = y
    return xq


# folded int8 serving operands, memoized per (layers, act_scales) identity:
# re-folding alpha1·s and L scalar conversions on every call is exactly the
# per-call wrapper dispatch cost the megakernel path avoids for the pack
# arrays (see the NB in _call_fused).  Identity keying is safe because a
# frozen pack's arrays are never mutated in place (see repro.memo).
_INT8_FOLD_MEMO = IdentityMemo()


def _int8_folded_operands(layers: Sequence[dict],
                          act_scales: Sequence[float]) -> tuple:
    hit = _INT8_FOLD_MEMO.get((layers, act_scales))
    if hit is not MISS:
        return hit
    # fold s_{l-1} into alpha1_l — same expression as the per-layer chain
    # (fantastic4_mlp_chain_int8), so the arrays are bitwise identical on
    # both paths; the per-layer scale operand carries s_l (final layer:
    # sentinel 1.0, logits stay float).
    alpha1s = tuple(
        l["alpha1"] * (1.0 if i == 0 else act_scales[i - 1])
        for i, l in enumerate(layers))
    scales = tuple(
        jnp.asarray(act_scales[i] if i < len(layers) - 1 else 1.0,
                    jnp.float32)
        for i in range(len(layers)))
    _INT8_FOLD_MEMO.put((layers, act_scales), (), (alpha1s, scales))
    return alpha1s, scales


# stacked weight-stationary operands, memoized per (layers, act_scales)
# identity like the int8 fold above: the stacking concat/pad work must run
# once per frozen pack, not once per request.
_WS_OPERAND_MEMO = IdentityMemo()


def _ws_stacked_operands(layers: Sequence[dict], act_dtype: str,
                         act_scales: Optional[Sequence[float]]) -> tuple:
    hit = _WS_OPERAND_MEMO.get((layers, act_scales), (act_dtype,))
    if hit is not MISS:
        return hit
    shapes = tuple(tuple(l["shape"]) for l in layers)
    activations = tuple(l.get("activation") for l in layers)
    if act_dtype == "int8":
        alpha1s, scales = _int8_folded_operands(layers, act_scales)
    else:
        alpha1s = tuple(l["alpha1"] for l in layers)
        scales = tuple(l["alpha2"] for l in layers)
    stacked = build_ws_operands(
        tuple(l["packed"] for l in layers),
        tuple(l["omega"] for l in layers),
        alpha1s,
        tuple(l["bias"] for l in layers),
        scales,
        shapes=shapes, activations=activations, act_dtype=act_dtype)
    _WS_OPERAND_MEMO.put((layers, act_scales), (act_dtype,), stacked)
    return stacked


def forget_pack_operands(layers: Sequence[dict]) -> int:
    """Drop every decoded-operand cache entry keyed on ``layers``' identity
    (folded int8 operands and stacked weight-stationary operands);
    returns how many entries were released.  The serving pack cache and
    ``ModelRegistry.unregister`` call this when a model leaves the hot
    tier — these memos hold strong references to the decoded arrays, so
    without the drop an evicted pack's operands stay resident for the
    process lifetime."""
    return (_INT8_FOLD_MEMO.drop(layers)
            + _WS_OPERAND_MEMO.drop(layers))


def fantastic4_mlp_fused(x: jax.Array, layers: Sequence[dict], *,
                         use_kernel: bool = True,
                         interpret: Optional[bool] = None,
                         out_dtype=None,
                         block_m: Optional[int] = None,
                         act_dtype: str = "float32",
                         act_scales: Optional[Sequence[float]] = None,
                         double_buffer: bool = False,
                         weight_stationary: bool = False,
                         schedule: Optional[str] = None,
                         vmem_budget_bytes: int = VMEM_BUDGET_BYTES
                         ) -> jax.Array:
    """Whole-stack serving: one megakernel launch instead of L.

    ``layers`` is the frozen pack's layer list: each entry carries ``packed``
    (ceil(K/2), N) uint8, ``omega`` (4,), ``alpha1``/``bias`` (N,),
    ``alpha2`` scalar, ``shape`` (K, N) and ``activation``.  Falls back to
    the chained per-layer kernel when the stack's VMEM working set exceeds
    ``vmem_budget_bytes`` (see ``fantastic4_fused_mlp.fused_mlp_fits``).

    ``act_dtype="int8"`` runs the paper's §VI-C configuration end-to-end
    inside the kernel: inter-layer activations are re-quantized to int8 in
    VMEM (``act_scales``, one scale per layer boundary, from
    ``calibrate_act_scales``), with each layer's alpha1 absorbing the
    previous scale — folded here exactly as the per-layer chain folds it,
    so fused and chained int8 agree on the quantized grid bit for bit
    whenever the per-layer kernel accumulates K in a single block (always
    true in interpret/CPU mode, where the heuristic takes whole dims; a
    TPU block_k split of a wide layer can move a sum by one ulp and flip
    a quantization boundary, leaving grid-level-but-not-bitwise
    agreement).

    ``schedule`` names the kernel schedule explicitly — one of
    ``"batch_tiled"`` (default), ``"db"`` (pipelined two-row-group
    batch tile), ``"ws"`` (weight-stationary: grid over layers,
    activation resident — the batch=1 latency path) or ``"stream"``
    (decode-amortized streaming: layers-outer/batch-tiles-inner grid,
    each layer decoded once per inference batch).  The legacy
    ``double_buffer`` / ``weight_stationary`` booleans map onto it and
    remain for callers that predate the serving plans.  Every schedule
    falls back to the per-layer chain past its own VMEM fit.
    """
    if schedule is None:
        schedule = ("ws" if weight_stationary
                    else "db" if double_buffer else "batch_tiled")
    assert schedule in autotune.SCHEDULES, schedule
    shapes = tuple(tuple(l["shape"]) for l in layers)
    activations = tuple(l.get("activation") for l in layers)
    interpret = _default_interpret() if interpret is None else interpret
    m, k0 = x.shape
    n_last = shapes[-1][1]

    if act_dtype == "int8":
        if act_scales is None or len(act_scales) < len(layers) - 1:
            raise ValueError("act_dtype='int8' needs act_scales with one "
                             "entry per layer boundary")
        alpha1s, scales = _int8_folded_operands(layers, act_scales)
    else:
        alpha1s = tuple(l["alpha1"] for l in layers)
        scales = tuple(l["alpha2"] for l in layers)

    def _chain_fallback(use_k: bool) -> jax.Array:
        if act_dtype == "int8":
            y = fantastic4_mlp_chain_int8(x, layers, act_scales,
                                          use_kernel=use_k,
                                          interpret=interpret)
        else:
            y = fantastic4_mlp_chain(x, layers, use_kernel=use_k,
                                     interpret=interpret)
        return y.astype(out_dtype or y.dtype)

    if schedule == "ws" and use_kernel:
        if ws_mlp_fits(shapes, rows=m, budget_bytes=vmem_budget_bytes,
                       act_dtype=act_dtype):
            stacked = _ws_stacked_operands(
                layers, act_dtype, act_scales if act_dtype == "int8"
                else None)
            return fantastic4_fused_mlp_ws_pallas(
                x, *stacked, shapes=shapes, activations=activations,
                out_dtype=out_dtype or x.dtype, interpret=interpret,
                act_dtype=act_dtype)
        # over-budget even per layer: same per-layer-chain fallback as the
        # batch-tiled schedule below.
        return _chain_fallback(True)

    if schedule == "stream" and use_kernel:
        bm = block_m or 128
        if stream_mlp_fits(shapes, rows=m, block_m=bm,
                           budget_bytes=vmem_budget_bytes,
                           act_dtype=act_dtype):
            stacked = _ws_stacked_operands(
                layers, act_dtype, act_scales if act_dtype == "int8"
                else None)
            return fantastic4_fused_mlp_stream_pallas(
                x, *stacked, shapes=shapes, activations=activations,
                out_dtype=out_dtype or x.dtype, block_m=bm,
                interpret=interpret, act_dtype=act_dtype)
        return _chain_fallback(True)

    def _measure(cfg: autotune.BlockConfig) -> float:
        return _timeit(lambda: _call_fused(cfg.block_m))

    def _call_fused(bm: int) -> jax.Array:
        # NB: no jnp.asarray here — pack entries are already device arrays
        # and per-array asarray dominates the wrapper's dispatch cost.
        return fantastic4_fused_mlp_pallas(
            x,
            tuple(l["packed"] for l in layers),
            tuple(l["omega"] for l in layers),
            alpha1s,
            tuple(l["bias"] for l in layers),
            scales,
            shapes=shapes, activations=activations,
            out_dtype=out_dtype or x.dtype, block_m=bm,
            interpret=interpret, act_dtype=act_dtype,
            double_buffer=schedule == "db")

    # fits check first (conservatively at the largest candidate block_m):
    # an over-budget stack must not pay for a fused-candidate sweep whose
    # result would be thrown away.
    fits = fused_mlp_fits(shapes, block_m=block_m or 256,
                          budget_bytes=vmem_budget_bytes,
                          act_dtype=act_dtype,
                          double_buffer=schedule == "db")
    if use_kernel and fits and block_m is None:
        cfg = autotune.get_block_config(
            m, k0, n_last, dtype=str(x.dtype), fused=True,
            backend="interpret" if interpret else None,
            act_dtype=act_dtype,
            # (M, K₀, N_last) alone cannot distinguish two stacks with the
            # same ends (MLP-GSC vs MLP-HR): key the hidden widths too.
            extra="stack" + "x".join(str(n) for _, n in shapes),
            measure=_measure if not interpret else None)
        block_m = cfg.block_m
    if not use_kernel or not fits:
        return _chain_fallback(use_kernel)
    return _call_fused(block_m)


def ecl_quant(w: jax.Array, omega: jax.Array, penalty: jax.Array,
              use_kernel: bool = True,
              interpret: Optional[bool] = None,
              block_r: Optional[int] = None,
              block_c: Optional[int] = None):
    """Fused ECL assign + dequant. Returns (codes uint8, w_hat f32).

    ``block_r/block_c=None`` (default) defer to the autotuner — the same
    cache → timed-sweep → heuristic tiering as the matmul kernels, keyed
    as an elementwise problem so it can never collide with a matmul
    shape's blocks.  Explicit values win.
    """
    if not use_kernel:
        return ref.ecl_quant_ref(w, omega, penalty)
    interpret = _default_interpret() if interpret is None else interpret
    squeeze = w.ndim == 1
    w2 = w[None, :] if squeeze else w.reshape(w.shape[0], -1)
    if block_r is None or block_c is None:
        def _measure(cfg: autotune.BlockConfig) -> float:
            return _timeit(lambda: ecl_quant_pallas(
                w2, omega, penalty, block_r=cfg.block_m,
                block_c=cfg.block_n, interpret=interpret))

        cfg = autotune.get_elementwise_config(
            w2.shape[0], w2.shape[1], dtype=str(w2.dtype),
            backend="interpret" if interpret else None,
            measure=_measure if not interpret else None)
        block_r = block_r or cfg.block_m
        block_c = block_c or cfg.block_n
    codes, what = ecl_quant_pallas(w2, omega, penalty,
                                   block_r=block_r, block_c=block_c,
                                   interpret=interpret)
    if squeeze:
        return codes[0], what[0]
    return codes.reshape(w.shape), what.reshape(w.shape)
