"""Pallas TPU kernel: packed-int4 ACM matmul with fused §V epilogue.

TPU adaptation of the FantastIC4 ACM engine (DESIGN.md §2): the packed 4-bit
codes travel HBM→VMEM at 4 bits/weight (the paper's data-movement win); a
VMEM tile is decoded to ``W_tile = Σ_i ω_i B_i`` with VPU ops (the 4
"multipliers" of the paper become 4 scalar·mask AXPYs per tile) and consumed
by a single MXU matmul. The per-layer epilogue (×α₁ per-feature, +bias,
ReLU, ×α₂) is fused so the (M,N) output never round-trips HBM between ops —
the software analogue of the paper's pipelined float unit.

Layouts / tiling:
  x       (M, K)     activation tile (bm, bk) — revisited across the N grid
                     (activation-stationary dataflow, §V-C).
  packed  (K//2, N)  two codes per byte along K (sublane interleave unpack).
  omega   (1, 4) f32; bias/alpha1 (1, N) f32; alpha2 (1, 1) f32.
  out     (M, N)     accumulated in an f32 VMEM scratch across the K grid.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary"), M/N parallel.

Relation to the fused serving megakernel (fantastic4_fused_mlp.py): this
kernel fuses *within* one layer, so a served L-layer stack still round-trips
the (M, N) activation through HBM L−1 times:

    per-layer:  HBM ─x─▶ [L₁] ─▶ HBM ─▶ [L₂] ─▶ HBM ─▶ … ─▶ [L_n] ─▶ HBM
    fused:      HBM ─x─▶ [L₁ ▸ L₂ ▸ … ▸ L_n] ─▶ HBM   (acts in VMEM scratch)

The megakernel is the default serving path whenever the whole stack's
packed weights + activation scratch fit the VMEM budget (all paper MLPs
do at 4 bits/weight); this kernel is the fallback for oversized layers and
the building block for everything non-MLP.  Block sizes default to the
shape-aware autotuner (autotune.py) via ops.fantastic4_matmul.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import COMPILER_PARAMS, ref


def _kernel(x_ref, w_ref, omega_ref, alpha1_ref, bias_ref, alpha2_ref,
            o_ref, acc_ref, *, activation: Optional[str], n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = w_ref[...]                                   # (bk//2, bn) uint8
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    codes = jnp.stack([lo, hi], axis=1)                   # (bk//2, 2, bn)
    codes = codes.reshape(packed.shape[0] * 2, packed.shape[1])

    # W_tile = Σ_i ω_i B_i  — the 4-multiplier ACM recombination, per tile.
    w_tile = jnp.zeros(codes.shape, jnp.float32)
    for i in range(4):
        bit = ((codes >> i) & 1).astype(jnp.float32)
        w_tile = w_tile + omega_ref[0, i] * bit

    x_tile = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_tile, w_tile,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        y = acc_ref[...]
        y = y * alpha1_ref[...]                           # (1, bn) broadcasts
        y = y + bias_ref[...]
        y = ref.apply_activation(y, activation)
        y = y * alpha2_ref[0, 0]
        o_ref[...] = y.astype(o_ref.dtype)


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "out_dtype", "block_m", "block_n",
                     "block_k", "interpret"))
def fantastic4_matmul_pallas(
        x: jax.Array, packed: jax.Array, omega: jax.Array,
        alpha1: jax.Array, bias: jax.Array, alpha2: jax.Array,
        *, activation: Optional[str] = None, out_dtype=None,
        block_m: int = 128, block_n: int = 256, block_k: int = 512,
        interpret: bool = False) -> jax.Array:
    """x:(M,K) f32/bf16/int8 · packed:(K//2,N) uint8 -> (M,N) out_dtype."""
    m, k = x.shape
    k2, n = packed.shape
    assert k == 2 * k2, (x.shape, packed.shape)
    out_dtype = out_dtype or x.dtype

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(packed, 0, bk // 2), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    alpha1 = _pad_to(alpha1.reshape(1, -1).astype(jnp.float32), 1, bn)
    bias = _pad_to(bias.reshape(1, -1).astype(jnp.float32), 1, bn)
    alpha2 = alpha2.reshape(1, 1).astype(jnp.float32)
    omega = omega.reshape(1, 4).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wp, omega, alpha1, bias, alpha2)
    return out[:m, :n]
