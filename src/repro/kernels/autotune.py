"""Shape-aware block-size autotuner for the FantastIC4 Pallas kernels.

The seed kernels ran every shape with hard-coded ``block_m=128 / block_n=256
/ block_k=512``; paper-shaped layers (512×512 down to 128×12) and serving
batches (1…256) leave most of those tiles as padding.  This module picks
per-shape blocks instead, in three tiers:

1. **memory cache** — a dict keyed by
   ``(backend, M, K, N, dtype, fused, act_dtype)``; ``act_dtype`` is the
   serving path's inter-layer activation dtype (the fused kernel's int8
   mode has a different body — extra quantize/cast per layer — so its best
   block must not shadow the fp32 sweep for the same shape).  Cache files
   written before this field existed are migrated on load: their keys are
   re-interpreted as ``act_dtype=float32`` entries.
2. **persistent JSON cache** — survives processes, so the timed sweep runs
   once per shape per host.  Location: ``$FANTASTIC4_AUTOTUNE_CACHE`` or
   ``~/.cache/fantastic4/autotune.json``.
3. **resolution** — on a real accelerator a *timed candidate sweep* (the
   caller supplies ``measure``, a ``BlockConfig -> seconds`` closure running
   the actual kernel; AttentionEngine-style empirical tuning); in
   interpret/CPU mode a *pure heuristic* (timing the interpreter is
   meaningless), which clamps blocks to the padded problem dims so small
   layers stop paying for 128×256×512 tiles.

``ops.fantastic4_matmul`` / ``ops.fantastic4_mlp_fused`` consult this module
whenever a block size is left as ``None`` — the default for every entry
point (serving launcher, benchmarks, models), so all of them exercise the
same tuned configuration.

**Autotuner v2 — schedule-aware bucket tuning.**  The serving engine runs
four fused kernel schedules (batch-tiled / double-buffered / weight-
stationary / decode-amortized streaming) and the right one depends on the
batch bucket, not just the shape: the tuning unit is ``(bucket_rows,
schedule)``.  :func:`get_schedule_config` resolves one bucket's binding —
a timed sweep over every eligible ``(schedule, block_m)`` candidate on a
real backend, a dataflow prior plus migration from the old single-entry
fused keys otherwise — and persists it in the same JSON cache under a
``…|bucket`` key whose value carries a ``schedule`` field.  Old cache
files (block-only values, single fused entry tuned at the largest bucket)
load unchanged and seed the per-bucket entries instead of being
discarded.  The measured ws↔batch-tiled crossover row count is stored
alongside (:func:`record_ws_crossover` / :func:`get_ws_crossover`) so a
committed TPU cache replaces the ``WS_BUCKET_ROWS`` constant with a
measurement.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import jax

ENV_CACHE = "FANTASTIC4_AUTOTUNE_CACHE"

# sublane/lane granularity of a f32 TPU tile; block dims are clamped to
# multiples of these so padding stays inside one tile.
SUBLANE = 8
LANE = 128

# the fused megakernel schedules a bucket can bind to (serving.plans maps
# these onto its bucket paths); "ws_crossover" additionally marks the
# stored ws↔batch-tiled crossover entry, which is metadata, not a schedule.
SCHEDULES = ("ws", "batch_tiled", "db", "stream")


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    block_m: int
    block_n: int
    block_k: int
    source: str = "heuristic"  # "heuristic" | "sweep" | "cache" | "migrated"
    schedule: Optional[str] = None     # set on (bucket, schedule) entries
    # the eligible set a (bucket, schedule) sweep actually measured over:
    # a cached winner only answers callers whose eligible set it covered
    # (a ws-opt-out plan's sweep must not shadow a default plan's)
    swept: Optional[Tuple[str, ...]] = None

    def as_tuple(self) -> tuple:
        return (self.block_m, self.block_n, self.block_k)

    def same_blocks(self, other: "BlockConfig") -> bool:
        return self.as_tuple() == other.as_tuple()


_lock = threading.Lock()
_memory: Dict[str, BlockConfig] = {}
_disk_loaded_for: Optional[str] = None


def _round_up(v: int, mult: int) -> int:
    return -(-max(v, 1) // mult) * mult


def cache_path() -> str:
    return os.environ.get(ENV_CACHE) or os.path.join(
        os.path.expanduser("~"), ".cache", "fantastic4", "autotune.json")


def cache_key(m: int, k: int, n: int, *, dtype: str, fused: bool,
              backend: str, act_dtype: str = "float32",
              extra: str = "") -> str:
    """``extra`` disambiguates problems that share (M, K, N) — e.g. a fused
    stack's intermediate widths, which (M, K₀, N_last) alone cannot see."""
    tail = f"|{extra}" if extra else ""
    return (f"{backend}|m{m}|k{k}|n{n}|{dtype}|fused{int(fused)}"
            f"|act{act_dtype}{tail}")


def _migrate_key(key: str) -> str:
    """Rewrite a pre-act_dtype cache key to the current format.

    Old keys read ``backend|m..|k..|n..|dtype|fusedX[|extra]``; the act
    segment slots in after ``fusedX`` as ``actfloat32`` (the only act dtype
    that existed then).  Current-format keys pass through unchanged."""
    segs = key.split("|")
    for i, seg in enumerate(segs):
        if seg.startswith("fused") and seg[5:].isdigit():
            if i + 1 < len(segs) and segs[i + 1].startswith("act"):
                return key
            return "|".join(segs[:i + 1] + ["actfloat32"] + segs[i + 1:])
    return key


def clear_memory_cache() -> None:
    """Drop the in-process cache (tests; the JSON file is untouched)."""
    global _disk_loaded_for
    with _lock:
        _memory.clear()
        _disk_loaded_for = None


def _load_disk_locked() -> None:
    global _disk_loaded_for
    path = cache_path()
    if _disk_loaded_for == path:
        return
    _disk_loaded_for = path
    if not os.path.exists(path):
        return
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return
    for key, v in raw.items():
        try:
            sched = v.get("schedule")
            swept = v.get("swept")
            cfg = BlockConfig(int(v["block_m"]), int(v["block_n"]),
                              int(v["block_k"]),
                              source=v.get("source", "cache"),
                              schedule=str(sched) if sched else None,
                              swept=tuple(str(s) for s in swept)
                              if swept else None)
        except (KeyError, TypeError, ValueError):
            continue                     # stale/corrupt entry: ignore
        key = _migrate_key(key)          # pre-act_dtype files -> actfloat32
        if key not in _memory:
            _memory[key] = cfg


def _save_disk_locked() -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {}
    for key, c in sorted(_memory.items()):
        entry = {"block_m": c.block_m, "block_n": c.block_n,
                 "block_k": c.block_k, "source": c.source}
        if c.schedule is not None:       # block-only entries keep the old
            entry["schedule"] = c.schedule   # format byte for byte
        if c.swept is not None:
            entry["swept"] = list(c.swept)
        payload[key] = entry
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)


def heuristic_blocks(m: int, k: int, n: int, *, fused: bool = False,
                     backend: Optional[str] = None) -> BlockConfig:
    """Shape-clamped blocks, no timing.

    The guiding costs: (a) never tile past the (tile-rounded) problem dims —
    a 128-wide layer must not pay for a 256-wide block of padding; (b) on a
    real TPU keep x-tile + packed-tile + decoded-W-tile + acc inside a
    conservative VMEM slice; (c) in interpret mode grid steps are the cost,
    so take whole (rounded) dims up to a cap.  Fused kernels tile only over
    M (weights/activations are VMEM-resident), so block_n/block_k are the
    rounded full dims.
    """
    backend = backend or jax.default_backend()
    mp = _round_up(m, SUBLANE)
    np_ = _round_up(n, LANE)
    kp = _round_up(k, LANE)
    if fused or backend != "tpu":
        # one grid axis (fused) / interpreter (CPU): minimise grid steps.
        return BlockConfig(min(mp, 256), min(np_, 1024), min(kp, 2048))
    # TPU per-layer kernel: MXU-friendly tiles clamped to the problem.
    bm = min(mp, 128)
    bn = min(np_, 256)
    bk = min(kp, 512)
    # keep x(bm,bk)f32 + packed(bk/2,bn)u8 + W(bk,bn)f32 + acc(bm,bn)f32
    # comfortably under a ~4 MiB working-set slice of VMEM.
    def _bytes(bm, bn, bk):
        return 4 * bm * bk + bk * bn // 2 + 4 * bk * bn + 4 * bm * bn
    while _bytes(bm, bn, bk) > 4 << 20 and bk > LANE:
        bk //= 2
    while _bytes(bm, bn, bk) > 4 << 20 and bn > LANE:
        bn //= 2
    return BlockConfig(bm, bn, bk)


def heuristic_elementwise_blocks(r: int, c: int, *,
                                 backend: Optional[str] = None
                                 ) -> BlockConfig:
    """Shape-clamped tiles for 2-D elementwise kernels (``ecl_quant``).

    ``block_k`` is meaningless for an elementwise grid and is pinned to 0
    (the sentinel the cache key carries).  Costs mirror
    :func:`heuristic_blocks`: clamp to the (tile-rounded) problem, minimise
    grid steps in interpret mode, and on TPU keep the w/codes/w_hat tiles
    (4 + 1 + 4 bytes per element) inside a conservative VMEM slice.
    """
    backend = backend or jax.default_backend()
    rp = _round_up(r, SUBLANE)
    cp = _round_up(c, LANE)
    if backend != "tpu":
        return BlockConfig(min(rp, 512), min(cp, 1024), 0)
    br, bc = min(rp, 256), min(cp, 512)
    while 9 * br * bc > (4 << 20) and bc > LANE:
        bc //= 2
    while 9 * br * bc > (4 << 20) and br > SUBLANE:
        br //= 2
    return BlockConfig(br, bc, 0)


def candidate_elementwise_blocks(r: int, c: int) -> Sequence[BlockConfig]:
    """Candidate (block_r, block_c) grid for the elementwise timed sweep."""
    rp, cp = _round_up(r, SUBLANE), _round_up(c, LANE)
    brs = sorted({min(rp, v) for v in (64, 128, 256, 512)})
    bcs = sorted({min(cp, v) for v in (128, 256, 512, 1024)})
    return [BlockConfig(br, bc, 0, source="sweep")
            for br in brs for bc in bcs]


def _resolve_and_cache(key: str, *,
                       measure: Optional[Callable[[BlockConfig], float]],
                       candidates: Callable[[], Iterable[BlockConfig]],
                       heuristic: Callable[[], BlockConfig],
                       persist: bool) -> BlockConfig:
    """Shared cache → timed-sweep → heuristic tiering (one implementation
    for the matmul and elementwise entry points).  ``candidates`` and
    ``heuristic`` are thunks so neither is built on a cache hit."""
    with _lock:
        _load_disk_locked()
        hit = _memory.get(key)
    if hit is not None:
        return hit
    if measure is not None:
        cands = list(candidates())
        timed = [(measure(c), i) for i, c in enumerate(cands)]
        best_t, best_i = min(timed)
        if best_t != float("inf"):
            cfg = dataclasses.replace(cands[best_i], source="sweep")
        else:
            cfg = heuristic()
    else:
        cfg = heuristic()
    with _lock:
        _memory[key] = cfg
        if persist:
            try:
                _save_disk_locked()
            except OSError:
                pass                      # read-only FS: memory cache only
    return cfg


def get_elementwise_config(r: int, c: int, *,
                           dtype: str = "float32",
                           backend: Optional[str] = None,
                           measure: Optional[
                               Callable[[BlockConfig], float]] = None,
                           op: str = "eclquant",
                           persist: bool = True) -> BlockConfig:
    """Resolve (block_r, block_c) for a 2-D elementwise kernel.

    Same cache → sweep → heuristic tiering as :func:`get_block_config`;
    entries live in the same store under ``k=0`` plus an ``op`` extra, so
    they can never collide with a matmul shape's blocks.
    """
    backend = backend or jax.default_backend()
    return _resolve_and_cache(
        cache_key(r, 0, c, dtype=dtype, fused=False, backend=backend,
                  extra=op),
        measure=measure,
        candidates=lambda: candidate_elementwise_blocks(r, c),
        heuristic=lambda: heuristic_elementwise_blocks(r, c,
                                                       backend=backend),
        persist=persist)


def candidate_blocks(m: int, k: int, n: int, *, fused: bool = False
                     ) -> Sequence[BlockConfig]:
    """Candidate grid for the timed sweep (deduped, shape-clamped)."""
    mp, np_, kp = _round_up(m, SUBLANE), _round_up(n, LANE), _round_up(k, LANE)
    bms = sorted({min(mp, v) for v in (32, 64, 128, 256)})
    if fused:
        return [BlockConfig(bm, min(np_, 1024), min(kp, 2048), source="sweep")
                for bm in bms]
    bns = sorted({min(np_, v) for v in (128, 256, 512)})
    bks = sorted({min(kp, v) for v in (128, 256, 512, 1024)})
    return [BlockConfig(bm, bn, bk, source="sweep")
            for bm in bms for bn in bns for bk in bks]


def get_block_config(m: int, k: int, n: int, *,
                     dtype: str = "float32", fused: bool = False,
                     backend: Optional[str] = None,
                     measure: Optional[Callable[[BlockConfig], float]] = None,
                     candidates: Optional[Iterable[BlockConfig]] = None,
                     act_dtype: str = "float32",
                     extra: str = "",
                     persist: bool = True) -> BlockConfig:
    """Resolve blocks for one problem shape (cache → sweep → heuristic).

    ``measure`` runs one candidate and returns seconds (``inf`` = candidate
    failed to compile/run); when omitted — the interpret/CPU path — the
    heuristic answers directly.  Results land in the memory cache and, when
    ``persist``, the JSON cache, so a warm call never re-measures.

    Callers running in interpret mode must pass ``backend="interpret"``:
    keying those heuristic answers under the real backend would permanently
    mask the timed sweep for the same shape on actual hardware.
    """
    backend = backend or jax.default_backend()
    return _resolve_and_cache(
        cache_key(m, k, n, dtype=dtype, fused=fused, backend=backend,
                  act_dtype=act_dtype, extra=extra),
        measure=measure,
        candidates=lambda: (candidates if candidates is not None
                            else candidate_blocks(m, k, n, fused=fused)),
        heuristic=lambda: heuristic_blocks(m, k, n, fused=fused,
                                           backend=backend),
        persist=persist)


# --------------------------------------- v2: (bucket, schedule) tuning unit

def bucket_cache_key(rows: int, k: int, n: int, *, dtype: str = "float32",
                     backend: Optional[str] = None,
                     act_dtype: str = "float32", stack: str = "") -> str:
    """Key of one batch bucket's (schedule, block_m) binding."""
    backend = backend or jax.default_backend()
    return cache_key(rows, k, n, dtype=dtype, fused=True, backend=backend,
                     act_dtype=act_dtype,
                     extra=(f"{stack}|" if stack else "") + "bucket")


def ws_crossover_key(k: int, n: int, *, dtype: str = "float32",
                     backend: Optional[str] = None,
                     act_dtype: str = "float32", stack: str = "") -> str:
    backend = backend or jax.default_backend()
    return cache_key(0, k, n, dtype=dtype, fused=True, backend=backend,
                     act_dtype=act_dtype,
                     extra=(f"{stack}|" if stack else "") + "wscross")


def candidate_schedule_blocks(rows: int, schedules: Sequence[str]
                              ) -> Sequence[Tuple[str, int]]:
    """Candidate (schedule, block_m) grid for one bucket's timed sweep.

    ``ws`` holds the whole (padded) bucket in its scratch — block_m is not
    a free variable there; the tiled schedules sweep the shape-clamped
    block_m ladder (``db`` needs two whole sublane groups per tile, so its
    candidates keep to multiples of 16).
    """
    mp = _round_up(rows, SUBLANE)
    out = []
    for sched in schedules:
        if sched == "ws":
            out.append((sched, mp))
            continue
        bms = sorted({min(mp, v) for v in (32, 64, 128, 256)})
        if sched == "db":
            bms = [b for b in bms if b % 16 == 0]
        out.extend((sched, bm) for bm in bms)
    return out


def get_schedule_config(rows: int, k: int, n: int, *,
                        schedules: Sequence[str],
                        prior: str,
                        dtype: str = "float32",
                        backend: Optional[str] = None,
                        act_dtype: str = "float32",
                        stack: str = "",
                        measure: Optional[
                            Callable[[str, int], float]] = None,
                        legacy_m: Optional[int] = None,
                        block_m_hint: Optional[int] = None,
                        persist: bool = True) -> BlockConfig:
    """Resolve one batch bucket's (schedule, block_m) binding.

    ``schedules`` is the bucket's *eligible* set (VMEM-fit and opt-outs
    already applied by the caller, in plans); ``prior`` the dataflow-
    motivated pre-measurement answer.  ``measure(schedule, block_m) ->
    seconds`` runs the actual kernel on a real backend (``inf`` =
    candidate failed); without it — the interpret/CPU tier, where timing
    the interpreter is meaningless — the prior answers, with ``block_m``
    migrated from the old single-entry fused key (``legacy_m`` = the rows
    it was tuned at) or from ``block_m_hint`` rather than re-derived.

    Cache-validity is *eligibility-aware*: an entry records the set it was
    swept over (``swept``) and only answers callers whose eligible set it
    covered.  When coverage is incomplete (or the cached winner is one the
    caller forbids — e.g. a measured ``ws`` binding under
    ``ws_bucket_rows=0`` opt-out) and a ``measure`` is available, the
    sweep runs over the *union* of the caller's set and the entry's
    covered set: the stored entry becomes the union's winner (valid for
    every caller the union covers, so two plans with different eligible
    sets converge instead of alternately re-sweeping and shadowing each
    other), while the caller receives the best candidate *it* is allowed
    to bind.  Without a measure, a forbidden winner is bypassed but not
    overwritten — the prior answers uncached and the measurement survives.
    """
    if not schedules:
        raise ValueError("schedules must name at least one eligible "
                         "schedule")
    unknown = [s for s in schedules if s not in SCHEDULES]
    if unknown:
        raise ValueError(f"unknown schedules {unknown}; valid: {SCHEDULES}")
    if prior not in schedules:
        prior = schedules[0]
    backend = backend or jax.default_backend()
    key = bucket_cache_key(rows, k, n, dtype=dtype, backend=backend,
                           act_dtype=act_dtype, stack=stack)
    with _lock:
        _load_disk_locked()
        hit = _memory.get(key)
    covered: set = set()
    if hit is not None:
        covered = set(hit.swept) if hit.swept else \
            ({hit.schedule} if hit.schedule else set())
        # a hit answers only when its sweep covered every schedule this
        # caller may bind (else a restricted plan's winner would shadow
        # the broader sweep); without a measure it is still the best
        # measurement this backend has, so take it.
        if hit.schedule in schedules and \
                (set(schedules) <= covered or measure is None):
            return hit
    mp = _round_up(rows, SUBLANE)
    cfg = None
    store = None
    if measure is not None:
        # sweep the union of the caller's set and whatever the existing
        # entry had covered: the stored result then answers both this
        # caller and the ones the old entry served, so plans with
        # different eligible sets converge on one complete entry instead
        # of alternately re-sweeping and shadowing each other.
        sweep_set = tuple(schedules) + tuple(
            s for s in SCHEDULES if s in covered and s not in schedules)
        cands = list(candidate_schedule_blocks(rows, sweep_set))
        timed = [(measure(s, bm), i) for i, (s, bm) in enumerate(cands)]
        finite = [(t, i) for t, i in timed if t != float("inf")]
        caller_finite = [(t, i) for t, i in finite
                         if cands[i][0] in schedules]
        if caller_finite:
            t, i = min(caller_finite)
            s, bm = cands[i]
            cfg = BlockConfig(bm, 0, 0, source="sweep", schedule=s,
                              swept=sweep_set)
            tu, iu = min(finite)
            if iu == i:
                store = cfg
            else:                        # union winner differs: store it,
                su, bmu = cands[iu]      # hand the caller its own best
                store = BlockConfig(bmu, 0, 0, source="sweep",
                                    schedule=su, swept=sweep_set)
    if cfg is None:
        bm, source = None, "heuristic"
        if legacy_m is not None:
            # old single-entry fused key: one block_m tuned at the largest
            # bucket — reuse it (clamped to this bucket) instead of
            # discarding the measurement.
            with _lock:
                legacy = _memory.get(cache_key(
                    legacy_m, k, n, dtype=dtype, fused=True,
                    backend=backend, act_dtype=act_dtype, extra=stack))
            if legacy is not None:
                bm, source = min(legacy.block_m, mp), "migrated"
        if bm is None and block_m_hint is not None:
            bm = min(block_m_hint, mp)
        if bm is None:
            bm = heuristic_blocks(rows, k, n, fused=True,
                                  backend=backend).block_m
        cfg = BlockConfig(bm, 0, 0, source=source, schedule=prior)
    if store is None:
        # prior/migrated answers depend on the *caller's* eligibility and
        # requests (ws opt-out, double_buffer) — caching them would let one
        # plan's configuration shadow another's, and would mask the real
        # backend's future sweep.  Only measurements enter the cache.
        return cfg
    with _lock:
        _memory[key] = store
        if persist:
            try:
                _save_disk_locked()
            except OSError:
                pass
    return cfg


def record_ws_crossover(rows: int, k: int, n: int, *,
                        dtype: str = "float32",
                        backend: Optional[str] = None,
                        act_dtype: str = "float32", stack: str = "",
                        persist: bool = True) -> None:
    """Persist the measured ws↔batch-tiled crossover: the largest bucket
    row count at which the weight-stationary schedule won the sweep (0 =
    ws never won).  Replaces the ``WS_BUCKET_ROWS`` constant as the plan's
    gate once a real backend has measured."""
    backend = backend or jax.default_backend()
    key = ws_crossover_key(k, n, dtype=dtype, backend=backend,
                           act_dtype=act_dtype, stack=stack)
    cfg = BlockConfig(int(rows), 0, 0, source="sweep",
                      schedule="ws_crossover")
    with _lock:
        _load_disk_locked()      # merge with existing entries, never clobber
        _memory[key] = cfg
        if persist:
            try:
                _save_disk_locked()
            except OSError:
                pass


def get_ws_crossover(k: int, n: int, *, dtype: str = "float32",
                     backend: Optional[str] = None,
                     act_dtype: str = "float32",
                     stack: str = "") -> Optional[int]:
    """Measured ws↔batch-tiled crossover row count, or None if this
    backend has never swept the stack."""
    backend = backend or jax.default_backend()
    key = ws_crossover_key(k, n, dtype=dtype, backend=backend,
                           act_dtype=act_dtype, stack=stack)
    with _lock:
        _load_disk_locked()
        hit = _memory.get(key)
    if hit is None or hit.schedule != "ws_crossover":
        return None
    return hit.block_m
