"""Pallas TPU kernel: fused ECL assignment + dequantization (QAT hot loop).

Every EC4T training step re-assigns every master weight to one of the 16
subset-sum centroids (cost = squared distance + entropy penalty, §IV-C) and
dequantizes it for the STE forward. Unfused, that is an HBM-bound chain of
~20 elementwise ops over every parameter; fused it is one read of W and one
write each of (codes, w_hat) per element.

Tiling: plain 2-D elementwise grid, (block_r, block_c) VMEM tiles. The 16
candidate costs are an unrolled VPU loop with a running (best_cost,
best_code, best_val) select — no gather, MXU untouched.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import COMPILER_PARAMS


def _kernel(w_ref, omega_ref, pen_ref, codes_ref, what_ref):
    w = w_ref[...].astype(jnp.float32)
    best_cost = jnp.full(w.shape, jnp.inf, jnp.float32)
    best_code = jnp.zeros(w.shape, jnp.uint8)
    best_val = jnp.zeros(w.shape, jnp.float32)
    for c in range(16):
        v = jnp.zeros((), jnp.float32)
        for i in range(4):
            if (c >> i) & 1:
                v = v + omega_ref[0, i]
        cost = (w - v) ** 2 + pen_ref[0, c]
        take = cost < best_cost
        best_cost = jnp.where(take, cost, best_cost)
        best_code = jnp.where(take, jnp.uint8(c), best_code)
        best_val = jnp.where(take, v, best_val)
    codes_ref[...] = best_code
    what_ref[...] = best_val.astype(what_ref.dtype)


def _pad_to(a, axis, mult):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_c", "interpret"))
def ecl_quant_pallas(w: jax.Array, omega: jax.Array, penalty: jax.Array,
                     *, block_r: int = 256, block_c: int = 512,
                     interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
    """w:(R,C) -> (codes uint8 (R,C), w_hat f32 (R,C)).

    penalty: (16,) f32 = lam * (-log2 probs), precomputed on host/XLA side.
    """
    r, c = w.shape
    br, bc = min(block_r, r), min(block_c, c)
    wp = _pad_to(_pad_to(w, 0, br), 1, bc)
    rp, cp = wp.shape
    grid = (rp // br, cp // bc)

    omega2 = omega.reshape(1, 4).astype(jnp.float32)
    pen2 = penalty.reshape(1, 16).astype(jnp.float32)

    codes, what = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 4), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 16), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), jnp.uint8),
            jax.ShapeDtypeStruct((rp, cp), jnp.float32),
        ],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(wp, omega2, pen2)
    return codes[:r, :c], what[:r, :c]
