"""Pure-jnp oracles for the FantastIC4 kernels.

``fantastic4_matmul_ref`` — decode packed 4-bit codes to weights, one f32
matmul, fused §V epilogue.  ``acm_bitplane_ref`` — the *literal* ACM paradigm
of eq. (1): four bit-plane dot products accumulated first, multiplied by the
4 basis centroids last.  Both are mathematically identical; tests assert it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core import bitplanes


# elementwise activations the epilogue can fuse.  The integer codes are
# the wire format of the ws/stream schedules' meta operand (the layer id is
# traced there, so the choice must be data, not a python branch); the
# batch-tiled kernels and this oracle branch statically on the name.  Both
# relu(0) and gelu(0) are exactly 0.0, so zero-padded epilogue columns stay
# zero under every supported activation.
ACTIVATION_CODES = {None: 0, "none": 0, "relu": 1, "gelu": 2}


def activation_code(activation: Optional[str]) -> int:
    try:
        return ACTIVATION_CODES[activation]
    except KeyError:
        raise ValueError(f"unsupported activation {activation}") from None


def apply_activation(y: jax.Array, activation: Optional[str]) -> jax.Array:
    """Shared static-activation branch: oracle and every kernel schedule
    route through the same expressions, so schedule parity is bitwise."""
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation in (None, "none"):
        return y
    raise ValueError(f"unsupported activation {activation}")


def apply_activation_coded(y: jax.Array, code: jax.Array) -> jax.Array:
    """Traced-code twin of ``apply_activation`` for the ws/stream kernels,
    where the layer id (hence the activation choice) is runtime data.  The
    selected branch computes the exact same expression as the static one,
    so the two forms agree bitwise."""
    return jnp.where(code > 1.5, jax.nn.gelu(y),
                     jnp.where(code > 0.5, jnp.maximum(y, 0.0), y))


def _epilogue(y: jax.Array, bias, alpha1, alpha2, activation: Optional[str],
              out_dtype) -> jax.Array:
    if alpha1 is not None:
        y = y * alpha1.astype(y.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    y = apply_activation(y, activation)
    if alpha2 is not None:
        y = y * jnp.asarray(alpha2, y.dtype)
    return y.astype(out_dtype)


def unpack_rows(packed: jax.Array) -> jax.Array:
    """(K//2, N) uint8 -> (K, N) uint8 codes; byte r = c[2r] | c[2r+1]<<4."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    return jnp.stack([lo, hi], axis=1).reshape(packed.shape[0] * 2,
                                               packed.shape[1])


def decode_weights(packed: jax.Array, omega: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    return bitplanes.decode(unpack_rows(packed), omega, dtype)


def fantastic4_matmul_ref(x: jax.Array, packed: jax.Array, omega: jax.Array,
                          bias=None, alpha1=None, alpha2=None,
                          activation: Optional[str] = None,
                          out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    w = decode_weights(packed, omega, jnp.float32)
    y = x.astype(jnp.float32) @ w
    return _epilogue(y, bias, alpha1, alpha2, activation, out_dtype)


def acm_bitplane_ref(x: jax.Array, packed: jax.Array, omega: jax.Array,
                     bias=None, alpha1=None, alpha2=None,
                     activation: Optional[str] = None,
                     out_dtype=None) -> jax.Array:
    """Literal accumulate-then-multiply (paper fig. 1): accumulate activations
    per bit-plane, then 4 multiplies + 3 adds per output element."""
    out_dtype = out_dtype or x.dtype
    codes = unpack_rows(packed)
    xf = x.astype(jnp.float32)
    acc = 0.0
    for i in range(bitplanes.NUM_BASIS):
        plane = ((codes >> i) & 1).astype(jnp.float32)       # B_i
        acc = acc + omega[i].astype(jnp.float32) * (xf @ plane)
    return _epilogue(acc, bias, alpha1, alpha2, activation, out_dtype)


def ecl_quant_ref(w: jax.Array, omega: jax.Array, penalty: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Fused ECL assignment + dequantization oracle.

    penalty = lam * (-log2 probs), precomputed (16,).
    Returns (codes uint8, w_hat f32).
    """
    book = bitplanes.codebook(omega).astype(jnp.float32)
    cost = (w.astype(jnp.float32)[..., None] - book) ** 2 + penalty
    codes = jnp.argmin(cost, axis=-1).astype(jnp.uint8)
    return codes, book[codes]
