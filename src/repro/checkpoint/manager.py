"""Checkpointing: atomic, keep-k, elastic restore, compressed 4-bit exports.

Two artifact kinds:

* **train checkpoints** (``save``/``restore``) — the full train state
  (fp32 masters, Adam moments, ECL probs, step).  Written to a temp dir and
  ``os.replace``d into place, so a preemption mid-write never corrupts the
  latest checkpoint; ``keep`` old steps are garbage-collected.  Restore is
  *elastic*: arrays are loaded host-side and ``jax.device_put`` with the
  *current* mesh's NamedSharding — restoring a 512-chip checkpoint onto 256
  chips (or a different DP/TP split) just reshards (DESIGN.md §4).

* **serving exports** (``export_quantized``) — the paper's artifact: per
  quantized tensor, ECL codes stored in their cheapest lossless format
  (CSR / bitmask / dense4, contribution 4) + the 4 fp32 centroids.  This is
  where Table II's 8–29× byte reduction lands on checkpoint/restart I/O.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..core import ecl, formats, qat

SEP = "//"


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[name] = np.asarray(leaf)
    return flat


def _tree_like(template: Any, flat: dict) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        name = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing {name}")
        arr = flat[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Atomic: write to tmp, fsync, rename.  Returns the final path."""
        flat = _flatten(state)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            meta = {"step": int(step), **(extra or {})}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---------------------------------------------------------- restore

    def restore(self, template: Any, step: Optional[int] = None,
                sharding_fn: Optional[Callable] = None):
        """Load into the structure of ``template``.  ``sharding_fn(path
        leaf) -> Sharding`` places each array on the *current* mesh
        (elastic resharding); None keeps arrays on the default device.
        Returns (state, meta)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "state.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _tree_like(template, flat)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        if sharding_fn is not None:
            state = jax.tree_util.tree_map(
                lambda leaf: jax.device_put(leaf, sharding_fn(leaf)), state)
        return state, meta


# ------------------------------------------------------------- exports

def export_quantized(path: str, params: Any, qstate: Any, lam: float):
    """Write the 4-bit serving artifact: codes in their cheapest lossless
    format + centroids; unquantized leaves as-is.  Returns a size report
    (the Table II analogue over this model)."""
    os.makedirs(path, exist_ok=True)
    payload: dict = {}
    report = {"tensors": {}, "compressed_bytes": 0, "fp32_bytes": 0,
              "dense4_bytes": 0}

    def visit(prefix, node, qs):
        if qat.is_quant_leaf(node):
            codes = np.asarray(ecl.assign(node["w"], node["omega"],
                                          qs["probs"], lam))
            flat2d = codes.reshape(-1, codes.shape[-1])
            # extended selection: CSR / bitmask / dense4 (paper) + the
            # entropy-coded huffman option (beyond-paper; wins whenever
            # EC4T pushed H below ~3.5 bits even without sparsity)
            ct = formats.encode(flat2d, formats.select_format_ext(flat2d))
            payload[prefix + SEP + "format"] = np.frombuffer(
                ct.format.encode(), dtype=np.uint8)
            payload[prefix + SEP + "shape"] = np.asarray(codes.shape)
            for k, v in ct.payload.items():
                payload[prefix + SEP + k] = v
            payload[prefix + SEP + "omega"] = np.asarray(node["omega"])
            nbytes = ct.size_bytes + node["omega"].size * 4
            report["tensors"][prefix] = {
                "format": ct.format, "bytes": nbytes,
                "sparsity": float((codes == 0).mean())}
            report["compressed_bytes"] += nbytes
            report["fp32_bytes"] += codes.size * 4
            report["dense4_bytes"] += (codes.size + 1) // 2
            return
        if isinstance(node, dict):
            for k in node:
                visit(prefix + SEP + k if prefix else k, node[k],
                      qs[k] if isinstance(qs, dict) else 0)
        elif isinstance(node, (list, tuple)):
            for i, sub in enumerate(node):
                visit(f"{prefix}{SEP}{i}", sub,
                      qs[i] if isinstance(qs, (list, tuple)) else 0)
        else:
            payload[prefix] = np.asarray(node)
            report["fp32_bytes"] += np.asarray(node).nbytes
            report["compressed_bytes"] += np.asarray(node).nbytes
            report["dense4_bytes"] += np.asarray(node).nbytes

    visit("", params, qstate)
    np.savez(os.path.join(path, "export.npz"), **payload)
    report["compression_ratio"] = (report["fp32_bytes"]
                                   / max(report["compressed_bytes"], 1))
    with open(os.path.join(path, "report.json"), "w") as f:
        json.dump(report, f, indent=2)
    return report
