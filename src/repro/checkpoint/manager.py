"""Checkpointing: atomic, keep-k, elastic restore, compressed 4-bit exports.

Two artifact kinds:

* **train checkpoints** (``save``/``restore``) — the full train state
  (fp32 masters, Adam moments, ECL probs, step).  Written to a temp dir and
  ``os.replace``d into place, so a preemption mid-write never corrupts the
  latest checkpoint; ``keep`` old steps are garbage-collected.  Restore is
  *elastic*: arrays are loaded host-side and ``jax.device_put`` with the
  *current* mesh's NamedSharding — restoring a 512-chip checkpoint onto 256
  chips (or a different DP/TP split) just reshards (DESIGN.md §4).

* **serving exports** (``export_quantized``/``load_quantized`` for raw
  train-state tensors, ``export_pack``/``load_pack`` for frozen serving
  packs) — the paper's artifact: per quantized tensor, ECL codes stored
  in their cheapest lossless format (CSR / bitmask / dense4,
  contribution 4, + the beyond-paper huffman option) + the 4 fp32
  centroids.  This is where Table II's 8–29× byte reduction lands on
  checkpoint/restart I/O.  ``export_pack``'s on-disk form *is* the
  serving cold tier's :class:`~repro.serving.pack_cache.ColdPack` — a
  loaded pack goes straight into a ``PackCache`` without ever
  materializing decoded weights (the pack-update hot-swap path).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..core import ecl, formats, qat
from ..runtime.integrity import IntegrityError

SEP = "//"


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[name] = np.asarray(leaf)
    return flat


def _tree_like(template: Any, flat: dict) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        name = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing {name}")
        arr = flat[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Atomic: write to tmp, fsync, rename.  Returns the final path."""
        flat = _flatten(state)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            meta = {"step": int(step), **(extra or {})}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---------------------------------------------------------- restore

    def restore(self, template: Any, step: Optional[int] = None,
                sharding_fn: Optional[Callable] = None):
        """Load into the structure of ``template``.  ``sharding_fn(path
        leaf) -> Sharding`` places each array on the *current* mesh
        (elastic resharding); None keeps arrays on the default device.
        Returns (state, meta)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "state.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _tree_like(template, flat)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        if sharding_fn is not None:
            state = jax.tree_util.tree_map(
                lambda leaf: jax.device_put(leaf, sharding_fn(leaf)), state)
        return state, meta


# ------------------------------------------------------------- exports

def export_quantized(path: str, params: Any, qstate: Any, lam: float):
    """Write the 4-bit serving artifact: codes in their cheapest lossless
    format + centroids; unquantized leaves as-is.  Returns a size report
    (the Table II analogue over this model)."""
    os.makedirs(path, exist_ok=True)
    payload: dict = {}
    report = {"tensors": {}, "compressed_bytes": 0, "fp32_bytes": 0,
              "dense4_bytes": 0}

    def visit(prefix, node, qs):
        if qat.is_quant_leaf(node):
            codes = np.asarray(ecl.assign(node["w"], node["omega"],
                                          qs["probs"], lam))
            flat2d = codes.reshape(-1, codes.shape[-1])
            # extended selection: CSR / bitmask / dense4 (paper) + the
            # entropy-coded huffman option (beyond-paper; wins whenever
            # EC4T pushed H below ~3.5 bits even without sparsity)
            ct = formats.encode(flat2d, formats.select_format_ext(flat2d))
            payload[prefix + SEP + "format"] = np.frombuffer(
                ct.format.encode(), dtype=np.uint8)
            payload[prefix + SEP + "shape"] = np.asarray(codes.shape)
            for k, v in ct.payload.items():
                payload[prefix + SEP + k] = v
            payload[prefix + SEP + "omega"] = np.asarray(node["omega"])
            nbytes = ct.size_bytes + node["omega"].size * 4
            report["tensors"][prefix] = {
                "format": ct.format, "bytes": nbytes,
                "sparsity": float((codes == 0).mean())}
            report["compressed_bytes"] += nbytes
            report["fp32_bytes"] += codes.size * 4
            report["dense4_bytes"] += (codes.size + 1) // 2
            return
        if isinstance(node, dict):
            for k in node:
                visit(prefix + SEP + k if prefix else k, node[k],
                      qs[k] if isinstance(qs, dict) else 0)
        elif isinstance(node, (list, tuple)):
            for i, sub in enumerate(node):
                visit(f"{prefix}{SEP}{i}", sub,
                      qs[i] if isinstance(qs, (list, tuple)) else 0)
        else:
            payload[prefix] = np.asarray(node)
            report["fp32_bytes"] += np.asarray(node).nbytes
            report["compressed_bytes"] += np.asarray(node).nbytes
            report["dense4_bytes"] += np.asarray(node).nbytes

    visit("", params, qstate)
    np.savez(os.path.join(path, "export.npz"), **payload)
    report["compression_ratio"] = (report["fp32_bytes"]
                                   / max(report["compressed_bytes"], 1))
    with open(os.path.join(path, "report.json"), "w") as f:
        json.dump(report, f, indent=2)
    return report


def load_quantized(path: str) -> dict:
    """Read an :func:`export_quantized` artifact back (the function used
    to be write-only — nothing consumed the paper's own artifact).
    Returns ``{tensor prefix: {"codes": (…, n) uint8, "omega": (4,)
    fp32}}`` for each quantized tensor plus ``{prefix: array}`` for the
    unquantized leaves — the decoded-code form ``bitplanes.codebook`` /
    ``decode`` consume."""
    with np.load(os.path.join(path, "export.npz")) as z:
        payload = {k: z[k] for k in z.files}
    quant_prefixes = sorted(
        k[: -len(SEP + "format")] for k in payload
        if k.endswith(SEP + "format"))
    out: dict = {}
    claimed = set()
    for prefix in quant_prefixes:
        fmt = payload[prefix + SEP + "format"].tobytes().decode()
        shape = tuple(int(d) for d in payload[prefix + SEP + "shape"])
        meta_keys = {prefix + SEP + k for k in ("format", "shape", "omega")}
        ct_payload = {}
        for key in payload:
            if key.startswith(prefix + SEP) and key not in meta_keys:
                field = key[len(prefix + SEP):]
                if SEP not in field:      # not a nested sibling tensor
                    ct_payload[field] = payload[key]
        flat2d_shape = (int(np.prod(shape[:-1])), shape[-1])
        ct = formats.CompressedTensor(fmt, flat2d_shape, ct_payload)
        out[prefix] = {"codes": formats.decode(ct).reshape(shape),
                       "omega": payload[prefix + SEP + "omega"]}
        claimed.update(meta_keys)
        claimed.update(prefix + SEP + k for k in ct_payload)
    for key, arr in payload.items():
        if key not in claimed:
            out[key] = arr
    return out


# frozen serving packs: at-rest ColdPack artifact (the cold tier's format)

def export_pack(path: str, pack_or_cold, *, meta: Optional[dict] = None
                ) -> dict:
    """Write a frozen serving pack (``models.mlp.freeze_mlp`` dict or an
    already-cold ``ColdPack``) as its at-rest compressed artifact —
    ``pack.npz`` + ``report.json`` under ``path``, atomically.  This is
    the unit a serving host pulls to (re)register a model: the bytes on
    the wire are the cold tier's bytes."""
    from ..serving.pack_cache import ColdPack, cold_pack_to_payload, \
        compress_pack
    cold = pack_or_cold if isinstance(pack_or_cold, ColdPack) \
        else compress_pack(pack_or_cold)
    payload = cold_pack_to_payload(cold)
    report = {
        "layers": [{"format": l.codes.format, "shape": list(l.shape),
                    "bytes": l.size_bytes} for l in cold.layers],
        "compressed_bytes": cold.size_bytes,
        "fp32_bytes": cold.fp32_bytes,
        "compression_ratio": cold.compression_ratio,
        **(meta or {}),
    }
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    # a crash between mkdtemp and os.replace leaves an orphaned temp
    # behind; sweep stale ones (ours are dirs, but tolerate plain *.tmp
    # files from other writers) before paying for the new write
    for name in os.listdir(parent):
        if not (name.startswith(".tmp_pack_") or name.endswith(".tmp")):
            continue
        stale = os.path.join(parent, name)
        try:
            if os.path.isdir(stale):
                shutil.rmtree(stale, ignore_errors=True)
            else:
                os.remove(stale)
        except OSError:
            pass
    tmp = tempfile.mkdtemp(dir=parent, prefix=".tmp_pack_")
    try:
        np.savez(os.path.join(tmp, "pack.npz"), **payload)
        with open(os.path.join(tmp, "report.json"), "w") as f:
            json.dump(report, f, indent=2)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return report


def load_pack(path: str, *, verify: bool = True):
    """Load an :func:`export_pack` artifact as a
    :class:`~repro.serving.pack_cache.ColdPack` — feed it to
    ``PackCache.add`` (cold registration) or ``PackCache.update`` (plan
    hot-swap on pack update) without decoding anything here.

    Partial-write hardening: a truncated / garbled / field-stripped
    ``pack.npz`` raises a typed
    :class:`~repro.runtime.integrity.IntegrityError` naming the file
    instead of a bare numpy/zlib traceback, and (``verify=True``) the
    stored payload checksums are re-verified before the pack is
    trusted."""
    from ..serving.pack_cache import cold_pack_from_payload, \
        verify_cold_pack
    npz = os.path.join(path, "pack.npz")
    try:
        with np.load(npz) as z:
            payload = {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise
    except Exception as exc:       # zipfile/zlib/pickle decode failures
        raise IntegrityError(
            f"pack artifact {npz} is truncated or garbled: {exc}",
            kind="artifact", path=npz) from exc
    try:
        cold = cold_pack_from_payload(payload)
    except IntegrityError as exc:
        raise IntegrityError(
            f"pack artifact {npz} failed verification: {exc}",
            kind="artifact", path=npz) from exc
    except (KeyError, ValueError) as exc:
        raise IntegrityError(
            f"pack artifact {npz} is missing fields (partial write?): "
            f"{exc}", kind="artifact", path=npz) from exc
    if verify:
        try:
            verify_cold_pack(cold)
        except IntegrityError as exc:
            raise IntegrityError(
                f"pack artifact {npz} failed checksum verification: "
                f"{exc}", kind="artifact", path=npz) from exc
    return cold
