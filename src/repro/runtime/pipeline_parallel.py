"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

For depth-dominated models (grok 64L / deepseek 61L at >512-chip scale) an
extra pipeline axis beats wider TP (which hits ICI latency) — DESIGN.md §4
keeps the default 2-axis mesh for the assigned 256-chip pods, and this
module supplies the third axis when scaling beyond.

Mechanics (``pipeline_apply``): the layer stack (L, ...) is split into
``n_stages`` contiguous stages, one per 'pipe'-axis shard, via shard_map.
Microbatches stream through stages with the canonical rotating schedule:
each of the ``n_micro + n_stages - 1`` ticks runs every stage on its
resident microbatch, then ``collective_permute`` rotates activations to the
next stage.  Bubble fraction = (S-1)/(M+S-1), the GPipe formula — tests
check both the math (vs a single-device reference) and the bubble
accounting.

The per-stage body is an arbitrary ``layer_fn`` (the same scan body the
non-PP path uses), so PP composes with EC4T quantization and with TP on the
trailing 'model' axis unchanged.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def stage_split(stacked_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (S, L//S, ...) stage-major."""
    def f(leaf):
        l = leaf.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return leaf.reshape(n_stages, l // n_stages, *leaf.shape[1:])
    return jax.tree_util.tree_map(f, stacked_params)


def pipeline_apply(layer_fn: Callable, stage_params: Any, x: jax.Array, *,
                   mesh: Mesh, n_micro: int, axis: str = "pipe") -> jax.Array:
    """Run x (B, ...) through all stages with GPipe microbatching.

    ``layer_fn(stage_local_params, micro_x) -> micro_y`` applies one stage's
    layer block (it may itself scan over the stage's local layers).
    ``stage_params`` leaves are (S, L/S, ...) — stage-sharded over ``axis``.
    B must divide by n_micro.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro

    micro = x.reshape(n_micro, mb, *x.shape[1:])

    def stage_body(params_local, micro_local):
        # params_local: (1, L/S, ...) this stage's slice
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1

        def tick(carry, t):
            buf, out = carry            # buf: (mb, ...) in-flight activation
            # stage 0 ingests microbatch t (when valid)
            take = jnp.clip(t, 0, n_micro - 1)
            fresh = micro_local[take]
            buf = jnp.where(stage_id == 0,
                            jnp.where(t < n_micro, fresh, buf), buf)
            y = layer_fn(params_local, buf)
            # the last stage retires microbatch (t - n_stages + 1)
            retire = t - (n_stages - 1)
            ok = (stage_id == n_stages - 1) & (retire >= 0)
            out = jax.lax.cond(
                ok,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(retire, 0, n_micro - 1), 0),
                lambda o: o, out)
            # rotate stage s -> s+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, out), None

        buf0 = jnp.zeros_like(micro_local[0])
        out0 = jnp.zeros_like(micro_local)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0),
                                   jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast = masked psum
        out = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, out, jnp.zeros_like(out)),
            axis)
        return out

    in_specs = (jax.tree_util.tree_map(lambda _: P(axis), stage_params),
                P(*([None] * micro.ndim)))
    out = shard_map(stage_body, mesh=mesh, in_specs=in_specs,
                    out_specs=P(), check_vma=False)(stage_params, micro)
    return out.reshape(b, *x.shape[1:])


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble: (S-1) / (M + S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
