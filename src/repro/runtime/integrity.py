"""End-to-end integrity for compact packs: checksums, guards, recovery.

A FantastIC4 pack concentrates an entire fp32 layer into a handful of
4-bit bit-plane bytes plus a §V epilogue — the highest value-density
bytes in the system, where a single flipped bit silently corrupts a
whole column.  This module makes corruption *detectable* at every tier
the bytes live in:

* ``layer_content_crc`` — the canonical per-layer checksum over the
  TRUE-shape code matrix (``codes[:k]``, uint8) and the epilogue arrays
  (omega / alpha1 / bias / alpha2, float32).  It is invariant across
  representations: the frozen hot dict (row-pair packed nibbles), the
  cold ``CompressedTensor`` tier, and the on-disk ``pack.npz`` artifact
  all verify against the same value, so a flip anywhere in the chain is
  caught at the next boundary crossing.
* ``payload_crc`` — a cheap checksum over a ``CompressedTensor``'s raw
  payload arrays; lets the cold tier be scrubbed without decoding.
* ``GuardedPlan`` — a delegating plan proxy that re-verifies the live
  operands after each launch (detection happens before results are
  returned, so the micro-batcher's requeue-on-failure keeps the bucket
  intact), screens outputs for NaN/Inf, and can replay a golden canary
  probe through the live plan.
* ``IntegrityError`` — the typed failure every verification raises;
  ``ServingFrontend`` catches it to run the recovery rung (evict the
  poisoned plan, re-decode from the verified cold tier).

Checksum algorithm: CRC32C when the optional ``crc32c`` package is
importable (hardware-accelerated on most hosts), else zlib's CRC-32 —
no new dependencies.  Artifacts record which algorithm produced their
digests (``CRC_ALGO``) so a mismatched reader fails loudly instead of
mis-verifying.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

try:                                    # pragma: no cover - env-dependent
    from crc32c import crc32c as _crc_impl

    CRC_ALGO = "crc32c"
except ImportError:                     # no new deps: fall back to zlib
    import zlib

    _crc_impl = zlib.crc32
    CRC_ALGO = "crc32"


class IntegrityError(RuntimeError):
    """Typed corruption signal.

    ``kind`` says which tier failed verification:

    * ``"hot"``      — a resolved plan's live operands drifted from the
      frozen checksums (recoverable: re-decode from cold);
    * ``"cold"``     — a cold-tier payload or its decoded content failed
      (NOT recoverable from this cache: quarantine);
    * ``"artifact"`` — an on-disk pack (``pack.npz``) is truncated,
      garbled, or fails its stored checksums;
    * ``"content"``  — a hot pack's stamped ``"crc"`` disagrees with its
      arrays at compress time;
    * ``"output"``   — a launch produced NaN/Inf;
    * ``"canary"``   — the golden probe's output changed.
    """

    def __init__(self, message: str, *, kind: str = "hot",
                 model_id: Optional[str] = None,
                 layer: Optional[int] = None,
                 path: Optional[str] = None):
        super().__init__(message)
        self.kind = kind
        self.model_id = model_id
        self.layer = layer
        self.path = path


def _crc(data, crc: int = 0) -> int:
    return _crc_impl(data, crc) & 0xFFFFFFFF


def crc_update(crc: int, arr: np.ndarray, name: str = "") -> int:
    """Fold one array into a running CRC.  The header (name, dtype,
    shape) is part of the digest so a reshape or dtype change never
    aliases to the same value."""
    arr = np.ascontiguousarray(arr)
    header = f"{name}:{arr.dtype.str}:{arr.shape}".encode()
    crc = _crc(header, crc)
    return _crc(arr.tobytes(), crc)


def layer_content_crc(codes: np.ndarray, omega, alpha1, bias,
                      alpha2) -> int:
    """Canonical checksum of one frozen layer: true-shape (k, n) uint8
    codes + float32 epilogue arrays.  Representation-independent — hot
    packed dicts, cold ``CompressedTensor`` layers, and disk artifacts
    all reduce to this before digesting."""
    crc = crc_update(0, np.asarray(codes, np.uint8), "codes")
    for name, a in (("omega", omega), ("alpha1", alpha1),
                    ("bias", bias), ("alpha2", alpha2)):
        crc = crc_update(crc, np.asarray(a, np.float32), name)
    return crc


def unpack_codes_np(packed: np.ndarray, k: int, n: int) -> np.ndarray:
    """Host-side inverse of ``bitplanes.pack_codes_rows``: row-pair
    nibbles back to the true (k, n) uint8 code matrix (dropping the
    odd-k zero pad row if one was appended at freeze time)."""
    packed = np.asarray(packed, np.uint8)
    lo = packed & np.uint8(0xF)
    hi = packed >> np.uint8(4)
    full = np.stack([lo, hi], axis=1).reshape(2 * packed.shape[0], n)
    return full[:k]


def hot_layer_crc(layer: Dict[str, Any]) -> int:
    """``layer_content_crc`` of a hot (resolved / frozen) layer dict."""
    k, n = (int(s) for s in layer["shape"])
    codes = unpack_codes_np(layer["packed"], k, n)
    return layer_content_crc(codes, layer["omega"], layer["alpha1"],
                             layer["bias"], layer["alpha2"])


def stamp_pack_crcs(pack: Dict[str, Any]) -> Dict[str, Any]:
    """Stamp ``layer["crc"]`` into every layer of a frozen pack that
    does not already carry one (idempotent; mutates in place)."""
    for layer in pack["layers"]:
        if layer.get("crc") is None:
            layer["crc"] = hot_layer_crc(layer)
    return pack


def payload_crc(ct) -> int:
    """Checksum of a ``CompressedTensor``'s raw payload (format tag,
    logical shape, and every payload array in sorted key order) —
    verifies the cold tier without paying for a decode."""
    crc = _crc(f"{ct.format}:{tuple(ct.shape)}".encode())
    for key, arr in ct.canonical_items():
        crc = crc_update(crc, arr, key)
    return crc


def unwrap_chain(plan, limit: int = 8) -> List[Any]:
    """The plan and every ``.plan``-linked inner proxy, outermost first.
    Wrapper proxies (GuardedPlan, FaultInjector) expose the wrapped
    plan as ``.plan``; terminal plans (ExecutionPlan, CachedPlan) do
    not, which ends the walk."""
    chain: List[Any] = []
    p = plan
    while p is not None and len(chain) < limit:
        chain.append(p)
        nxt = getattr(p, "plan", None)
        if nxt is p:
            break
        p = nxt
    return chain


@dataclass(frozen=True)
class IntegrityPolicy:
    """What ``GuardedPlan`` checks and when.

    ``verify_launch``   re-checksum the live operands after every launch
                        (the acceptance guarantee: every corrupted
                        launch is caught before results return).
    ``screen_outputs``  reject launches that produce NaN/Inf.
    ``canary``          keep a golden probe (seeded input + captured
                        output) and re-play it through the live plan at
                        scrub time; bit-equality required.  Only sound
                        while the plan's bucket bindings are stable — a
                        degradation-ladder ``demote_bucket`` legally
                        changes fp32 accumulation order, so leave the
                        canary off for models subject to fallback.
    """

    verify_launch: bool = True
    screen_outputs: bool = True
    canary: bool = False
    canary_rows: int = 1
    canary_seed: int = 0


class GuardedPlan:
    """Delegating plan proxy that verifies operand checksums and screens
    outputs on the live launch path.

    Guards any :class:`~repro.serving.plans.ServableProgram` whose
    ``.layers`` are standard frozen layer dicts — pack plans, cache
    handles, and the transformer ``LMProgram`` (every block's FFN layer
    is checksummed per launch) alike.  The canary probe drives
    ``run()`` with synthetic rows, so leave it off for *stateful*
    programs whose wire rows carry request framing (the LM program).

    Expected per-layer checksums come from the stamped ``layer["crc"]``
    when the pack carries them (freeze / decode both stamp), else are
    computed from the first-seen operands (trust-on-first-use for
    hand-built test packs).  Verification runs AFTER the inner launch —
    a flip injected during the same call is still caught before results
    are returned, and the raising entry keeps the micro-batcher's
    requeue-on-failure contract intact.

    After the frontend's recovery rung re-decodes from the cold tier,
    the same expected checksums re-verify the fresh operands — recovery
    is bit-identical, so no re-arming is needed.
    """

    def __init__(self, plan, *, policy: Optional[IntegrityPolicy] = None,
                 model_id: Optional[str] = None):
        self._plan = plan
        self.policy = policy or IntegrityPolicy()
        self.model_id = model_id
        self._expected: Optional[List[int]] = None
        self._canary_x: Optional[np.ndarray] = None
        self._canary_y: Optional[np.ndarray] = None
        self._lock = threading.Lock()
        self.stats = {"verifies": 0, "detected": 0, "screened": 0,
                      "canary_runs": 0, "canary_failures": 0}

    # -- delegation --------------------------------------------------
    @property
    def plan(self):
        return self._plan

    def __getattr__(self, name):
        return getattr(self._plan, name)

    # -- checksums ---------------------------------------------------
    def expected_crcs(self) -> List[int]:
        with self._lock:
            if self._expected is None:
                exp = []
                for layer in self._plan.layers:
                    crc = layer.get("crc")
                    exp.append(int(crc) if crc is not None
                               else hot_layer_crc(layer))
                self._expected = exp
            return list(self._expected)

    def verify(self) -> None:
        """Re-checksum the live operands against the frozen values."""
        expected = self.expected_crcs()
        layers = self._plan.layers
        if len(layers) != len(expected):
            raise IntegrityError(
                f"layer count changed ({len(expected)} -> {len(layers)})",
                kind="hot", model_id=self.model_id)
        for i, (layer, exp) in enumerate(zip(layers, expected)):
            got = hot_layer_crc(layer)
            if got != exp:
                self.stats["detected"] += 1
                raise IntegrityError(
                    f"hot operand checksum mismatch at layer {i} "
                    f"(expected {exp:#010x}, got {got:#010x})",
                    kind="hot", model_id=self.model_id, layer=i)
        self.stats["verifies"] += 1

    def _after_launch(self, y):
        if self.policy.verify_launch:
            self.verify()
        if self.policy.screen_outputs:
            host = np.asarray(y)
            if not bool(np.all(np.isfinite(host))):
                self.stats["screened"] += 1
                raise IntegrityError(
                    "non-finite values in launch output",
                    kind="output", model_id=self.model_id)
        return y

    # -- launch surface ----------------------------------------------
    def entry(self, bucket: int):
        inner = self._plan.entry(bucket)

        def guarded_entry(xb):
            return self._after_launch(inner(xb))

        return guarded_entry

    def run(self, x):
        return self._after_launch(self._plan.run(x))

    # -- canary ------------------------------------------------------
    def arm_canary(self, x: Optional[np.ndarray] = None) -> None:
        """Capture the golden (input, output) pair through the live
        plan.  Called lazily by the first ``check_canary`` when the
        policy enables the canary."""
        if x is None:
            rng = np.random.default_rng(self.policy.canary_seed)
            x = rng.standard_normal(
                (self.policy.canary_rows, self._plan.d_in)).astype(
                    np.float32)
        self._canary_x = np.asarray(x, np.float32)
        self._canary_y = np.asarray(self._plan.run(self._canary_x))

    def check_canary(self) -> None:
        if self._canary_y is None:
            self.arm_canary()
            return
        y = np.asarray(self._plan.run(self._canary_x))
        if y.shape != self._canary_y.shape or \
                not np.array_equal(y, self._canary_y):
            self.stats["canary_failures"] += 1
            raise IntegrityError(
                "canary probe output changed", kind="canary",
                model_id=self.model_id)
        self.stats["canary_runs"] += 1

    def describe(self) -> Dict[str, Any]:
        inner = self._plan.describe() if hasattr(self._plan, "describe") \
            else {}
        return {**inner, "guarded": True,
                "integrity_stats": dict(self.stats)}
