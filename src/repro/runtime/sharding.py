"""Partition rules: parameter/optimizer/activation sharding for the mesh.

Mesh axes (launch/mesh.py): ``('data', 'model')`` single-pod,
``('pod', 'data', 'model')`` multi-pod.  Batch shards over the data axes
(pod included), weights Megatron-style over ``model``:

* QKV / gate / up / q_up / kv_up: column-sharded (output features);
* O / down / out_proj: row-sharded (contraction dim → psum);
* embedding + LM head: vocab-sharded;
* MoE expert banks: expert-sharded over 'model' when E % tp == 0
  (deepseek 256e), else per-expert TP on the FFN width (grok 8e);
* everything small/sensitive (norms, biases, router, ω, probs, SSM
  dynamics): replicated — they are the paper's full-precision parameters
  and a negligible byte fraction.

Every rule is **divisibility-guarded**: a dim that doesn't divide by the
axis size falls back to replication for that dim (e.g. smollm's 15 heads on
a 16-wide model axis).  The rules operate on *names + shapes* via
``tree_map_with_path``, so they apply identically to concrete arrays and to
``jax.eval_shape`` results — the dry-run shards a model that was never
materialised.

ZeRO-1 (:func:`zero1_spec`): optimizer moments and fp32 masters additionally
shard their first still-replicated dim over the data axes — GSPMD then
lowers the grad reduction into reduce-scatter + the param broadcast into
all-gather, the standard ZeRO-1 collective schedule.

Leading scan dims ((L, ...) stacked layers) are detected from the path and
skipped (never sharded: every device runs every layer of its shard).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# path-regex -> role; order matters (first match wins)
_RULES: Tuple[Tuple[str, str], ...] = (
    (r"embed//table$", "vocab_rows"),
    (r"lm_head//kernel(//w|//packed)?$", "vocab_cols"),
    (r"(attn|cross)//(q|k|v)//(kernel(//w|//packed)?|bias)$", "attn_qkv"),
    (r"(attn|cross)//o//kernel(//w|//packed)?$", "attn_o"),
    (r"attn//(q_down|kv_down)//kernel(//w|//packed)?$", "col"),
    (r"attn//(q_up|kv_up)//kernel(//w|//packed)?$", "head_col"),
    (r"(mlp|shared)//(gate|up|fc1)//(kernel(//w|//packed)?|bias)$", "col"),
    (r"(mlp|shared)//(down|fc2)//kernel(//w|//packed)?$", "row"),
    (r"(mlp|shared)//(down|fc2)//bias$", "rep"),
    (r"moe//experts//(gate|up)//(w|packed)$", "expert_col"),
    (r"moe//experts//down//(w|packed)$", "expert_row"),
    (r"moe//experts//(gate|up)$", "expert_col"),
    (r"moe//experts//down$", "expert_row"),
    (r"moe//router//", "rep"),
    (r"ssm//in_proj//kernel(//w|//packed)?$", "row_contract"),
    (r"ssm//out_proj//kernel(//w|//packed)?$", "row"),
    (r"//omega$", "rep"),
    (r"//probs$", "rep"),
)

_STACK_MARKERS = ("stacks//", "enc_layers//", "dec_layers//", "layers//")


def path_name(path) -> str:
    return "//".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)


def _n_lead(name: str, ndim: int, trailing: int) -> int:
    """Number of leading stacked dims (scan L, etc.) before the logical
    tensor dims."""
    for m in _STACK_MARKERS:
        if m in name:
            return max(ndim - trailing, 0)
    return 0


def _div(n: int, d: int) -> bool:
    return d > 0 and n % d == 0


class Rules:
    def __init__(self, mesh_axes: Tuple[str, ...], mesh_shape: dict, cfg):
        self.axes = mesh_axes
        self.shape = mesh_shape
        self.cfg = cfg
        self.tp = mesh_shape.get("model", 1)
        self.dp_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
        self.dp = int(np.prod([mesh_shape[a] for a in self.dp_axes] or [1]))

    # ---- role -> spec over the *logical* trailing dims
    def _role_spec(self, role: str, shape: Tuple[int, ...]) -> P:
        tp, cfg = self.tp, self.cfg
        m = "model"
        if role == "vocab_rows":
            return P(m if _div(shape[0], tp) else None, None)
        if role == "vocab_cols":
            return P(None, m if _div(shape[1], tp) else None)
        if role == "attn_qkv":
            heads_ok = (_div(getattr(cfg, "n_heads", 0), tp)
                        and _div(getattr(cfg, "n_kv", 0), tp))
            if len(shape) == 1:      # qkv bias
                return P(m if heads_ok and _div(shape[0], tp) else None)
            return P(None, m if heads_ok and _div(shape[1], tp) else None)
        if role == "head_col":       # MLA up-projections: per-head columns
            heads_ok = _div(getattr(cfg, "n_heads", 0), tp)
            return P(None, m if heads_ok and _div(shape[1], tp) else None)
        if role == "attn_o":
            heads_ok = _div(getattr(cfg, "n_heads", 0), tp)
            return P(m if heads_ok and _div(shape[0], tp) else None, None)
        if role == "col":
            if len(shape) == 1:
                return P(m if _div(shape[0], tp) else None)
            return P(None, m if _div(shape[1], tp) else None)
        if role == "row":
            return P(m if _div(shape[0], tp) else None, None)
        if role == "row_contract":
            return P(m if _div(shape[0], tp) else None, None)
        if role == "expert_col":
            if _div(shape[0], tp):
                return P(m, None, None)
            return P(None, None, m if _div(shape[2], tp) else None)
        if role == "expert_row":
            if _div(shape[0], tp):
                return P(m, None, None)
            return P(None, m if _div(shape[1], tp) else None, None)
        return P(*([None] * len(shape)))

    def spec_for(self, name: str, shape: Tuple[int, ...]) -> P:
        for pattern, role in _RULES:
            if re.search(pattern, name):
                trailing = {"vocab_rows": 2, "vocab_cols": 2, "attn_qkv": None,
                            }.get(role)
                # roles operate on their natural trailing arity
                arity = 3 if role.startswith("expert") else (
                    1 if len(shape) >= 1 and (name.endswith("bias")
                                              or role == "rep") else 2)
                if role == "rep":
                    return P(*([None] * len(shape)))
                if name.endswith("bias"):
                    arity = 1
                lead = _n_lead(name, len(shape), arity)
                logical = shape[lead:]
                if len(logical) != arity:
                    return P(*([None] * len(shape)))
                sub = self._role_spec(role, logical)
                return P(*([None] * lead), *sub)
        return P(*([None] * len(shape)))

    # ------------------------------------------------------ tree mappers

    def param_specs(self, params: Any) -> Any:
        def f(path, leaf):
            return self.spec_for(path_name(path), np.shape(leaf))
        return jax.tree_util.tree_map_with_path(f, params)

    def zero1_spec(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Shard the first replicated, divisible dim over the data axes."""
        if not self.dp_axes or self.dp == 1:
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, (s, dim) in enumerate(zip(parts, shape)):
            if s is None and _div(dim, self.dp):
                parts[i] = self.dp_axes if len(self.dp_axes) > 1 \
                    else self.dp_axes[0]
                return P(*parts)
        return spec

    def opt_specs(self, params: Any, zero1: bool = True) -> Any:
        """Specs for one params-shaped moment tree (m or v)."""
        def f(path, leaf):
            spec = self.spec_for(path_name(path), np.shape(leaf))
            if zero1:
                spec = self.zero1_spec(spec, np.shape(leaf))
            return spec
        return jax.tree_util.tree_map_with_path(f, params)

    def qstate_specs(self, qstate: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda leaf: P(*([None] * np.ndim(leaf))), qstate)

    def batch_spec(self, ndim: int, batch_dim: Optional[int] = None) -> P:
        """Batch over the data axes; replicate when indivisible (B=1 in
        long_500k — a single sequence cannot data-shard)."""
        if batch_dim is not None and not _div(batch_dim, self.dp):
            return P(*([None] * ndim))
        ax = self.dp_axes if len(self.dp_axes) > 1 else (
            self.dp_axes[0] if self.dp_axes else None)
        return P(ax, *([None] * (ndim - 1)))

    def batch_specs(self, batch: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda leaf: self.batch_spec(np.ndim(leaf),
                                         np.shape(leaf)[0]
                                         if np.ndim(leaf) else None), batch)

    def cache_specs(self, cache: Any) -> Any:
        """KV/SSM caches: batch over data axes; heads over model when
        divisible.  Leading (L,) stack dim replicated.  Cache leaves are
        (L, B, S, n_kv, hd) / (L, B, S, r) / (L, B, H, P, N) / scalars."""
        tp = self.tp

        def f(path, leaf):
            name = path_name(path)
            nd = np.ndim(leaf)
            shape = np.shape(leaf)
            if nd <= 1 or name.endswith("len") or name.endswith("pos"):
                return P(*([None] * nd))
            b_dim = shape[1] if nd >= 2 else None
            bx = None
            if b_dim is not None and _div(b_dim, self.dp) and self.dp_axes:
                bx = (self.dp_axes if len(self.dp_axes) > 1
                      else self.dp_axes[0])
            if name.endswith(("//k", "//v")) and nd == 5:
                kv_ok = _div(shape[3], tp)
                return P(None, bx, None, "model" if kv_ok else None, None)
            if name.endswith("//ssm") and nd == 5:
                h_ok = _div(shape[2], tp)
                return P(None, bx, "model" if h_ok else None, None, None)
            if nd >= 2:
                return P(None, bx, *([None] * (nd - 2)))
            return P(*([None] * nd))
        return jax.tree_util.tree_map_with_path(f, cache)

    # ------------------------------------------------------- shardings

    def named(self, mesh: Mesh, specs: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))


def serving_pack_specs(layers, rules: "Rules"):
    """Partition specs for a frozen serving pack's layer tensors.

    The serving pack is the packed-int4 form ``models.mlp.freeze_mlp``
    emits: per layer ``packed`` (ceil(K/2), N) row-pair bit-planes, the
    ω recombination vector, and the §V epilogue parameters (alpha1 /
    bias / alpha2).  Each layer's ``packed`` flows through the SAME
    ``//packed`` column rule the training-side tree uses (Megatron
    column split over the output features, divisibility-guarded: an N
    that does not divide by the model axis replicates), and the
    epilogue vectors follow their layer's column split — they are
    per-output-feature, so a sharded layer needs only its slice.  ω is
    the paper's full-precision shared parameter and always replicates
    (the ``//omega`` rule), like alpha2 (scalar).

    Returns one dict of :class:`PartitionSpec` per layer with keys
    ``packed / omega / alpha1 / bias / alpha2``.
    """
    specs = []
    for i, layer in enumerate(layers):
        shape = tuple(np.shape(layer["packed"]))
        packed = rules.spec_for(f"layers//{i}//mlp//fc1//kernel//packed",
                                shape)
        col_ok = len(packed) == 2 and packed[1] is not None
        vec = P("model") if col_ok else P(None)
        specs.append({
            "packed": packed,
            "omega": rules.spec_for(f"layers//{i}//omega",
                                    np.shape(layer["omega"])),
            "alpha1": vec,
            "bias": vec,
            "alpha2": P(),
        })
    return specs
