"""Fault tolerance for the training loop.

Large fleets fail constantly; the posture here (DESIGN.md §4):

* **checkpoint/restart** — ``FaultTolerantLoop`` checkpoints every
  ``ckpt_every`` steps through the atomic CheckpointManager; on (re)start it
  resumes from the latest step found.  Data is step-seeded
  (data/synthetic.py) so skip-ahead is exact with zero replay.
* **preemption** — SIGTERM/SIGINT set a flag; the loop checkpoints at the
  next step boundary and exits cleanly (the SLURM/Borg eviction contract).
* **transient-failure retry** — a step that raises an XLA runtime error is
  retried up to ``max_retries`` times from the last good state before the
  job surrenders; systematic (deterministic) failures exhaust retries
  immediately rather than looping forever.
* **bounded-stale metrics** — device→host metric fetches only block every
  ``metrics_every`` steps, so a slow host NIC never serialises the step
  (straggler mitigation on the observability path; the data path is handled
  by the prefetching ShardedFeed).
* **elastic restart** — restore maps arrays onto the *current* mesh, so a
  job resized 512→256 chips resumes from the same checkpoint (exercised in
  tests/test_checkpoint.py with two different fake-device meshes).

The same transient-retry posture extends to **serving**
(``serving.frontend``'s degradation ladder: retry → per-layer chain
fallback → per-model quarantine); :class:`FaultInjector` below is the
test/benchmark harness for it — it wraps any ``serving.ServableProgram``
(an ``ExecutionPlan``, a ``CachedPlan`` handle, an ``LMProgram``) so
launches raise synthetic XLA/VMEM-style errors probabilistically or on
schedule, which is how the goodput-under-fault numbers in
``benchmarks/bench_slo_traces.py`` and the retry-parity/quarantine tests
drive the ladder deterministically.
"""
from __future__ import annotations

import logging
import signal
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager

log = logging.getLogger(__name__)


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a checkpoint-at-next-boundary flag."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:      # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        log.warning("preemption signal %s received", signum)
        self.requested = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class InjectedFault(RuntimeError):
    """Synthetic launch failure: stands in for the XLA runtime / VMEM
    exhaustion errors a real device raises, without needing a real
    device to misbehave.  Deliberately NOT a ``jax.errors.JaxRuntimeError``
    subclass (those require live XLA state to construct); the serving
    retry policy treats any ``Exception`` from a launch as retryable, so
    the distinction does not matter to the ladder."""


class FaultInjector:
    """Wrap a ``ServableProgram`` so launches fail on demand.

    Proxies every attribute to the wrapped program (a batcher or frontend
    cannot tell the difference; hot flips need only the protocol's
    ``.layers`` surface of standard frozen layer dicts, which every
    program implementation carries) but intercepts the two launch
    surfaces —
    ``entry(bucket)`` and ``run(x)`` — and raises :class:`InjectedFault`
    *before* the kernel runs when the configured trigger fires:

    * ``rate`` — probabilistic: each launch fails with this probability
      (seeded ``numpy`` generator, so a given seed is a reproducible
      fault schedule — the retry-parity tests depend on that).
    * ``fail_nth`` — on schedule: launch indices (0-based, counted across
      all buckets) that fail deterministically.
    * ``fail_buckets`` — systematic per entry: these bucket sizes always
      fail — the "poisoned (bucket, schedule)" case.
    * ``only_fused`` — restrict injection to launches whose bucket is
      currently bound to a fused path: after the frontend demotes the
      poisoned bucket to the per-layer chain, injection stops, modeling
      a megakernel-specific fault (VMEM blowup, bad schedule) that the
      chain path does not share.  With ``only_fused=False`` the fault is
      model-wide and the ladder ends in quarantine.

    Beyond raising, the injector models **silent data corruption**:
    seeded bit-flips landed in the live bytes rather than thrown as
    exceptions, which is what the integrity subsystem
    (``runtime.integrity`` + the frontend's recovery rung) exists to
    catch:

    * ``flip_rate`` / ``flip_nth`` — when a launch flips (probabilistic
      per launch, or deterministic launch indices);
    * ``flip_targets`` — where the flip lands, drawn uniformly per
      event: ``"packed"`` (a resolved plan's packed bit-plane operand —
      one nibble, i.e. one 4-bit code, corrupted), ``"epilogue"`` (one
      byte of omega/alpha1/bias fp32), or ``"cold"`` (one byte of a
      cold-tier ``CompressedTensor`` payload, reached through a wrapped
      :class:`~repro.serving.pack_cache.CachedPlan`).

    Every RNG path is explicitly seeded and *separate*: the failure
    schedule draws from ``seed`` and the flip schedule from a child of
    ``seed``, so enabling flips never perturbs the failure sequence (and
    vice versa) — two runs with the same seed produce identical
    ``failures`` and ``flips`` logs (pinned by the reproducibility
    regression test).

    ``injected`` counts fired faults; ``launches`` counts every launch
    attempt; ``failures`` / ``flips`` log the exact schedule (launch
    index, and for flips the target / layer / byte / bit).  Plan-operand
    flips are applied in place and the kernel operand memos invalidated
    (``ops.forget_pack_operands``), so the corrupted bytes genuinely
    flow into subsequent launches.  Single-dispatch-thread use (the
    frontend's contract) needs no locking here.
    """

    FLIP_TARGETS = ("packed", "epilogue", "cold")

    def __init__(self, plan, *, rate: float = 0.0, seed: int = 0,
                 fail_nth: tuple = (), fail_buckets: tuple = (),
                 only_fused: bool = False, flip_rate: float = 0.0,
                 flip_nth: tuple = (), flip_targets: tuple = ("packed",)):
        self._plan = plan
        self.rate = rate
        self.fail_nth = frozenset(fail_nth)
        self.fail_buckets = frozenset(fail_buckets)
        self.only_fused = only_fused
        self.flip_rate = flip_rate
        self.flip_nth = frozenset(flip_nth)
        for t in flip_targets:
            if t not in self.FLIP_TARGETS:
                raise ValueError(f"unknown flip target {t!r}; choose "
                                 f"from {self.FLIP_TARGETS}")
        self.flip_targets = tuple(flip_targets)
        self._rng = np.random.default_rng(seed)
        self._flip_rng = np.random.default_rng(
            np.random.SeedSequence((int(seed), 0x4B17F11B)))
        self.launches = 0
        self.injected = 0
        self.failures: list = []    # launch indices that raised
        self.flips: list = []       # (launch, target, layer, field, byte, bit)

    @property
    def flipped(self) -> int:
        return len(self.flips)

    @property
    def plan(self):
        """The wrapped plan (unwrap for parity baselines)."""
        return self._plan

    def __getattr__(self, name):
        return getattr(self._plan, name)

    def _maybe_fail(self, bucket: int) -> None:
        if self.only_fused:
            bp = getattr(self._plan, "buckets", {}).get(bucket)
            if bp is None or not bp.path.startswith("fused"):
                return
        idx = self.launches
        self.launches += 1
        self._maybe_flip(idx)
        fire = (bucket in self.fail_buckets or idx in self.fail_nth
                or (self.rate > 0 and self._rng.random() < self.rate))
        if fire:
            self.injected += 1
            self.failures.append(idx)
            raise InjectedFault(
                f"injected launch failure (launch {idx}, bucket {bucket})")

    # ------------------------------------------------- silent corruption

    def _maybe_flip(self, idx: int) -> None:
        fire = idx in self.flip_nth
        if self.flip_rate > 0 and \
                self._flip_rng.random() < self.flip_rate:
            fire = True
        if not fire:
            return
        target = self.flip_targets[
            int(self._flip_rng.integers(len(self.flip_targets)))]
        if target == "cold":
            self._flip_cold(idx)
        else:
            self._flip_hot(idx, target)

    def _flip_hot(self, idx: int, target: str) -> None:
        """Flip one bit of a resolved plan's live operands: the packed
        bit-plane bytes or an epilogue fp32."""
        layers = self._plan.layers
        li = int(self._flip_rng.integers(len(layers)))
        layer = layers[li]
        if target == "packed":
            field = "packed"
            host = np.asarray(layer["packed"], np.uint8).copy()
        else:
            field = ("omega", "alpha1", "bias")[
                int(self._flip_rng.integers(3))]
            host = np.asarray(layer[field], np.float32).copy()
        flat = host.reshape(-1).view(np.uint8)
        byte = int(self._flip_rng.integers(flat.size))
        bit = int(self._flip_rng.integers(8))
        flat[byte] ^= np.uint8(1 << bit)
        import jax.numpy as jnp
        layer[field] = jnp.asarray(host)
        # the kernel-level operand memos are keyed by layer-list identity
        # under a no-mutation assumption this flip just violated — drop
        # them so the corrupted bytes reach the next launch
        from ..kernels import ops as kops
        kops.forget_pack_operands(layers)
        self.flips.append((idx, target, li, field, byte, bit))

    def _flip_cold(self, idx: int) -> None:
        """Flip one bit of the cold-tier compressed payload backing a
        wrapped CachedPlan (in place: the cache's ColdPack references
        the same arrays)."""
        from ..runtime.integrity import unwrap_chain
        from ..serving.pack_cache import CachedPlan
        cached = next((p for p in unwrap_chain(self._plan)
                       if isinstance(p, CachedPlan)), None)
        if cached is None:
            raise ValueError(
                'flip target "cold" needs a cache-backed plan '
                "(CachedPlan) somewhere in the wrapped chain")
        cold = cached.cache.cold(cached.model_id)
        li = int(self._flip_rng.integers(len(cold.layers)))
        ct = cold.layers[li].codes
        items = [(key, arr) for key, arr in ct.canonical_items()
                 if arr.nbytes > 0]
        key, arr = items[int(self._flip_rng.integers(len(items)))]
        flat = ct.payload[key].view(np.uint8).reshape(-1)
        byte = int(self._flip_rng.integers(flat.size))
        bit = int(self._flip_rng.integers(8))
        flat[byte] ^= np.uint8(1 << bit)
        self.flips.append((idx, "cold", li, key, byte, bit))

    def entry(self, bucket: int):
        inner = self._plan.entry(bucket)

        def faulty_entry(xb):
            self._maybe_fail(bucket)
            return inner(xb)
        return faulty_entry

    def run(self, x):
        self._maybe_fail(int(x.shape[0]))
        return self._plan.run(x)


class FaultTolerantLoop:
    def __init__(self, step_fn: Callable, manager: CheckpointManager, *,
                 ckpt_every: int = 100, metrics_every: int = 10,
                 max_retries: int = 3,
                 on_metrics: Optional[Callable] = None):
        self.step_fn = step_fn
        self.manager = manager
        self.ckpt_every = ckpt_every
        self.metrics_every = metrics_every
        self.max_retries = max_retries
        self.on_metrics = on_metrics or (lambda step, m: None)

    def resume_or(self, init_state: Any, sharding_fn=None) -> tuple:
        """(state, start_step): latest checkpoint if present, else init."""
        step = self.manager.latest_step()
        if step is None:
            return init_state, 0
        state, meta = self.manager.restore(init_state, step,
                                           sharding_fn=sharding_fn)
        log.info("resumed from step %d", meta["step"])
        return state, meta["step"]

    def run(self, state: Any, batches: Iterator, *, start_step: int = 0,
            total_steps: int = 1000) -> tuple:
        """Returns (state, last_step, reason) with reason in
        {"done", "preempted", "failed"}."""
        guard = PreemptionGuard()
        pending_metrics = None
        step = start_step
        try:
            while step < total_steps:
                if guard.requested:
                    self.manager.save(step, state)
                    return state, step, "preempted"
                batch = next(batches)
                retries = 0
                while True:
                    try:
                        new_state, metrics = self.step_fn(state, batch)
                        break
                    except jax.errors.JaxRuntimeError as e:
                        retries += 1
                        log.warning("step %d failed (%s), retry %d/%d",
                                    step, e, retries, self.max_retries)
                        if retries > self.max_retries:
                            self.manager.save(step, state)
                            return state, step, "failed"
                        time.sleep(0.1 * retries)
                state = new_state
                step += 1
                # bounded-stale metrics: fetch the metrics of N steps ago
                if step % self.metrics_every == 0:
                    if pending_metrics is not None:
                        fetched = jax.device_get(pending_metrics[1])
                        self.on_metrics(pending_metrics[0], fetched)
                    pending_metrics = (step, metrics)
                if step % self.ckpt_every == 0:
                    self.manager.save(step, state)
            if pending_metrics is not None:
                self.on_metrics(pending_metrics[0],
                                jax.device_get(pending_metrics[1]))
            self.manager.save(step, state)
            return state, step, "done"
        finally:
            guard.restore()
