"""Abstract input/state construction for the dry-run and launchers.

Everything here is ``jax.ShapeDtypeStruct`` — weak-type-correct, shardable,
zero allocation.  The full 671B-parameter deepseek train state is "built"
in milliseconds; only the smoke tests ever materialise weights.

Shape vocabulary (the assignment's four cells):
  train_4k     -> train_step   (B=256,  S=4096)
  prefill_32k  -> prefill      (B=32,   S=32768)
  decode_32k   -> serve_step   (B=128,  KV len 32768, one new token)
  long_500k    -> serve_step   (B=1,    KV len 524288) — sub-quadratic archs
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import whisper as W
from ..nn import transformer as T
from ..optim import ec4t

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple:
    """(runs?, reason-if-skipped).  DESIGN.md §long_500k / §decode."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: O(S) KV per token and "
                       "O(S^2) prefill at 524288 — skipped per assignment")
    return True, ""


def abstract(tree: Any) -> Any:
    """Concrete-or-abstract tree -> ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(jnp.shape(l), l.dtype), tree)


# ------------------------------------------------------------ parameters

def abstract_params(cfg: ArchConfig) -> Any:
    key = jax.random.PRNGKey(0)
    if cfg.family == "audio":
        return jax.eval_shape(functools.partial(W.whisper_init, cfg=cfg), key)
    return jax.eval_shape(functools.partial(T.lm_init, cfg=cfg), key)


def abstract_train_state(cfg: ArchConfig) -> Any:
    params = abstract_params(cfg)
    return jax.eval_shape(ec4t.init_train_state, params)


# ----------------------------------------------------------------- inputs

def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]

    if kind == "train":
        if cfg.family == "audio":
            # stubbed conv frontend: precomputed frames; decoder trains on
            # its own (<=448) context
            tgt = min(s, W.MAX_TGT)
            return {"embeds": jax.ShapeDtypeStruct((b, cfg.enc_len,
                                                    cfg.d_model), jnp.bfloat16),
                    "tokens": _tok(b, tgt), "labels": _tok(b, tgt)}
        if cfg.family == "vlm":
            # stubbed vision frontend: patch embeddings replace the token
            # embedding lookup for the backbone dry-run
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16),
                    "labels": _tok(b, s)}
        return {"tokens": _tok(b, s), "labels": _tok(b, s)}

    if kind == "prefill":
        if cfg.family == "audio":
            tgt = min(s, W.MAX_TGT)
            return {"embeds": jax.ShapeDtypeStruct(
                        (b, cfg.enc_len, cfg.d_model), jnp.bfloat16),
                    "tokens": _tok(b, tgt)}
        if cfg.family == "vlm":
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)}
        return {"tokens": _tok(b, s)}

    # decode: one new token against a seq_len-deep cache
    if cfg.family == "audio":
        hd = cfg.resolved_head_dim
        cross = (jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.enc_len,
                                       cfg.n_kv, hd), jnp.bfloat16),) * 2
        cache = jax.eval_shape(
            functools.partial(W.init_dec_cache, cfg, b, W.MAX_TGT))
        return {"tokens": _tok(b, 1),
                "positions": _tok(b, 1),
                "cache": cache, "cross_kv": cross}
    cache = jax.eval_shape(functools.partial(
        T.init_cache, cfg, b, s, cap_window=True))
    out = {"tokens": _tok(b, 1), "positions": _tok(b, 1), "cache": cache}
    if cfg.family == "vlm":
        out["embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
        del out["tokens"]
    return out
