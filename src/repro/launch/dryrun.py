import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import — jax locks the device
count at first initialisation.  512 host devices back both the 16×16
single-pod mesh (256 chips) and the 2×16×16 multi-pod mesh (512 chips).

Per cell this driver:
  1. builds the step bundle (launch/steps.py) from ShapeDtypeStructs only,
  2. ``jax.jit(...).lower(...)`` with the cell's in/out shardings,
  3. ``.compile()`` — proving the sharding is coherent end-to-end,
  4. records ``memory_analysis()`` / ``cost_analysis()`` and the summed
     per-collective operand bytes parsed from the optimized HLO
     (launch/roofline.py) into results/dryrun/<arch>_<shape>_<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun", **step_kw) -> dict:
    import jax

    from ..configs import get_config
    from . import roofline, steps
    from .mesh import make_production_mesh
    from .specs import SHAPES, shape_applicable

    cfg = get_config(arch)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}_{shape_name}_{mesh_name}"
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        rec = {"cell": cell, "status": "SKIP", "reason": reason}
        _write(out_dir, cell, rec)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        bundle = steps.build_step(cfg, mesh, shape_name, **step_kw)
        with mesh:
            jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings,
                             donate_argnums=bundle.donate)
            lowered = jitted.lower(*bundle.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        from ..compat import cost_analysis
        mem = compiled.memory_analysis()
        cost = cost_analysis(compiled)
        from . import hlo_analysis
        hlo = hlo_analysis.analyze(compiled.as_text())
        n_dev = int(mesh.devices.size)
        rec = {
            "cell": cell, "status": "OK", "arch": arch, "shape": shape_name,
            "mesh": mesh_name, "n_devices": n_dev,
            "kind": SHAPES[shape_name]["kind"],
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            # trip-count-aware walker (launch/hlo_analysis.py); XLA's own
            # cost_analysis counts while bodies once and is kept for x-check
            "flops_per_device": hlo["flops"],
            "bytes_per_device": hlo["bytes"],
            "xla_flops_per_device": cost.get("flops", 0.0),
            "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
            },
            "collectives": hlo["collectives"],
            "step_kw": {k: str(v) for k, v in step_kw.items()},
        }
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        rec = {"cell": cell, "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:],
               "elapsed_s": round(time.time() - t0, 1)}
    _write(out_dir, cell, rec)
    return rec


def _write(out_dir: str, cell: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=2, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--serve-dtype", default="packed4",
                    choices=("packed4", "bf16"))
    args = ap.parse_args()

    from ..configs import list_configs
    from .specs import SHAPES

    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    if args.all:
        archs = list_configs()
        shapes = list(SHAPES)
    pods = []
    if not args.multi_pod_only:
        pods.append(False)
    if not args.single_pod_only:
        pods.append(True) if (args.multi_pod or args.all
                              or args.multi_pod_only) else None

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                kw = ({"remat": args.remat}
                      if SHAPES[shape]["kind"] == "train"
                      else {"serve_dtype": args.serve_dtype})
                rec = run_cell(arch, shape, mp, out_dir=args.out, **kw)
                status = rec["status"]
                extra = (f" flops/dev={rec['flops_per_device']:.3g}"
                         if status == "OK" else
                         rec.get("reason", rec.get("error", ""))[:120])
                print(f"[{status:4s}] {rec['cell']}: {extra}", flush=True)
                failures += status == "FAIL"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
