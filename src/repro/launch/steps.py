"""Step functions (train / prefill / decode) + their sharding contracts.

``build_step`` returns (fn, in_shardings, out_shardings, input_specs) for a
given (arch × shape × mesh) cell — the exact object the dry-run lowers and
the launchers execute.  Serving steps run on the *frozen* tree (packed
4-bit codes + ω): weights enter HBM at 4 bits each and are decoded inline —
FantastIC4's data-movement win expressed where the TPU roofline can see it
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core import qat
from ..models import lm as lm_model
from ..models import whisper as W
from ..nn import transformer as T
from ..nn.module import QuantCtx
from ..optim import adam, ec4t
from ..runtime.sharding import Rules
from . import specs as specs_mod


@dataclasses.dataclass(frozen=True)
class StepBundle:
    name: str
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    args: tuple                  # abstract args (ShapeDtypeStructs)
    donate: tuple = ()


def make_rules(cfg: ArchConfig, mesh: jax.sharding.Mesh) -> Rules:
    return Rules(tuple(mesh.axis_names),
                 dict(zip(mesh.axis_names, mesh.devices.shape)), cfg)


def _ctx(cfg: ArchConfig, *, quant: bool, dtype=jnp.bfloat16) -> QuantCtx:
    return QuantCtx(quant=quant, lam=cfg.lam, compute_dtype=dtype)


def _loss_fn(cfg: ArchConfig, mesh, use_ep: bool, remat: str):
    fwd = (W.whisper_forward_loss if cfg.family == "audio"
           else lm_model.lm_forward_loss)

    def loss(params, qstate, batch, lam):
        ctx = QuantCtx(quant=cfg.quantize, lam=lam,
                       compute_dtype=jnp.bfloat16)
        return fwd(params, qstate, batch, ctx, cfg, mesh=mesh,
                   use_ep=use_ep, remat=remat)
    return loss


# ---------------------------------------------------------------- train

def build_train_step(cfg: ArchConfig, mesh: jax.sharding.Mesh, *,
                     shape_name: str = "train_4k", remat: str = "full",
                     use_ep: bool = True, zero1: bool = True,
                     adam_cfg: Optional[adam.AdamConfig] = None) -> StepBundle:
    rules = make_rules(cfg, mesh)
    adam_cfg = adam_cfg or adam.AdamConfig()
    step_fn = ec4t.make_train_step(
        _loss_fn(cfg, mesh, use_ep, remat), adam_cfg, lam=cfg.lam)

    a_state = specs_mod.abstract_train_state(cfg)
    a_batch = specs_mod.input_specs(cfg, shape_name)

    p_specs = rules.param_specs(a_state["params"])
    state_specs = {
        "params": p_specs,
        "opt": {"m": rules.opt_specs(a_state["params"], zero1=zero1),
                "v": rules.opt_specs(a_state["params"], zero1=zero1),
                "step": P()},
        "qstate": rules.qstate_specs(a_state["qstate"]),
    }
    batch_specs = rules.batch_specs(a_batch)
    in_sh = (rules.named(mesh, state_specs), rules.named(mesh, batch_specs))
    out_sh = (rules.named(mesh, state_specs), None)
    return StepBundle("train", step_fn, in_sh, out_sh,
                      (a_state, a_batch), donate=(0,))


# -------------------------------------------------------------- serving

def _frozen_params(cfg: ArchConfig, serve_dtype: str = "packed4") -> Any:
    """Abstract serving tree: "packed4" (codes at 4 bits/weight, decoded
    on the fly — the FantastIC4 path) or "bf16" (plain weights — the
    comparison point that isolates what the Pallas VMEM-decode kernel must
    beat; §Perf deepseek iterations)."""
    a_params = specs_mod.abstract_params(cfg)
    if serve_dtype == "bf16":
        def to_bf16(tree):
            def f(node):
                if qat.is_quant_leaf(node):
                    return node["w"].astype(jnp.bfloat16)
                return node
            return jax.tree_util.tree_map(f, tree,
                                          is_leaf=qat.is_quant_leaf)
        return jax.eval_shape(to_bf16, a_params)
    a_q = jax.eval_shape(qat.build_qstate, a_params)
    return jax.eval_shape(
        functools.partial(qat.freeze_tree, lam=cfg.lam), a_params, a_q)


def build_prefill_step(cfg: ArchConfig, mesh: jax.sharding.Mesh, *,
                       shape_name: str = "prefill_32k",
                       use_ep: bool = True,
                       serve_dtype: str = "packed4") -> StepBundle:
    rules = make_rules(cfg, mesh)
    info = specs_mod.SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    a_params = _frozen_params(cfg, serve_dtype)
    a_batch = specs_mod.input_specs(cfg, shape_name)

    if cfg.family == "audio":
        def fn(params, batch):
            ctx = _ctx(cfg, quant=False)
            enc = W.whisper_encode(params, 0, batch["embeds"], ctx, cfg)
            cross = W.precompute_cross(params, 0, enc, ctx, cfg)
            tgt = batch["tokens"].shape[1]
            cache = W.init_dec_cache(cfg, b, W.MAX_TGT)
            logits, cache = W.whisper_decode(params, 0, batch["tokens"],
                                             cross, ctx, cfg, cache=cache)
            nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
            return nxt, cache, cross

        a_cache = jax.eval_shape(
            functools.partial(W.init_dec_cache, cfg, b, W.MAX_TGT))
        hd = cfg.resolved_head_dim
        a_cross = (jax.ShapeDtypeStruct(
            (cfg.n_layers, b, cfg.enc_len, cfg.n_kv, hd), jnp.bfloat16),) * 2
        out_specs = (rules.batch_spec(2, b), rules.cache_specs(a_cache),
                     rules.cache_specs(a_cross))
    else:
        def fn(params, batch):
            ctx = _ctx(cfg, quant=False)
            cache = T.init_cache(cfg, b, s)
            logits, cache, _ = T.lm_apply(
                params, 0, batch.get("tokens"), ctx, cfg,
                embeds=batch.get("embeds"), cache=cache, mesh=mesh,
                use_ep=use_ep)
            nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
            return nxt, cache

        a_cache = jax.eval_shape(functools.partial(T.init_cache, cfg, b, s))
        out_specs = (rules.batch_spec(2, b), rules.cache_specs(a_cache))

    p_specs = rules.param_specs(a_params)
    in_sh = (rules.named(mesh, p_specs),
             rules.named(mesh, rules.batch_specs(a_batch)))
    out_sh = rules.named(mesh, out_specs)
    return StepBundle("prefill", fn, in_sh, out_sh, (a_params, a_batch))


def build_decode_step(cfg: ArchConfig, mesh: jax.sharding.Mesh, *,
                      shape_name: str = "decode_32k",
                      use_ep: bool = True,
                      serve_dtype: str = "packed4") -> StepBundle:
    rules = make_rules(cfg, mesh)
    a_params = _frozen_params(cfg, serve_dtype)
    a_batch = specs_mod.input_specs(cfg, shape_name)

    if cfg.family == "audio":
        def fn(params, batch):
            ctx = _ctx(cfg, quant=False)
            logits, cache = W.whisper_decode(
                params, 0, batch["tokens"], batch["cross_kv"], ctx, cfg,
                positions=batch["positions"], cache=batch["cache"])
            nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
            return nxt, cache
        a_out_cache = a_batch["cache"]
    else:
        def fn(params, batch):
            ctx = _ctx(cfg, quant=False)
            logits, cache, _ = T.lm_apply(
                params, 0, batch.get("tokens"), ctx, cfg,
                embeds=batch.get("embeds"), positions=batch["positions"],
                cache=batch["cache"], mesh=mesh, use_ep=use_ep)
            nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
            return nxt, cache
        a_out_cache = a_batch["cache"]

    batch_specs = dict(rules.batch_specs(
        {k: v for k, v in a_batch.items() if k not in ("cache", "cross_kv")}))
    batch_specs["cache"] = rules.cache_specs(a_batch["cache"])
    if "cross_kv" in a_batch:
        batch_specs["cross_kv"] = rules.cache_specs(a_batch["cross_kv"])

    info = specs_mod.SHAPES[shape_name]
    p_specs = rules.param_specs(a_params)
    in_sh = (rules.named(mesh, p_specs), rules.named(mesh, batch_specs))
    out_sh = rules.named(mesh, (rules.batch_spec(2, info["batch"]),
                                rules.cache_specs(a_out_cache)))
    return StepBundle("decode", fn, in_sh, out_sh, (a_params, a_batch),
                      donate=(1,))


BUILDERS = {"train": build_train_step, "prefill": build_prefill_step,
            "decode": build_decode_step}


def build_step(cfg: ArchConfig, mesh: jax.sharding.Mesh, shape_name: str,
               **kw) -> StepBundle:
    kind = specs_mod.SHAPES[shape_name]["kind"]
    return BUILDERS[kind](cfg, mesh, shape_name=shape_name, **kw)
