"""Serving launcher: frozen 4-bit weights, batched greedy decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 16 --max-new 16

Loads (or initialises) a model, freezes it to the packed-int4 serving form
(qat.freeze_tree — weights live at 4 bits/weight from then on), runs a
jitted prefill over the prompt batch and a jitted single-token decode loop.
Requests are batched: the decode step advances every sequence in lockstep
(continuous batching's inner loop; slot management would sit above this).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import qat
from ..nn import transformer as T
from ..nn.module import QuantCtx


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family == "audio":
        raise SystemExit("use examples/serve_whisper-style driving for enc-dec")

    key = jax.random.PRNGKey(0)
    params = T.lm_init(key, cfg)
    qstate = qat.build_qstate(params)
    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    ctx = QuantCtx(quant=False, compute_dtype=jnp.float32)

    b, s, new = args.batch, args.prompt_len, args.max_new
    prompt = jax.random.randint(key, (b, s), 0, cfg.vocab)
    total = s + new

    @jax.jit
    def prefill(params, tokens):
        cache = T.init_cache(cfg, b, total, dtype=jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        logits, cache, _ = T.lm_apply(params, 0, tokens, ctx, cfg,
                                      positions=pos, cache=cache)
        nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        return nxt, cache

    @jax.jit
    def decode(params, tok, pos, cache):
        logits, cache, _ = T.lm_apply(params, 0, tok, ctx, cfg,
                                      positions=pos, cache=cache)
        nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        return nxt, cache

    t0 = time.time()
    tok, cache = prefill(frozen, prompt)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for t in range(new - 1):
        pos = jnp.full((b, 1), s + t, jnp.int32)
        tok, cache = decode(frozen, tok, pos, cache)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_dec = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"prefill: {t_prefill*1e3:.1f} ms  decode: "
          f"{t_dec/(new-1)*1e3 if new > 1 else 0:.1f} ms/token "
          f"({b} sequences)")
    print("generated ids[0]:", gen[0].tolist())
    return gen


if __name__ == "__main__":
    main()
