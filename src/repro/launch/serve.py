"""Serving launcher: frozen 4-bit weights, batched greedy decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 16 --max-new 16

Loads (or initialises) a model, freezes it to the packed-int4 serving form
(qat.freeze_tree — weights live at 4 bits/weight from then on), runs a
jitted prefill over the prompt batch and a jitted single-token decode loop.
Requests are batched: the decode step advances every sequence in lockstep
(continuous batching's inner loop; slot management would sit above this).

Paper MLP archs (``--arch mlp-gsc | mlp-hr | lenet-300-100``) take the
classification serving path instead: freeze to the packed-int4 pack,
resolve a ``serving.ExecutionPlan`` (mode, autotuned blocks, VMEM-fit
fallback and — with ``--int8`` — activation calibration, all decided once
up front) and run the batch through the plan's bucket entry.  The resolved
plan is validated and printed *before* the timed run, and the run is
labeled by what actually executed, not by the flags: a ``--double-buffer``
request that cannot engage (no ≥16-row tile) or a stack that falls back
past the VMEM budget surfaces as a plan note first.  ``--no-fused``
selects the chained per-layer kernel; ``--engine`` additionally pushes the
batch through the micro-batcher as single-row ragged requests (the
continuous-batching path).

With ``--engine --async`` the ragged requests go through the threaded
``serving.ServingFrontend`` instead of the inline flush — a real-clock
dispatch thread, futures on the submit side — and ``--multi a,b`` freezes
additional paper-MLP packs into the same frontend so several models share
the single execution stream (deadline-FIFO across models; per-model
latency reported).

Robustness knobs on the async path: ``--tier`` / ``--max-delay`` accept
one value or a comma-separated list aligned to ``[--arch] + --multi``
(per-model SLO tier names / coalescing budgets in ms), ``--max-queued``
bounds every model's queue in rows (overflow is a typed
``serving.Rejected``, counted and reported, never a hang), and
``--inject-fault RATE`` wraps every plan in a ``FaultInjector`` so the
frontend's degradation ladder (retry -> chain fallback -> quarantine)
can be watched live; the run reports retries/fallbacks/quarantines and
validates the rows that completed.

Scale-out: ``--streams N`` replicates the async frontend's
execution stream N ways (one per device on a multi-device host —
join-shortest-estimated-work dispatch, per-stream quarantine);
``--shard`` column-shards the plan itself over the host's
``('data','model')`` mesh (``launch.mesh.fit_mesh``) — the two compose
with every robustness knob above.

LM archs accept ``--engine`` too (this PR): the prompt batch is re-served
through the :class:`~repro.serving.lm.LMProgram` servable program — one
megakernel-backed FFN plan set per transformer block, prefill and decode
steps as wire rows through a ``ServingFrontend`` — and the engine's decode
tokens are asserted bit-identical to the program's direct ``generate``
loop.  Dense-attention archs only (the program's contract).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..configs.paper_mlps import MLPS
from ..core import qat
from ..nn import transformer as T
from ..nn.module import QuantCtx
from .. import serving


def _freeze_mlp_pack(cfg, seed: int = 0):
    """Init + freeze one paper MLP to its packed-int4 serving pack."""
    from ..models import mlp as M

    key = jax.random.PRNGKey(seed)
    params, bn = M.mlp_init(key, cfg)
    qs = qat.build_qstate(params)
    pack = M.freeze_mlp(params, qs, bn, lam=cfg.lam)
    summ = M.pack_compression_summary(pack)
    print(f"{cfg.name}: {len(pack['layers'])} layers frozen to "
          f"{summ['compressed_bytes']} bytes "
          f"({summ['compression_ratio']:.1f}x vs fp32), "
          f"formats {summ['formats']}")
    return pack


def _mode_kwargs(args):
    """The plan-mode kwargs the flags resolve to, shared by the primary
    plan, --multi co-served packs and the pack-cache registration path
    (all models must run the requested configuration)."""
    if args.shard:
        from .mesh import fit_mesh
        mesh = fit_mesh()
        print(f"shard: ('data','model') mesh "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))} over "
              f"{mesh.devices.size} device(s)")
        return {"mode": "sharded", "mesh": mesh}
    return {"mode": "fused" if args.fused else "per_layer"}


def serve_mlp(args):
    """Frozen paper-MLP serving through the unified serving engine."""
    cfg = MLPS[args.arch]
    key = jax.random.PRNGKey(0)
    pack = _freeze_mlp_pack(cfg)

    b = args.batch
    x = jax.random.normal(key, (b, cfg.d_in), jnp.float32)

    args._mode_kwargs = _mode_kwargs(args)
    plan = serving.build_plan(
        pack,
        act_dtype="int8" if args.int8 else "float32",
        double_buffer=args.double_buffer,
        calib_x=x if args.int8 else None,
        **args._mode_kwargs)

    # resolved-plan report BEFORE anything is timed: the label below is
    # what will actually execute for this batch, and every requested-but-
    # not-engaged option surfaces as a note here, not after the numbers.
    desc = plan.describe()
    mode = plan.mode_label(b)
    print(f"plan: requested {desc['requested_mode']}"
          f"{' +double-buffer' if args.double_buffer else ''}"
          f"{' +int8' if args.int8 else ''} -> resolved "
          f"{desc['resolved_mode']} (batch {b}: {mode}; "
          f"block_m {desc['block_m']} [{desc['block_source']}], "
          f"buckets {desc['bucket_sizes']})")
    if desc.get("sharding"):
        sh = desc["sharding"]
        print(f"plan: sharded over {sh['mesh']} — column-split layers "
              f"{sh['col_sharded_layers']}, replicated "
              f"{sh['replicated_layers'] or 'none'}")
    print("plan: bucket -> schedule " + ", ".join(
        f"{bk}:{desc['bucket_schedules'][bk]}"
        f"[bm={desc['bucket_block_m'][bk]},{desc['bucket_sources'][bk]}]"
        for bk in desc["bucket_sizes"]))
    print(f"plan: ws crossover {desc['ws_crossover_rows']} rows "
          f"(prior {desc['ws_prior_rows']} "
          f"[{desc['ws_prior_source']}])")
    for note in desc["notes"]:
        print(f"note: {note}")

    def _run():
        return plan.run(x)

    y = jax.block_until_ready(_run())         # compile (+ autotune) warm-up
    t0 = time.time()
    iters = max(args.iters, 1)
    for _ in range(iters):
        y = _run()
    jax.block_until_ready(y)
    dt = (time.time() - t0) / iters
    print(f"{mode}: {dt*1e3:.2f} ms/batch  "
          f"({b/max(dt, 1e-12):.0f} samples/s, batch {b})")
    print("logits[0]:", np.asarray(y[0]).round(3).tolist())

    if args.engine and args.async_frontend:
        serve_mlp_async(args, cfg, plan, x, y)
    elif args.engine:
        # ragged path: the same batch as b single-row requests through the
        # queue -> bucket -> plan pipeline.  One untimed pass first — the
        # timed number must be a serving figure, not a trace/compile one
        # (bucket entries plus the submit/coalesce/scatter glue ops all
        # compile on first use; the batch path above only warmed its own
        # bucket).
        jax.block_until_ready(
            serving.MicroBatcher(plan).serve(list(x))[-1])
        batcher = serving.MicroBatcher(plan)
        t0 = time.time()
        ys = batcher.serve(list(x))
        jax.block_until_ready(ys[-1])
        dt_e = time.time() - t0
        st = batcher.stats
        print(f"engine (ragged, {st['flushes']} flushes, bucket hist "
              f"{st['bucket_hist']}): {dt_e*1e3:.2f} ms total "
              f"({b/max(dt_e, 1e-12):.0f} samples/s)")
        np.testing.assert_allclose(np.concatenate([np.asarray(v) for v in ys]),
                                   np.asarray(y), atol=1e-5, rtol=1e-5)
    return y


def _per_model(opt, flag, names, cast):
    """Split a one-or-comma-separated flag across the registered models
    (order: [--arch] + --multi).  A single value broadcasts."""
    if not opt:
        return {n: None for n in names}
    vals = opt.split(",")
    if len(vals) == 1:
        vals = vals * len(names)
    if len(vals) != len(names):
        raise SystemExit(f"{flag}: expected 1 or {len(names)} "
                         f"comma-separated values, got {len(vals)}")
    try:
        return {n: cast(v) for n, v in zip(names, vals)}
    except ValueError as e:
        raise SystemExit(f"{flag}: {e}")


def serve_mlp_async(args, cfg, plan, x, y_ref):
    """``--engine --async``: the ragged requests through the threaded
    ServingFrontend; ``--multi`` co-serves additional frozen packs on the
    same dispatch thread/execution stream."""
    key = jax.random.PRNGKey(1)
    models = {cfg.name: (plan, list(x))}
    for arch in (a for a in (args.multi or "").split(",") if a):
        if arch not in MLPS:
            raise SystemExit(f"--multi: unknown paper MLP {arch!r} "
                             f"(have {sorted(MLPS)})")
        if MLPS[arch].name in models:
            raise SystemExit(f"--multi: {arch!r} duplicates --arch or an "
                             "earlier --multi entry")
        mcfg = MLPS[arch]
        mpack = _freeze_mlp_pack(mcfg, seed=1)
        key, sub = jax.random.split(key)
        mx = jax.random.normal(sub, (args.batch, mcfg.d_in), jnp.float32)
        # co-served packs honor the same flags as the primary plan — the
        # per-model latency lines are only comparable if every model runs
        # the requested configuration.
        mplan = serving.build_plan(
            mpack,
            act_dtype="int8" if args.int8 else "float32",
            double_buffer=args.double_buffer,
            calib_x=mx if args.int8 else None,
            **args._mode_kwargs)
        models[mcfg.name] = (mplan, list(mx))

    names = list(models)
    tiers = _per_model(args.tier, "--tier", names, serving.resolve_tier)
    delays = _per_model(args.max_delay, "--max-delay", names,
                        lambda v: float(v) / 1e3)    # flag is in ms

    # warm every model's request path untimed (compile is not a serving
    # number), then serve all models' ragged rows through one frontend.
    for mplan, rows in models.values():
        jax.block_until_ready(serving.MicroBatcher(mplan).serve(rows)[-1])
    cache = None
    if args.max_hot_models is not None or args.hot_bytes is not None:
        cache = serving.PackCache(max_hot=args.max_hot_models,
                                  hot_bytes=args.hot_bytes)
        print(f"pack cache: hot budget "
              f"{args.max_hot_models if args.max_hot_models else '∞'} "
              f"models / "
              f"{args.hot_bytes if args.hot_bytes else '∞'} bytes — "
              "models registered compressed, decoded on first traffic")
    integrity = True if args.verify_launch else None
    frontend = serving.ServingFrontend(
        cache=cache, streams=args.streams,
        scrub_interval_s=(None if args.scrub_interval is None
                          else args.scrub_interval / 1e3))
    if args.verify_launch or args.scrub_interval is not None:
        print("integrity: "
              + ("per-launch checksum verification + output screen"
                 if args.verify_launch else "no launch guard")
              + (f", scrubber every {args.scrub_interval:.1f} ms"
                 if args.scrub_interval is not None else ""))
    if args.streams > 1:
        devs = [d if d is not None else "<default>"
                for d in frontend._devices]
        print(f"streams: {args.streams} replicated execution streams "
              f"(devices {devs})")
    for name, (mplan, mx_) in models.items():
        wrap = None
        if args.inject_fault > 0 or args.flip_rate > 0:
            def wrap(p):
                return serving.FaultInjector(p, rate=args.inject_fault,
                                             flip_rate=args.flip_rate)
        if cache is not None:
            # compressed-tier registration: the frontend holds the cold
            # pack; the resolved plan lives (and churns) under the LRU.
            # The injector (if any) wraps the cache handle and the guard
            # wraps the injector, so injected corruption is detected by
            # the guard and recovered from the verified cold tier.
            frontend.register_pack(
                name, mplan.pack,
                plan_kwargs={
                    **args._mode_kwargs,
                    "act_dtype": "int8" if args.int8 else "float32",
                    "double_buffer": args.double_buffer,
                    "calib": ({"act_scales": list(mplan.act_scales)}
                              if mplan.act_scales is not None else None),
                },
                wrap=wrap, integrity=integrity,
                tier=tiers[name], max_delay=delays[name],
                max_queued_rows=args.max_queued)
            continue
        target = mplan if wrap is None else wrap(mplan)
        frontend.register(name, target, tier=tiers[name],
                          max_delay=delays[name],
                          max_queued_rows=args.max_queued,
                          integrity=integrity)
        if tiers[name] is not None or delays[name] is not None:
            b = frontend.registry.batcher(name)
            print(f"model [{name}]: tier {b.tier.name}, max_delay "
                  f"{b.max_delay * 1e3:.2f} ms"
                  + (f", queue bound {args.max_queued} rows"
                     if args.max_queued else ""))
    t0 = time.time()
    served, rejected = [], []
    with frontend:
        futs = [(name, i, frontend.submit(name, row))
                for name, (_, rows) in models.items()
                for i, row in enumerate(rows)]
        for name, i, f in futs:
            try:
                served.append((name, i, f.result(60.0)))
            except serving.Rejected as rej:
                rejected.append((name, i, rej.reason))
            except serving.InjectedFault as exc:
                # quarantined model under --inject-fault: its futures
                # carry the injected root cause instead of hanging.
                rejected.append((name, i, f"fault: {exc}"))
            except serving.IntegrityError as exc:
                # corruption that could not be recovered (no cold tier,
                # or the cold copy failed too): typed root cause.
                rejected.append((name, i, f"corrupted: {exc}"))
    dt = time.time() - t0
    n = len(served)
    for name in models:
        lats = [s.latency * 1e3 for m, _, s in served if m == name]
        st = frontend.stats["by_model"][name]
        line = (f"async frontend [{name}]: {st['requests']} requests in "
                f"{st['launches']} launches")
        if lats:
            line += (f", latency mean {np.mean(lats):.2f} ms / p95 "
                     f"{np.percentile(lats, 95):.2f} ms")
        if st["rejected"]:
            line += f", {st['rejected']} rejected"
        if st["quarantined"]:
            line += ", QUARANTINED"
        print(line)
    print(f"async frontend: {n} served / {len(rejected)} rejected across "
          f"{len(models)} model(s) in {dt*1e3:.2f} ms total "
          f"({n/max(dt, 1e-12):.0f} samples/s, "
          f"{frontend.stats['launches']} launches)")
    if args.streams > 1:
        for i, ss in enumerate(frontend.stats["streams"]):
            print(f"stream {i}: {ss['launches']} launches, "
                  f"{ss['busy_s'] * 1e3:.1f} ms busy"
                  + (", QUARANTINED" if ss["quarantined"] else ""))
    if args.inject_fault > 0 or rejected:
        fs = frontend.stats
        print(f"degradation: {fs['launch_failures']} launch failures, "
              f"{fs['retries']} retries, {fs['fallbacks']} chain "
              f"fallbacks, quarantined {fs['quarantined'] or 'none'}")
    if args.flip_rate > 0 or args.verify_launch \
            or args.scrub_interval is not None:
        it = frontend.stats["integrity"]
        sc = frontend.stats["scrub"]
        rec = (f", recovery p95 "
               f"{np.percentile(it['recovery_s'], 95) * 1e3:.2f} ms"
               if it["recovery_s"] else "")
        print(f"integrity: {it['detected']} corruptions detected, "
              f"{it['recovered']} recovered from cold tier{rec}; "
              f"scrubber {sc['cycles']} cycles / {sc['checked']} checks "
              f"({sc['deferred']} busy deferrals)")
    if cache is not None:
        d = cache.describe()
        print(f"pack cache: {d['resolves']} resolves / {d['hits']} hits "
              f"/ {d['evictions']} evictions; resident "
              f"{d['resident_bytes']} B (high water "
              f"{d['resident_high_water']} B), cold tier "
              f"{d['cold_bytes']} B for {d['models']} models "
              f"({d['fp32_bytes'] / max(d['cold_bytes'], 1):.1f}x vs "
              "fp32)")
    # validate whatever completed for the primary model row-by-row (under
    # --inject-fault/--max-queued some rows may be typed rejections).
    done = {i: s for m, i, s in served if m == cfg.name}
    if done:
        got = np.concatenate([np.asarray(done[i].y) for i in sorted(done)])
        ref = np.asarray(y_ref)[sorted(done)]
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)


def serve_lm_engine(args, cfg, frozen, prompt, gen_ref):
    """``--engine`` on an LM arch: the same batch through the servable-
    program path — an :class:`~repro.serving.lm.LMProgram` registered in
    a ``ServingFrontend``, every sequence prefilled, then lockstep decode
    steps submitted as wire rows (each decode flush reaches the FFN as an
    ``m = n_seqs`` weight-stationary bucket)."""
    from ..serving.lm import LMProgram

    b, s, new = args.batch, args.prompt_len, args.max_new
    max_bucket = 1 << (max(s, b, 8) - 1).bit_length()
    prog = LMProgram(frozen, cfg, max_prompt=s, max_new=new,
                     max_bucket=max_bucket)
    direct = prog.generate(np.asarray(prompt), new)

    sids = list(range(1000, 1000 + b))
    toks = []
    t0 = time.time()
    frontend = serving.ServingFrontend()
    with frontend:
        frontend.register(cfg.name, prog, max_delay=1e-3)
        futs = [frontend.submit(
                    cfg.name,
                    prog.encode_prefill(sid, np.asarray(prompt)[i])[None])
                for i, sid in enumerate(sids)]
        toks.append([int(f.result(60.0).y[0, 0]) for f in futs])
        for _ in range(new - 1):
            futs = [frontend.submit(cfg.name,
                                    prog.encode_decode(sid)[None])
                    for sid in sids]
            toks.append([int(f.result(60.0).y[0, 0]) for f in futs])
    dt = time.time() - t0
    for sid in sids:
        prog.release(sid)
    engine = np.asarray(toks, np.int64).T
    if not np.array_equal(engine, direct):
        raise AssertionError(
            "engine decode diverged from LMProgram.generate")
    st = frontend.stats
    match = np.array_equal(engine, np.asarray(gen_ref, np.int64))
    print(f"engine (LM program): {b} seqs x {new} tokens in "
          f"{st['launches']} launches, {dt*1e3:.1f} ms total; decode "
          f"bit-identical to the direct generate loop"
          + ("" if match else
             " (jitted baseline tokens differ — accumulation order)"))
    print("program schedules:", prog.describe()["ffn_schedules"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--iters", type=int, default=10,
                    help="timed iterations (MLP serving path)")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="MLP path: whole-stack megakernel vs per-layer")
    ap.add_argument("--int8", action="store_true",
                    help="MLP path: int8 inter-layer activations (§VI-C)")
    ap.add_argument("--double-buffer", action="store_true",
                    help="MLP path: pipelined two-row-group megakernel")
    ap.add_argument("--engine", action="store_true",
                    help="MLP path: also serve the batch as ragged "
                         "single-row requests through the micro-batcher")
    ap.add_argument("--async", dest="async_frontend", action="store_true",
                    help="with --engine: drive the ragged requests "
                         "through the threaded ServingFrontend (real "
                         "clock, futures) instead of the inline flush")
    ap.add_argument("--multi", default=None, metavar="ARCH[,ARCH...]",
                    help="with --engine --async: co-serve additional "
                         "frozen paper-MLP packs from the same frontend "
                         "(one execution stream, deadline-FIFO across "
                         "models)")
    ap.add_argument("--tier", default=None, metavar="TIER[,TIER...]",
                    help="with --engine --async: per-model SLO tier "
                         f"({'|'.join(sorted(serving.TIERS))}); one value "
                         "broadcasts, a comma-separated list aligns to "
                         "[--arch] + --multi.  Enables deadline-based "
                         "admission control for that model")
    ap.add_argument("--max-delay", default=None, metavar="MS[,MS...]",
                    help="with --engine --async: per-model coalescing "
                         "budget in ms (same alignment as --tier); "
                         "overrides the tier's budget")
    ap.add_argument("--max-queued", type=int, default=None, metavar="ROWS",
                    help="with --engine --async: bound every model's "
                         "queue; overflow is a typed serving.Rejected")
    ap.add_argument("--inject-fault", type=float, default=0.0,
                    metavar="RATE",
                    help="with --engine --async: wrap every plan in a "
                         "FaultInjector failing launches at RATE to "
                         "exercise the retry/fallback/quarantine ladder "
                         "(composes with --max-hot-models/--hot-bytes: "
                         "the injector wraps the cache handle)")
    ap.add_argument("--flip-rate", type=float, default=0.0,
                    metavar="RATE",
                    help="with --engine --async: FaultInjector bit-flip "
                         "corruption of live plan operands at RATE per "
                         "launch; requires --verify-launch (detection) "
                         "and, for transparent recovery, the pack cache "
                         "flags (cold-tier re-decode)")
    ap.add_argument("--verify-launch", action="store_true",
                    help="with --engine --async: wrap every model in a "
                         "GuardedPlan — per-launch operand checksum "
                         "verification + NaN/Inf output screen")
    ap.add_argument("--scrub-interval", type=float, default=None,
                    metavar="MS",
                    help="with --engine --async: background integrity "
                         "scrubber cadence in ms (idle-aware; verifies "
                         "cold payload checksums and resident guarded "
                         "plans)")
    ap.add_argument("--max-hot-models", type=int, default=None,
                    metavar="N",
                    help="with --engine --async: register models by "
                         "compressed pack through a serving.PackCache "
                         "and keep at most N resolved plans resident "
                         "(LRU; evicted models re-resolve on next "
                         "traffic, bit-identically)")
    ap.add_argument("--hot-bytes", type=int, default=None, metavar="BYTES",
                    help="with --engine --async: byte budget for the "
                         "pack cache's resident decoded plans (combines "
                         "with --max-hot-models)")
    ap.add_argument("--streams", type=int, default=1, metavar="N",
                    help="with --engine --async: N replicated execution "
                         "streams (one per device on a multi-device "
                         "host; thread-only on a single device) with "
                         "join-shortest-estimated-work dispatch")
    ap.add_argument("--shard", action="store_true",
                    help="MLP path: column-shard the megakernel plan "
                         "over the host's ('data','model') mesh "
                         "(launch.mesh.fit_mesh) — wide layers split "
                         "their output features per device, indivisible "
                         "widths replicate")
    args = ap.parse_args(argv)
    if args.streams < 1:
        raise SystemExit(f"--streams must be >= 1, got {args.streams}")
    if args.streams > 1 and not args.async_frontend:
        raise SystemExit("--streams applies to the async frontend: add "
                         "--engine --async")
    if args.shard and args.arch not in MLPS:
        raise SystemExit("--shard applies to the paper-MLP serving path "
                         f"(--arch one of {sorted(MLPS)})")
    if (args.tier or args.max_delay or args.max_queued is not None
            or args.inject_fault) and not args.async_frontend:
        raise SystemExit("--tier/--max-delay/--max-queued/--inject-fault "
                         "apply to the async frontend: add --engine --async")
    if (args.max_hot_models is not None or args.hot_bytes is not None):
        if not args.async_frontend:
            raise SystemExit("--max-hot-models/--hot-bytes apply to the "
                             "async frontend: add --engine --async")
    if (args.flip_rate > 0 or args.scrub_interval is not None
            or args.verify_launch) and not args.async_frontend:
        raise SystemExit("--flip-rate/--scrub-interval/--verify-launch "
                         "apply to the async frontend: add --engine "
                         "--async")
    if args.flip_rate > 0 and not args.verify_launch:
        raise SystemExit("--flip-rate corrupts live weights; add "
                         "--verify-launch so the corruption is caught "
                         "(and, with the pack cache flags, recovered)")
    if args.multi and not (args.engine and args.async_frontend):
        raise SystemExit("--multi requires --engine --async")
    if args.async_frontend and not args.engine:
        raise SystemExit("--async requires --engine")

    if args.arch in MLPS:
        return serve_mlp(args)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.family == "audio":
        raise SystemExit("use examples/serve_whisper-style driving for enc-dec")

    key = jax.random.PRNGKey(0)
    params = T.lm_init(key, cfg)
    qstate = qat.build_qstate(params)
    frozen = qat.freeze_tree(params, qstate, cfg.lam)
    ctx = QuantCtx(quant=False, compute_dtype=jnp.float32)

    b, s, new = args.batch, args.prompt_len, args.max_new
    prompt = jax.random.randint(key, (b, s), 0, cfg.vocab)
    total = s + new

    @jax.jit
    def prefill(params, tokens):
        cache = T.init_cache(cfg, b, total, dtype=jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        logits, cache, _ = T.lm_apply(params, 0, tokens, ctx, cfg,
                                      positions=pos, cache=cache)
        nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        return nxt, cache

    @jax.jit
    def decode(params, tok, pos, cache):
        logits, cache, _ = T.lm_apply(params, 0, tok, ctx, cfg,
                                      positions=pos, cache=cache)
        nxt = jnp.argmax(logits[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        return nxt, cache

    t0 = time.time()
    tok, cache = prefill(frozen, prompt)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for t in range(new - 1):
        pos = jnp.full((b, 1), s + t, jnp.int32)
        tok, cache = decode(frozen, tok, pos, cache)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_dec = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"prefill: {t_prefill*1e3:.1f} ms  decode: "
          f"{t_dec/(new-1)*1e3 if new > 1 else 0:.1f} ms/token "
          f"({b} sequences)")
    print("generated ids[0]:", gen[0].tolist())
    if args.engine:
        try:
            serve_lm_engine(args, cfg, frozen, prompt, gen)
        except ValueError as e:
            raise SystemExit(f"--engine: {e}")
    return gen


if __name__ == "__main__":
    main()
