"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires every substrate together: config -> model init -> sharded EC4T train
step (launch/steps.py semantics on whatever mesh the process actually has)
-> step-seeded data feed -> fault-tolerant loop (checkpoint/restart,
preemption, retry) -> compressed 4-bit export at the end.

On this CPU container, ``--smoke`` runs the reduced config on a 1×1 mesh —
the same code path the production launch takes on a pod (the dry-run proves
the 16×16 / 2×16×16 lowering of the identical step function).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..checkpoint.manager import CheckpointManager, export_quantized
from ..configs import get_config
from ..data import pipeline, synthetic
from ..optim import adam, ec4t, schedule
from ..runtime.fault import FaultTolerantLoop
from . import steps as steps_mod
from .mesh import single_device_mesh
from .specs import SHAPES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--lam-ramp", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--export", default=None,
                    help="directory for the 4-bit serving export")
    ap.add_argument("--remat", default="none")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, lam=args.lam)

    mesh = single_device_mesh() if jax.device_count() == 1 else None
    key = jax.random.PRNGKey(0)

    if cfg.family == "audio":
        from ..models.whisper import whisper_init as init_fn
    else:
        from ..nn.transformer import lm_init as init_fn
    params = init_fn(key, cfg)
    state = ec4t.init_train_state(params)

    lam_fn = lambda step: schedule.lambda_ramp(
        step, lam=args.lam, ramp_steps=args.lam_ramp)
    lr_fn = lambda step: schedule.warmup_cosine(
        step, base_lr=1.0, warmup=max(args.steps // 20, 1), total=args.steps)
    loss_fn = steps_mod._loss_fn(cfg, mesh=None, use_ep=False,
                                 remat=args.remat)
    step_fn = jax.jit(ec4t.make_train_step(
        loss_fn, adam.AdamConfig(lr=args.lr), lam=lam_fn,
        lr_schedule=lr_fn))

    data_cfg = synthetic.LMDataCfg(vocab=cfg.vocab, seq_len=args.seq,
                                   global_batch=args.batch)

    def batch_fn(step):
        b = synthetic.lm_batch(data_cfg, step)
        out = {"tokens": b["tokens"], "labels": b["labels"]}
        if cfg.family in ("audio", "vlm"):
            rng_frames = jax.random.PRNGKey(step)
            t = cfg.enc_len if cfg.family == "audio" else args.seq
            out["embeds"] = jax.random.normal(
                rng_frames, (args.batch, t, cfg.d_model), jnp.float32)
            if cfg.family == "vlm":
                del out["tokens"]
        return out

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    history = []

    def on_metrics(step, m):
        rec = {"step": step, **{k: float(v) for k, v in m.items()}}
        history.append(rec)
        print(f"step {step:5d} loss {rec['loss']:.4f} ce {rec['ce']:.4f} "
              f"gnorm {rec['grad_norm']:.2f} lam {rec['lam']:.4f}", flush=True)

    loop = FaultTolerantLoop(step_fn, mgr, ckpt_every=args.ckpt_every,
                             metrics_every=10, on_metrics=on_metrics)
    state, start = loop.resume_or(state)
    feed = pipeline.ShardedFeed(batch_fn, mesh=None, start_step=start)
    t0 = time.time()
    state, last, reason = loop.run(state, feed, start_step=start,
                                   total_steps=args.steps)
    feed.close()
    print(f"finished: {reason} at step {last} "
          f"({(time.time()-t0)/max(last-start,1)*1e3:.0f} ms/step)")

    if args.export:
        report = export_quantized(args.export, state["params"],
                                  state["qstate"], args.lam)
        print(f"export: {report['compression_ratio']:.2f}x compression -> "
              f"{args.export}")
    return history


if __name__ == "__main__":
    main()
