"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: a leading 'pod'
axis, (pod=2, data=16, model=16) = 512 chips; batch shards over
('pod', 'data') and the model axis stays intra-pod (ICI), so the only
inter-pod (DCI) collective is the DP gradient reduction — the standard
multi-pod posture.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before *any* device query).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def fit_mesh(n_devices: Optional[int] = None, *,
             model: Optional[int] = None) -> jax.sharding.Mesh:
    """The largest valid ``('data', 'model')`` mesh the host actually has.

    ``make_production_mesh`` hard-codes 256/512 chips and simply cannot be
    constructed on a 1–8 device host; everything that wants a mesh sized
    to reality (``launch.serve --shard``, the multi-stream bench, tests on
    forced-host-device subprocesses) goes through here instead.

    ``n_devices`` caps how many devices to use (default: all available —
    never more than the host has).  ``model`` pins the tensor-parallel
    axis; by default it is the largest power-of-two divisor of the device
    count with ``model**2 <= n`` — balanced, and degenerating to
    ``(n, 1)`` on non-power-of-two counts so the mesh always builds:

        1 -> (1, 1)   2 -> (2, 1)   4 -> (2, 2)   8 -> (4, 2)
        16 -> (4, 4)  64 -> (8, 8)  256 -> (16, 16)  6 -> (3, 2)
    """
    avail = jax.device_count()
    n = avail if n_devices is None else min(int(n_devices), avail)
    if n < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if model is not None:
        model = int(model)
        if model < 1 or n % model:
            raise ValueError(
                f"model axis {model} does not divide {n} devices")
    else:
        model = 1
        while n % (model * 2) == 0 and (model * 2) ** 2 <= n:
            model *= 2
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes)


def single_device_mesh() -> jax.sharding.Mesh:
    return jax.make_mesh((1, 1), ("data", "model"))


def describe(mesh: jax.sharding.Mesh) -> dict:
    return {"axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "n_devices": int(mesh.devices.size)}
