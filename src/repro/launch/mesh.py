"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: a leading 'pod'
axis, (pod=2, data=16, model=16) = 512 chips; batch shards over
('pod', 'data') and the model axis stays intra-pod (ICI), so the only
inter-pod (DCI) collective is the DP gradient reduction — the standard
multi-pod posture.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before *any* device query).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes)


def single_device_mesh() -> jax.sharding.Mesh:
    return jax.make_mesh((1, 1), ("data", "model"))


def describe(mesh: jax.sharding.Mesh) -> dict:
    return {"axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "n_devices": int(mesh.devices.size)}
