"""Three-term roofline model from the compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = Σ per-class collective bytes / link_bw

Sources: ``compiled.cost_analysis()`` provides FLOPs and bytes-accessed per
device (XLA reports per-partition numbers under SPMD).  Collective bytes are
NOT in cost_analysis — :func:`collective_bytes` parses the optimized HLO and
sums operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, scaled by how many times the op runs
(trip counts of enclosing while-loops, i.e. scan-over-layers).

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment sheet).

MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) anchors the "useful fraction":
HLO_FLOPs ≫ MODEL_FLOPS exposes remat recompute, masked-attention waste and
dispatch overhead — the per-cell notes call out which.
"""
from __future__ import annotations

import math
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-chip effective)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(?:\(([^)]*)\)|(\S+?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_WHILE_TRIP_RE = re.compile(
    r"while\(.*?\)[^\n]*?trip_count[=\":\s]+(\d+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective class, weighted by the trip
    count of the innermost enclosing while loop (scan-over-layers runs each
    in-body collective L times).

    Returns {class: bytes} + {"total": ..., "count": ...}.  Byte figures are
    per-device (HLO shapes under SPMD are the per-partition shapes).
    """
    # map line index -> trip count by tracking while-body computations
    trip_by_comp: dict = {}
    cur_comp = None
    comp_re = re.compile(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*(?:->.*)?\{\s*$")
    # first pass: find calls to while with known trip counts and their bodies
    body_trip: dict = {}
    for m in re.finditer(
            r"while\([^\n]*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
            r"[^\n]*", hlo_text):
        line = m.group(0)
        tc = re.search(r'known_trip_count=\{n="?(\d+)"?\}', line)
        if not tc:
            tc = re.search(r"trip_count[=\":\s]+(\d+)", line)
        body_trip[m.group(2)] = int(tc.group(1)) if tc else 1

    out: dict = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
                 "all-to-all": 0, "collective-permute": 0, "count": 0}
    cur_trip = 1
    for line in hlo_text.splitlines():
        mm = comp_re.match(line.strip()) if line.strip().endswith("{") else None
        if mm is not None and not line.lstrip().startswith(("ENTRY",)):
            name = mm.group(1).lstrip("%")
            cur_trip = body_trip.get(name, 1)
        if line.lstrip().startswith("ENTRY"):
            cur_trip = 1
        cm = _COLL_RE.match(line)
        if cm:
            shape_str = cm.group(1) or cm.group(2)
            kind = cm.group(3)
            out[kind] += _shape_bytes(shape_str) * cur_trip
            out["count"] += cur_trip
    out["total"] = sum(out[k] for k in ("all-gather", "all-reduce",
                                        "reduce-scatter", "all-to-all",
                                        "collective-permute"))
    return out


def model_flops(cfg, shape_info: dict) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N·D per generated
    token for inference kinds.  N counts *active* params touched per token."""
    n_active = active_params(cfg)
    b, s = shape_info["batch"], shape_info["seq"]
    kind = shape_info["kind"]
    if kind == "train":
        tokens = b * s
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = b * s
        return 2.0 * n_active * tokens
    return 2.0 * n_active * b          # decode: one token per sequence


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: top_k + shared experts only)."""
    d, L = cfg.d_model, cfg.n_layers
    total = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    hd = cfg.resolved_head_dim

    def attn_params():
        if cfg.mla is not None:
            m = cfg.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            return (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim
                                                      + m.v_head_dim)
                    + cfg.n_heads * m.v_head_dim * d)
        return d * hd * (cfg.n_heads * 2 + cfg.n_kv * 2)

    def ffn_params(ff):
        mult = 3 if cfg.act == "swiglu" else 2
        return mult * d * ff

    def ssm_params():
        di = cfg.d_inner
        dproj = 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
        return d * dproj + di * d

    kinds = {"dense": 0, "moe": 0, "ssm": 0, "hybrid": 0}
    if cfg.family == "moe":
        kinds["dense"] = cfg.n_dense_layers
        kinds["moe"] = L - cfg.n_dense_layers
    elif cfg.family == "ssm":
        kinds["ssm"] = L
    elif cfg.family == "hybrid":
        kinds["hybrid"] = L
    else:
        kinds["dense"] = L

    total += kinds["dense"] * (attn_params() + ffn_params(cfg.dense_ff
                                                          or cfg.d_ff))
    total += kinds["moe"] * (attn_params()
                             + (cfg.top_k + cfg.n_shared_experts)
                             * ffn_params(cfg.d_ff))
    total += kinds["ssm"] * ssm_params()
    total += kinds["hybrid"] * (attn_params() + ssm_params()
                                + ffn_params(cfg.d_ff))
    if cfg.family == "audio":
        total += cfg.n_enc_layers * (attn_params() + ffn_params(cfg.d_ff))
        total += L * (2 * attn_params() + ffn_params(cfg.d_ff))
        total -= L * (attn_params() + ffn_params(cfg.d_ff))  # counted above
    return float(total)


def roofline_terms(rec: dict, cfg=None) -> dict:
    """Per-device seconds for each term + the dominant bottleneck."""
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = rec["bytes_per_device"] / HBM_BW
    coll = rec.get("collectives", {})
    collective_s = coll.get("total", 0) / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])
    out = {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dom[0],
        "bound_s": dom[1],
    }
    if cfg is not None:
        from .specs import SHAPES
        info = SHAPES[rec["shape"]]
        mf = model_flops(cfg, info)
        hlo_total = rec["flops_per_device"] * rec["n_devices"]
        out["model_flops"] = mf
        out["hlo_flops_total"] = hlo_total
        out["useful_fraction"] = mf / hlo_total if hlo_total else 0.0
        # roofline fraction: model-flops-time over the bound term
        ideal_s = mf / (rec["n_devices"] * PEAK_FLOPS)
        out["ideal_compute_s"] = ideal_s
        out["roofline_fraction"] = ideal_s / dom[1] if dom[1] else 0.0
    return out
