"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — under
scan-over-layers that understates FLOPs/bytes by ~n_layers×.  This walker
parses the optimized HLO, builds the computation call graph (while bodies ×
``known_trip_count``, fusion/call/conditional × 1) and accumulates:

* **flops** — dot-generals from shapes (2 · |out| · |contract|), plus 1
  flop/element for arithmetic elementwise ops (the softmax/SSD VPU work);
* **bytes** — Σ (operand + output bytes) of every *memory-level* instruction
  (fusions count their boundary, not their internals — matching what HBM
  actually sees after fusion);
* **collectives** — per-class operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute.

All values are per-device: SPMD-partitioned HLO carries per-partition
shapes.  Validated in tests against hand-counted programs (scan matmul,
psum) and against ``cost_analysis`` on loop-free programs.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "tanh", "logistic", "power", "negate", "abs", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "clamp",
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "convert", "cosine", "sine", "erf", "atan2",
    "remainder", "sign", "cbrt",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ZERO_COST_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
    # control flow: bodies are walked separately; the instruction itself
    # aliases its operand buffers
    "while", "conditional", "call",
}

#: ops whose HBM traffic is the *addressed region*, not the whole operand
_SLICING_OPS = {"dynamic-slice", "gather", "slice"}
_UPDATE_OPS = {"dynamic-update-slice", "scatter"}


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    return out


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(s):
        total += math.prod(dims) * _DTYPE_BYTES[dt]
    return total


def _shape_elems(s: str) -> int:
    return sum(math.prod(dims) for _, dims in _parse_shapes(s))


@dataclass
class Instr:
    name: str
    opcode: str
    out_shape: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # instr -> shape str


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
# shape group: either a tuple "(...)" — which may contain /*index=N*/
# comments — or a single token; tuple shapes never nest parens.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:\S+?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%?([\w\.\-]+)")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hm = _COMP_HEADER.match(line.strip())
        if hm and line.strip().endswith("{"):
            cur = Computation(hm.group(2))
            comps[cur.name] = cur
            if hm.group(1):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, shape, opcode, rest = im.groups()
        # operands: inside the first balanced parens of `rest`
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        opnd_str = rest[:i - 1] if depth == 0 else rest
        operands = []
        for tok in opnd_str.split(","):
            tok = tok.strip()
            mm = re.search(r"%([\w\.\-]+)\s*$", tok)
            if mm:
                operands.append(mm.group(1))
        inst = Instr(name, opcode, shape, operands, line)
        cur.instrs.append(inst)
        cur.shapes[name] = shape
    return comps, entry


def _trip_count(line: str) -> int:
    m = re.search(r'known_trip_count[^\d]*(\d+)', line)
    return int(m.group(1)) if m else 1


def _called(line: str) -> List[Tuple[str, int]]:
    """(computation, multiplier) pairs invoked by this instruction line."""
    out = []
    wb = re.search(r"body=%?([\w\.\-]+)", line)
    if wb:
        out.append((wb.group(1), _trip_count(line)))
        wc = re.search(r"condition=%?([\w\.\-]+)", line)
        if wc:
            out.append((wc.group(1), _trip_count(line) + 1))
        return out
    cm = re.search(r"calls=%?([\w\.\-]+)", line)
    if cm:
        out.append((cm.group(1), 1))
    tm = re.search(r"to_apply=%?([\w\.\-]+)", line)
    if tm:
        out.append((tm.group(1), 1))
    bm = re.search(r"branch_computations=\{([^}]*)\}", line)
    if bm:
        for b in bm.group(1).split(","):
            out.append((b.strip().lstrip("%"), 1))
    tb = re.search(r"true_computation=%?([\w\.\-]+)", line)
    fb = re.search(r"false_computation=%?([\w\.\-]+)", line)
    if tb:
        out.append((tb.group(1), 1))
    if fb:
        out.append((fb.group(1), 1))
    return out


def _exec_counts(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    counts: Dict[str, float] = {c: 0.0 for c in comps}
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                for c, _ in _called(ins.line):
                    fusion_bodies.add(c)

    def visit(name: str, mult: float):
        if name not in comps:
            return
        counts[name] += mult
        for ins in comps[name].instrs:
            for callee, m in _called(ins.line):
                visit(callee, mult * m)

    visit(entry, 1.0)
    return counts


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.out_shape)
    lhs_shape = comp.shapes.get(ins.operands[0]) if ins.operands else None
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contract = 1
    if lhs_shape and m:
        dims = _parse_shapes(lhs_shape)
        if dims:
            _, lhs_dims = dims[0]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze(text: str) -> dict:
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    counts = _exec_counts(comps, entry)

    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                for c, _ in _called(ins.line):
                    fusion_bodies.add(c)

    flops = 0.0
    bytes_accessed = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_count = 0.0

    for comp in comps.values():
        mult = counts.get(comp.name, 0.0)
        if mult == 0.0:
            continue
        memory_level = comp.name not in fusion_bodies
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                flops += mult * _dot_flops(ins, comp)
            elif op in _ELEMENTWISE_FLOP_OPS:
                flops += mult * _shape_elems(ins.out_shape)
            elif op == "reduce":
                flops += mult * sum(
                    _shape_elems(comp.shapes.get(o, "")) for o in
                    ins.operands[:len(ins.operands) // 2])
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                b = sum(_shape_bytes(comp.shapes.get(o, ""))
                        for o in ins.operands)
                if b == 0:
                    b = _shape_bytes(ins.out_shape)
                coll[base] += mult * b
                coll_count += mult
            if memory_level and op not in _ZERO_COST_OPS \
                    and not op.endswith("-done"):
                out_b = _shape_bytes(ins.out_shape)
                if op in _SLICING_OPS:
                    # read the addressed region (== output) + write it
                    bytes_accessed += mult * 2 * out_b
                elif op in _UPDATE_OPS:
                    # read + write the updated region only (buffer aliased)
                    upd = (_shape_bytes(comp.shapes.get(ins.operands[1], ""))
                           if len(ins.operands) > 1 else out_b)
                    bytes_accessed += mult * 2 * upd
                else:
                    opnd_bytes = sum(_shape_bytes(comp.shapes.get(o, ""))
                                     for o in ins.operands)
                    bytes_accessed += mult * (opnd_bytes + out_b)

    coll_total = sum(coll.values())
    return {
        "flops": flops,
        "bytes": bytes_accessed,
        "collectives": {**coll, "total": coll_total, "count": coll_count},
    }
