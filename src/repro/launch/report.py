"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from ..configs import get_config
from . import roofline


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(s):
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s*1e6:.0f}µs"
    if s < 1:
        return f"{s*1e3:.1f}ms"
    return f"{s:.2f}s"


def load(dir_: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table(recs, mesh: str) -> str:
    lines = [
        "| arch | shape | status | lower+compile | FLOPs/dev | bytes/dev | "
        "arg bytes/dev | temp bytes/dev | AG | AR | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted((r for r in recs if r.get("mesh") == mesh
                     or (r["status"] == "SKIP" and mesh in r["cell"])),
                    key=lambda r: (r["cell"].split("_")[0],
                                   SHAPE_ORDER.index(next(
                                       s for s in SHAPE_ORDER
                                       if s in r["cell"])))):
        arch = r["cell"].split("_" + next(
            s for s in SHAPE_ORDER if s in r["cell"]))[0]
        shape = next(s for s in SHAPE_ORDER if s in r["cell"])
        if r["status"] != "OK":
            lines.append(f"| {arch} | {shape} | {r['status']} | — | — | — |"
                         f" — | — | — | — | — | — |")
            continue
        c = r["collectives"]
        lines.append(
            f"| {arch} | {shape} | OK | {r['lower_s']:.0f}+{r['compile_s']:.0f}s "
            f"| {r['flops_per_device']:.3g} | "
            f"{fmt_bytes(r['bytes_per_device'])} | "
            f"{fmt_bytes(r['memory']['argument_bytes'])} | "
            f"{fmt_bytes(r['memory']['temp_bytes'])} | "
            f"{fmt_bytes(c['all-gather'])} | {fmt_bytes(c['all-reduce'])} | "
            f"{fmt_bytes(c['all-to-all'])} | "
            f"{fmt_bytes(c['collective-permute'])} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL_FLOPS | HLO/MODEL | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted((r for r in recs if r.get("status") == "OK"
                     and r.get("mesh") == "pod16x16"),
                    key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))):
        cfg = get_config(r["arch"])
        t = roofline.roofline_terms(r, cfg)
        waste = 1.0 / t["useful_fraction"] if t["useful_fraction"] else 0
        note = bottleneck_note(r, t)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {t['model_flops']:.3g} | "
            f"{waste:.1f}x | {t['roofline_fraction']:.1%} | {note} |")
    return "\n".join(lines)


def bottleneck_note(r, t) -> str:
    d = t["dominant"]
    if d == "memory":
        return ("shrink fusion-boundary traffic (attention scores bf16, "
                "flash-fusion kernel)")
    if d == "collective":
        if r["collectives"]["all-to-all"] > r["collectives"]["all-reduce"]:
            return "overlap a2a with expert compute; widen EP groups"
        return "reduce-scatter grads (ZeRO-1), int8 compression, overlap"
    return "already MXU-bound; raise per-chip batch or quantized matmul"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = load(args.dir)
    ok = sum(r["status"] == "OK" for r in recs)
    skip = sum(r["status"] == "SKIP" for r in recs)
    fail = sum(r["status"] == "FAIL" for r in recs)
    out = []
    out.append(f"records: {ok} OK, {skip} SKIP, {fail} FAIL\n")
    out.append("### Single-pod mesh (data=16, model=16) = 256 chips\n")
    out.append(dryrun_table(recs, "pod16x16"))
    out.append("\n### Multi-pod mesh (pod=2, data=16, model=16) = 512 chips\n")
    out.append(dryrun_table(recs, "pod2x16x16"))
    out.append("\n### Roofline (single-pod)\n")
    out.append(roofline_table(recs))
    text = "\n".join(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
