"""Version-compat shims for JAX API drift.

Same pattern as ``kernels.COMPILER_PARAMS`` (pltpu.TPUCompilerParams →
pltpu.CompilerParams): resolve the symbol once at import, adapt keyword
renames, and have every call site import from here instead of touching the
moved API directly.

* ``shard_map`` — newer JAX exposes ``jax.shard_map`` with a ``check_vma``
  kwarg; older releases only have ``jax.experimental.shard_map.shard_map``
  with the same knob spelled ``check_rep``.
* ``cost_analysis`` — ``compiled.cost_analysis()`` returns a dict on newer
  JAX but a one-element list of dicts (per program) on older releases.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

if hasattr(jax, "shard_map"):                       # newer JAX
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                               # e.g. 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` across the rename; ``check_vma`` maps onto the
    installed spelling (``check_rep`` on older releases).

    On old releases an unspecified check defaults to ``check_rep=False``:
    the replication-rewrite transpose there chokes on symbolic-Zero
    cotangents (``'Zero' object has no attribute 'reshape'``) whenever a
    shard-mapped function has an output the loss doesn't use (e.g. a MoE
    aux scalar); the unrewritten path differentiates fine.
    """
    if check_vma is None:
        kwargs = {"check_rep": False} if _CHECK_KW == "check_rep" else {}
    else:
        kwargs = {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def cost_analysis(compiled: Any) -> dict:
    """``compiled.cost_analysis()`` normalised to a flat dict (possibly
    empty — callers use ``.get`` with defaults either way)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}
