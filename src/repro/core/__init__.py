# FantastIC4 core: 4-bit bit-plane quantization (eq. 1), entropy-constrained
# Lloyd assignment (§IV-C), EC4T training parameterisation (§IV), multiple
# lossless compressed formats (§III-B.2) and ACM execution paths (§III-A).
from . import acm, bitplanes, ecl, formats, qat  # noqa: F401
