"""Entropy-Constrained Lloyd (ECL) assignment — paper §IV-C.

Assignment rule for weight w given the 16 subset-sum centroids v_c and the
empirical cluster probabilities P_c:

    code(w) = argmin_c  (w - v_c)^2 + lam * (-log2 P_c)

The entropy penalty makes high-probability clusters cheaper, pushing mass
onto few codes (usually code 0 == exact zero) — this is what produces the
low first-order entropy H = -Σ P_c log2 P_c that the compressed formats and
the accelerator exploit.

Deviation from classic Lloyd (paper §IV-C): centroids are NOT updated by the
Lloyd step; they are fine-tuned by gradient descent (eq. 2), implemented via
the differentiable-decode parameterisation in ``qat.py``. The probability
state is EMA-updated from the assignment histogram, so one training step
performs one (assignment, probs) ECL iteration — across steps this is the
full alternating algorithm.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .bitplanes import NUM_CODES, codebook

#: floor for cluster probabilities; keeps -log2(P) finite and bounds the
#: penalty so dead clusters can be revived by the distance term.
PROB_FLOOR = 1e-8


def entropy_bits(probs: jax.Array) -> jax.Array:
    """First-order entropy H = -Σ P log2 P (bits per weight).

    probs: (*lead, 16) -> (*lead,)."""
    p = jnp.clip(probs, PROB_FLOOR, 1.0)
    return -jnp.sum(jnp.where(probs > 0, p * jnp.log2(p), 0.0), axis=-1)


def assign(w: jax.Array, omega: jax.Array, probs: jax.Array,
           lam: float | jax.Array) -> jax.Array:
    """ECL assignment: uint8 codes minimising distance + entropy penalty.

    w: (*lead, R, C) (or any shape when omega/probs are unbatched (4,)/(16,));
    omega: (*lead, 4); probs: (*lead, 16) — returns codes with w.shape.
    """
    book = codebook(omega).astype(jnp.float32)                # (*lead, 16)
    penalty = -jnp.log2(jnp.clip(probs, PROB_FLOOR, 1.0))     # (*lead, 16)
    # Scale-invariant λ: the rate-distortion trade-off weighs bits against
    # *squared distance*, whose magnitude is tensor-dependent (init scale,
    # BN folding).  Normalising the penalty by mean(w²) makes one global λ
    # meaningful across every layer of every arch — λ≈0.01-0.1 spans the
    # paper's accuracy↔compression Pareto front for all of them.
    wf = w.astype(jnp.float32)
    if omega.ndim > 1:                                        # per-tensor sets
        scale = jnp.mean(wf * wf, axis=(-2, -1), keepdims=True)[..., None]
        book = book[..., None, None, :]                       # (*lead,1,1,16)
        penalty = penalty[..., None, None, :]
    else:
        scale = jnp.mean(wf * wf)
    cost = (wf[..., None] - book) ** 2 \
        + jnp.asarray(lam, jnp.float32) * scale * penalty
    return jnp.argmin(cost, axis=-1).astype(jnp.uint8)


def histogram(codes: jax.Array, lead_ndim: int = 0) -> jax.Array:
    """Normalised 16-bin histogram of codes (float32, sums to 1 per lead)."""
    lead = codes.shape[:lead_ndim]
    onehot = jax.nn.one_hot(codes.astype(jnp.int32), NUM_CODES, dtype=jnp.float32)
    counts = onehot.reshape(*lead, -1, NUM_CODES).sum(-2)
    return counts / jnp.maximum(counts.sum(-1, keepdims=True), 1.0)


def update_probs(probs: jax.Array, codes: jax.Array, momentum: float = 0.9) -> jax.Array:
    """EMA update of the cluster-probability state from fresh assignments."""
    return momentum * probs + (1.0 - momentum) * histogram(
        codes, lead_ndim=probs.ndim - 1)


@partial(jax.jit, static_argnames=("iters",))
def ecl_fit(w: jax.Array, omega: jax.Array, lam: float,
            iters: int = 10) -> tuple[jax.Array, jax.Array]:
    """Full alternating ECL (for post-training quantization / tests).

    Alternates assignment <-> probability update; centroids stay fixed
    (paper's modification). Returns (codes, probs).
    """
    lead = omega.shape[:-1]
    probs0 = jnp.full((*lead, NUM_CODES), 1.0 / NUM_CODES, jnp.float32)

    def body(probs, _):
        codes = assign(w, omega, probs, lam)
        return histogram(codes, lead_ndim=len(lead)), None

    probs, _ = jax.lax.scan(body, probs0, None, length=iters)
    codes = assign(w, omega, probs, lam)
    return codes, probs


def sparsity(codes: jax.Array) -> jax.Array:
    """Fraction of exact zeros (code 0)."""
    return jnp.mean((codes == 0).astype(jnp.float32))


def assign_general(w: jax.Array, book: jax.Array, probs: jax.Array,
                   lam) -> jax.Array:
    """ECL assignment against an arbitrary codebook (len C).

    Shared by EC4T (C=16 subset sums) and the EC2T ternary baseline
    (C=3, {-a, 0, +a}) — the paper's fig. 9 comparison.  Same
    scale-invariant entropy penalty as :func:`assign`."""
    wf = w.astype(jnp.float32)
    penalty = -jnp.log2(jnp.clip(probs, PROB_FLOOR, 1.0))
    scale = jnp.mean(wf * wf)
    cost = (wf[..., None] - book.astype(jnp.float32)) ** 2 \
        + jnp.asarray(lam, jnp.float32) * scale * penalty
    return jnp.argmin(cost, axis=-1).astype(jnp.uint8)
