"""Multiple lossless compressed formats for 4-bit code tensors (paper §III-B.2).

Three formats, selected per layer by minimum encoded size (contribution 4):

* ``dense4``  — trivial 4 bits/element, two codes per byte.
* ``bitmask`` — the paper's "simple Huffman" code: a 1-bit/element occupancy
  bitmask followed by the non-zero 4-bit codes in row-major order. Wins at
  moderate sparsity (25–90 %).
* ``csr``     — non-zero codes plus 8-bit column pointers within 256-wide
  row chunks (matching the paper's 256-wide adder tree / 8-bit CSR pointer
  chunks) and a per-chunk-row count. Wins at high sparsity (>90 %).

These are host-side codecs (numpy): they are used for checkpoint payloads,
host→device transfer accounting, and the Table-II benchmark. On-device
execution always uses the packed dense4 form (the Pallas kernel input);
``csr``/``bitmask`` are decoded on load — the software analogue of the
paper's CSR→bitmask converter circuit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np

CHUNK = 256  # paper's adder-tree width; CSR column pointers are 8-bit within a chunk

FORMATS = ("dense4", "bitmask", "csr")


@dataclass
class CompressedTensor:
    format: str
    shape: tuple
    payload: Dict[str, np.ndarray] = field(default_factory=dict)

    @property
    def size_bits(self) -> int:
        return int(sum(a.size * a.dtype.itemsize * 8 for a in self.payload.values()))

    @property
    def size_bytes(self) -> int:
        return (self.size_bits + 7) // 8

    def canonical_items(self):
        """Payload arrays in sorted key order — the canonical walk every
        payload-level checksum (``runtime.integrity.payload_crc``) and
        byte-level fault injector uses, so digests are stable across
        dict insertion orders."""
        return [(key, np.asarray(self.payload[key]))
                for key in sorted(self.payload)]


def _pack_nibbles(flat: np.ndarray) -> np.ndarray:
    flat = flat.astype(np.uint8)
    if flat.size % 2:
        flat = np.concatenate([flat, np.zeros(1, np.uint8)])
    return (flat[0::2] & 0xF) | (flat[1::2] << 4)


def _unpack_nibbles(packed: np.ndarray, n: int) -> np.ndarray:
    out = np.empty(packed.size * 2, np.uint8)
    out[0::2] = packed & 0xF
    out[1::2] = (packed >> 4) & 0xF
    return out[:n]


# ---------------------------------------------------------------- dense4

def encode_dense4(codes: np.ndarray) -> CompressedTensor:
    return CompressedTensor("dense4", codes.shape,
                            {"nibbles": _pack_nibbles(codes.reshape(-1))})


def decode_dense4(ct: CompressedTensor) -> np.ndarray:
    n = int(np.prod(ct.shape))
    return _unpack_nibbles(ct.payload["nibbles"], n).reshape(ct.shape)


# ---------------------------------------------------------------- bitmask

def encode_bitmask(codes: np.ndarray) -> CompressedTensor:
    flat = codes.reshape(-1).astype(np.uint8)
    mask = flat != 0
    return CompressedTensor("bitmask", codes.shape, {
        "mask": np.packbits(mask),
        "values": _pack_nibbles(flat[mask]),
        "nnz": np.asarray([int(mask.sum())], np.int64),
    })


def decode_bitmask(ct: CompressedTensor) -> np.ndarray:
    n = int(np.prod(ct.shape))
    mask = np.unpackbits(ct.payload["mask"])[:n].astype(bool)
    nnz = int(ct.payload["nnz"][0])
    vals = _unpack_nibbles(ct.payload["values"], nnz)
    out = np.zeros(n, np.uint8)
    out[mask] = vals
    return out.reshape(ct.shape)


# ---------------------------------------------------------------- csr

def encode_csr(codes: np.ndarray) -> CompressedTensor:
    """CSR over 256-wide chunks: per chunk-row nnz count (uint16), 8-bit
    column pointers, 4-bit values."""
    if codes.size == 0:        # empty/zero-row tensor: no chunks at all
        return CompressedTensor("csr", codes.shape, {
            "counts": np.zeros(0, np.uint16),
            "colptr": np.zeros(0, np.uint8),
            "values": np.zeros(0, np.uint8),
            "nnz": np.asarray([0], np.int64),
        })
    mat = codes.reshape(codes.shape[0], -1) if codes.ndim > 1 else codes.reshape(1, -1)
    rows, cols = mat.shape
    pad = (-cols) % CHUNK
    if pad:
        mat = np.concatenate([mat, np.zeros((rows, pad), np.uint8)], axis=1)
    chunked = mat.reshape(rows * (mat.shape[1] // CHUNK), CHUNK)
    nz_r, nz_c = np.nonzero(chunked)
    counts = np.bincount(nz_r, minlength=chunked.shape[0]).astype(np.uint16)
    return CompressedTensor("csr", codes.shape, {
        "counts": counts,
        "colptr": nz_c.astype(np.uint8),
        "values": _pack_nibbles(chunked[nz_r, nz_c]),
        "nnz": np.asarray([nz_r.size], np.int64),
    })


def decode_csr(ct: CompressedTensor) -> np.ndarray:
    shape = ct.shape
    if int(np.prod(shape)) == 0:
        return np.zeros(shape, np.uint8)
    rows = shape[0] if len(shape) > 1 else 1
    cols = int(np.prod(shape)) // rows
    padded_cols = cols + ((-cols) % CHUNK)
    chunked = np.zeros((rows * (padded_cols // CHUNK), CHUNK), np.uint8)
    counts = ct.payload["counts"].astype(np.int64)
    nnz = int(ct.payload["nnz"][0])
    vals = _unpack_nibbles(ct.payload["values"], nnz)
    row_idx = np.repeat(np.arange(chunked.shape[0]), counts)
    chunked[row_idx, ct.payload["colptr"]] = vals
    mat = chunked.reshape(rows, padded_cols)[:, :cols]
    return mat.reshape(shape)


_ENC = {"dense4": encode_dense4, "bitmask": encode_bitmask, "csr": encode_csr}
_DEC = {"dense4": decode_dense4, "bitmask": decode_bitmask, "csr": decode_csr}


def encode(codes: np.ndarray, fmt: str) -> CompressedTensor:
    return _ENC[fmt](np.asarray(codes, np.uint8))


def decode(ct: CompressedTensor) -> np.ndarray:
    return _DEC[ct.format](ct)


def analytic_size_bits(shape: tuple, nnz: int, fmt: str) -> int:
    """Closed-form encoded size (bits) — used for fast format selection and
    the Table-II style benchmark (matches the codecs above exactly)."""
    n = int(np.prod(shape))
    rows = shape[0] if len(shape) > 1 else 1
    cols = n // rows if rows else 0     # zero-row shard: nothing to chunk
    chunk_rows = rows * ((cols + CHUNK - 1) // CHUNK)
    if fmt == "dense4":
        return 2 * ((n + 1) // 2) * 4
    if fmt == "bitmask":
        return 8 * ((n + 7) // 8) + 2 * ((nnz + 1) // 2) * 4 + 64
    if fmt == "csr":
        return 16 * chunk_rows + 8 * nnz + 2 * ((nnz + 1) // 2) * 4 + 64
    raise ValueError(fmt)


def select_format(codes: np.ndarray) -> str:
    """Pick the most compact of the three formats (paper contribution 4)."""
    codes = np.asarray(codes, np.uint8)
    nnz = int(np.count_nonzero(codes))
    sizes = {f: analytic_size_bits(codes.shape, nnz, f) for f in FORMATS}
    return min(sizes, key=sizes.get)


def encode_best(codes: np.ndarray) -> CompressedTensor:
    return encode(codes, select_format(codes))


def compression_ratio(codes: np.ndarray, fmt: str | None = None,
                      orig_bits_per_weight: int = 32) -> float:
    """Full-precision size / compressed size (paper Table II 'CR')."""
    codes = np.asarray(codes, np.uint8)
    fmt = fmt or select_format(codes)
    nnz = int(np.count_nonzero(codes))
    comp = analytic_size_bits(codes.shape, nnz, fmt)
    return codes.size * orig_bits_per_weight / comp


# ------------------------------------------------------------- huffman
# Beyond-paper extension in the paper's own lineage ([5] Deep Compression,
# [6] DeepCABAC): a canonical Huffman code over the 16 cluster ids.  Where
# CSR/bitmask only exploit *zeros*, Huffman exploits the full low-entropy
# histogram that EC4T training produces — encoded size approaches
# H bits/weight, beating every other format once H < ~3.5 bits.  Decode is
# table-driven (canonical codes), the natural software analogue of the
# paper's "efficient loading of repeated values".

def _huffman_lengths(counts: np.ndarray) -> np.ndarray:
    """Code lengths for 16 symbols (package-merge-free simple Huffman)."""
    import heapq
    heap = [(int(c), i, (i,)) for i, c in enumerate(counts) if c > 0]
    if len(heap) == 1:
        lengths = np.zeros(16, np.uint8)
        lengths[heap[0][1]] = 1
        return lengths
    heapq.heapify(heap)
    lengths = np.zeros(16, np.uint8)
    tie = 16
    while len(heap) > 1:
        c1, _, s1 = heapq.heappop(heap)
        c2, _, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            lengths[s] += 1
        heapq.heappush(heap, (c1 + c2, tie, s1 + s2))
        tie += 1
    return lengths


def _canonical_codes(lengths: np.ndarray):
    """(code, length) per symbol, canonical ordering.

    Pure-python ints throughout: ``int << np.uint8`` promotes to uint8
    under NumPy 2 and silently wraps at 255 (bug found by hypothesis)."""
    order = sorted((int(l), s) for s, l in enumerate(lengths) if l > 0)
    codes = np.zeros(16, np.uint32)
    if not order:        # empty tensor: no symbols, no codewords
        return codes
    code = 0
    prev_len = order[0][0]
    for l, s in order:
        code <<= (l - prev_len)
        codes[s] = code
        code += 1
        prev_len = l
    return codes


def encode_huffman(codes: np.ndarray) -> CompressedTensor:
    flat = codes.reshape(-1).astype(np.uint8)
    counts = np.bincount(flat, minlength=16)
    lengths = _huffman_lengths(counts)
    cw = _canonical_codes(lengths)
    # bit-pack MSB-first
    sym_lengths = lengths[flat].astype(np.int64)
    total_bits = int(sym_lengths.sum())
    out = np.zeros((total_bits + 7) // 8, np.uint8)
    pos = np.concatenate([[0], np.cumsum(sym_lengths)[:-1]])
    for s in range(16):
        l = int(lengths[s])
        if l == 0:
            continue
        idx = np.nonzero(flat == s)[0]
        if idx.size == 0:
            continue
        word = int(cw[s])
        for b in range(l):
            bit = (word >> (l - 1 - b)) & 1
            if bit:
                p = pos[idx] + b
                # ufunc.at: plain fancy-index |= drops duplicate byte hits
                np.bitwise_or.at(out, p // 8,
                                 (128 >> (p % 8)).astype(np.uint8))
    return CompressedTensor("huffman", codes.shape, {
        "bits": out,
        "lengths": lengths,
        "nbits": np.asarray([total_bits], np.int64),
    })


def decode_huffman(ct: CompressedTensor) -> np.ndarray:
    lengths = ct.payload["lengths"]
    cw = _canonical_codes(lengths)
    n = int(np.prod(ct.shape))
    bits = np.unpackbits(ct.payload["bits"])[: int(ct.payload["nbits"][0])]
    # build (length, code) -> symbol lookup
    lut = {(int(lengths[s]), int(cw[s])): s
           for s in range(16) if lengths[s] > 0}
    out = np.empty(n, np.uint8)
    acc, alen, j = 0, 0, 0
    for b in bits:
        acc = (acc << 1) | int(b)
        alen += 1
        sym = lut.get((alen, acc))
        if sym is not None:
            out[j] = sym
            j += 1
            acc, alen = 0, 0
    assert j == n, (j, n)
    return out.reshape(ct.shape)


_ENC["huffman"] = encode_huffman
_DEC["huffman"] = decode_huffman
FORMATS_EXT = FORMATS + ("huffman",)


def analytic_size_bits_huffman(codes: np.ndarray) -> int:
    counts = np.bincount(codes.reshape(-1).astype(np.uint8), minlength=16)
    lengths = _huffman_lengths(counts) if counts.sum() else np.zeros(16)
    data_bits = int((counts * lengths).sum())
    return 8 * ((data_bits + 7) // 8) + 16 * 8 + 64   # + table + header


def select_format_ext(codes: np.ndarray) -> str:
    """Format selection over the extended set (incl. huffman)."""
    codes = np.asarray(codes, np.uint8)
    nnz = int(np.count_nonzero(codes))
    sizes = {f: analytic_size_bits(codes.shape, nnz, f) for f in FORMATS}
    sizes["huffman"] = analytic_size_bits_huffman(codes)
    return min(sizes, key=sizes.get)
