"""Bit-plane decomposition of 4-bit weight codes (FantastIC4 eq. 1).

A quantized weight tensor is represented by
  * ``codes``  — uint8 tensor of 4-bit cluster ids in [0, 16)
  * ``omega``  — the 4 real-valued basis centroids ω_i

The dequantized value of code ``c`` is the subset-sum
``v_c = Σ_i ω_i * bit_i(c)`` so that ``W = Σ_i ω_i B_i`` with
``B_i = bit_i(codes)``.  Code 0 ⇒ value 0 ⇒ sparsity is a code.

Packed storage keeps two 4-bit codes per uint8 (low nibble first), which is
what the Pallas kernel consumes from HBM (4 bits/weight of traffic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NUM_BASIS = 4
NUM_CODES = 16


def codes_to_bitplanes(codes: jax.Array) -> jax.Array:
    """uint8 codes [..] -> bool bit-planes [4, ..] (LSB first)."""
    codes = codes.astype(jnp.uint8)
    planes = [(codes >> i) & 1 for i in range(NUM_BASIS)]
    return jnp.stack(planes).astype(jnp.bool_)


def bitplanes_to_codes(planes: jax.Array) -> jax.Array:
    """bool bit-planes [4, ..] -> uint8 codes [..]."""
    planes = planes.astype(jnp.uint8)
    out = jnp.zeros(planes.shape[1:], jnp.uint8)
    for i in range(NUM_BASIS):
        out = out | (planes[i] << i)
    return out


def codebook(omega: jax.Array) -> jax.Array:
    """All 16 subset-sum centroid values v_c = Σ_i ω_i bit_i(c).

    omega: (*lead, 4) float -> (*lead, 16) float, v_0 == 0.  Leading dims
    carry per-tensor centroid sets (paper §IV-B: each weight tensor gets its
    own Ω) for layer-stacked (L, ...) and expert-stacked (E, ...) weights.
    """
    omega = jnp.asarray(omega)
    idx = jnp.arange(NUM_CODES)
    bits = jnp.stack([(idx >> i) & 1 for i in range(NUM_BASIS)], axis=-1)
    return jnp.einsum("...i,ci->...c", omega, bits.astype(omega.dtype))


def decode(codes: jax.Array, omega: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Dequantize codes to values. Differentiable w.r.t. omega.

    codes: (*lead, R, C); omega: (*lead, 4) — or the classic unbatched
    (R, C) / (4,).  Implemented as the bit-plane linear combination (not a
    table gather) so that ``d decode / d ω_i = B_i`` — this is exactly
    eq. (2) of the paper when reverse-mode differentiated, giving centroid
    fine-tuning for free.
    """
    out = jnp.zeros(codes.shape, dtype)
    for i in range(NUM_BASIS):
        bit = ((codes >> i) & 1).astype(dtype)
        w_i = omega[..., i].astype(dtype)
        if omega.ndim > 1:
            w_i = w_i[..., None, None]
        out = out + w_i * bit
    return out


def pack_codes(codes: jax.Array) -> jax.Array:
    """uint8 codes (..., K) -> packed uint8 (..., K//2), low nibble first.

    Requires the trailing dim to be even.
    """
    if codes.shape[-1] % 2:
        raise ValueError(f"trailing dim must be even, got {codes.shape}")
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return (lo & 0xF) | (hi << 4)


def pack_codes_rows(codes: jax.Array) -> jax.Array:
    """uint8 codes (*lead, K, N) -> packed uint8 (*lead, K//2, N):
    byte r = c[2r] | c[2r+1]<<4.

    Row-pair (contraction-axis) packing — the layout the Pallas matmul kernel
    consumes, so the in-kernel unpack is a cheap sublane interleave rather
    than a lane shuffle. Requires K even.
    """
    if codes.shape[-2] % 2:
        raise ValueError(f"contraction dim must be even, got {codes.shape}")
    lo = codes[..., 0::2, :].astype(jnp.uint8)
    hi = codes[..., 1::2, :].astype(jnp.uint8)
    return (lo & 0xF) | (hi << 4)


def unpack_codes_rows(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_codes_rows`: (*lead, K//2, N) -> (*lead, K, N)."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-2)        # (*lead, K//2, 2, N)
    return out.reshape(*packed.shape[:-2], packed.shape[-2] * 2,
                       packed.shape[-1])


def unpack_codes(packed: jax.Array) -> jax.Array:
    """packed uint8 (..., K//2) -> uint8 codes (..., K)."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def init_omega_from_weights(w: jax.Array) -> jax.Array:
    """Heuristic basis init: powers-of-two ladder scaled to the weight range.

    With ω_i = s·2^i the 16 subset sums form a uniform grid [0, 15s]; we use
    a symmetric variant {-8s, 4s, 2s, s} whose subset sums cover
    [-8s, 7s] — i.e. int4 two's-complement — so that before any fine-tuning
    the codebook behaves like a standard symmetric 4-bit quantizer. Centroid
    fine-tuning (eq. 2) then departs from powers of two, which the paper
    highlights as added expressivity.

    w: (*lead, R, C) -> omega (*lead, 4): per-tensor scale over the trailing
    two (matrix) dims, one centroid set per leading index.
    """
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=(-2, -1)), 1e-8)
    s = amax / 8.0
    return jnp.stack([s, 2 * s, 4 * s, -8 * s], axis=-1).astype(w.dtype)
