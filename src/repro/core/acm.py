"""ACM execution of 4-bit-compact linear layers (paper eq. 1 + §V epilogue).

Two execution paths, numerically identical (tests assert allclose):

* **training / fake-quant** — ``linear_qat``: STE fake-quantized weights,
  plain XLA matmul (differentiable).
* **serving / frozen** — ``linear_serving``: weights are packed 4-bit codes
  (two per byte) + 4 basis centroids; dispatched to the Pallas
  ``fantastic4_matmul`` kernel (VMEM decode + MXU matmul + fused epilogue)
  or its pure-jnp reference.

The fused epilogue mirrors the paper's §V pipeline:
    y = round_or_id( α₂ · act( α₁ ⊙ (x·W) + b ) )
with α₁ a per-output-feature scale (absorbs de-quantization and batch-norm),
α₂ a scalar re-quantization scale, act ∈ {relu, none}.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import bitplanes, qat
from ..kernels import ops as kops


def linear_qat(x: jax.Array, node: dict, qstate: dict, lam,
               bias: Optional[jax.Array] = None,
               dtype=None) -> jax.Array:
    """Training-path quantized linear: x @ fake_quant(W) (+ bias)."""
    dtype = dtype or x.dtype
    w = qat.apply_quant(node, qstate, lam, dtype)
    y = x @ w
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def freeze_linear(node: dict, qstate: dict, lam) -> dict:
    """Quantize a {"w","omega"} leaf to its serving form (packed codes)."""
    from . import ecl
    codes = ecl.assign(node["w"], node["omega"], qstate["probs"], lam)
    if codes.ndim != 2:
        codes = codes.reshape(codes.shape[0], -1)
    return {
        "packed": bitplanes.pack_codes_rows(codes),
        "omega": node["omega"].astype(jnp.float32),
        "shape": codes.shape,
    }


def linear_serving(x: jax.Array, frozen: dict,
                   bias: Optional[jax.Array] = None,
                   alpha1: Optional[jax.Array] = None,
                   alpha2: Optional[jax.Array] = None,
                   activation: Optional[str] = None,
                   use_kernel: bool = True,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Serving-path quantized linear on packed 4-bit codes."""
    k, n = frozen["shape"]
    y = kops.fantastic4_matmul(
        x.reshape(-1, k), frozen["packed"], frozen["omega"],
        bias=bias, alpha1=alpha1, alpha2=alpha2, activation=activation,
        use_kernel=use_kernel, interpret=interpret)
    return y.reshape(*x.shape[:-1], n)


def acm_flop_count(m: int, k: int, n: int, sparsity: float = 0.0) -> dict:
    """Operation-count model of ACM vs MAC (paper §III-A / Table analog).

    MAC: k multiplies + k adds per output element.
    ACM: additions dominated by non-zero bit-plane pop-count; exactly 4
    multiplies + 3 adds per output element for the basis combination.
    """
    mac_mul = m * n * k
    mac_add = m * n * k
    dens = 1.0 - sparsity
    # each non-zero weight contributes on average popcount(code) ≈ 2 bit-adds
    acm_add = int(m * n * k * dens * 2)
    acm_mul = m * n * 4
    return {"mac_mul": mac_mul, "mac_add": mac_add,
            "acm_mul": acm_mul, "acm_add": acm_add,
            "mul_reduction": mac_mul / max(acm_mul, 1)}
